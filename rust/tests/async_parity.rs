//! Asynchronous-selection contracts: `--async` with one slot must
//! reproduce the sequential Algorithm 1 bit-exactly, async trajectories at
//! a pinned in-flight target must be bitwise identical at any worker count
//! (the logical-clock absorption contract), the per-pick bookkeeping and
//! EventLog ordering must hold, and abandoned picks under faults must
//! neither produce records nor feed `StopCondition::NoImprovement`.

use trimtuner::coordinator::{
    job_ids, EventKind, FaultSpec, Interrupted, Job, JobLauncher, JobResult,
    SimLauncher,
};
use trimtuner::engine::{
    self, BatchMode, EngineConfig, EvalBackend, LiveEval, OptimizerKind,
    RetryPolicy, RunResult, StopCondition,
};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

/// Paper defaults shrunk like `live_parity`'s so the GP variants stay fast.
fn small_cfg(optimizer: OptimizerKind, seed: u64, iters: usize) -> EngineConfig {
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.max_iters = iters;
    cfg.n_rep = 10;
    cfg.n_popt_samples = 40;
    cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
    // pin the batch mode: an ambient TRIMTUNER_BATCH must not change what
    // these tests exercise
    cfg.batch_mode = BatchMode::Fantasy;
    cfg
}

fn live_run(
    launcher: Box<dyn JobLauncher>,
    workers: usize,
    retry: RetryPolicy,
    eval: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> RunResult {
    let mut backend = EvalBackend::Live(
        LiveEval::new(launcher, workers)
            .with_eval(eval)
            .with_retry(retry, cfg.seed ^ 0xB0FF),
    );
    let run = engine::run_backend(&mut backend, constraints, cfg)
        .expect("live run failed");
    backend.shutdown();
    run
}

fn assert_same_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(ra.round, rb.round, "{label}: round id");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.explore_cost.to_bits(),
            rb.explore_cost.to_bits(),
            "{label}: charged cost"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(ra.incumbent.id(), rb.incumbent.id(), "{label}: incumbent");
    }
}

/// ISSUE acceptance: with an in-flight target of 1 (replay, or live on one
/// worker) the async scheduler degenerates to exactly the barriered q = 1
/// sequence — same operations, same RNG draws, bit-identical traces — for
/// both TrimTuner model kinds.
#[test]
fn async_with_one_slot_is_bit_identical_to_sequential() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (optimizer, iters) in [
        (OptimizerKind::TrimTuner(ModelKind::Gp), 3),
        (OptimizerKind::TrimTuner(ModelKind::Trees), 6),
    ] {
        let mut seq = small_cfg(optimizer, 5, iters);
        seq.batch_size = 1;
        let mut acfg = small_cfg(optimizer, 5, iters);
        acfg.async_mode = true;
        let barriered = engine::run(&truth, &constraints, &seq);
        let replay_async = engine::run(&truth, &constraints, &acfg);
        assert_same_trajectory(
            &barriered,
            &replay_async,
            &format!("{}: replay async vs q=1", optimizer.name()),
        );
        // zero-noise live async on one worker replays the same trace
        let live_async = live_run(
            Box::new(SimLauncher::noiseless(net)),
            1,
            RetryPolicy::default(),
            &truth,
            &constraints,
            &acfg,
        );
        assert_same_trajectory(
            &barriered,
            &live_async,
            &format!("{}: live async vs q=1", optimizer.name()),
        );
        // per-pick attribution: every main record is its own round
        for r in replay_async.records.iter().filter(|r| !r.is_init) {
            assert_eq!(r.round, r.iter + 1, "round ids drifted in async");
        }
    }
}

/// ISSUE acceptance: zero-noise async runs at a pinned in-flight target
/// are bitwise identical across worker counts — the logical-clock
/// absorption makes the trajectory a pure function of submission order,
/// never of physical completion order — and agree with the replay backend
/// driven at the same target.
#[test]
fn zero_noise_async_is_deterministic_across_worker_counts() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 7, 8);
    cfg.async_mode = true;
    cfg.max_inflight = Some(4);
    let replay = engine::run(&truth, &constraints, &cfg);
    let one = live_run(
        Box::new(SimLauncher::noiseless(net)),
        1,
        RetryPolicy::default(),
        &truth,
        &constraints,
        &cfg,
    );
    let four = live_run(
        Box::new(SimLauncher::noiseless(net)),
        4,
        RetryPolicy::default(),
        &truth,
        &constraints,
        &cfg,
    );
    assert_same_trajectory(&one, &four, "async workers 1 vs 4");
    assert_same_trajectory(&replay, &one, "replay vs live async");
    assert!(replay.n_rounds() >= 3, "init round + at least 2 main picks");
}

/// ISSUE satellite: EventLog ordering under async — submissions are
/// recorded in logical (selection) order even while earlier picks are
/// still deploying, every job completes, and the engine-level
/// `IterationDone` fires once per absorbed observation.
#[test]
fn event_log_records_async_submissions_in_logical_order() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 17, 8);
    cfg.async_mode = true;
    cfg.max_inflight = Some(3);
    let mut backend = EvalBackend::Live(
        LiveEval::new(Box::new(SimLauncher::noiseless(net)), 3)
            .with_eval(&truth),
    );
    let run = engine::run_backend(&mut backend, &caps(net), &cfg)
        .expect("live run failed");
    let events = backend.event_log().unwrap().snapshot();
    backend.shutdown();

    // submissions appear in selection order (ids are assigned sequentially
    // at submit time; no failures -> no retry ids)
    let submitted: Vec<u64> = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::JobSubmitted { job } => Some(job),
            _ => None,
        })
        .collect();
    assert!(
        submitted.windows(2).all(|w| w[0] < w[1]),
        "submission ids out of order: {submitted:?}"
    );
    let completed = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::JobCompleted { .. }))
        .count();
    assert_eq!(submitted.len(), completed, "every job completes");
    assert_eq!(
        events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::JobFailed { .. }))
            .count(),
        0
    );
    // engine-level events: one IterationDone per init record and one per
    // absorbed observation (async logs per pick, not per round)
    let n_main = run.records.iter().filter(|r| !r.is_init).count();
    let iteration_done = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::IterationDone { .. }))
        .count();
    assert_eq!(iteration_done, 4 + n_main, "one per absorbed observation");
}

/// ISSUE satellite: async composes with the fault-injection stack — the
/// campaign survives a preemption + flaky-launch cocktail, and because
/// fault decisions key on job ids (assigned in logical order) and
/// absorption is logical-ordered, the whole faulty trace is deterministic
/// in the worker count.
#[test]
fn async_fault_trace_is_deterministic_across_worker_counts() {
    let net = NetKind::Mlp;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let spec = FaultSpec::parse("spot:0.4,straggle:2.0,flaky:0.3").unwrap();
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 9, 6);
    cfg.async_mode = true;
    cfg.max_inflight = Some(2);
    let mk = |workers| {
        live_run(
            spec.wrap(Box::new(SimLauncher::new(net, 33)), 0xFA17),
            workers,
            RetryPolicy::default(),
            &truth,
            &constraints,
            &cfg,
        )
    };
    let one = mk(1);
    let four = mk(4);
    assert_same_trajectory(&one, &four, "faulty async 1 vs 4 workers");
    assert_eq!(one.faults.n_failures, four.faults.n_failures);
    assert_eq!(one.faults.n_abandoned, four.faults.n_abandoned);
    assert_eq!(
        one.faults.wasted_cost.to_bits(),
        four.faults.wasted_cost.to_bits(),
        "waste totals must match bitwise"
    );
    assert!(
        one.faults.n_failures > 0,
        "a 40% preemption + 30% flaky cocktail over 7+ jobs must fault"
    );
}

/// Kills every attempt (primary and retries) of the probes whose *primary*
/// id is listed — a deterministic preemption charging half the real cost
/// per dead attempt, guaranteed to exhaust any retry budget.
struct KillListLauncher {
    inner: SimLauncher,
    kill: fn(u64) -> bool,
}

impl JobLauncher for KillListLauncher {
    fn launch(&self, job: &Job) -> anyhow::Result<JobResult> {
        let r = self.inner.launch(job)?;
        if (self.kill)(job_ids::original(job.id)) {
            return Err(anyhow::Error::new(Interrupted {
                partial_cost: r.charged_cost * 0.5,
                partial_duration_s: r.duration_s * 0.5,
            }));
        }
        Ok(r)
    }
}

/// ISSUE satellite: abandoned picks are not `NoImprovement` evidence in
/// async mode. They consume a logical round index but produce no record
/// and trigger no stop check, so with an unmeetable `min_delta` the engine
/// keeps launching through a run of deterministic kills instead of
/// misreading it as a plateau — the full launch budget is consumed.
#[test]
fn abandoned_async_picks_are_not_no_improvement_evidence() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let mut cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 3, 8);
    cfg.async_mode = true;
    cfg.stop = StopCondition::NoImprovement { window: 2, min_delta: 1.0 };
    // id 0 = init snapshot; main primaries 1 and 2 observe, later ones die
    let launcher = KillListLauncher {
        inner: SimLauncher::noiseless(net),
        kill: |id| id >= 3,
    };
    let retry = RetryPolicy { max_retries: 0, ..RetryPolicy::default() };
    let run = live_run(
        Box::new(launcher),
        2,
        retry,
        &truth,
        &caps(net),
        &cfg,
    );
    let main: Vec<_> = run.records.iter().filter(|r| !r.is_init).collect();
    assert_eq!(main.len(), 2, "only the two pre-kill picks observe");
    assert_eq!(
        run.faults.n_abandoned, 6,
        "the remaining budget was launched and abandoned, not stopped on"
    );
    // the partial kills stay charged into the cumulative totals
    let observed_sum: f64 = run.records.iter().map(|r| r.explore_cost).sum();
    assert!(
        run.total_cost() > observed_sum,
        "cum {} must exceed observed {}",
        run.total_cost(),
        observed_sum
    );
    // abandoned picks consumed their logical round indices: the last
    // record's round stays at its own pick index, but n_rounds counts only
    // to the last *recorded* pick — both observed picks carry early ids
    for r in &main {
        assert!(r.round <= 2 + 1, "observed picks are early logical rounds");
    }
}
