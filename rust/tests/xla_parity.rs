//! Integration tests for the PJRT runtime: AOT artifacts (Pallas Layer-1
//! kernel + JAX Layer-2 graphs) must agree with the native Rust
//! implementations. Skipped (with a message) when `make artifacts` has not
//! been run — CI should always run it first.

use trimtuner::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIPPING xla parity tests: {e:#}");
            None
        }
    }
}

#[test]
fn cov_artifact_matches_native_kernel() {
    let Some(rt) = runtime() else { return };
    let (max_err, n) = trimtuner::runtime::cov_parity_check(&rt).unwrap();
    assert!(n > 10_000);
    assert!(max_err < 1e-4, "cov parity err {max_err}");
}

#[test]
fn gp_posterior_artifact_matches_native_gp() {
    let Some(rt) = runtime() else { return };
    let (mu_err, var_err) = trimtuner::runtime::gp_parity_check(&rt).unwrap();
    assert!(mu_err < 1e-3, "mu err {mu_err}");
    assert!(var_err < 1e-3, "var err {var_err}");
}

#[test]
fn mlp_artifacts_train_and_learn() {
    let Some(rt) = runtime() else { return };
    let (first, last, acc) = trimtuner::runtime::mlp_train_smoke(&rt, 25).unwrap();
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(acc > 0.5, "eval accuracy {acc}");
}

#[test]
fn manifest_shapes_match_rust_constants() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest.d_in, trimtuner::space::D_IN);
    assert_eq!(rt.manifest.n_hyp, 10);
    assert!(rt.manifest.n_train >= 48, "artifact too small for 44-iter runs");
    assert_eq!(rt.manifest.artifacts.len(), 8);
}
