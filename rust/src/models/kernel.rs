//! The Matérn-5/2 × FABOLAS sub-sampling covariance kernel (native f64).
//!
//! This mirrors, formula for formula, the Layer-1 Pallas kernel
//! (`python/compile/kernels/matern_fabolas.py`) and its jnp oracle; parity
//! is asserted against the AOT artifacts in `rust/tests/xla_parity.rs`.

use super::surrogate::Feat;
use crate::linalg::Mat;
use crate::space::D_FEAT;

/// Which sub-sampling basis the kernel uses (paper §III-A):
/// accuracy grows as s→1 (phi = (1, 1-s)); cost grows with s (phi = (1, s)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Basis {
    Acc,
    Cost,
}

impl Basis {
    #[inline]
    pub fn g(&self, s: f64) -> f64 {
        match self {
            Basis::Acc => 1.0 - s,
            Basis::Cost => s,
        }
    }
}

/// Kernel hyper-parameters. Layout matches the Python N_HYP vector:
/// [ls_0..ls_5, sigma2, l00, l10, l11] (+ observation noise kept here too,
/// which the XLA artifacts receive separately as the per-point noise input).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelParams {
    pub ls: [f64; D_FEAT],
    pub sigma2: f64,
    pub l00: f64,
    pub l10: f64,
    pub l11: f64,
    /// observation noise variance
    pub noise: f64,
}

impl Default for KernelParams {
    fn default() -> Self {
        KernelParams {
            ls: [0.5; D_FEAT],
            sigma2: 1.0,
            l00: 1.0,
            l10: 0.5,
            l11: 0.5,
            noise: 1e-3,
        }
    }
}

impl KernelParams {
    /// Pack as the f32 hyper vector consumed by the AOT artifacts.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        let mut v: Vec<f32> = self.ls.iter().map(|&x| x as f32).collect();
        v.push(self.sigma2 as f32);
        v.push(self.l00 as f32);
        v.push(self.l10 as f32);
        v.push(self.l11 as f32);
        v
    }

    /// Serialize to the log-space vector the hyper-optimizer searches over
    /// (noise included, 11 dims).
    pub fn to_log_vec(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.ls.iter().map(|x| x.ln()).collect();
        v.push(self.sigma2.ln());
        v.push(self.l00.ln());
        v.push(self.l10.ln());
        v.push(self.l11.ln());
        v.push(self.noise.ln());
        v
    }

    pub fn from_log_vec(v: &[f64]) -> KernelParams {
        assert_eq!(v.len(), D_FEAT + 5);
        let clamp = |x: f64, lo: f64, hi: f64| x.exp().clamp(lo, hi);
        let mut ls = [0.0; D_FEAT];
        for (i, l) in ls.iter_mut().enumerate() {
            *l = clamp(v[i], 0.03, 20.0);
        }
        KernelParams {
            ls,
            sigma2: clamp(v[D_FEAT], 1e-4, 50.0),
            l00: clamp(v[D_FEAT + 1], 1e-3, 10.0),
            l10: clamp(v[D_FEAT + 2], 1e-3, 10.0),
            l11: clamp(v[D_FEAT + 3], 1e-3, 10.0),
            noise: clamp(v[D_FEAT + 4], 1e-8, 1.0),
        }
    }

    /// Basis factor phi(s1)^T Theta phi(s2) with Theta = L L^T.
    #[inline]
    pub fn basis_factor(&self, basis: Basis, s1: f64, s2: f64) -> f64 {
        let (g1, g2) = (basis.g(s1), basis.g(s2));
        let t00 = self.l00 * self.l00;
        let t01 = self.l00 * self.l10;
        let t11 = self.l10 * self.l10 + self.l11 * self.l11;
        t00 + t01 * (g1 + g2) + t11 * g1 * g2
    }

    /// Full kernel value k((x1,s1),(x2,s2)).
    pub fn k(&self, basis: Basis, a: &Feat, b: &Feat) -> f64 {
        let mut r2 = 0.0;
        for d in 0..D_FEAT {
            let diff = (a[d] - b[d]) / self.ls[d];
            r2 += diff * diff;
        }
        let r = r2.sqrt();
        let sqrt5 = 5f64.sqrt();
        let matern = (1.0 + sqrt5 * r + (5.0 / 3.0) * r2) * (-sqrt5 * r).exp();
        self.sigma2 * matern * self.basis_factor(basis, a[D_FEAT], b[D_FEAT])
    }

    /// k((x,s),(x,s)) — Matérn at r=0 is 1.
    #[inline]
    pub fn k_diag(&self, basis: Basis, a: &Feat) -> f64 {
        self.sigma2 * self.basis_factor(basis, a[D_FEAT], a[D_FEAT])
    }

    /// Training covariance matrix K(X, X) + noise I.
    pub fn cov_matrix(&self, basis: Basis, xs: &[Feat]) -> Mat {
        let n = xs.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.k(basis, &xs[i], &xs[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
            k[(i, i)] += self.noise;
        }
        k
    }

    /// Cross-covariance vector k(X, x).
    pub fn cov_vec(&self, basis: Basis, xs: &[Feat], x: &Feat) -> Vec<f64> {
        xs.iter().map(|xi| self.k(basis, xi, x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn rand_feat(rng: &mut crate::util::Rng) -> Feat {
        let mut f = [0.0; crate::space::D_IN];
        for v in f.iter_mut() {
            *v = rng.f64();
        }
        f
    }

    fn rand_params(rng: &mut crate::util::Rng) -> KernelParams {
        let mut ls = [0.0; D_FEAT];
        for l in ls.iter_mut() {
            *l = rng.uniform(0.1, 2.0);
        }
        KernelParams {
            ls,
            sigma2: rng.uniform(0.1, 3.0),
            l00: rng.uniform(0.05, 1.5),
            l10: rng.uniform(0.05, 1.5),
            l11: rng.uniform(0.05, 1.5),
            noise: 1e-4,
        }
    }

    #[test]
    fn kernel_symmetric_and_bounded_by_diag() {
        check("k symmetry + CS inequality", 48, |rng| {
            let p = rand_params(rng);
            let basis = if rng.f64() < 0.5 { Basis::Acc } else { Basis::Cost };
            let (a, b) = (rand_feat(rng), rand_feat(rng));
            let kab = p.k(basis, &a, &b);
            let kba = p.k(basis, &b, &a);
            if (kab - kba).abs() > 1e-12 {
                return Err(format!("asymmetric {kab} {kba}"));
            }
            // Cauchy–Schwarz for PSD kernels
            let bound = (p.k_diag(basis, &a) * p.k_diag(basis, &b)).sqrt();
            if kab.abs() > bound + 1e-9 {
                return Err(format!("|k|={kab} > sqrt(kaa kbb)={bound}"));
            }
            Ok(())
        });
    }

    #[test]
    fn cov_matrix_is_pd() {
        check("cov PD via cholesky", 24, |rng| {
            let p = rand_params(rng);
            let xs: Vec<Feat> = (0..12).map(|_| rand_feat(rng)).collect();
            let k = p.cov_matrix(Basis::Acc, &xs);
            crate::linalg::Cholesky::factor(&k)
                .map(|_| ())
                .map_err(|e| e.to_string())
        });
    }

    #[test]
    fn log_vec_round_trips() {
        let mut rng = crate::util::Rng::new(5);
        let p = rand_params(&mut rng);
        let q = KernelParams::from_log_vec(&p.to_log_vec());
        assert!((p.sigma2 - q.sigma2).abs() < 1e-9);
        assert!((p.l10 - q.l10).abs() < 1e-9);
        for d in 0..D_FEAT {
            assert!((p.ls[d] - q.ls[d]).abs() < 1e-9);
        }
    }

    #[test]
    fn acc_basis_vanishing_data_term_at_full() {
        // At s=1 the accuracy basis reduces to Theta00 = l00² for all pairs.
        let p = KernelParams::default();
        assert!((p.basis_factor(Basis::Acc, 1.0, 1.0) - p.l00 * p.l00).abs() < 1e-12);
        // and the cost basis grows with s
        assert!(
            p.basis_factor(Basis::Cost, 1.0, 1.0)
                > p.basis_factor(Basis::Cost, 0.1, 0.1)
        );
    }

    #[test]
    fn matches_python_constants() {
        // Same layout as python N_HYP vector
        let p = KernelParams::default();
        let v = p.to_f32_vec();
        assert_eq!(v.len(), D_FEAT + 4);
    }
}
