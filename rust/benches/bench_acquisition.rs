//! Acquisition-function micro-benchmarks: the cost of one α_T evaluation
//! (the unit Table IV counts), its EI/EIc baselines, p_opt estimation, and
//! the per-iteration candidate-sweep latency of the sequential vs the
//! parallel slate evaluator.
//!
//! Results are also written to `BENCH_acquisition.json` (override the path
//! with the `BENCH_JSON` env var) so CI can track the perf trajectory.
mod common;

use trimtuner::acq::{
    eic, eic_usd, fabolas_alpha, joint_feasibility_many, trimtuner_alpha,
    EntropyEstimator, TrimTunerAcq,
};
use trimtuner::heuristics::AlphaCache;
use trimtuner::models::{Feat, ModelKind};
use trimtuner::space::{encode, Config, Point};
use trimtuner::util::timer::{bench, BenchStats};
use trimtuner::util::Rng;

fn main() {
    common::print_header("acquisition");
    let mut all: Vec<BenchStats> = Vec::new();
    let caps = common::caps();
    let full_feats: Vec<Feat> = (0..288)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let probe = encode(&Point { config: Config::from_id(33), s_idx: 1 });
    // β = 0.1 of the 1440-point grid: the slate one engine iteration sweeps
    let slate: Vec<Point> = (0..1440).step_by(10).map(Point::from_id).collect();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    for (label, kind, k) in [
        ("dt", ModelKind::Trees, 1usize),
        ("gp-ml2", ModelKind::Gp, 1),
        ("gp-mcmc8", ModelKind::Gp, 8),
    ] {
        let models = common::fitted(kind, 48, k);
        let mut rng = Rng::new(5);
        let rep: Vec<Feat> = (0..40).map(|i| full_feats[i * 7]).collect();
        let est = EntropyEstimator::new(rep, 160, &mut rng);
        let baseline =
            EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));

        let stats = bench(&format!("{label} p_opt(40 reps,160 mc)"), 1, 10, || {
            est.p_opt(models.acc.as_ref())
        });
        println!("{}", stats.report());
        all.push(stats);

        let shortlist: Vec<usize> = (0..32).collect();
        let shortlist_feats: Vec<Feat> =
            shortlist.iter().map(|&id| full_feats[id]).collect();
        let feas = joint_feasibility_many(&models, &caps, &shortlist_feats);
        let ctx = TrimTunerAcq {
            models: &models,
            est: &est,
            constraints: &caps,
            inc_shortlist: &shortlist,
            inc_shortlist_feats: &shortlist_feats,
            inc_feas: if models.constraints_fixed_under_condition() {
                Some(feas.as_slice())
            } else {
                None
            },
            baseline,
        };
        let stats = bench(&format!("{label} alpha_T(1 candidate)"), 1, 10, || {
            trimtuner_alpha(&ctx, &probe)
        });
        println!("{}", stats.report());
        all.push(stats);
        let stats = bench(&format!("{label} fabolas(1 candidate)"), 1, 10, || {
            fabolas_alpha(&models, &est, baseline, &probe)
        });
        println!("{}", stats.report());
        all.push(stats);
        let stats = bench(&format!("{label} eic x288"), 2, 10, || {
            full_feats
                .iter()
                .map(|x| eic(&models, &caps, x, 0.9))
                .sum::<f64>()
        });
        println!("{}", stats.report());
        all.push(stats);
        let stats = bench(&format!("{label} eic_usd x288"), 2, 10, || {
            full_feats
                .iter()
                .map(|x| eic_usd(&models, &caps, x, 0.9))
                .sum::<f64>()
        });
        println!("{}", stats.report());
        all.push(stats);

        // The headline comparison: one engine iteration's α_T candidate
        // sweep, sequential vs sharded across all cores. mcmc8 is skipped
        // (same code path as gp-ml2, 8x the runtime).
        if k <= 1 {
            let mut per_threads = Vec::new();
            for threads in [1usize, workers] {
                let stats = bench(
                    &format!(
                        "{label} alpha_T slate x{} threads={threads}",
                        slate.len()
                    ),
                    1,
                    5,
                    || {
                        let mut alpha = AlphaCache::shared(|p: &Point| {
                            trimtuner_alpha(&ctx, &encode(p))
                        })
                        .with_threads(threads);
                        alpha.eval_slate(&slate);
                        alpha.best()
                    },
                );
                println!("{}", stats.report());
                per_threads.push(stats.mean_s);
                all.push(stats);
            }
            if per_threads.len() == 2 && per_threads[1] > 0.0 {
                println!(
                    "{:<44} {:.2}x speedup ({} workers)",
                    format!("{label} slate parallel vs sequential"),
                    per_threads[0] / per_threads[1],
                    workers,
                );
            }
        }
    }

    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_acquisition.json".to_string());
    common::write_bench_json("acquisition", &path, &all);
}
