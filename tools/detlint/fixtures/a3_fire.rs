// A3 fire: fresh scratch temporaries in argument position — the callee's
// scratch parameter exists precisely so the buffer survives across calls,
// and `&mut Vec::new()` / `&mut Scratch::default()` throw it away each time.

pub struct Scratch {
    pub work: Vec<f64>,
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch { work: Vec::new() }
    }
}

pub struct Factor {
    n: usize,
}

impl Factor {
    pub fn downdate_into(&self, u: &[f64], out: &mut [f64], work: &mut Vec<f64>) {
        work.clear();
        work.extend_from_slice(u);
        for i in 0..self.n {
            out[i] -= work[i];
        }
    }
}

pub fn sweep(factor: &Factor, us: &[Vec<f64>], out: &mut [f64]) {
    for u in us {
        factor.downdate_into(u, out, &mut Vec::new());
    }
}

pub fn sweep_scored(factor: &Factor, us: &[Vec<f64>], out: &mut [f64], score: fn(&mut Scratch) -> f64) -> f64 {
    let mut acc = 0.0;
    for u in us {
        factor.downdate_into(u, out, &mut vec![0.0; u.len()]);
        acc += score(&mut Scratch::default());
    }
    acc
}
