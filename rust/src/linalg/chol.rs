//! Cholesky factorization with O(n²) incremental extension.

use super::Mat;
use anyhow::{bail, Result};

/// Lower-triangular Cholesky factor `L` with `K = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorize a symmetric positive-definite matrix. Retries with growing
    /// jitter on the diagonal (1e-10 … 1e-4) before giving up — standard GP
    /// practice for near-singular covariance matrices.
    pub fn factor(k: &Mat) -> Result<Cholesky> {
        assert_eq!(k.rows, k.cols);
        let mut jitter = 0.0;
        for attempt in 0..8 {
            match Self::try_factor(k, jitter) {
                Ok(c) => return Ok(c),
                Err(_) => {
                    jitter = if attempt == 0 { 1e-10 } else { jitter * 10.0 };
                }
            }
        }
        bail!("matrix not positive definite even with jitter {jitter}")
    }

    fn try_factor(k: &Mat, jitter: f64) -> Result<Cholesky> {
        let n = k.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = k[(i, j)] + if i == j { jitter } else { 0.0 };
                for p in 0..j {
                    sum -= l[(i, p)] * l[(j, p)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        bail!("not PD at pivot {i}: {sum}");
                    }
                    l[(i, i)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    pub fn n(&self) -> usize {
        self.l.rows
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Row-block size for the blocked triangular solves: a block of
    /// solution rows stays cache-resident while every finalized row is
    /// streamed through it exactly once.
    const SOLVE_BLOCK: usize = 32;

    /// Solve `L x = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut x = Vec::new();
        self.solve_lower_into(b, &mut x);
        x
    }

    /// [`Cholesky::solve_lower`] into a caller-provided buffer (cleared
    /// and refilled; reuses its allocation across calls).
    pub fn solve_lower_into(&self, b: &[f64], x: &mut Vec<f64>) {
        let n = self.n();
        assert_eq!(b.len(), n);
        x.clear();
        x.extend_from_slice(b);
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = x[i];
            for j in 0..i {
                s -= row[j] * x[j];
            }
            x[i] = s / row[i];
        }
    }

    /// Solve `L X = B` for every column of `B` in one pass (multi-RHS
    /// forward substitution), cache-blocked: solution rows are processed in
    /// blocks of [`Cholesky::SOLVE_BLOCK`]; each finalized row above the
    /// block is loaded once and applied to *every* row of the block with
    /// contiguous axpy updates before the small in-block triangle is
    /// solved. Column `c` of the result is bit-identical to
    /// `solve_lower(column c of B)` — per column, each row still subtracts
    /// its `j < i` contributions in ascending-`j` order, merely regrouped.
    pub fn solve_lower_multi(&self, b: &Mat) -> Mat {
        let mut x = b.clone();
        self.solve_lower_multi_in_place(&mut x);
        x
    }

    /// [`Cholesky::solve_lower_multi`] into a caller-provided output
    /// (overwritten with the solution; reuses its allocation). The batched
    /// slate sweep calls this once per hyper-sample with a scratch matrix
    /// instead of allocating a fresh solution per solve.
    pub fn solve_lower_multi_into(&self, b: &Mat, out: &mut Mat) {
        out.copy_from(b);
        self.solve_lower_multi_in_place(out);
    }

    fn solve_lower_multi_in_place(&self, x: &mut Mat) {
        let n = self.n();
        assert_eq!(x.rows, n);
        let m = x.cols;
        if m == 0 {
            return;
        }
        let data = x.as_mut_slice();
        let mut kb = 0;
        while kb < n {
            let hi = (kb + Self::SOLVE_BLOCK).min(n);
            let (done, rest) = data.split_at_mut(kb * m);
            // finalized rows feed the whole block; row j of the partial
            // solution is loaded once per block instead of once per row
            for j in 0..kb {
                let xj = &done[j * m..(j + 1) * m];
                for i in kb..hi {
                    let c = self.l[(i, j)];
                    let xi = &mut rest[(i - kb) * m..(i - kb + 1) * m];
                    for (x, &v) in xi.iter_mut().zip(xj) {
                        *x -= c * v;
                    }
                }
            }
            // in-block forward substitution
            for i in kb..hi {
                let (above, cur) = rest.split_at_mut((i - kb) * m);
                let xi = &mut cur[..m];
                let lrow = self.l.row(i);
                for (j, xj) in (kb..i).zip(above.chunks_exact(m)) {
                    let c = lrow[j];
                    for (x, &v) in xi.iter_mut().zip(xj) {
                        *x -= c * v;
                    }
                }
                let d = lrow[i];
                for x in xi.iter_mut() {
                    *x /= d;
                }
            }
            kb = hi;
        }
    }

    /// Solve `Lᵀ x = b` (back substitution), in the outer-product ("saxpy")
    /// form: once `x[j]` is final, row `j` of `L` — a contiguous slice —
    /// scatters its contribution to every remaining unknown, instead of
    /// each unknown gathering down a strided column of `L`. Same solution
    /// up to summation order (each `x[i]` now accumulates its `j > i`
    /// terms in descending-`j` order).
    pub fn solve_lower_t(&self, b: &[f64]) -> Vec<f64> {
        let mut x = Vec::new();
        self.solve_lower_t_into(b, &mut x);
        x
    }

    /// [`Cholesky::solve_lower_t`] into a caller-provided buffer.
    pub fn solve_lower_t_into(&self, b: &[f64], x: &mut Vec<f64>) {
        let n = self.n();
        assert_eq!(b.len(), n);
        x.clear();
        x.extend_from_slice(b);
        for j in (0..n).rev() {
            let row = self.l.row(j);
            let xj = x[j] / row[j];
            x[j] = xj;
            for (xi, &c) in x[..j].iter_mut().zip(row) {
                *xi -= c * xj;
            }
        }
    }

    /// Solve `K x = b` via the factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut y = Vec::new();
        self.solve_lower_into(b, &mut y);
        let mut x = Vec::new();
        self.solve_lower_t_into(&y, &mut x);
        x
    }

    /// log det K = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// An empty factor usable as the overwrite target of the `*_into`
    /// scratch APIs ([`Cholesky::update_into`] / [`Cholesky::downdate_into`]
    /// resize it on first use and then reuse its allocation).
    pub fn scratch() -> Cholesky {
        Cholesky { l: Mat::zeros(0, 0) }
    }

    /// Rank-one *update* in O(n²): the factor of `K + u uᵀ` from the factor
    /// of `K` (LINPACK `dchud`-style Givens sweep). Never loses positive
    /// definiteness for finite input, since `K + u uᵀ` is PD whenever `K`
    /// is.
    ///
    /// Allocating convenience over [`Cholesky::update_into`], the
    /// caller-visible scratch path; per-candidate loops must call the
    /// `_into` twin with reused scratch (detlint rules A2/A3 enforce this
    /// in the hot modules).
    pub fn update(&self, u: &[f64]) -> Cholesky {
        let mut out = Cholesky::scratch();
        let mut w = Vec::new();
        self.update_into(u, &mut out, &mut w);
        out
    }

    /// [`Cholesky::update`] into caller-provided scratch: `out` is
    /// overwritten with the updated factor and `w` is the sweep's working
    /// vector — both reuse their allocations across calls, so a hot loop
    /// (the slate sweep conditions one factor per candidate) performs no
    /// per-call heap allocation beyond what it keeps.
    pub fn update_into(&self, u: &[f64], out: &mut Cholesky, w: &mut Vec<f64>) {
        let n = self.n();
        assert_eq!(u.len(), n);
        out.l.copy_from(&self.l);
        let l = &mut out.l;
        w.clear();
        w.extend_from_slice(u);
        for k in 0..n {
            let lkk = l[(k, k)];
            let r = (lkk * lkk + w[k] * w[k]).sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            l[(k, k)] = r;
            for i in k + 1..n {
                l[(i, k)] = (l[(i, k)] + s * w[i]) / c;
                w[i] = c * w[i] - s * l[(i, k)];
            }
        }
    }

    /// Rank-one *downdate* in O(n²): the factor of `K − u uᵀ` from the
    /// factor of `K` (hyperbolic-rotation sweep, LINPACK `dchdd`). Fails
    /// when the downdated matrix is no longer (numerically) positive
    /// definite — callers fall back to a full refactorization or a diagonal
    /// approximation.
    ///
    /// This is the α_T fantasy-posterior hot path: conditioning a GP on one
    /// simulated observation shrinks the joint posterior covariance over a
    /// fixed query grid by exactly one outer product, so each candidate's
    /// conditioned covariance factor is one O(m²) downdate of the shared
    /// per-iteration factor instead of an O(m³) refactorization.
    pub fn downdate(&self, u: &[f64]) -> Result<Cholesky> {
        let mut out = Cholesky::scratch();
        let mut w = Vec::new();
        self.downdate_into(u, &mut out, &mut w)?;
        Ok(out)
    }

    /// [`Cholesky::downdate`] into caller-provided scratch (see
    /// [`Cholesky::update_into`]). On failure `out` holds a partially
    /// swept factor and must not be used.
    pub fn downdate_into(
        &self,
        u: &[f64],
        out: &mut Cholesky,
        w: &mut Vec<f64>,
    ) -> Result<()> {
        let n = self.n();
        assert_eq!(u.len(), n);
        out.l.copy_from(&self.l);
        let l = &mut out.l;
        w.clear();
        w.extend_from_slice(u);
        for k in 0..n {
            let lkk = l[(k, k)];
            let r2 = lkk * lkk - w[k] * w[k];
            // near-singular pivots (ratio below ~1e-7) cannot be resolved
            // in f64 hyperbolic rotations; report failure instead of
            // emitting a garbage factor
            if r2.is_nan() || r2 <= lkk * lkk * 1e-14 {
                bail!("downdate loses positive definiteness at pivot {k}");
            }
            let r = r2.sqrt();
            let c = r / lkk;
            let s = w[k] / lkk;
            l[(k, k)] = r;
            for i in k + 1..n {
                l[(i, k)] = (l[(i, k)] - s * w[i]) / c;
                w[i] = c * w[i] - s * l[(i, k)];
            }
        }
        Ok(())
    }

    /// Extend the factor with one extra row/column of K in O(n²):
    /// given K' = [[K, k12], [k12ᵀ, k22]], the new factor row is
    /// l12 = L⁻¹ k12 and l22 = sqrt(k22 − l12ᵀ l12). Fails when the
    /// appended pivot is numerically non-positive — the same near-singular
    /// rejection contract as [`Cholesky::update`]/[`Cholesky::downdate`];
    /// callers that must never fail (the fantasy conditioning path) use
    /// [`Cholesky::extend_clamped`] instead.
    ///
    /// Allocating convenience over [`Cholesky::extend_into`]; the
    /// per-observation absorption loop uses the `_into` twin or
    /// [`Cholesky::extend_in_place`] with reused scratch.
    pub fn extend(&self, k12: &[f64], k22: f64) -> Result<Cholesky> {
        let mut out = Cholesky::scratch();
        let mut w = Vec::new();
        self.extend_into(k12, k22, &mut out, &mut w)?;
        Ok(out)
    }

    /// New-pivot square l22² = k22 − l12ᵀl12, shared by every strict
    /// extend entry point (`w` must already hold l12 = L⁻¹ k12). Rejects
    /// pivots whose square fell below k22·1e-14 — the appended row would
    /// be numerically rank-deficient, exactly the regime
    /// [`Cholesky::downdate`] refuses at its pivots.
    fn extend_pivot(k22: f64, w: &[f64]) -> Result<f64> {
        let rem = k22 - w.iter().map(|v| v * v).sum::<f64>();
        if rem.is_nan() || rem <= k22.abs() * 1e-14 {
            bail!("extend loses positive definiteness at the appended pivot");
        }
        Ok(rem)
    }

    /// [`Cholesky::extend`] into caller-provided scratch: `out` is
    /// overwritten with the (n+1)×(n+1) factor and `w` ends up holding the
    /// new off-diagonal row l12 — both reuse their allocations across
    /// calls, so a warm loop allocates nothing. On failure `out` keeps its
    /// previous contents.
    pub fn extend_into(
        &self,
        k12: &[f64],
        k22: f64,
        out: &mut Cholesky,
        w: &mut Vec<f64>,
    ) -> Result<()> {
        let n = self.n();
        assert_eq!(k12.len(), n);
        self.solve_lower_into(k12, w);
        let rem = Self::extend_pivot(k22, w)?;
        out.l.reshape_zeroed(n + 1, n + 1);
        for i in 0..n {
            let (src, dst) = (self.l.row(i), out.l.row_mut(i));
            dst[..=i].copy_from_slice(&src[..=i]);
        }
        let last = out.l.row_mut(n);
        last[..n].copy_from_slice(w);
        last[n] = rem.sqrt();
        Ok(())
    }

    /// Grow `self` by one observation row *in place* — the amortized-O(n²)
    /// absorption path of the incremental surrogate refit: the factor's
    /// backing buffer is re-strided row by row ([`Mat::grow_square`], so a
    /// warm absorb loop performs no per-call heap allocation between
    /// capacity doublings) and the new row (l12, l22) is written last. On
    /// failure `self` is untouched.
    pub fn extend_in_place(
        &mut self,
        k12: &[f64],
        k22: f64,
        w: &mut Vec<f64>,
    ) -> Result<()> {
        let n = self.n();
        assert_eq!(k12.len(), n);
        self.solve_lower_into(k12, w);
        let rem = Self::extend_pivot(k22, w)?;
        self.l.grow_square();
        let last = self.l.row_mut(n);
        last[..n].copy_from_slice(w);
        last[n] = rem.sqrt();
        Ok(())
    }

    /// The clamping extend: a near-singular appended pivot is clamped to
    /// l22 = 1e-6 instead of rejected. The fantasy conditioning path
    /// ([`crate::models`]' `condition`, the per-candidate "simulate the
    /// refit" step of DESIGN.md §8) relies on this never failing, mirroring
    /// its v_eff variance clamp — the constants are load-bearing for the
    /// batch/alpha parity suites, so absorption's strict [`Cholesky::extend`]
    /// is a separate entry point.
    pub fn extend_clamped(&self, k12: &[f64], k22: f64) -> Cholesky {
        let n = self.n();
        assert_eq!(k12.len(), n);
        let mut l12 = Vec::new();
        self.solve_lower_into(k12, &mut l12);
        let rem = k22 - l12.iter().map(|v| v * v).sum::<f64>();
        // Guard: padding/jitter keeps this positive in practice.
        let l22 = if rem > 1e-12 { rem.sqrt() } else { 1e-6 };
        let mut l = Mat::zeros(n + 1, n + 1);
        for i in 0..n {
            let (src, dst) = (self.l.row(i), l.row_mut(i));
            dst[..=i].copy_from_slice(&src[..=i]);
        }
        let last = l.row_mut(n);
        last[..n].copy_from_slice(&l12);
        last[n] = l22;
        Cholesky { l }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Rng;

    fn random_spd(rng: &mut Rng, n: usize) -> Mat {
        // A Aᵀ + n·I is SPD.
        let a = Mat::from_fn(n, n, |_, _| rng.normal());
        let mut k = a.matmul(&a.transpose());
        for i in 0..n {
            k[(i, i)] += n as f64;
        }
        k
    }

    #[test]
    fn factor_reconstructs_k() {
        check("LLt == K", 32, |rng| {
            let n = 2 + rng.below(12);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let rec = c.l().matmul(&c.l().transpose());
            let err = rec.max_abs_diff(&k);
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("reconstruction error {err}"))
            }
        });
    }

    #[test]
    fn solve_matches_direct() {
        check("K x = b solve", 32, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let x = c.solve(&b);
            let kb = k.matvec(&x);
            let err = kb
                .iter()
                .zip(&b)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            if err < 1e-7 {
                Ok(())
            } else {
                Err(format!("residual {err}"))
            }
        });
    }

    #[test]
    fn extend_matches_full_refactor() {
        check("incremental extend", 32, |rng| {
            let n = 2 + rng.below(10);
            let k_full = random_spd(rng, n + 1);
            let k_sub = Mat::from_fn(n, n, |i, j| k_full[(i, j)]);
            let c_sub = Cholesky::factor(&k_sub).map_err(|e| e.to_string())?;
            let k12: Vec<f64> = (0..n).map(|i| k_full[(i, n)]).collect();
            let ext = c_sub
                .extend(&k12, k_full[(n, n)])
                .map_err(|e| e.to_string())?;
            let full = Cholesky::factor(&k_full).map_err(|e| e.to_string())?;
            let err = ext.l().max_abs_diff(full.l());
            if err < 1e-7 {
                Ok(())
            } else {
                Err(format!("factor mismatch {err}"))
            }
        });
    }

    #[test]
    fn extend_matches_full_refactor_across_block_boundaries() {
        // shapes straddle SOLVE_BLOCK (1 … ~2 blocks) and include the 1×1
        // base factor; the absorption contract is the tight 1e-9 of the
        // update/downdate suite, not extend's historic 1e-7
        check("incremental extend, blocked shapes", 12, |rng| {
            let n = 1 + rng.below(70);
            let k_full = random_spd(rng, n + 1);
            let k_sub = Mat::from_fn(n, n, |i, j| k_full[(i, j)]);
            let c_sub = Cholesky::factor(&k_sub).map_err(|e| e.to_string())?;
            let k12: Vec<f64> = (0..n).map(|i| k_full[(i, n)]).collect();
            let ext = c_sub
                .extend(&k12, k_full[(n, n)])
                .map_err(|e| e.to_string())?;
            let full = Cholesky::factor(&k_full).map_err(|e| e.to_string())?;
            let err = ext.l().max_abs_diff(full.l());
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("n={n}: factor mismatch {err}"))
            }
        });
        // degenerate base: extending the 0×0 factor is the first-ever
        // observation — the result is the scalar factor [√k22]
        let empty = Cholesky::factor(&Mat::zeros(0, 0)).unwrap();
        let one = empty.extend(&[], 4.0).unwrap();
        assert_eq!(one.n(), 1);
        assert_eq!(one.l()[(0, 0)].to_bits(), 2.0f64.to_bits());
    }

    #[test]
    fn extend_into_and_in_place_bitwise_match_extend() {
        // scratch reused dirty and wrongly sized across iterations — the
        // absorption hot-loop usage pattern
        let mut out = Cholesky::scratch();
        let mut w = vec![9.0; 3];
        check("extend_into / extend_in_place == extend", 24, |rng| {
            let n = 1 + rng.below(40);
            let k_full = random_spd(rng, n + 1);
            let k_sub = Mat::from_fn(n, n, |i, j| k_full[(i, j)]);
            let c_sub = Cholesky::factor(&k_sub).map_err(|e| e.to_string())?;
            let k12: Vec<f64> = (0..n).map(|i| k_full[(i, n)]).collect();
            let k22 = k_full[(n, n)];
            let want = c_sub.extend(&k12, k22).map_err(|e| e.to_string())?;
            c_sub
                .extend_into(&k12, k22, &mut out, &mut w)
                .map_err(|e| e.to_string())?;
            if out.l().max_abs_diff(want.l()) != 0.0 {
                return Err("extend_into diverged from extend".into());
            }
            let mut grown = c_sub.clone();
            grown
                .extend_in_place(&k12, k22, &mut w)
                .map_err(|e| e.to_string())?;
            if grown.l().max_abs_diff(want.l()) != 0.0 {
                return Err("extend_in_place diverged from extend".into());
            }
            Ok(())
        });
    }

    #[test]
    fn extend_rejects_pd_breaking_row() {
        // k12 = L v makes l12 = v exactly, so k22 ≤ ‖v‖² appends a
        // non-positive pivot: the strict family must refuse and leave the
        // in-place factor untouched, while the clamped legacy path keeps
        // its never-fail contract
        check("extend rejects rank-deficient rows", 24, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let k12: Vec<f64> = (0..n)
                .map(|i| {
                    c.l().row(i)[..=i]
                        .iter()
                        .zip(&v)
                        .map(|(a, b)| a * b)
                        .sum()
                })
                .collect();
            let k22 = 0.5 * v.iter().map(|x| x * x).sum::<f64>();
            if c.extend(&k12, k22).is_ok() {
                return Err("accepted a PD-breaking extension".into());
            }
            let mut grown = c.clone();
            let mut w = Vec::new();
            if grown.extend_in_place(&k12, k22, &mut w).is_ok() {
                return Err("in-place accepted a PD-breaking extension".into());
            }
            if grown.l().max_abs_diff(c.l()) != 0.0 {
                return Err("failed extend_in_place mutated the factor".into());
            }
            let clamped = c.extend_clamped(&k12, k22);
            if clamped.l()[(n, n)].to_bits() != 1e-6f64.to_bits() {
                return Err("clamped path lost its 1e-6 floor".into());
            }
            Ok(())
        });
    }

    #[test]
    fn extend_composes_with_update_downdate_roundtrip() {
        // the grown factor is a first-class factor: rank-one
        // update ∘ downdate on it round-trips to itself, and a downdate of
        // it matches refactoring the extended-then-downdated matrix — the
        // "extend ∘ downdate ≈ id" compositionality contract
        check("extend ∘ (update ∘ downdate) == extend", 24, |rng| {
            let n = 2 + rng.below(10);
            let k_full = random_spd(rng, n + 1);
            let k_sub = Mat::from_fn(n, n, |i, j| k_full[(i, j)]);
            let c_sub = Cholesky::factor(&k_sub).map_err(|e| e.to_string())?;
            let k12: Vec<f64> = (0..n).map(|i| k_full[(i, n)]).collect();
            let ext = c_sub
                .extend(&k12, k_full[(n, n)])
                .map_err(|e| e.to_string())?;
            let u: Vec<f64> = (0..=n).map(|_| rng.normal()).collect();
            let round =
                ext.update(&u).downdate(&u).map_err(|e| e.to_string())?;
            let err = round.l().max_abs_diff(ext.l());
            if err >= 1e-9 {
                return Err(format!("round-trip drift {err}"));
            }
            let d = scaled_downdate_vec(&ext, rng, 0.6);
            let down = ext.downdate(&d).map_err(|e| e.to_string())?;
            let mut k2 = k_full.clone();
            for i in 0..=n {
                for j in 0..=n {
                    k2[(i, j)] -= d[i] * d[j];
                }
            }
            let full = Cholesky::factor(&k2).map_err(|e| e.to_string())?;
            let err = down.l().max_abs_diff(full.l());
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("extend∘downdate vs refactor drift {err}"))
            }
        });
    }

    #[test]
    fn solve_lower_multi_bitwise_matches_columnwise() {
        check("multi-RHS forward solve", 24, |rng| {
            let n = 2 + rng.below(10);
            let m = 1 + rng.below(8);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let b = Mat::from_fn(n, m, |_, _| rng.normal());
            let x = c.solve_lower_multi(&b);
            for col in 0..m {
                let bcol: Vec<f64> = (0..n).map(|i| b[(i, col)]).collect();
                let xcol = c.solve_lower(&bcol);
                for i in 0..n {
                    if x[(i, col)].to_bits() != xcol[i].to_bits() {
                        return Err(format!(
                            "col {col} row {i}: {} != {}",
                            x[(i, col)],
                            xcol[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_lower_multi_blocked_shapes_match_columnwise() {
        // sizes straddling SOLVE_BLOCK (1 … ~3 row blocks) — the blocked
        // path's regrouped axpy order must stay bit-identical per column
        check("blocked multi-RHS forward solve", 8, |rng| {
            let n = 33 + rng.below(60);
            let m = 1 + rng.below(12);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let b = Mat::from_fn(n, m, |_, _| rng.normal());
            let x = c.solve_lower_multi(&b);
            for col in 0..m {
                let bcol: Vec<f64> = (0..n).map(|i| b[(i, col)]).collect();
                let xcol = c.solve_lower(&bcol);
                for i in 0..n {
                    if x[(i, col)].to_bits() != xcol[i].to_bits() {
                        return Err(format!(
                            "n={n} col {col} row {i}: {} != {}",
                            x[(i, col)],
                            xcol[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_lower_multi_one_by_one_and_empty_rhs() {
        // 1×1 factor: a single divide, no block machinery in the way
        let k = Mat::from_rows(&[vec![4.0]]);
        let c = Cholesky::factor(&k).unwrap();
        let b = Mat::from_rows(&[vec![6.0, -2.0, 0.5]]);
        let x = c.solve_lower_multi(&b);
        for (col, want) in [3.0, -1.0, 0.25].iter().enumerate() {
            assert_eq!(x[(0, col)].to_bits(), want.to_bits());
        }
        // empty right-hand side: n×0 in, n×0 out, no work, no panic
        let mut rng = Rng::new(3);
        let k = random_spd(&mut rng, 5);
        let c = Cholesky::factor(&k).unwrap();
        let empty = Mat::zeros(5, 0);
        let x = c.solve_lower_multi(&empty);
        assert_eq!((x.rows, x.cols), (5, 0));
        // and the scratch entry point reuses whatever shape it is handed
        let mut out = Mat::zeros(2, 9);
        c.solve_lower_multi_into(&empty, &mut out);
        assert_eq!((out.rows, out.cols), (5, 0));
    }

    #[test]
    fn scratch_solve_buffers_match_allocating_calls() {
        check("solve_*_into == solve_*", 16, |rng| {
            let n = 1 + rng.below(40);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            // deliberately dirty, wrongly-sized scratch
            let mut fwd = vec![7.0; 3];
            let mut bwd = vec![-1.0; 77];
            c.solve_lower_into(&b, &mut fwd);
            c.solve_lower_t_into(&b, &mut bwd);
            let want_f = c.solve_lower(&b);
            let want_b = c.solve_lower_t(&b);
            for i in 0..n {
                if fwd[i].to_bits() != want_f[i].to_bits()
                    || bwd[i].to_bits() != want_b[i].to_bits()
                {
                    return Err(format!("row {i} diverged"));
                }
            }
            Ok(())
        });
    }

    /// Random vector scaled so that `uᵀ K⁻¹ u == target` — the downdated
    /// matrix `K − u uᵀ` is PD iff that quadratic form is < 1.
    fn scaled_downdate_vec(
        c: &Cholesky,
        rng: &mut Rng,
        target: f64,
    ) -> Vec<f64> {
        let n = c.n();
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let kinv_u = c.solve(&u);
        let q: f64 = u.iter().zip(&kinv_u).map(|(a, b)| a * b).sum();
        let scale = (target / q).sqrt();
        u.into_iter().map(|v| v * scale).collect()
    }

    #[test]
    fn update_matches_refactorization() {
        check("rank-one update == refactor", 32, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let up = c.update(&u);
            let mut k2 = k.clone();
            for i in 0..n {
                for j in 0..n {
                    k2[(i, j)] += u[i] * u[j];
                }
            }
            let full = Cholesky::factor(&k2).map_err(|e| e.to_string())?;
            let err = up.l().max_abs_diff(full.l());
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("update factor mismatch {err}"))
            }
        });
    }

    #[test]
    fn downdate_matches_refactorization() {
        check("rank-one downdate == refactor", 32, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            // keep K − u uᵀ safely PD (uᵀK⁻¹u = 0.6 < 1)
            let u = scaled_downdate_vec(&c, rng, 0.6);
            let down = c.downdate(&u).map_err(|e| e.to_string())?;
            let mut k2 = k.clone();
            for i in 0..n {
                for j in 0..n {
                    k2[(i, j)] -= u[i] * u[j];
                }
            }
            let full = Cholesky::factor(&k2).map_err(|e| e.to_string())?;
            let err = down.l().max_abs_diff(full.l());
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("downdate factor mismatch {err}"))
            }
        });
    }

    #[test]
    fn update_then_downdate_roundtrips() {
        check("update ∘ downdate == identity", 32, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let round = c.update(&u).downdate(&u).map_err(|e| e.to_string())?;
            let err = round.l().max_abs_diff(c.l());
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("round-trip drift {err}"))
            }
        });
    }

    #[test]
    fn update_then_downdate_roundtrips_under_scratch_api() {
        // same contract as above, driven through the `*_into` entry points
        // with scratch reused (dirty and wrongly sized) across iterations —
        // the hot-loop usage pattern
        let mut up = Cholesky::scratch();
        let mut down = Cholesky::scratch();
        let mut w = vec![9.0; 5];
        check("update_into ∘ downdate_into == identity", 32, |rng| {
            let n = 2 + rng.below(10);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            c.update_into(&u, &mut up, &mut w);
            up.downdate_into(&u, &mut down, &mut w)
                .map_err(|e| e.to_string())?;
            let err = down.l().max_abs_diff(c.l());
            if err >= 1e-9 {
                return Err(format!("round-trip drift {err}"));
            }
            // and the scratch results are bitwise the allocating results
            let want = c.update(&u).downdate(&u).map_err(|e| e.to_string())?;
            if down.l().max_abs_diff(want.l()) != 0.0 {
                return Err("scratch path diverged from allocating path".into());
            }
            Ok(())
        });
    }

    #[test]
    fn downdate_rejects_pd_breaking_vector() {
        check("downdate rejects uᵀK⁻¹u > 1", 24, |rng| {
            let n = 2 + rng.below(8);
            let k = random_spd(rng, n);
            let c = Cholesky::factor(&k).map_err(|e| e.to_string())?;
            let u = scaled_downdate_vec(&c, rng, 1.5);
            match c.downdate(&u) {
                Err(_) => Ok(()),
                Ok(_) => Err("accepted a PD-breaking downdate".into()),
            }
        });
    }

    #[test]
    fn downdate_rejects_near_singular_pivot() {
        // Downdating by the factor's own first column drives the first
        // pivot of K − u uᵀ to exactly zero: the degenerate path must
        // report failure instead of emitting a factor full of garbage.
        let mut rng = Rng::new(7);
        let k = random_spd(&mut rng, 6);
        let c = Cholesky::factor(&k).unwrap();
        let u: Vec<f64> = (0..6).map(|i| c.l()[(i, 0)]).collect();
        assert!(c.downdate(&u).is_err());
    }

    #[test]
    fn log_det_matches_2x2() {
        let k = Mat::from_rows(&[vec![4.0, 1.0], vec![1.0, 3.0]]);
        let c = Cholesky::factor(&k).unwrap();
        assert!((c.log_det() - (11.0f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn non_pd_rejected() {
        let k = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig -1
        assert!(Cholesky::try_factor(&k, 0.0).is_err());
    }
}
