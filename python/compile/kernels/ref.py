"""Pure-jnp oracle for the Matérn-5/2 × FABOLAS covariance kernel.

This is the correctness reference for the Pallas kernel in
``matern_fabolas.py``; pytest/hypothesis compare them with
``assert_allclose`` (python/tests/test_kernel.py) and the Rust native GP
(rust/src/models/kernel.rs) implements the same formulas, cross-checked via
the AOT artifacts in rust/tests.
"""

import jax.numpy as jnp
import numpy as np

from .matern_fabolas import D_FEAT, D_IN, N_HYP, cov_diag  # noqa: F401

_SQRT5 = np.sqrt(5.0).astype(np.float32)


def _basis_g(s, basis):
    return (1.0 - s) if basis == "acc" else s


def cov_ref(x1, x2, hyp, *, basis: str = "acc"):
    """Reference covariance matrix, no tiling, no fusion."""
    ls = hyp[:D_FEAT]
    sigma2 = hyp[D_FEAT]
    l00, l10, l11 = hyp[D_FEAT + 1], hyp[D_FEAT + 2], hyp[D_FEAT + 3]

    a = x1[:, :D_FEAT] / ls[None, :]
    b = x2[:, :D_FEAT] / ls[None, :]
    diff = a[:, None, :] - b[None, :, :]
    r2 = jnp.sum(diff * diff, axis=-1)
    r = jnp.sqrt(jnp.maximum(r2, 0.0))
    matern = (1.0 + _SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-_SQRT5 * r)

    g1 = _basis_g(x1[:, D_FEAT], basis)
    g2 = _basis_g(x2[:, D_FEAT], basis)
    theta = jnp.array([[l00, 0.0], [l10, l11]], dtype=jnp.float32)
    theta = theta @ theta.T
    phi1 = jnp.stack([jnp.ones_like(g1), g1], axis=1)
    phi2 = jnp.stack([jnp.ones_like(g2), g2], axis=1)
    bas = phi1 @ theta @ phi2.T
    return sigma2 * matern * bas


def gp_posterior_ref(x_tr, y, noise, x_q, hyp, *, basis: str = "acc"):
    """Reference GP posterior (mean, variance) — mirrors model.gp_posterior."""
    n = x_tr.shape[0]
    k = cov_ref(x_tr, x_tr, hyp, basis=basis) + jnp.diag(noise) + 1e-6 * jnp.eye(n)
    l = jnp.linalg.cholesky(k)
    alpha = jnp.linalg.solve(k, y)
    ks = cov_ref(x_tr, x_q, hyp, basis=basis)
    mu = ks.T @ alpha
    v = jnp.linalg.solve(l, ks)  # lower-triangular solve L^-1 Ks
    var = cov_diag(x_q, hyp, basis=basis) - jnp.sum(v * v, axis=0)
    return mu, jnp.maximum(var, 1e-12)
