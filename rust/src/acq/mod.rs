//! Acquisition functions (paper §II–III): EI, constrained EI (CherryPick),
//! EIc/USD (Lynceus), Entropy-Search machinery (p_opt / information gain),
//! FABOLAS, and TrimTuner's constrained sub-sampling-aware α_T.
//!
//! The per-candidate reference path is [`trimtuner_alpha`]; the hot path
//! is [`AlphaSlate`], which scores a whole candidate slate off one shared
//! per-round precompute of rank-one *fantasy posteriors*
//! (`Surrogate::fantasy_surface`), primed per slate
//! (`FantasySurface::prime`: one multi-RHS `w = L⁻¹k(X, x)` solve per GP
//! hyper-sample, one cached conditioned tree structure) — bit-exact for
//! tree surrogates, ≤ 1e-9 relative for GPs, with `TRIMTUNER_ALPHA=clone`
//! (per-candidate clone-conditioning) and `TRIMTUNER_TREES=rebuild`
//! (per-candidate seeded tree rebuilds) as escape hatches.
//! [`Models`] also exposes the conditioning
//! entry points the engine's batched probe slates build on:
//! [`Models::condition`] (kriging-believer fantasy observation at the
//! predictive mean) and [`Models::condition_with_acc`] (constant-liar
//! value supplied by the caller).

mod ei;
mod entropy;
mod fabolas;
mod models;
mod trimtuner;

pub use ei::{ei, eic, eic_usd};
pub use entropy::{EntropyEstimator, EntropyScratch};
pub use fabolas::fabolas_alpha;
pub use models::{
    feasibility_prob, feasibility_probs, joint_feasibility,
    joint_feasibility_many, select_incumbent, select_incumbent_from,
    select_incumbent_over, select_incumbent_over_with_feas, Incumbent,
    Models, FEAS_THRESHOLD, FEAS_THRESHOLD_HYST,
};
pub use trimtuner::{
    alpha_slate, trimtuner_alpha, AlphaMode, AlphaSlate, TrimTunerAcq,
};
