//! Evaluation metrics: Constrained Accuracy (paper Eq. 7) and derived
//! savings measures (Fig. 2).

use super::backend::FaultStats;
use super::pareto::ParetoPoint;
use crate::sim::{Dataset, Outcome};
use crate::space::{Constraint, Point};

/// Constrained Accuracy (Eq. 7): the incumbent's accuracy, multiplicatively
/// penalized by how much it violates each constraint.
pub fn accuracy_c(
    dataset: &Dataset,
    p: &Point,
    constraints: &[Constraint],
) -> f64 {
    let acc = dataset.outcome(p).acc;
    let mut penalty = 1.0;
    for c in constraints {
        let v = dataset.metric(p, c);
        if v > c.max {
            penalty *= c.max / v;
        }
    }
    acc * penalty
}

/// One optimizer iteration's record (per-iteration row of every figure).
#[derive(Debug, Clone)]
pub struct IterRecord {
    /// 0-based iteration (init tests get negative-phase flag instead)
    pub iter: usize,
    pub is_init: bool,
    /// 0-based selection *round* this observation belongs to. The init
    /// batch is round 0; each main-loop round selects a slate of up to
    /// `EngineConfig::batch_size` probes, launches them concurrently and
    /// refits once — so with q = 1 every main record is its own round and
    /// with q > 1 consecutive records share a round id. Round-level
    /// quantities (`rec_wall_s`, `n_alpha_evals`) are attributed to the
    /// round's last record.
    ///
    /// Under `async_mode` there are no slates: `round` is the pick's
    /// *logical selection index* (init = round 0, the k-th absorbed pick =
    /// round k), every record is its own round, and the round-level
    /// quantities are per-pick. A pick abandoned under faults consumes its
    /// index without a record — exactly like a barriered round whose whole
    /// slate was abandoned — so round ids stay comparable across modes.
    pub round: usize,
    pub tested: Point,
    pub outcome: Outcome,
    /// exploration cost charged for this test (USD)
    pub explore_cost: f64,
    pub cum_cost: f64,
    /// cumulative simulated exploration time (s)
    pub cum_time: f64,
    /// measured wall-clock duration of the deployment that produced this
    /// observation (replay: the recorded training time; live: the job's
    /// duration as reported by the launcher)
    pub duration_s: f64,
    /// wall-clock seconds spent choosing this test + refitting (Table III).
    /// Async mode: the wall-clock between consecutive absorptions (the
    /// selections submitted plus the wait for this pick's logical turn),
    /// so the per-record values still sum to the campaign wall
    pub rec_wall_s: f64,
    /// recommended incumbent after this iteration (full data-set config)
    pub incumbent: Point,
    /// the recommender's own accuracy estimate for the incumbent —
    /// model-predicted (or observed, for observation-based recommenders).
    /// This is what adaptive stop conditions consume: it involves no
    /// ground truth, so it exists in live runs too.
    pub inc_pred_acc: f64,
    /// the incumbent's accuracy estimate came from a sub-sampled probe
    /// (no full-data-set observation of any config existed yet)
    pub inc_from_subsample: bool,
    /// EVALUATION-ONLY: ground-truth outcome of the incumbent in the
    /// dataset (NaN in live runs without an offline oracle attached)
    pub inc_acc: f64,
    /// EVALUATION-ONLY: ground-truth feasibility of the incumbent.
    /// Meaningless (always `false`) when no ground-truth oracle exists —
    /// i.e. whenever `inc_acc.is_nan()`; check that before reading this.
    pub inc_feasible: bool,
    /// EVALUATION-ONLY: Constrained Accuracy of the incumbent (Eq. 7)
    pub accuracy_c: f64,
    /// unique acquisition evaluations spent this iteration
    pub n_alpha_evals: usize,
}

/// Result of one optimizer run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub records: Vec<IterRecord>,
    /// true optimum: best feasible full-data-set accuracy in the dataset
    pub optimum_acc: f64,
    pub optimum: Option<Point>,
    /// predicted (cost, accuracy) Pareto frontier under the final models,
    /// populated when [`super::EngineConfig`]'s `pareto` flag is set
    pub pareto: Option<Vec<ParetoPoint>>,
    /// fault counters from the backend (all zero under replay or a clean
    /// live run): failed launches, abandoned probes, and the partial
    /// cost/time charged without producing an observation
    pub faults: FaultStats,
}

impl RunResult {
    pub fn final_accuracy_c(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.accuracy_c)
    }

    pub fn total_cost(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.cum_cost)
    }

    pub fn total_time(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.cum_time)
    }

    /// Mean wall-clock recommendation latency per main-loop *round*.
    /// `rec_wall_s` is recorded once per round (on the round's last
    /// record), so the average divides by the number of rounds, not
    /// records — a per-record mean would dilute the latency by the batch
    /// factor at `batch_size` > 1. Identical to the per-record mean when
    /// every round holds one observation (q = 1). Async runs attribute one
    /// round per logical pick (abandoned picks included, exactly as
    /// barriered all-abandoned rounds are), so the same round-span
    /// denominator stays correct across modes.
    pub fn mean_rec_wall_s(&self) -> f64 {
        let main: Vec<&IterRecord> =
            self.records.iter().filter(|r| !r.is_init).collect();
        match (main.first(), main.last()) {
            (Some(first), Some(last)) => {
                let n_rounds = (last.round - first.round + 1) as f64;
                main.iter().map(|r| r.rec_wall_s).sum::<f64>() / n_rounds
            }
            _ => f64::NAN,
        }
    }

    /// Number of selection rounds, including the init batch (round 0).
    /// Async runs count logical picks: the init batch plus one round per
    /// selection (including picks abandoned under faults, which carry a
    /// round index but no record — mirroring barriered all-abandoned
    /// rounds).
    pub fn n_rounds(&self) -> usize {
        self.records.last().map_or(0, |r| r.round + 1)
    }

    /// Total measured wall-clock across all rounds (selection + slate
    /// deployment + refit; `rec_wall_s` is recorded once per round) — the
    /// denominator of the batched-probe regret-vs-wall-clock trade-off
    /// that `bench_coordinator`'s q × workers sweep quantifies. Async
    /// records carry per-absorption walls that sum to the same campaign
    /// total, so this is also the quantity the async-vs-barrier speedup
    /// gate compares.
    pub fn total_wall_s(&self) -> f64 {
        self.records.iter().map(|r| r.rec_wall_s).sum()
    }
}

/// Exploration (cost, time) spent until the incumbent's Accuracy_C first
/// reaches `frac` of the optimum — the Fig. 2 "savings" quantity. `None`
/// if never reached.
pub fn cost_to_quality(run: &RunResult, frac: f64) -> Option<(f64, f64)> {
    let target = frac * run.optimum_acc;
    run.records
        .iter()
        .find(|r| r.accuracy_c >= target)
        .map(|r| (r.cum_cost, r.cum_time))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NetKind;

    #[test]
    fn accuracy_c_no_penalty_when_feasible() {
        let d = Dataset::generate(NetKind::Rnn, 1);
        let caps = vec![Constraint::cost_max(1e9)];
        for id in [0usize, 500, 1439] {
            let p = Point::from_id(id);
            assert_eq!(accuracy_c(&d, &p, &caps), d.outcome(&p).acc);
        }
    }

    #[test]
    fn accuracy_c_penalizes_violations_proportionally() {
        let d = Dataset::generate(NetKind::Rnn, 1);
        let p = Point::from_id(700);
        let cost = d.outcome(&p).cost_usd;
        let caps = vec![Constraint::cost_max(cost / 2.0)];
        let expect = d.outcome(&p).acc * 0.5;
        assert!((accuracy_c(&d, &p, &caps) - expect).abs() < 1e-9);
        // double violation -> multiplicative
        let caps2 = vec![
            Constraint::cost_max(cost / 2.0),
            Constraint::time_max(d.outcome(&p).time_s / 4.0),
        ];
        let expect2 = d.outcome(&p).acc * 0.5 * 0.25;
        assert!((accuracy_c(&d, &p, &caps2) - expect2).abs() < 1e-9);
    }

    #[test]
    fn cost_to_quality_finds_first_crossing() {
        let d = Dataset::generate(NetKind::Rnn, 1);
        let p = Point::from_id(4); // arbitrary
        let mk = |acc_c: f64, cum: f64| IterRecord {
            iter: 0,
            is_init: false,
            round: 0,
            tested: p,
            outcome: d.outcome(&p),
            explore_cost: 0.0,
            cum_cost: cum,
            cum_time: cum * 10.0,
            duration_s: 0.0,
            rec_wall_s: 0.0,
            incumbent: p,
            inc_pred_acc: acc_c,
            inc_from_subsample: false,
            inc_acc: 0.0,
            inc_feasible: true,
            accuracy_c: acc_c,
            n_alpha_evals: 0,
        };
        let run = RunResult {
            records: vec![mk(0.1, 1.0), mk(0.85, 2.0), mk(0.95, 3.0)],
            optimum_acc: 1.0,
            optimum: None,
            pareto: None,
            faults: FaultStats::default(),
        };
        assert_eq!(cost_to_quality(&run, 0.9), Some((3.0, 30.0)));
        assert_eq!(cost_to_quality(&run, 0.5), Some((2.0, 20.0)));
        assert_eq!(cost_to_quality(&run, 0.99), None);
    }
}
