//! Fault injection for the live execution spine: stacking [`JobLauncher`]
//! decorators that reproduce the transient-cloud failure modes TrimTuner's
//! cost accounting has to survive — spot preemption with partial-cost
//! charging and bid-driven dynamic pricing (SpotTune, arxiv 2012.03576),
//! heavy-tailed stragglers (Scavenger, arxiv 2303.06659), transient launch
//! failures, and per-probe deadlines.
//!
//! Every decorator draws its fault decisions from a seeded RNG keyed by
//! (fault seed, decorator salt, job id) — the same scheme `SimLauncher`'s
//! observation noise uses — so a fault trace is a pure function of the
//! submitted job ids, identical across worker counts and replays, and
//! never a function of thread timing (detlint R3). Retries carry fresh ids
//! ([`job_ids::retry`]), so each attempt redraws its fate independently.
//!
//! Zero-valued parameters are exact pass-throughs: a `PreemptingLauncher`
//! at rate 0 (or a `StragglerLauncher` at severity 0) forwards the inner
//! result bit-for-bit, which `tests/fault_parity.rs` pins against the bare
//! launcher.

use super::launcher::{job_ids, Job, JobLauncher, JobResult};
use crate::util::Rng;
use anyhow::{anyhow, bail, ensure, Result};

/// Error payload of a deployment that died *mid-run* (spot preemption,
/// deadline kill). Unlike a launch that never started, the attempt consumed
/// real resources before dying, and §III's accounting still charges the
/// partial snapshot cost: the engine's retry path downcasts launch errors
/// to this type and books `partial_cost`/`partial_duration_s` against the
/// probe even when a later attempt (or no attempt) succeeds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interrupted {
    pub partial_cost: f64,
    pub partial_duration_s: f64,
}

impl std::fmt::Display for Interrupted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "deployment interrupted mid-run after {:.3}s (${:.6} charged)",
            self.partial_duration_s, self.partial_cost
        )
    }
}

impl std::error::Error for Interrupted {}

// Distinct salts keep each decorator's fault stream independent of its
// stack-mates and of the launcher's own observation-noise stream.
const SALT_PREEMPT: u64 = 0x5107_F417;
const SALT_STRAGGLE: u64 = 0x57A6_61E5;
const SALT_FLAKY: u64 = 0xF1A4_7A11;

/// Per-(decorator, job) RNG stream: deterministic in the fault seed and the
/// job id only.
fn fault_rng(seed: u64, salt: u64, job_id: u64) -> Rng {
    Rng::new(seed ^ salt ^ job_id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// A synthetic spot market: per-interval spot prices (as fractions of the
/// on-demand price, which is what the inner launcher charges) driving
/// SpotTune-style dynamic cost and bid-based preemption. A deployment walks
/// the trace from a per-job offset, accruing spot-priced cost interval by
/// interval; the first interval pricing above the campaign's `bid` kills it
/// with the cost accrued so far.
#[derive(Debug, Clone, PartialEq)]
pub struct SpotMarket {
    /// spot price per interval, relative to on-demand (1.0 = parity)
    pub prices: Vec<f64>,
    /// seconds of deployment time each interval covers
    pub interval_s: f64,
    /// an interval pricing strictly above this preempts the run
    pub bid: f64,
}

impl SpotMarket {
    /// Deterministic synthetic trace: a diurnal sine plus a faster harmonic
    /// around `mean`, clipped positive — enough structure that different
    /// per-job offsets see genuinely different price regimes.
    pub fn synthetic(
        len: usize,
        mean: f64,
        amplitude: f64,
        interval_s: f64,
        bid: f64,
    ) -> SpotMarket {
        assert!(len > 0 && interval_s > 0.0);
        let prices = (0..len)
            .map(|i| {
                let t = i as f64 / len as f64 * std::f64::consts::TAU;
                (mean + amplitude * (t.sin() + 0.4 * (3.0 * t).sin())).max(0.01)
            })
            .collect();
        SpotMarket { prices, interval_s, bid }
    }
}

/// Spot preemption: kills a seeded fraction of deployments mid-run, still
/// charging the pro-rata partial cost ([`Interrupted`]). Two modes:
///
/// * **rate mode** (`new`): each attempt is preempted with probability
///   `rate`, at a uniform fraction of its runtime;
/// * **market mode** (`with_market`): a [`SpotMarket`] trace drives both
///   the (discounted) per-interval cost and the preemption point — the
///   first interval above the bid kills the run.
///
/// With `on_demand_fallback` (the SpotTune policy, default in market mode)
/// retries — recognizable by their [`job_ids`] marker — run on-demand:
/// full inner price, immune to preemption.
pub struct PreemptingLauncher {
    inner: Box<dyn JobLauncher>,
    seed: u64,
    rate: f64,
    market: Option<SpotMarket>,
    on_demand_fallback: bool,
}

impl PreemptingLauncher {
    pub fn new(inner: Box<dyn JobLauncher>, seed: u64, rate: f64) -> PreemptingLauncher {
        assert!((0.0..=1.0).contains(&rate), "preemption rate must be in [0,1]");
        PreemptingLauncher { inner, seed, rate, market: None, on_demand_fallback: false }
    }

    pub fn with_market(
        inner: Box<dyn JobLauncher>,
        seed: u64,
        market: SpotMarket,
    ) -> PreemptingLauncher {
        PreemptingLauncher { inner, seed, rate: 0.0, market: Some(market), on_demand_fallback: true }
    }

    pub fn with_fallback(mut self, on: bool) -> PreemptingLauncher {
        self.on_demand_fallback = on;
        self
    }
}

impl JobLauncher for PreemptingLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        let r = self.inner.launch(job)?;
        if self.on_demand_fallback && job_ids::is_retry(job.id) {
            // fallback: after a spot kill the retry runs on-demand — full
            // inner price, immune to preemption
            return Ok(r);
        }
        let mut rng = fault_rng(self.seed, SALT_PREEMPT, job.id);
        match &self.market {
            None => {
                if self.rate > 0.0 && rng.f64() < self.rate {
                    // killed a uniform fraction into the run; the dead
                    // attempt's pro-rata cost is still charged
                    let frac = rng.f64();
                    return Err(anyhow::Error::new(Interrupted {
                        partial_cost: r.charged_cost * frac,
                        partial_duration_s: r.duration_s * frac,
                    }));
                }
                Ok(r)
            }
            Some(m) => {
                let start = rng.below(m.prices.len());
                let rate_per_s =
                    if r.duration_s > 0.0 { r.charged_cost / r.duration_s } else { 0.0 };
                let (mut t, mut cost, mut k) = (0.0f64, 0.0f64, 0usize);
                while t < r.duration_s {
                    let price = m.prices[(start + k) % m.prices.len()];
                    if price > m.bid {
                        return Err(anyhow::Error::new(Interrupted {
                            partial_cost: cost,
                            partial_duration_s: t,
                        }));
                    }
                    let span = m.interval_s.min(r.duration_s - t);
                    cost += rate_per_s * span * price;
                    t += span;
                    k += 1;
                }
                Ok(JobResult { charged_cost: cost, ..r })
            }
        }
    }
}

// Straggler tail shape: Pareto(α) with a cap so a single sample cannot
// dominate an entire campaign's wall-clock.
const STRAGGLE_ALPHA: f64 = 1.5;
const STRAGGLE_CAP: f64 = 20.0;

/// Heavy-tailed latency multipliers: each deployment's duration is scaled
/// by `1 + severity · (P − 1)` where `P` is a capped Pareto(α=1.5) sample —
/// most jobs are barely slowed, a seeded few take many times longer (the
/// classic straggler profile). Costs are untouched: the work is the same,
/// the worker is just slow. It is the interplay with per-probe deadlines
/// (`RetryPolicy` or [`TimeoutLauncher`]) that turns a straggler into a
/// charged fault.
pub struct StragglerLauncher {
    inner: Box<dyn JobLauncher>,
    seed: u64,
    severity: f64,
}

impl StragglerLauncher {
    pub fn new(inner: Box<dyn JobLauncher>, seed: u64, severity: f64) -> StragglerLauncher {
        assert!(severity >= 0.0, "straggler severity must be non-negative");
        StragglerLauncher { inner, seed, severity }
    }

    /// The multiplier applied to `job_id`'s duration — exposed so tests can
    /// assert the exact trace.
    pub fn multiplier(seed: u64, job_id: u64, severity: f64) -> f64 {
        if severity <= 0.0 {
            return 1.0;
        }
        let mut rng = fault_rng(seed, SALT_STRAGGLE, job_id);
        let pareto = (1.0 - rng.f64()).powf(-1.0 / STRAGGLE_ALPHA).min(STRAGGLE_CAP);
        1.0 + severity * (pareto - 1.0)
    }
}

impl JobLauncher for StragglerLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        let mut r = self.inner.launch(job)?;
        let m = StragglerLauncher::multiplier(self.seed, job.id, self.severity);
        if m != 1.0 {
            r.duration_s *= m;
        }
        Ok(r)
    }
}

/// Transient launch failures: with probability `rate` per attempt —
/// deterministic per (seed, job id), so a retry's fresh id redraws — the
/// launch fails *before* any resources are consumed (API error, capacity
/// shortage). No cost is charged; the engine's `RetryPolicy` absorbs these
/// unless the budget runs out.
pub struct FlakyLauncher {
    inner: Box<dyn JobLauncher>,
    seed: u64,
    rate: f64,
}

impl FlakyLauncher {
    pub fn new(inner: Box<dyn JobLauncher>, seed: u64, rate: f64) -> FlakyLauncher {
        assert!((0.0..=1.0).contains(&rate), "flaky rate must be in [0,1]");
        FlakyLauncher { inner, seed, rate }
    }
}

impl JobLauncher for FlakyLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        if self.rate > 0.0 {
            let mut rng = fault_rng(self.seed, SALT_FLAKY, job.id);
            if rng.f64() < self.rate {
                bail!("transient launch failure injected (job {})", job.id);
            }
        }
        self.inner.launch(job)
    }
}

/// Launcher-side per-probe deadline: a deployment that would run longer
/// than `deadline_s` is killed at the deadline with its pro-rata cost
/// charged ([`Interrupted`]). `RetryPolicy::probe_deadline_s` expresses the
/// same policy at the engine's retry layer; this decorator exists for
/// launcher stacks that should time out below the engine (e.g. under a
/// straggler decorator, before the pool reports a result).
pub struct TimeoutLauncher {
    inner: Box<dyn JobLauncher>,
    deadline_s: f64,
}

impl TimeoutLauncher {
    pub fn new(inner: Box<dyn JobLauncher>, deadline_s: f64) -> TimeoutLauncher {
        assert!(deadline_s > 0.0, "deadline must be positive");
        TimeoutLauncher { inner, deadline_s }
    }
}

impl JobLauncher for TimeoutLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        let r = self.inner.launch(job)?;
        if r.duration_s > self.deadline_s {
            let frac = self.deadline_s / r.duration_s;
            return Err(anyhow::Error::new(Interrupted {
                partial_cost: r.charged_cost * frac,
                partial_duration_s: self.deadline_s,
            }));
        }
        Ok(r)
    }
}

/// Parsed `--faults` specification: comma-separated `kind:value` tokens
/// (`spot:RATE`, `straggle:SEVERITY`, `flaky:RATE`, `timeout:SECONDS`) plus
/// the bare flag `fallback` (retries run on-demand, immune to spot
/// preemption). [`FaultSpec::wrap`] stacks the corresponding decorators
/// around a base launcher.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    pub spot: Option<f64>,
    pub straggle: Option<f64>,
    pub flaky: Option<f64>,
    pub timeout: Option<f64>,
    pub fallback: bool,
    /// programmatic only (no CLI token): trace-driven spot market;
    /// overrides `spot`
    pub market: Option<SpotMarket>,
}

impl FaultSpec {
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            if tok == "fallback" {
                spec.fallback = true;
                continue;
            }
            let (kind, val) = tok
                .split_once(':')
                .ok_or_else(|| anyhow!("fault token `{tok}` is not kind:value"))?;
            let v: f64 = val
                .parse()
                .map_err(|_| anyhow!("fault value `{val}` in `{tok}` is not a number"))?;
            match kind {
                "spot" => {
                    ensure!((0.0..=1.0).contains(&v), "spot rate must be in [0,1]");
                    spec.spot = Some(v);
                }
                "straggle" => {
                    ensure!(v >= 0.0, "straggle severity must be non-negative");
                    spec.straggle = Some(v);
                }
                "flaky" => {
                    ensure!((0.0..=1.0).contains(&v), "flaky rate must be in [0,1]");
                    spec.flaky = Some(v);
                }
                "timeout" => {
                    ensure!(v > 0.0, "timeout must be positive seconds");
                    spec.timeout = Some(v);
                }
                other => bail!(
                    "unknown fault kind `{other}` (known: spot, straggle, flaky, \
                     timeout, fallback)"
                ),
            }
        }
        Ok(spec)
    }

    pub fn is_empty(&self) -> bool {
        *self == FaultSpec::default()
    }

    /// Stack the configured decorators around `inner`. Order, innermost
    /// first: straggler (shapes the duration every outer layer judges),
    /// timeout, preemption, flaky outermost (a flaky failure consumes no
    /// resources, so nothing below it may run). Decorators configured with
    /// zero-valued parameters are still stacked — they are exact
    /// pass-throughs, so the zero-fault stack stays bit-identical to the
    /// bare launcher.
    pub fn wrap(&self, inner: Box<dyn JobLauncher>, seed: u64) -> Box<dyn JobLauncher> {
        let mut l = inner;
        if let Some(sev) = self.straggle {
            l = Box::new(StragglerLauncher::new(l, seed, sev));
        }
        if let Some(d) = self.timeout {
            l = Box::new(TimeoutLauncher::new(l, d));
        }
        if let Some(m) = &self.market {
            l = Box::new(PreemptingLauncher::with_market(l, seed, m.clone()));
        } else if let Some(rate) = self.spot {
            l = Box::new(PreemptingLauncher::new(l, seed, rate).with_fallback(self.fallback));
        }
        if let Some(rate) = self.flaky {
            l = Box::new(FlakyLauncher::new(l, seed, rate));
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimLauncher;
    use crate::sim::NetKind;
    use crate::space::{Config, S_INIT};

    fn job(id: u64) -> Job {
        Job { id, config: Config::from_id(40), s_levels: S_INIT.to_vec() }
    }

    fn sim() -> Box<dyn JobLauncher> {
        Box::new(SimLauncher::new(NetKind::Mlp, 7))
    }

    #[test]
    fn zero_valued_decorators_pass_through_bit_exact() {
        let bare = SimLauncher::new(NetKind::Mlp, 7);
        let stack = FaultSpec::parse("spot:0,straggle:0,flaky:0")
            .unwrap()
            .wrap(sim(), 0xFA17);
        for id in 0..6u64 {
            let a = bare.launch(&job(id)).unwrap();
            let b = stack.launch(&job(id)).unwrap();
            assert_eq!(a.charged_cost.to_bits(), b.charged_cost.to_bits());
            assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
            for ((sa, oa), (sb, ob)) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(sa, sb);
                assert_eq!(oa.acc.to_bits(), ob.acc.to_bits());
                assert_eq!(oa.cost_usd.to_bits(), ob.cost_usd.to_bits());
            }
        }
    }

    #[test]
    fn preemption_charges_partial_cost_and_is_deterministic() {
        let l = PreemptingLauncher::new(sim(), 3, 1.0);
        let full = sim().launch(&job(5)).unwrap();
        let kill = |l: &PreemptingLauncher| {
            let e = l.launch(&job(5)).expect_err("rate 1.0 must always preempt");
            *e.downcast_ref::<Interrupted>().expect("Interrupted payload")
        };
        let a = kill(&l);
        let b = kill(&l);
        assert_eq!(a, b, "preemption must be deterministic per (seed, id)");
        assert!(a.partial_cost >= 0.0 && a.partial_cost < full.charged_cost);
        assert!(a.partial_duration_s < full.duration_s);
    }

    #[test]
    fn fallback_retries_run_on_demand_and_complete() {
        let l = PreemptingLauncher::new(sim(), 3, 1.0).with_fallback(true);
        assert!(l.launch(&job(5)).is_err(), "primary attempt is spot");
        let retry = Job { id: job_ids::retry(5, 1), ..job(5) };
        let r = l.launch(&retry).expect("fallback retry must not be preempted");
        let full = sim().launch(&retry).unwrap();
        assert_eq!(r.charged_cost.to_bits(), full.charged_cost.to_bits());
    }

    #[test]
    fn straggler_slows_duration_only_with_heavy_tail() {
        let l = StragglerLauncher::new(sim(), 11, 2.0);
        let mut slowed = 0;
        for id in 0..32u64 {
            let base = sim().launch(&job(id)).unwrap();
            let r = l.launch(&job(id)).unwrap();
            assert_eq!(r.charged_cost.to_bits(), base.charged_cost.to_bits());
            assert!(r.duration_s >= base.duration_s);
            if r.duration_s > base.duration_s * 2.0 {
                slowed += 1;
            }
        }
        assert!(slowed > 0, "a severity-2 Pareto tail must produce stragglers");
        assert!(slowed < 32, "not every job may straggle heavily");
        assert_eq!(
            StragglerLauncher::multiplier(11, 4, 0.0),
            1.0,
            "severity 0 is the identity"
        );
    }

    #[test]
    fn timeout_kills_at_deadline_with_prorata_charge() {
        let base = sim().launch(&job(2)).unwrap();
        let l = TimeoutLauncher::new(sim(), base.duration_s * 0.5);
        let e = l.launch(&job(2)).expect_err("deadline at half the runtime");
        let i = e.downcast_ref::<Interrupted>().expect("Interrupted payload");
        assert!((i.partial_duration_s - base.duration_s * 0.5).abs() < 1e-9);
        assert!((i.partial_cost - base.charged_cost * 0.5).abs() < 1e-9);
        let ok = TimeoutLauncher::new(sim(), base.duration_s * 2.0);
        assert!(ok.launch(&job(2)).is_ok(), "deadline above runtime passes");
    }

    #[test]
    fn flaky_failures_are_free_and_redrawn_per_attempt() {
        let l = FlakyLauncher::new(sim(), 5, 1.0);
        let e = l.launch(&job(3)).expect_err("rate 1.0 always fails");
        assert!(e.downcast_ref::<Interrupted>().is_none(), "flaky faults are free");
        // a retry id redraws: at rate < 1 some attempt eventually differs
        let half = FlakyLauncher::new(sim(), 5, 0.5);
        let fates: Vec<bool> = (1..=16)
            .map(|a| half.launch(&Job { id: job_ids::retry(3, a), ..job(3) }).is_ok())
            .collect();
        assert!(fates.iter().any(|&ok| ok) && fates.iter().any(|&ok| !ok));
    }

    #[test]
    fn market_walk_prices_and_preempts_by_bid() {
        // trace entirely below the bid: completes at a discount
        let cheap = SpotMarket { prices: vec![0.4; 8], interval_s: 1e9, bid: 1.0 };
        let l = PreemptingLauncher::with_market(sim(), 9, cheap);
        let base = sim().launch(&job(1)).unwrap();
        let r = l.launch(&job(1)).unwrap();
        assert!((r.charged_cost - base.charged_cost * 0.4).abs() < 1e-9);
        assert_eq!(r.duration_s.to_bits(), base.duration_s.to_bits());
        // trace entirely above the bid: preempted at t = 0 with zero cost
        let hostile = SpotMarket { prices: vec![2.0; 8], interval_s: 1e9, bid: 1.0 };
        let l = PreemptingLauncher::with_market(sim(), 9, hostile).with_fallback(false);
        let e = l.launch(&job(1)).expect_err("bid below every price");
        let i = e.downcast_ref::<Interrupted>().unwrap();
        assert_eq!((i.partial_cost, i.partial_duration_s), (0.0, 0.0));
    }

    #[test]
    fn spec_parses_round_trip_and_rejects_garbage() {
        let s = FaultSpec::parse("spot:0.3, straggle:2.0,flaky:0.1,timeout:600,fallback")
            .unwrap();
        assert_eq!(s.spot, Some(0.3));
        assert_eq!(s.straggle, Some(2.0));
        assert_eq!(s.flaky, Some(0.1));
        assert_eq!(s.timeout, Some(600.0));
        assert!(s.fallback && !s.is_empty());
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert!(FaultSpec::parse("spot").is_err());
        assert!(FaultSpec::parse("spot:1.5").is_err());
        assert!(FaultSpec::parse("chaos:0.5").is_err());
        assert!(FaultSpec::parse("straggle:-1").is_err());
    }

    #[test]
    fn synthetic_market_is_positive_and_deterministic() {
        let a = SpotMarket::synthetic(48, 0.4, 0.5, 60.0, 0.8);
        let b = SpotMarket::synthetic(48, 0.4, 0.5, 60.0, 0.8);
        assert_eq!(a, b);
        assert!(a.prices.iter().all(|&p| p > 0.0));
        assert!(a.prices.iter().any(|&p| p > a.bid), "some interval must preempt");
        assert!(a.prices.iter().any(|&p| p < a.bid), "some interval must run");
    }
}
