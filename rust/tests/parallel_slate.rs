//! Integration tests for the batched + parallel acquisition pipeline:
//! the parallel slate evaluator must return bit-identical results to the
//! sequential path for every filtering heuristic and both surrogate
//! families, and every optimizer must still run end-to-end.

use trimtuner::acq::{
    joint_feasibility_many, trimtuner_alpha, EntropyEstimator, Models,
    TrimTunerAcq,
};
use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::heuristics::{select_next, AlphaCache, FilterKind};
use trimtuner::models::{Feat, FitOptions, ModelKind, Surrogate};
use trimtuner::sim::{CloudSim, Dataset, NetKind};
use trimtuner::space::{all_points, encode, Config, Constraint, Point};
use trimtuner::util::Rng;

fn fitted(kind: ModelKind) -> (Models, Vec<Constraint>, Vec<Point>) {
    let sim = CloudSim::new(NetKind::Mlp);
    let mut rng = Rng::new(17);
    let mut pts = Vec::new();
    let mut outs = Vec::new();
    for _ in 0..20 {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        pts.push(p);
        outs.push(sim.observe(&p, &mut rng));
    }
    let mut m = Models::new(kind, 3);
    m.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
    let tested: std::collections::HashSet<usize> =
        pts.iter().map(|p| p.id()).collect();
    // a slice of the grid keeps the NoFilter sweep fast while still
    // exercising hundreds of candidates
    let untested: Vec<Point> = all_points()
        .filter(|p| !tested.contains(&p.id()))
        .take(220)
        .collect();
    (m, vec![Constraint::cost_max(0.06)], untested)
}

#[test]
fn parallel_slate_bit_identical_for_every_filter_and_model() {
    for kind in [ModelKind::Gp, ModelKind::Trees] {
        let (models, constraints, untested) = fitted(kind);
        let full_feats: Vec<Feat> = (0..288)
            .map(|id| {
                encode(&Point { config: Config::from_id(id), s_idx: 4 })
            })
            .collect();
        let mut rng = Rng::new(5);
        let rep: Vec<Feat> = (0..12).map(|i| full_feats[i * 23]).collect();
        let est = EntropyEstimator::new(rep, 60, &mut rng);
        let baseline = EntropyEstimator::kl_from_uniform(
            &est.p_opt(models.acc.as_ref()),
        );
        let shortlist: Vec<usize> = (0..288).step_by(12).collect();
        let shortlist_feats: Vec<Feat> =
            shortlist.iter().map(|&id| full_feats[id]).collect();
        let feas =
            joint_feasibility_many(&models, &constraints, &shortlist_feats);
        let ctx = TrimTunerAcq {
            models: &models,
            est: &est,
            constraints: &constraints,
            inc_shortlist: &shortlist,
            inc_shortlist_feats: &shortlist_feats,
            inc_feas: if models.constraints_fixed_under_condition() {
                Some(feas.as_slice())
            } else {
                None
            },
            baseline,
        };
        for filter in [
            FilterKind::Cea,
            FilterKind::RandomFilter,
            FilterKind::NoFilter,
            FilterKind::Direct,
            FilterKind::Cmaes,
        ] {
            let run = |threads: usize| {
                let mut rng = Rng::new(99);
                let mut alpha = AlphaCache::shared(|p: &Point| {
                    trimtuner_alpha(&ctx, &encode(p))
                })
                .with_threads(threads);
                let (chosen, evals) = select_next(
                    filter,
                    &models,
                    &constraints,
                    &untested,
                    24,
                    &mut alpha,
                    &mut rng,
                );
                (chosen.id(), evals)
            };
            let seq = run(1);
            let par = run(4);
            assert_eq!(
                seq, par,
                "{kind:?}/{filter:?}: parallel (chosen, n_evals) diverged"
            );
        }
    }
}

#[test]
fn batched_predict_many_is_bitwise_scalar_for_all_surrogates() {
    for kind in [ModelKind::Gp, ModelKind::Trees] {
        let (models, _, untested) = fitted(kind);
        let xs: Vec<Feat> = untested.iter().take(64).map(encode).collect();
        for model in
            [models.acc.as_ref(), models.cost.as_ref(), models.time.as_ref()]
        {
            let batch = model.predict_many(&xs);
            for (x, (bm, bs)) in xs.iter().zip(&batch) {
                let (m, s) = model.predict(x);
                assert_eq!(m.to_bits(), bm.to_bits(), "{kind:?} mean");
                assert_eq!(s.to_bits(), bs.to_bits(), "{kind:?} std");
            }
        }
    }
}

#[test]
fn every_optimizer_smokes_end_to_end() {
    let dataset = Dataset::generate(NetKind::Mlp, 42);
    let caps = vec![Constraint::cost_max(NetKind::Mlp.paper_cost_cap())];
    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Gp),
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
        OptimizerKind::Fabolas,
        OptimizerKind::RandomSearch,
    ] {
        let mut cfg = EngineConfig::paper_default(optimizer, 11);
        cfg.max_iters = 3;
        // shrink the entropy machinery so the GP variants stay fast
        cfg.n_rep = 10;
        cfg.n_popt_samples = 40;
        cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
        let run = engine::run(&dataset, &caps, &cfg);
        assert_eq!(
            run.records.len(),
            4 + 3,
            "{optimizer:?}: unexpected record count"
        );
        for r in &run.records {
            assert!(r.incumbent.is_full(), "{optimizer:?}: partial incumbent");
            assert!(r.outcome.acc.is_finite());
        }
    }
}
