// R2 allow: the NaN-total comparators from util, plus one pragma'd site
// whose inputs are proven finite by the caller.
use crate::util::stats::cmp_nan_high;

fn rank(xs: &mut [(usize, f64)]) {
    xs.sort_by(|a, b| cmp_nan_high(a.1, b.1));
}

fn ordering(a: f64, b: f64) -> std::cmp::Ordering {
    // detlint: allow(R2, reason="caller guarantees finite inputs")
    a.partial_cmp(&b).unwrap()
}
