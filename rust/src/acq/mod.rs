//! Acquisition functions (paper §II–III): EI, constrained EI (CherryPick),
//! EIc/USD (Lynceus), Entropy-Search machinery (p_opt / information gain),
//! FABOLAS, and TrimTuner's constrained sub-sampling-aware α_T.

mod ei;
mod entropy;
mod fabolas;
mod models;
mod trimtuner;

pub use ei::{ei, eic, eic_usd};
pub use entropy::EntropyEstimator;
pub use fabolas::fabolas_alpha;
pub use models::{feasibility_prob, joint_feasibility, select_incumbent, select_incumbent_from, Incumbent, Models, FEAS_THRESHOLD, FEAS_THRESHOLD_HYST};
pub use trimtuner::{trimtuner_alpha, TrimTunerAcq};
