//! Cloud-training simulator — the evaluation substrate.
//!
//! The paper's evaluation is trace-driven: the authors trained 3 neural
//! networks (CNN / MLP / RNN on MNIST, distributed TensorFlow) on a 1440
//! point grid of AWS configurations (~$1200, ~2 months) and replayed the
//! resulting lookup tables inside the optimizers. We cannot re-run AWS, so
//! [`CloudSim`] is a parametric generative model of that measurement
//! campaign (DESIGN.md §1, substitution table):
//!
//! - **accuracy** follows an inverse-power-law learning curve in the number
//!   of training samples `n = s · 60000`, with hyper-parameter effects
//!   (learning-rate sweet spot, batch-size penalty, asynchrony staleness
//!   growing with worker count, large-effective-batch penalty);
//! - **time** decomposes into startup + compute (scaled by fleet size and
//!   per-vCPU speed with burstable-instance sub-linearity) + communication
//!   (per-step synchronization barriers, worse for sync mode, small batches
//!   and large fleets);
//! - **cost** = time × #VMs × on-demand price.
//!
//! Three "networks" are three calibrated parameter sets whose feasibility
//! structure under the paper's cost caps reproduces Table II's bands.
//! [`Dataset`] materializes the full grid (3 noisy repetitions averaged,
//! like the paper) for replay by the optimizers.

mod dataset;
mod oracle;

pub use dataset::*;
pub use oracle::*;
