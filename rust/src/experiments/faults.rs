//! Transient-cloud robustness experiment (not a paper figure): the same
//! live tuning campaign run clean and under a fault cocktail (spot
//! preemptions + stragglers + flaky launches), comparing incumbent-cost
//! trajectories. Demonstrates graceful degradation: abandoned probes are
//! charged their partial cost and the campaign re-plans around the holes
//! instead of aborting.
//!
//! `trimtuner repro faults [--seeds 3] [--iters 20]`

use super::ExpOptions;
use crate::coordinator::{FaultSpec, SimLauncher};
use crate::engine::{
    self, EngineConfig, EvalBackend, LiveEval, OptimizerKind, RetryPolicy,
    RunResult,
};
use crate::models::ModelKind;
use crate::sim::{Dataset, NetKind};
use crate::space::Constraint;
use crate::util::csv::CsvWriter;
use anyhow::Result;

const FAULT_COCKTAIL: &str = "spot:0.25,straggle:2.0,flaky:0.15";
const FAULT_SEED_SALT: u64 = 0xFA17;

fn live_run(
    dataset: &Dataset,
    caps: &[Constraint],
    cfg: &EngineConfig,
    seed: u64,
    faults: &FaultSpec,
) -> Result<RunResult> {
    let net = dataset.net;
    let base: Box<dyn crate::coordinator::JobLauncher> =
        Box::new(SimLauncher::with_options(net, seed ^ 0x11FE, 1.0, 0.0));
    let launcher = faults.wrap(base, seed ^ FAULT_SEED_SALT);
    let retry = RetryPolicy { max_retries: 2, ..RetryPolicy::default() };
    let mut backend = EvalBackend::Live(
        LiveEval::new(launcher, 4)
            .with_eval(dataset)
            .with_retry(retry, seed ^ 0xB0FF),
    );
    let run = engine::run_backend(&mut backend, caps, cfg)?;
    backend.shutdown();
    Ok(run)
}

pub fn faults(opts: &ExpOptions) -> Result<()> {
    println!("== Fault injection: clean vs transient cloud (RNN, TrimTuner-DT) ==");
    let net = NetKind::Rnn;
    let dataset = Dataset::generate(net, opts.dataset_seed);
    let caps = [Constraint::cost_max(net.paper_cost_cap())];
    let seeds = opts.seeds.min(if opts.full { 10 } else { 3 });
    let iters = opts.max_iters.min(if opts.full { 44 } else { 20 });
    let faulty_spec = FaultSpec::parse(FAULT_COCKTAIL)?;

    let mut w = CsvWriter::create(
        format!("{}/faults_{}.csv", opts.out_dir, net.name()),
        &[
            "variant",
            "seed",
            "iter",
            "cum_cost",
            "accuracy_c",
            "n_abandoned",
            "wasted_cost",
        ],
    )?;
    w.comment(&format!(
        "clean vs `{FAULT_COCKTAIL}` (retry max=2), {seeds} seeds x {iters} probes"
    ))?;

    for (variant, spec) in
        [("clean", FaultSpec::default()), ("faulty", faulty_spec)]
    {
        let mut finals = Vec::new();
        let mut costs = Vec::new();
        let mut abandoned = 0usize;
        let mut wasted = 0.0;
        for seed in 0..seeds {
            let mut cfg = EngineConfig::paper_default(
                OptimizerKind::TrimTuner(ModelKind::Trees),
                seed as u64,
            );
            cfg.max_iters = iters;
            cfg.batch_size = 2;
            let run = live_run(&dataset, &caps, &cfg, seed as u64, &spec)?;
            for r in &run.records {
                w.row(&[
                    variant.to_string(),
                    format!("{seed}"),
                    format!("{}", r.iter),
                    format!("{:.6}", r.cum_cost),
                    format!("{:.4}", r.accuracy_c),
                    format!("{}", run.faults.n_abandoned),
                    format!("{:.6}", run.faults.wasted_cost),
                ])?;
            }
            finals.push(run.final_accuracy_c());
            costs.push(run.total_cost());
            abandoned += run.faults.n_abandoned;
            wasted += run.faults.wasted_cost;
        }
        let (acc_m, acc_s) = crate::util::stats::mean_std_pop(&finals);
        let cost_m = crate::util::stats::mean(&costs);
        println!(
            "  {variant:<7} final Acc_C {acc_m:.4}±{acc_s:.4}  explored ${cost_m:.4}  \
             abandoned {abandoned} probes (${wasted:.4} wasted)"
        );
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_experiment_writes_csv() {
        let dir = std::env::temp_dir().join("trimtuner_faults_exp_test");
        std::fs::create_dir_all(&dir).unwrap();
        let opts = ExpOptions {
            out_dir: dir.to_str().unwrap().to_string(),
            seeds: 1,
            max_iters: 4,
            dataset_seed: 42,
            full: false,
        };
        faults(&opts).unwrap();
        let t = crate::util::csv::CsvTable::read(
            dir.join("faults_rnn.csv"),
        )
        .unwrap();
        assert_eq!(t.header[0], "variant");
        assert!(!t.rows.is_empty());
        // both variants made it into the series
        assert!(t.rows.iter().any(|r| r[0] == "clean"));
        assert!(t.rows.iter().any(|r| r[0] == "faulty"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
