//! Paper Algorithm 1 and the baseline optimizers, driven through an
//! [`EvalBackend`]: trace replay over a [`Dataset`] (exactly the paper's
//! simulation methodology: every "Train M in configuration ⟨x,s⟩" is a
//! lookup of the measured outcome) or live deployments through the
//! threaded coordinator.

use super::backend::{EvalBackend, Probe, ProbeResult, ProbeTicket};
use super::metrics::{accuracy_c, IterRecord, RunResult};
use super::pareto::recommend_pareto;
use crate::acq::{
    eic, eic_usd, fabolas_alpha, joint_feasibility_many, select_incumbent,
    AlphaSlate, EntropyEstimator, Models, TrimTunerAcq,
};
use crate::coordinator::EventKind;
use crate::heuristics::{
    cea_scores_feats, cea_scores_feats_with_feas, select_slate, AlphaCache,
    FilterKind,
};
use crate::models::{Feat, FitOptions, ModelKind};
use crate::opt::latin_hypercube;
use crate::sim::{Dataset, Outcome};
use crate::space::{
    encode, nearest_point, Config, Constraint, Point, N_CONFIGS, N_POINTS,
    S_INIT, S_VALUES,
};
use crate::util::stats::cmp_nan_low;
use crate::util::timer::Timer;
use crate::util::Rng;
use anyhow::Result;
use std::collections::{HashSet, VecDeque};

/// Which optimizer to run (paper §IV "Baselines").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// TrimTuner with GP or decision-tree surrogates (the contribution).
    TrimTuner(ModelKind),
    /// Constrained EI over full-data-set configs (CherryPick).
    Eic,
    /// Constrained EI per dollar (Lynceus).
    EicUsd,
    /// FABOLAS: sub-sampling-aware, constraint-oblivious.
    Fabolas,
    /// Uniform random over full-data-set configs.
    RandomSearch,
}

impl OptimizerKind {
    pub fn name(&self) -> String {
        match self {
            OptimizerKind::TrimTuner(k) => format!("trimtuner-{}", k.name()),
            OptimizerKind::Eic => "eic".into(),
            OptimizerKind::EicUsd => "eic-usd".into(),
            OptimizerKind::Fabolas => "fabolas".into(),
            OptimizerKind::RandomSearch => "random".into(),
        }
    }

    pub fn from_name(s: &str) -> Option<OptimizerKind> {
        match s.to_ascii_lowercase().as_str() {
            "trimtuner-gp" => Some(OptimizerKind::TrimTuner(ModelKind::Gp)),
            "trimtuner-dt" => {
                Some(OptimizerKind::TrimTuner(ModelKind::Trees))
            }
            "eic" => Some(OptimizerKind::Eic),
            "eic-usd" | "eicusd" => Some(OptimizerKind::EicUsd),
            "fabolas" => Some(OptimizerKind::Fabolas),
            "random" => Some(OptimizerKind::RandomSearch),
            _ => None,
        }
    }

    /// Does the optimizer probe sub-sampled configurations?
    pub fn uses_subsampling(&self) -> bool {
        matches!(
            self,
            OptimizerKind::TrimTuner(_) | OptimizerKind::Fabolas
        )
    }

    fn model_kind(&self) -> ModelKind {
        match self {
            OptimizerKind::TrimTuner(k) => *k,
            // baselines use GPs (paper: "We use GPs as base models for both
            // EIc and EIc/USD ... implemented using the George library")
            _ => ModelKind::Gp,
        }
    }
}

/// Engine configuration (paper §IV defaults).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub optimizer: OptimizerKind,
    pub filter: FilterKind,
    /// filtering level β ∈ (0, 1]
    pub beta: f64,
    /// initial samples (4): 1 config × 4 s-levels for sub-sampling
    /// optimizers, 4 LHS full-data-set configs otherwise
    pub init_samples: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// representative-set size for p_opt
    pub n_rep: usize,
    /// Monte-Carlo samples for p_opt
    pub n_popt_samples: usize,
    /// when to pay for a *full* surrogate refit (GP hyper-parameter
    /// re-optimization + tree structural rebuild) versus incremental
    /// observation absorption — one refit per selection round, so with
    /// `batch_size` = 1 the cadence counts iterations (the paper's
    /// cadence). CLI: `--refit every=K,evidence-drop=X`.
    pub refit: RefitPolicy,
    /// GP hyper-parameter posterior samples (FABOLAS-style marginalization;
    /// 1 = plain ML-II as used by the EIc baselines)
    pub gp_hyper_samples: usize,
    /// adaptive stop condition evaluated after every selection round, in
    /// addition to `max_iters` (paper §III extension)
    pub stop: super::stop::StopCondition,
    /// also compute the predicted (cost, accuracy) Pareto frontier under
    /// the final models (`RunResult::pareto`, paper §V future work)
    pub pareto: bool,
    /// probes submitted concurrently per selection round (q). 1 — the
    /// default — reproduces the paper's strictly sequential Algorithm 1
    /// bit-exactly; q > 1 selects the top-q acquisition slate (diversified
    /// per [`BatchMode`]), launches it through the worker pool in one
    /// batch, absorbs the results in submission order and refits once.
    /// Ignored when `async_mode` is set — the async scheduler derives its
    /// parallelism from pool occupancy instead.
    pub batch_size: usize,
    /// how picks 2..q of a round's slate are diversified (defaults to the
    /// `TRIMTUNER_BATCH` environment variable, see [`BatchMode::from_env`]).
    /// The async scheduler reuses the same mode to condition each new pick
    /// on the in-flight probes.
    pub batch_mode: BatchMode,
    /// drop the round barrier: re-enter selection the moment any pool slot
    /// frees, conditioning on *all* in-flight probes, and absorb
    /// completions in logical (submission) order so the trajectory is
    /// bitwise independent of physical completion order. CLI: `--async`.
    pub async_mode: bool,
    /// pin the async scheduler's occupancy target (the number of in-flight
    /// probes it keeps saturated). `None` — the default — adapts to the
    /// backend: the live pool's worker count, or 1 under replay. Pinning
    /// it decouples the logical trajectory from the physical pool width
    /// (the determinism suite runs the same target over 1 and 4 workers).
    /// CLI: `--max-inflight`.
    pub max_inflight: Option<usize>,
}

impl EngineConfig {
    pub fn paper_default(optimizer: OptimizerKind, seed: u64) -> Self {
        EngineConfig {
            optimizer,
            filter: match optimizer {
                OptimizerKind::Fabolas => FilterKind::Direct,
                OptimizerKind::TrimTuner(_) => FilterKind::Cea,
                _ => FilterKind::NoFilter,
            },
            beta: 0.10,
            init_samples: 4,
            max_iters: 44,
            seed,
            n_rep: 40,
            n_popt_samples: 160,
            refit: RefitPolicy::paper_default(),
            gp_hyper_samples: match optimizer {
                // the sub-sampling ES optimizers marginalize GP hypers
                // (FABOLAS uses emcee); EIc/EIc-USD use plain ML-II GPs.
                OptimizerKind::TrimTuner(_) | OptimizerKind::Fabolas => 8,
                _ => 1,
            },
            stop: super::stop::StopCondition::Never,
            pareto: false,
            batch_size: 1,
            batch_mode: BatchMode::from_env(),
            async_mode: false,
            max_inflight: None,
        }
    }
}

/// How a round's pending slate picks condition the next pick (batched
/// Bayesian optimization needs the q-th pick to know about the q−1 probes
/// already in flight, or the slate degenerates into q near-duplicates of
/// the α-argmax).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Kriging-believer fantasy conditioning (the default): each pending
    /// pick is absorbed as a simulated observation at the surrogate's own
    /// predictive mean ([`Models::condition`] — the same single-root
    /// Gauss–Hermite collapse α_T's simulated refit uses), and the next
    /// pick maximizes α under the conditioned bundle.
    Fantasy,
    /// Constant liar: pending picks are absorbed at a fixed lie — the best
    /// *observed* accuracy so far (CL-max) — via
    /// [`Models::condition_with_acc`]. Cheaper-to-reason-about fallback
    /// when fantasy conditioning misbehaves.
    ConstantLiar,
    /// No conditioning: the slate is the ranked top-q of one α sweep
    /// ([`crate::heuristics::select_slate`]). Cheapest, but the picks may
    /// cluster; kept for A/B runs and benches.
    TopQ,
}

impl BatchMode {
    /// `TRIMTUNER_BATCH=liar` selects [`BatchMode::ConstantLiar`],
    /// `TRIMTUNER_BATCH=topq` the unconditioned ranked slate; anything
    /// else (or unset) is the fantasy default.
    pub fn from_env() -> BatchMode {
        match std::env::var("TRIMTUNER_BATCH") {
            Ok(v) if v.eq_ignore_ascii_case("liar") => {
                BatchMode::ConstantLiar
            }
            Ok(v) if v.eq_ignore_ascii_case("topq") => BatchMode::TopQ,
            _ => BatchMode::Fantasy,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BatchMode::Fantasy => "fantasy",
            BatchMode::ConstantLiar => "liar",
            BatchMode::TopQ => "topq",
        }
    }
}

/// When the engine pays for a *full* surrogate refit (GP hyper-parameter
/// re-optimization + tree structural rebuild, `fit(hyperopt: true)`)
/// versus the amortized-O(n²) incremental absorption
/// ([`Models::absorb`]). Full rounds recompute everything from the
/// complete history, so any structural or hyper-parameter staleness the
/// cheap rounds accumulate is bounded by `every` rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RefitPolicy {
    /// full refit every k selection rounds. 1 — the default — is the
    /// paper's cadence: hyper-parameters move after every round and
    /// incremental absorption never kicks in, reproducing the historic
    /// trajectories bit-exactly; k > 1 amortizes the O(n³) fit tax over k
    /// rounds of O(n²) absorption. 0 disables the cadence entirely
    /// (hyper-parameters stay at their initial fit).
    pub every: usize,
    /// additionally trigger a full refit when the mean predictive surprise
    /// (negative log predictive density of a round's fresh accuracy
    /// observations under the pre-absorb accuracy model, nats per
    /// observation) exceeds the running baseline by more than this —
    /// evidence that the frozen hyper-parameters stopped explaining new
    /// data. 0 (the default) disables the trigger.
    pub evidence_drop: f64,
    /// absorption mechanics on non-full rounds (defaults to the
    /// `TRIMTUNER_REFIT` environment hatch, see [`RefitMode::from_env`])
    pub mode: RefitMode,
}

impl RefitPolicy {
    pub fn paper_default() -> RefitPolicy {
        RefitPolicy {
            every: 1,
            evidence_drop: 0.0,
            mode: RefitMode::from_env(),
        }
    }

    /// Parse the CLI `--refit every=K,evidence-drop=X` spec (either key
    /// may be omitted; the other keeps its paper default).
    pub fn parse(spec: &str) -> Result<RefitPolicy> {
        let mut p = RefitPolicy::paper_default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("--refit: `{part}` is not key=value")
            })?;
            match key.trim() {
                "every" => {
                    p.every = val.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--refit: bad every `{val}`")
                    })?;
                }
                "evidence-drop" => {
                    p.evidence_drop = val.trim().parse().map_err(|_| {
                        anyhow::anyhow!("--refit: bad evidence-drop `{val}`")
                    })?;
                }
                other => {
                    anyhow::bail!("--refit: unknown key `{other}`")
                }
            }
        }
        Ok(p)
    }

    /// Is `round_idx` (0-based) a scheduled full-refit round?
    pub fn full_due(&self, round_idx: usize) -> bool {
        self.every > 0 && round_idx % self.every == 0
    }

    /// The full-refit decision for one round: the scheduled cadence, OR
    /// the evidence-drop trigger — the round's surprise exceeded the
    /// post-refit baseline by more than `evidence_drop` nats. Pure, so the
    /// trigger logic is unit-testable without running campaigns.
    pub fn full_refit(
        &self,
        round_idx: usize,
        surprise: Option<f64>,
        baseline: Option<f64>,
    ) -> bool {
        if self.full_due(round_idx) {
            return true;
        }
        match (surprise, baseline) {
            (Some(s), Some(b)) => {
                self.evidence_drop > 0.0 && s - b > self.evidence_drop
            }
            _ => false,
        }
    }
}

/// Which mechanics the rounds that skip the full refit use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RefitMode {
    /// Amortized incremental absorption (the default): O(n²) factor
    /// growth per GP hyper component, one leaf-statistic fold per tree.
    Incremental,
    /// From-scratch recomputation of exactly the same frozen-parameter
    /// state ([`Models::refit_frozen`]) — the reference twin the parity
    /// suite (`tests/refit_parity.rs`) pins the incremental path against.
    Full,
}

impl RefitMode {
    /// `TRIMTUNER_REFIT=full` is the escape hatch to from-scratch
    /// frozen-parameter recomputation on every non-full round; anything
    /// else (or unset) is the incremental default.
    pub fn from_env() -> RefitMode {
        match std::env::var("TRIMTUNER_REFIT") {
            Ok(v) if v.eq_ignore_ascii_case("full") => RefitMode::Full,
            _ => RefitMode::Incremental,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RefitMode::Incremental => "incremental",
            RefitMode::Full => "full",
        }
    }
}

/// Per-round acquisition context that is valid as long as the fitted
/// models are unchanged (`Models::generation`): the CEA config ordering,
/// the entropy estimator (representer set + CRN z-matrix) and the
/// current-model p_opt baseline. With q = 1 Algorithm 1 refits after every
/// observation, so the loop rebuilds it every round; with batched probe
/// slates (q > 1) the round's pending-conditioned picks re-enter selection
/// *without* a refit and reuse this context — rebuilding only the cheap
/// derived quantities (conditioned p_opt baseline, conditioned CEA
/// shortlist) per pick.
struct AcqContext {
    generation: u64,
    /// built for the constraint-free (FABOLAS) estimator
    constraint_free: bool,
    /// full-data-set config ids, CEA-descending under the current models
    cea_order: Vec<usize>,
    est: EntropyEstimator,
    /// KL(p_opt ‖ u) of the current accuracy model
    baseline: f64,
    /// joint feasibility of every full-data-set config under the current
    /// constraint models — cached only when conditioning cannot move them
    /// ([`Models::constraints_fixed_under_condition`], tree surrogates).
    /// Pending-conditioned picks in batched rounds then derive their CEA
    /// re-ranking and incumbent-shortlist feasibility from this one
    /// per-refit pass instead of re-predicting the constraint surrogates
    /// over the whole grid per pick.
    full_feas: Option<Vec<f64>>,
}

/// A post-iteration incumbent recommendation. `acc_estimate` is the
/// accuracy figure the recommender itself acted on — model-predicted for
/// the model-based recommenders, *observed* for the observation-based ones.
/// No ground truth is involved, so stop conditions may consume it.
#[derive(Debug, Clone, Copy)]
struct Recommendation {
    point: Point,
    acc_estimate: f64,
    /// true when the estimate had to fall back to a sub-sampled probe of
    /// the config (no full-data-set observation existed yet)
    from_subsample: bool,
}

struct State {
    tested: Vec<Point>,
    outcomes: Vec<Outcome>,
    tested_ids: HashSet<usize>,
    models: Models,
    cum_cost: f64,
    cum_time: f64,
    records: Vec<IterRecord>,
    /// sticky incumbent (recommendation hysteresis): config id at s=1
    incumbent_id: Option<usize>,
}

impl State {
    fn push_observation(&mut self, p: Point, o: Outcome) {
        self.tested.push(p);
        self.outcomes.push(o);
        self.tested_ids.insert(p.id());
    }
}

/// Run one optimizer replaying one dataset (the paper's trace-driven
/// evaluation). Deterministic per (config, seed).
pub fn run(
    dataset: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> RunResult {
    let mut backend = EvalBackend::Replay(dataset);
    run_backend(&mut backend, constraints, cfg)
        .expect("replay evaluation cannot fail")
}

/// Run one optimizer over any evaluation substrate — the same Algorithm 1
/// loop drives trace replay and live (worker-pool) deployments. Only a
/// `Live` backend can return an error, and only for unrecoverable states
/// (pool-level failures, or an initialization whose every deployment was
/// abandoned): main-loop probes that exhaust their retry budget are
/// *abandoned* — partial cost charged, `ProbeAbandoned` logged, the next
/// round re-plans around the hole — and the campaign keeps going.
pub fn run_backend(
    backend: &mut EvalBackend,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> Result<RunResult> {
    let mut rng = Rng::new(cfg.seed);
    // Per-run precomputed context: the full-data-set feature matrix (the
    // incumbent scan's domain) and the feature vector of every grid point,
    // indexed by Point::id(). The grid never changes, so the acquisition
    // closures look features up instead of re-encoding per α evaluation.
    let full_feats: Vec<Feat> = (0..N_CONFIGS)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let grid_feats: Vec<Feat> =
        (0..N_POINTS).map(|id| encode(&Point::from_id(id))).collect();
    // Evaluation-only: the true optimum, when a ground-truth oracle exists
    // (always under replay; optional for live runs).
    let (optimum, optimum_acc) = backend
        .eval_dataset()
        .and_then(|d| d.best_feasible_full(constraints))
        .map(|(p, a)| (Some(p), a))
        .unwrap_or((None, f64::NAN));

    let mut st = State {
        tested: Vec::new(),
        outcomes: Vec::new(),
        tested_ids: HashSet::new(),
        models: Models::with_gp_hyper_samples(
            cfg.optimizer.model_kind(),
            cfg.seed ^ 0x30D,
            cfg.gp_hyper_samples,
        ),
        cum_cost: 0.0,
        cum_time: 0.0,
        records: Vec::new(),
        incumbent_id: None,
    };

    initialize(backend, constraints, cfg, &mut st, &mut rng, &full_feats)?;

    // Acquisition context persisted across selection rounds; rebuilt only
    // when the models were refitted in between. With q = 1 Algorithm 1
    // refits after every observation, so the cache rebuilds every round;
    // with q > 1 the round's q − 1 pending-conditioned picks reuse the
    // round context (representer set, CRN z-matrix, CEA ordering) built by
    // the first pick — the batched-probe payoff the cache was designed for.
    let mut acq_cache: Option<AcqContext> = None;

    if cfg.async_mode {
        run_async_loop(
            backend,
            constraints,
            cfg,
            &mut st,
            &mut rng,
            &full_feats,
            &grid_feats,
            &mut acq_cache,
        )?;
        let pareto = cfg.pareto.then(|| recommend_pareto(&st.models));
        return Ok(RunResult {
            records: st.records,
            optimum_acc,
            optimum,
            pareto,
            faults: backend.fault_stats(),
        });
    }

    // ---------------- main optimization loop (Alg. 1 lines 11-20) --------
    // One *round* selects a slate of up to `batch_size` probes, launches
    // them through the backend in a single batch (concurrent across the
    // worker pool under `Live`), absorbs the results in submission order,
    // refits once, and records one IterRecord per observation. q = 1 is
    // the paper's sequential loop, reproduced bit-exactly.
    // `launched` counts submitted slate entries and bounds the loop (so a
    // campaign terminates even when every probe is abandoned under
    // faults); `iter` indexes *observations* and stays contiguous across
    // records. With no abandonment the two advance in lockstep and the
    // loop is bit-identical to the historic observation-counted one.
    let mut launched = 0;
    let mut iter = 0;
    let mut round = 1; // round 0 is the init batch
    let mut refit_memo = RefitMemo { baseline: None };
    while launched < cfg.max_iters {
        let timer = Timer::start();
        let untested = untested_points(cfg.optimizer, &st.tested_ids);
        if untested.is_empty() {
            break;
        }
        let budget =
            ((cfg.beta * untested.len() as f64).ceil() as usize).max(1);
        let q = cfg
            .batch_size
            .max(1)
            .min(cfg.max_iters - launched)
            .min(untested.len());

        let (slate, n_evals) = choose_slate(
            cfg, constraints, &st, &untested, &full_feats, &grid_feats,
            budget, &mut rng, &mut acq_cache, q,
        );

        let results: Vec<ProbeResult> = backend.probe_slate(&slate)?;
        launched += slate.len();
        // absorb in submission order, tracking the running totals each
        // observation sees (records stay per-observation even when the
        // whole slate was deployed concurrently). Abandoned probes add
        // their partial charge to the running totals but no observation
        // and no record — no phantom observations; the next round simply
        // re-plans around the hole (the abandoned point stays untested
        // and may be re-picked under a fresh job id).
        let mut observed: Vec<(Point, Probe)> = Vec::with_capacity(slate.len());
        let mut cums = Vec::with_capacity(slate.len());
        for (p, res) in slate.iter().zip(&results) {
            match res {
                ProbeResult::Observed(pr) => {
                    st.push_observation(*p, pr.outcome);
                    st.cum_cost += pr.charged_cost;
                    st.cum_time += pr.duration_s;
                    observed.push((*p, *pr));
                    cums.push((st.cum_cost, st.cum_time));
                }
                ProbeResult::Abandoned { charged_cost, duration_s, .. } => {
                    st.cum_cost += charged_cost;
                    st.cum_time += duration_s;
                }
            }
        }
        if observed.is_empty() {
            // the whole round was abandoned: nothing to refit on, no
            // records — and deliberately no stop check. A round that
            // produced zero observations is no evidence of convergence;
            // re-judging StopCondition::NoImprovement on the unchanged
            // window here would let a run of faults masquerade as a
            // plateau.
            round += 1;
            continue;
        }
        // One refit + one recommendation per round (not per observation).
        // The refit cadence counts *rounds*, not observations: gating on
        // the observation index would dilute the configured cadence by the
        // batch factor at q > 1. At q = 1 the round index equals the
        // observation index, preserving the sequential traces.
        let new_from = st.tested.len() - observed.len();
        refit(cfg, &mut st, round - 1, new_from, &mut refit_memo);
        let rec = recommend(cfg.optimizer, &mut st, constraints, &full_feats);
        let rec_wall_s = timer.elapsed_s();

        let n = observed.len();
        for (j, ((p, pr), (cc, ct))) in
            observed.iter().zip(&cums).enumerate()
        {
            let is_last = j + 1 == n;
            push_record(
                &mut st,
                backend,
                constraints,
                RecordArgs {
                    iter,
                    is_init: false,
                    round,
                    tested: *p,
                    outcome: pr.outcome,
                    explore_cost: pr.charged_cost,
                    duration_s: pr.duration_s,
                    cum_cost: *cc,
                    cum_time: *ct,
                    rec_wall_s: if is_last { rec_wall_s } else { 0.0 },
                    rec,
                    n_alpha_evals: if is_last { n_evals } else { 0 },
                    log_events: is_last,
                },
            );
            iter += 1;
        }
        round += 1;
        if cfg.stop.should_stop(&st.records) {
            break;
        }
    }

    let pareto = cfg.pareto.then(|| recommend_pareto(&st.models));
    Ok(RunResult {
        records: st.records,
        optimum_acc,
        optimum,
        pareto,
        faults: backend.fault_stats(),
    })
}

/// The asynchronous (non-barrier) main loop: a continuously-fed scheduler
/// replacing the round structure. The moment a pool slot frees, selection
/// re-enters conditioned on *all* in-flight probes (the same
/// kriging-believer / constant-liar fantasies batched rounds use), submits
/// the single best pick, and keeps the pool saturated at the occupancy
/// target — [`EngineConfig::max_inflight`], or adaptively the pool's
/// worker count.
///
/// Determinism contract (see `docs/ARCHITECTURE.md`, "Asynchronous
/// selection"): completions are absorbed in *logical* (submission) order —
/// the backend's ticket reorder buffer turns physical completion order
/// back into the logical clock — and every selection conditions on the
/// absorbed prefix plus the in-flight picks in submission order. The
/// trajectory is therefore a pure function of the logical order: bitwise
/// identical across worker counts (at a pinned occupancy target), and with
/// a target of 1 it degenerates to exactly the barriered q = 1 sequence —
/// same operations, same RNG draws.
///
/// Per-pick attribution: each absorbed observation gets its own record;
/// `round` is the pick's logical selection index (init = round 0, pick k =
/// round k; an abandoned pick consumes its index without a record, exactly
/// like a barriered round whose whole slate was abandoned), `rec_wall_s`
/// is the wall-clock between consecutive absorptions (summing to the
/// campaign wall — the quantity the async-vs-barrier bench compares), and
/// the refit cadence counts logical picks, so `RefitPolicy` interacts with
/// async runs exactly as it does with sequential ones.
#[allow(clippy::too_many_arguments)]
fn run_async_loop(
    backend: &mut EvalBackend,
    constraints: &[Constraint],
    cfg: &EngineConfig,
    st: &mut State,
    rng: &mut Rng,
    full_feats: &[Feat],
    grid_feats: &[Feat],
    acq_cache: &mut Option<AcqContext>,
) -> Result<()> {
    let target = cfg
        .max_inflight
        .unwrap_or_else(|| backend.pool_width())
        .max(1);
    // in-flight picks in logical submission order: (point, ticket, α
    // evaluations its selection spent)
    let mut inflight: VecDeque<(Point, ProbeTicket, usize)> = VecDeque::new();
    let mut launched = 0usize;
    // main-loop observation index (init records count separately, as in
    // the barriered loop)
    let mut iter = 0usize;
    let mut absorbed = 0usize; // logical pick index of the next absorption
    let mut refit_memo = RefitMemo { baseline: None };
    let mut stopping = false;
    // inter-absorption wall: restarted after every absorption, so each
    // record's rec_wall_s covers the selections + waiting that led to it
    let mut timer = Timer::start();
    loop {
        // (re)fill: one submission per freed slot keeps the pool saturated
        // until the budget runs out or a stop condition fired (then the
        // remaining in-flight picks drain below without new selections)
        while !stopping && launched < cfg.max_iters && inflight.len() < target
        {
            let taken: HashSet<usize> =
                inflight.iter().map(|(p, _, _)| p.id()).collect();
            let untested: Vec<Point> =
                untested_points(cfg.optimizer, &st.tested_ids)
                    .into_iter()
                    .filter(|p| !taken.contains(&p.id()))
                    .collect();
            if untested.is_empty() {
                stopping = true;
                break;
            }
            let budget =
                ((cfg.beta * untested.len() as f64).ceil() as usize).max(1);
            let pending: Vec<Point> =
                inflight.iter().map(|(p, _, _)| *p).collect();
            let (pick, n_evals) = choose_async(
                cfg, constraints, st, &untested, full_feats, grid_feats,
                budget, rng, acq_cache, &pending,
            );
            let ticket = backend.submit_probe(pick)?;
            inflight.push_back((pick, ticket, n_evals));
            launched += 1;
        }
        // absorb the logical head (blocking on *it*, never on the whole
        // slate — later tickets completing early buffer in the backend's
        // reorder book); an empty book means the campaign is done
        let Some((p, ticket, n_evals)) = inflight.pop_front() else {
            break;
        };
        let result = backend.await_probe(ticket)?;
        absorbed += 1;
        let round = absorbed; // init batch is round 0
        match result {
            ProbeResult::Observed(pr) => {
                st.push_observation(p, pr.outcome);
                st.cum_cost += pr.charged_cost;
                st.cum_time += pr.duration_s;
                let new_from = st.tested.len() - 1;
                refit(cfg, st, round - 1, new_from, &mut refit_memo);
                let rec =
                    recommend(cfg.optimizer, st, constraints, full_feats);
                let rec_wall_s = timer.elapsed_s();
                let (cum_cost, cum_time) = (st.cum_cost, st.cum_time);
                push_record(
                    st,
                    backend,
                    constraints,
                    RecordArgs {
                        iter,
                        is_init: false,
                        round,
                        tested: p,
                        outcome: pr.outcome,
                        explore_cost: pr.charged_cost,
                        duration_s: pr.duration_s,
                        cum_cost,
                        cum_time,
                        rec_wall_s,
                        rec,
                        n_alpha_evals: n_evals,
                        log_events: true,
                    },
                );
                iter += 1;
                if !stopping && cfg.stop.should_stop(&st.records) {
                    stopping = true;
                }
            }
            ProbeResult::Abandoned { charged_cost, duration_s, .. } => {
                // the pick's partial charge lands in the running totals,
                // but no observation, no record — and deliberately no
                // stop check: an abandoned probe is no evidence of a
                // plateau (the point stays untested and may be re-picked
                // under a fresh job id)
                st.cum_cost += charged_cost;
                st.cum_time += duration_s;
            }
        }
        timer = Timer::start();
    }
    Ok(())
}

/// One asynchronous selection: the α-argmax conditioned on the in-flight
/// picks. With nothing in flight this is exactly [`choose_ranked`] with
/// q = 1 — the sequential Algorithm 1 pick, consuming identical RNG draws
/// (the occupancy-1 parity contract). With pending picks the fantasy /
/// constant-liar chain of the barriered slate is rebuilt over the
/// in-flight points in logical submission order against the
/// freshly-absorbed models, then one [`choose_pending`] maximization runs
/// under the conditioned bundle. [`BatchMode::TopQ`] has no pending
/// conditioning by definition, so it re-ranks unconditioned over the
/// remaining candidates.
#[allow(clippy::too_many_arguments)]
fn choose_async(
    cfg: &EngineConfig,
    constraints: &[Constraint],
    st: &State,
    untested: &[Point],
    full_feats: &[Feat],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
    acq_cache: &mut Option<AcqContext>,
    pending: &[Point],
) -> (Point, usize) {
    if pending.is_empty()
        || cfg.batch_mode == BatchMode::TopQ
        || cfg.optimizer == OptimizerKind::RandomSearch
    {
        let (slate, evals) = choose_ranked(
            cfg, constraints, st, untested, full_feats, grid_feats, budget,
            rng, acq_cache, 1,
        );
        return (slate[0], evals);
    }
    // refresh the acquisition context (representer set, CRN z-matrix, CEA
    // ordering) under the current models before conditioning on the
    // in-flight picks — same cache, same staleness rule, same RNG
    // consumption as the barriered first pick
    match cfg.optimizer {
        OptimizerKind::Fabolas => {
            acq_context(cfg, st, &[], full_feats, rng, acq_cache);
        }
        OptimizerKind::TrimTuner(_) => {
            acq_context(cfg, st, constraints, full_feats, rng, acq_cache);
        }
        _ => {}
    }
    // constant-liar value: the best *observed* accuracy so far (CL-max)
    let lie = st
        .outcomes
        .iter()
        .map(|o| o.acc)
        .fold(f64::NEG_INFINITY, f64::max);
    // rebuild the fantasy chain over the in-flight picks in submission
    // order. The chain cannot persist across selections: every absorption
    // refits/absorbs real data (generation bump), so the conditioned
    // bundle must re-derive from the fresh models each time.
    let mut cond: Option<Models> = None;
    for p in pending {
        let x = &grid_feats[p.id()];
        let base = cond.as_ref().unwrap_or(&st.models);
        let next = match cfg.batch_mode {
            BatchMode::Fantasy => base.condition(x),
            BatchMode::ConstantLiar => base.condition_with_acc(x, lie),
            BatchMode::TopQ => unreachable!("handled above"),
        };
        cond = Some(next);
    }
    let models = cond.as_ref().expect("nonempty pending chain");
    choose_pending(
        cfg,
        constraints,
        models,
        st,
        acq_cache.as_ref(),
        untested,
        full_feats,
        grid_feats,
        budget.min(untested.len()),
        rng,
    )
}

/// How many fresh random configs the subsampling init tries when a
/// snapshot deployment is abandoned under faults (each replan re-draws
/// from the same seeded stream, so the zero-fault path consumes exactly
/// one draw, as before).
const INIT_REPLANS: usize = 6;

/// Initialization phase (Alg. 1 lines 2-10).
fn initialize(
    backend: &mut EvalBackend,
    constraints: &[Constraint],
    cfg: &EngineConfig,
    st: &mut State,
    rng: &mut Rng,
    full_feats: &[Feat],
) -> Result<()> {
    // (point, outcome, cost charged, deployment duration attributed here)
    let mut init: Vec<(Point, Outcome, f64, f64)> = Vec::new();
    if cfg.optimizer.uses_subsampling() {
        // one random config tested at the k init sub-sampling levels via a
        // single snapshot deployment (paper §III): only the largest level
        // is charged, and the whole batch costs one training run's time.
        // The levels ride probe_slate so an abandoned deployment (faults)
        // re-plans with a fresh random config — round 0's version of
        // re-planning around the hole — instead of aborting; with no
        // faults the first attempt always lands, identically to the
        // historic single-snapshot path.
        let levels = &S_INIT[..S_INIT.len().min(cfg.init_samples)];
        let mut landed = false;
        for _ in 0..INIT_REPLANS {
            let config = Config::from_id(rng.below(N_CONFIGS));
            let points: Vec<Point> = levels
                .iter()
                .map(|&s_idx| Point { config, s_idx })
                .collect();
            let results = backend.probe_slate(&points)?;
            // a shared snapshot deployment fails as a unit: either every
            // level observed, or every level a hole
            if results.iter().all(|r| r.observed().is_some()) {
                for (p, res) in points.iter().zip(&results) {
                    let pr = res.observed().expect("checked observed");
                    init.push((*p, pr.outcome, pr.charged_cost, pr.duration_s));
                }
                landed = true;
                break;
            }
            for res in &results {
                if let ProbeResult::Abandoned { charged_cost, duration_s, .. } =
                    res
                {
                    st.cum_cost += charged_cost;
                    st.cum_time += duration_s;
                }
            }
        }
        anyhow::ensure!(
            landed,
            "initialization failed: {INIT_REPLANS} consecutive init snapshot \
             deployments were abandoned; raise the retry budget (--retry \
             max=N) or lower the fault rate"
        );
    } else {
        // LHS over the feature space, snapped to distinct full configs;
        // independent deployments, launched in parallel under a live
        // backend (the testbed parallelized exactly this batch).
        let samples = latin_hypercube(rng, cfg.init_samples, 7);
        let mut seen = HashSet::new();
        let mut points = Vec::with_capacity(samples.len());
        for mut f in samples {
            f[6] = 1.0;
            let mut p = nearest_point(&f);
            p = Point { config: p.config, s_idx: S_VALUES.len() - 1 };
            while !seen.insert(p.config.id()) {
                p = Point {
                    config: Config::from_id(rng.below(N_CONFIGS)),
                    s_idx: S_VALUES.len() - 1,
                };
            }
            points.push(p);
        }
        // tolerant slate path (all configs distinct → independent jobs):
        // an abandoned init deployment charges its partial cost into the
        // running totals and the model simply fits on the survivors
        let results = backend.probe_slate(&points)?;
        for (p, res) in points.iter().zip(&results) {
            match res {
                ProbeResult::Observed(pr) => {
                    init.push((*p, pr.outcome, pr.charged_cost, pr.duration_s));
                }
                ProbeResult::Abandoned { charged_cost, duration_s, .. } => {
                    st.cum_cost += charged_cost;
                    st.cum_time += duration_s;
                }
            }
        }
        anyhow::ensure!(
            !init.is_empty(),
            "initialization failed: every init probe was abandoned; raise \
             the retry budget (--retry max=N) or lower the fault rate"
        );
    }

    let n = init.len();
    for (i, (p, o, charge, duration)) in init.iter().enumerate() {
        st.push_observation(*p, *o);
        st.cum_cost += charge;
        st.cum_time += duration;
        let is_last = i + 1 == n;
        let (rec, wall) = if is_last {
            let t = Timer::start();
            st.models.fit(
                &st.tested,
                &st.outcomes,
                FitOptions { hyperopt: true, restarts: 1 },
            );
            let rec = recommend(cfg.optimizer, st, constraints, full_feats);
            (rec, t.elapsed_s())
        } else {
            // record without a model-based incumbent yet: report the best
            // observed config (full-data-set observations preferred)
            (best_observed(st, constraints), 0.0)
        };
        let (cum_cost, cum_time) = (st.cum_cost, st.cum_time);
        push_record(
            st,
            backend,
            constraints,
            RecordArgs {
                iter: i,
                is_init: true,
                round: 0,
                tested: *p,
                outcome: *o,
                explore_cost: *charge,
                duration_s: *duration,
                cum_cost,
                cum_time,
                rec_wall_s: wall,
                rec,
                n_alpha_evals: 0,
                log_events: true,
            },
        );
    }
    Ok(())
}

fn untested_points(
    optimizer: OptimizerKind,
    tested_ids: &HashSet<usize>,
) -> Vec<Point> {
    if optimizer.uses_subsampling() {
        crate::space::all_points()
            .filter(|p| !tested_ids.contains(&p.id()))
            .collect()
    } else {
        crate::space::all_points()
            .filter(|p| p.is_full() && !tested_ids.contains(&p.id()))
            .collect()
    }
}

/// Pick the round's probe slate: the α-argmax first pick, plus q − 1
/// follow-up picks conditioned on the pending ones (per
/// [`EngineConfig::batch_mode`]) so the slate spreads over the space
/// instead of clustering around one maximum. Returns the slate in pick
/// order and the total unique α evaluations spent. With q = 1 this is
/// exactly one [`choose_ranked`] call — the sequential Algorithm 1 path,
/// consuming identical RNG draws.
#[allow(clippy::too_many_arguments)]
fn choose_slate(
    cfg: &EngineConfig,
    constraints: &[Constraint],
    st: &State,
    untested: &[Point],
    full_feats: &[Feat],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
    acq_cache: &mut Option<AcqContext>,
    q: usize,
) -> (Vec<Point>, usize) {
    if q > 1 {
        // random search needs no conditioning: q distinct uniform picks
        if cfg.optimizer == OptimizerKind::RandomSearch {
            let idx = rng.sample_indices(untested.len(), q);
            return (idx.into_iter().map(|i| untested[i]).collect(), 0);
        }
        // unconditioned ranked slate: one α sweep, top-q prefix
        if cfg.batch_mode == BatchMode::TopQ {
            return choose_ranked(
                cfg, constraints, st, untested, full_feats, grid_feats,
                budget, rng, acq_cache, q,
            );
        }
    }
    let (mut slate, mut evals) = choose_ranked(
        cfg, constraints, st, untested, full_feats, grid_feats, budget, rng,
        acq_cache, 1,
    );
    if q <= 1 {
        return (slate, evals);
    }
    // constant-liar value: the best *observed* accuracy so far (CL-max)
    let lie = st
        .outcomes
        .iter()
        .map(|o| o.acc)
        .fold(f64::NEG_INFINITY, f64::max);
    // pending-conditioned picks: absorb each pending pick into a fantasy
    // clone of the bundle, then re-maximize α under it over the remaining
    // candidates. The round context (representer set, CRN z-matrix) built
    // by the first pick is reused across all picks of the round.
    let mut cond: Option<Models> = None;
    while slate.len() < q {
        let x = &grid_feats[slate.last().expect("nonempty slate").id()];
        let next_models = {
            let base = cond.as_ref().unwrap_or(&st.models);
            match cfg.batch_mode {
                BatchMode::Fantasy => base.condition(x),
                BatchMode::ConstantLiar => base.condition_with_acc(x, lie),
                BatchMode::TopQ => unreachable!("handled above"),
            }
        };
        cond = Some(next_models);
        let models = cond.as_ref().expect("conditioned bundle");
        let taken: HashSet<usize> = slate.iter().map(|p| p.id()).collect();
        let remaining: Vec<Point> = untested
            .iter()
            .filter(|p| !taken.contains(&p.id()))
            .copied()
            .collect();
        if remaining.is_empty() {
            break;
        }
        let (next, e) = choose_pending(
            cfg,
            constraints,
            models,
            st,
            acq_cache.as_ref(),
            &remaining,
            full_feats,
            grid_feats,
            budget.min(remaining.len()),
            rng,
        );
        evals += e;
        slate.push(next);
    }
    (slate, evals)
}

/// One acquisition maximization over `untested`, returning the ranked
/// top-`q` slate (q = 1: exactly the point the sequential loop would test).
///
/// Every α closure is a pure `Fn + Sync` over precomputed per-round
/// context ([`AlphaCache::shared`] / [`AlphaCache::batch`]), so the slate
/// heuristics can shard the candidate sweep across threads while staying
/// bit-identical to the sequential path. The per-optimizer selection
/// bodies live in `select_*_slate` helpers shared with [`choose_pending`],
/// so the first pick and the pending-conditioned picks cannot drift apart.
#[allow(clippy::too_many_arguments)]
fn choose_ranked(
    cfg: &EngineConfig,
    constraints: &[Constraint],
    st: &State,
    untested: &[Point],
    full_feats: &[Feat],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
    acq_cache: &mut Option<AcqContext>,
    q: usize,
) -> (Vec<Point>, usize) {
    let (ranked, evals) = match cfg.optimizer {
        OptimizerKind::RandomSearch => {
            return (vec![untested[rng.below(untested.len())]], 0);
        }
        OptimizerKind::Eic | OptimizerKind::EicUsd => {
            let eta = incumbent_eta(st, constraints);
            let use_usd = cfg.optimizer == OptimizerKind::EicUsd;
            select_eic_slate(
                &st.models, constraints, use_usd, eta, untested, grid_feats,
                rng, q,
            )
        }
        OptimizerKind::Fabolas => {
            let actx =
                acq_context(cfg, st, &[], full_feats, rng, acq_cache);
            select_fabolas_slate(
                cfg,
                &st.models,
                &actx.est,
                actx.baseline,
                untested,
                grid_feats,
                budget,
                rng,
                q,
            )
        }
        OptimizerKind::TrimTuner(_) => {
            let actx =
                acq_context(cfg, st, constraints, full_feats, rng, acq_cache);
            select_trimtuner_slate(
                cfg,
                constraints,
                &st.models,
                &actx.est,
                actx.baseline,
                &actx.cea_order,
                actx.full_feas.as_deref(),
                untested,
                full_feats,
                grid_feats,
                budget,
                rng,
                q,
            )
        }
    };
    (ranked.into_iter().map(|(p, _)| p).collect(), evals)
}

/// One pending-conditioned acquisition maximization for pick 2..q of a
/// round's slate: the same `select_*_slate` bodies as [`choose_ranked`],
/// but evaluated under the fantasy/liar-conditioned `models` instead of
/// `st.models`. The entropy estimator (representer set + CRN z-matrix) is
/// reused from the round context; only its cheap derived quantities
/// (p_opt baseline, CEA shortlist ordering) are re-derived under the
/// conditioned bundle.
#[allow(clippy::too_many_arguments)]
fn choose_pending(
    cfg: &EngineConfig,
    constraints: &[Constraint],
    models: &Models,
    st: &State,
    actx: Option<&AcqContext>,
    untested: &[Point],
    full_feats: &[Feat],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
) -> (Point, usize) {
    let (ranked, evals) = match cfg.optimizer {
        OptimizerKind::RandomSearch => {
            return (untested[rng.below(untested.len())], 0);
        }
        OptimizerKind::Eic | OptimizerKind::EicUsd => {
            // η stays observation-based: pending picks have no outcome yet
            let eta = incumbent_eta(st, constraints);
            let use_usd = cfg.optimizer == OptimizerKind::EicUsd;
            select_eic_slate(
                models, constraints, use_usd, eta, untested, grid_feats,
                rng, 1,
            )
        }
        OptimizerKind::Fabolas => {
            let actx = actx.expect("round context built by the first pick");
            let baseline = EntropyEstimator::kl_from_uniform(
                &actx.est.p_opt(models.acc.as_ref()),
            );
            select_fabolas_slate(
                cfg, models, &actx.est, baseline, untested, grid_feats,
                budget, rng, 1,
            )
        }
        OptimizerKind::TrimTuner(_) => {
            let actx = actx.expect("round context built by the first pick");
            let baseline = EntropyEstimator::kl_from_uniform(
                &actx.est.p_opt(models.acc.as_ref()),
            );
            // re-rank the incumbent shortlist under the conditioned
            // bundle. Tree conditioning shares the constraint models, so
            // the round context's full-grid feasibility is reused here —
            // only the conditioned accuracy is re-predicted per pick.
            let scores = match &actx.full_feas {
                Some(feas) => {
                    cea_scores_feats_with_feas(models, full_feats, feas)
                }
                None => cea_scores_feats(models, constraints, full_feats),
            };
            let mut order: Vec<usize> = (0..full_feats.len()).collect();
            order.sort_by(|&a, &b| cmp_nan_low(scores[b], scores[a]));
            select_trimtuner_slate(
                cfg,
                constraints,
                models,
                &actx.est,
                baseline,
                &order,
                actx.full_feas.as_deref(),
                untested,
                full_feats,
                grid_feats,
                budget,
                rng,
                1,
            )
        }
    };
    (ranked[0].0, evals)
}

/// Constrained-EI selection body (CherryPick / Lynceus), shared by the
/// first pick and the pending-conditioned picks.
#[allow(clippy::too_many_arguments)]
fn select_eic_slate(
    models: &Models,
    constraints: &[Constraint],
    use_usd: bool,
    eta: f64,
    untested: &[Point],
    grid_feats: &[Feat],
    rng: &mut Rng,
    q: usize,
) -> (Vec<(Point, f64)>, usize) {
    let mut alpha = AlphaCache::shared(move |p: &Point| {
        let x = &grid_feats[p.id()];
        if use_usd {
            eic_usd(models, constraints, x, eta)
        } else {
            eic(models, constraints, x, eta)
        }
    });
    select_slate(
        FilterKind::NoFilter,
        models,
        constraints,
        untested,
        untested.len(),
        &mut alpha,
        rng,
        q,
    )
}

/// FABOLAS selection body (constraint-oblivious information gain per
/// dollar), shared by the first pick and the pending-conditioned picks.
#[allow(clippy::too_many_arguments)]
fn select_fabolas_slate(
    cfg: &EngineConfig,
    models: &Models,
    est: &EntropyEstimator,
    baseline: f64,
    untested: &[Point],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
    q: usize,
) -> (Vec<(Point, f64)>, usize) {
    let mut alpha = AlphaCache::shared(move |p: &Point| {
        fabolas_alpha(models, est, baseline, &grid_feats[p.id()])
    });
    select_slate(
        cfg.filter,
        models,
        &[], // FABOLAS ignores constraints
        untested,
        budget,
        &mut alpha,
        rng,
        q,
    )
}

/// TrimTuner α_T selection body, shared by the first pick (round context's
/// CEA order + baseline) and the pending-conditioned picks (order +
/// baseline re-derived under the conditioned bundle; `full_feas` — the
/// round context's cached full-grid feasibility — reused verbatim, since
/// tree conditioning shares the constraint models).
#[allow(clippy::too_many_arguments)]
fn select_trimtuner_slate(
    cfg: &EngineConfig,
    constraints: &[Constraint],
    models: &Models,
    est: &EntropyEstimator,
    baseline: f64,
    cea_order: &[usize],
    full_feas: Option<&[f64]>,
    untested: &[Point],
    full_feats: &[Feat],
    grid_feats: &[Feat],
    budget: usize,
    rng: &mut Rng,
    q: usize,
) -> (Vec<(Point, f64)>, usize) {
    // incumbent shortlist: top configs by CEA under `models`, with the
    // feature rows gathered once per selection pass
    let shortlist: Vec<usize> =
        cea_order.iter().take(INC_SHORTLIST).copied().collect();
    let shortlist_feats: Vec<Feat> =
        shortlist.iter().map(|&id| full_feats[id]).collect();
    // When conditioning leaves the constraint models untouched (trees —
    // see Models::constraints_fixed_under_condition), the shortlist
    // feasibility scanned inside every α_T call is pass-constant —
    // gathered from the round's cached full-grid pass when available,
    // computed once here otherwise. GP conditioning shifts the constraint
    // posteriors; their conditioned feasibility comes from the slate
    // evaluator's rank-one metric surfaces.
    let shortlist_feas: Option<Vec<f64>> =
        if models.constraints_fixed_under_condition() {
            Some(match full_feas {
                Some(feas) => {
                    shortlist.iter().map(|&id| feas[id]).collect()
                }
                None => joint_feasibility_many(
                    models,
                    constraints,
                    &shortlist_feats,
                ),
            })
        } else {
            None
        };
    let ctx = TrimTunerAcq {
        models,
        est,
        constraints,
        inc_shortlist: &shortlist,
        inc_shortlist_feats: &shortlist_feats,
        inc_feas: shortlist_feas.as_deref(),
        baseline,
    };
    // Slate-wide α_T: one shared fantasy-posterior precompute per pass,
    // then a rank-one conditioned view per candidate
    // (`TRIMTUNER_ALPHA=clone` reverts to per-candidate cloning).
    let slate = AlphaSlate::new(&ctx);
    let mut alpha = AlphaCache::batch(|pts: &[Point]| {
        let feats: Vec<Feat> =
            pts.iter().map(|p| grid_feats[p.id()]).collect();
        slate.eval_feats(&feats)
    });
    select_slate(
        cfg.filter,
        models,
        constraints,
        untested,
        budget,
        &mut alpha,
        rng,
        q,
    )
}

/// Size of the CEA-ranked incumbent shortlist scanned inside α_T
/// (EXPERIMENTS.md §Perf: 288 -> 32 with no measurable quality change).
const INC_SHORTLIST: usize = 32;

/// Representative set for p_opt: the top-n_rep full-data-set configs by CEA
/// under the current models (constraint-free CEA == predicted accuracy).
/// Also returns the full CEA-descending config ordering for shortlist
/// reuse, and — when conditioning cannot move the constraint models — the
/// full-grid joint feasibility that ordering was derived from (one batched
/// pass, shared with every pending-conditioned pick of the round).
#[allow(clippy::type_complexity)]
fn build_estimator(
    cfg: &EngineConfig,
    st: &State,
    constraints: &[Constraint],
    full_feats: &[Feat],
    rng: &mut Rng,
) -> (EntropyEstimator, Vec<usize>, Option<Vec<f64>>) {
    // full_feats[i] == encode(config_i at s=1), precomputed by run() — no
    // per-iteration re-encoding of the 288-config grid
    let full_feas = (!constraints.is_empty()
        && st.models.constraints_fixed_under_condition())
    .then(|| joint_feasibility_many(&st.models, constraints, full_feats));
    let scores = match &full_feas {
        Some(feas) => {
            cea_scores_feats_with_feas(&st.models, full_feats, feas)
        }
        None => cea_scores_feats(&st.models, constraints, full_feats),
    };
    let mut order: Vec<usize> = (0..full_feats.len()).collect();
    order.sort_by(|&a, &b| cmp_nan_low(scores[b], scores[a]));
    let rep: Vec<Feat> = order
        .iter()
        .take(cfg.n_rep.max(2))
        .map(|&i| full_feats[i])
        .collect();
    (EntropyEstimator::new(rep, cfg.n_popt_samples, rng), order, full_feas)
}

/// The cached [`AcqContext`] for the current models, rebuilt when stale.
/// A cache hit consumes no RNG (the CRN z-matrix is reused), which is
/// exactly the semantics the per-iteration estimator requires: the models
/// are unchanged, so the iteration's common random numbers may be too.
fn acq_context<'c>(
    cfg: &EngineConfig,
    st: &State,
    constraints: &[Constraint],
    full_feats: &[Feat],
    rng: &mut Rng,
    cache: &'c mut Option<AcqContext>,
) -> &'c AcqContext {
    let generation = st.models.generation();
    let constraint_free = constraints.is_empty();
    let stale = cache.as_ref().map_or(true, |c| {
        c.generation != generation || c.constraint_free != constraint_free
    });
    if stale {
        let (est, cea_order, full_feas) =
            build_estimator(cfg, st, constraints, full_feats, rng);
        let baseline = EntropyEstimator::kl_from_uniform(
            &est.p_opt(st.models.acc.as_ref()),
        );
        *cache = Some(AcqContext {
            generation,
            constraint_free,
            cea_order,
            est,
            baseline,
            full_feas,
        });
    }
    cache.as_ref().expect("acquisition context built")
}

/// Incumbent accuracy target for EI variants: best observed accuracy among
/// configurations whose *measured* metrics satisfy the constraints.
fn incumbent_eta(st: &State, constraints: &[Constraint]) -> f64 {
    let mut best_feasible = f64::NEG_INFINITY;
    let mut best_any = f64::NEG_INFINITY;
    for (p, o) in st.tested.iter().zip(&st.outcomes) {
        if !p.is_full() {
            continue;
        }
        best_any = best_any.max(o.acc);
        let feas = constraints.iter().all(|c| {
            let v = match c.metric {
                crate::space::Metric::Cost => o.cost_usd,
                crate::space::Metric::Time => o.time_s,
            };
            c.is_satisfied(v)
        });
        if feas {
            best_feasible = best_feasible.max(o.acc);
        }
    }
    if best_feasible.is_finite() {
        best_feasible
    } else if best_any.is_finite() {
        best_any
    } else {
        0.0
    }
}

/// Refit state carried across rounds: the post-full-refit surprise
/// baseline the evidence-drop trigger compares against. Reset after every
/// full refit, re-established on the first cheap round that follows.
struct RefitMemo {
    baseline: Option<f64>,
}

/// Mean negative log predictive density (nats per observation) of a
/// round's fresh accuracy observations under the *pre-absorb* accuracy
/// model — the evidence-drop trigger's surprise statistic. Model-agnostic:
/// both surrogate families expose a Gaussian predictive (mean, std).
fn predictive_surprise(
    models: &Models,
    points: &[Point],
    outcomes: &[Outcome],
) -> f64 {
    let xs: Vec<Feat> = points.iter().map(encode).collect();
    let preds = models.acc.predict_many(&xs);
    let mut nll = 0.0;
    for ((mu, std), o) in preds.into_iter().zip(outcomes) {
        let var = (std * std).max(1e-12);
        let z = o.acc - mu;
        nll += 0.5 * ((2.0 * std::f64::consts::PI * var).ln() + z * z / var);
    }
    nll / points.len().max(1) as f64
}

/// Refit or absorb after a round (`round_idx` is the 0-based main-loop
/// round index — with q = 1 that is exactly the observation index;
/// `new_from` marks where this round's fresh observations start in
/// `st.tested`). Scheduled full rounds — and evidence-drop triggers — pay
/// the full `fit(hyperopt: true)`: GP hyper-parameter re-optimization plus
/// tree structural rebuild over the complete history, which also resyncs
/// any state the cheap rounds approximated. In between, the fresh
/// observations are absorbed incrementally with everything structural
/// frozen — or, under the `TRIMTUNER_REFIT=full` hatch, recomputed from
/// scratch to the same frozen-parameter state (the parity reference).
fn refit(
    cfg: &EngineConfig,
    st: &mut State,
    round_idx: usize,
    new_from: usize,
    memo: &mut RefitMemo,
) {
    let policy = cfg.refit;
    // surprise is only measured when the trigger can consume it: it must
    // run *before* absorption, against the pre-absorb models
    let surprise = (policy.evidence_drop > 0.0 && !policy.full_due(round_idx))
        .then(|| {
            predictive_surprise(
                &st.models,
                &st.tested[new_from..],
                &st.outcomes[new_from..],
            )
        });
    if policy.full_refit(round_idx, surprise, memo.baseline) {
        st.models.fit(
            &st.tested,
            &st.outcomes,
            FitOptions { hyperopt: true, restarts: 1 },
        );
        memo.baseline = None;
        return;
    }
    st.models.absorb(&st.tested[new_from..], &st.outcomes[new_from..]);
    if policy.mode == RefitMode::Full {
        st.models.refit_frozen();
    }
    if memo.baseline.is_none() {
        memo.baseline = surprise;
    }
}

/// Best *observed* config satisfying the measured constraints, reported at
/// s = 1. Full-data-set observations take strict precedence; a sub-sampled
/// probe's accuracy is used only when no full observation exists yet, and
/// the recommendation is flagged so the record can't silently attribute a
/// sub-sampled accuracy to a full-data-set measurement.
fn best_observed(st: &State, constraints: &[Constraint]) -> Recommendation {
    let full_s = S_VALUES.len() - 1;
    let mut best_feas: Option<(Point, f64)> = None; // full + feasible
    let mut best_full: Option<(Point, f64)> = None; // full, any feasibility
    let mut best_sub: Option<(Point, f64)> = None; // sub-sampled fallback
    for (p, o) in st.tested.iter().zip(&st.outcomes) {
        if !p.is_full() {
            // fallback ranking: largest sub-sampling level first (closest
            // to a full-data-set measurement), accuracy second
            let better = match &best_sub {
                None => true,
                Some((q, a)) => {
                    p.s_idx > q.s_idx || (p.s_idx == q.s_idx && o.acc > *a)
                }
            };
            if better {
                best_sub = Some((*p, o.acc));
            }
            continue;
        }
        if best_full.as_ref().map_or(true, |(_, a)| o.acc > *a) {
            best_full = Some((*p, o.acc));
        }
        let feas = constraints.iter().all(|c| {
            let v = match c.metric {
                crate::space::Metric::Cost => o.cost_usd,
                crate::space::Metric::Time => o.time_s,
            };
            c.is_satisfied(v)
        });
        if feas && best_feas.as_ref().map_or(true, |(_, a)| o.acc > *a) {
            best_feas = Some((*p, o.acc));
        }
    }
    if let Some((p, acc)) = best_feas.or(best_full) {
        Recommendation { point: p, acc_estimate: acc, from_subsample: false }
    } else if let Some((p, acc)) = best_sub {
        Recommendation {
            point: Point { config: p.config, s_idx: full_s },
            acc_estimate: acc,
            from_subsample: true,
        }
    } else {
        panic!("no observations");
    }
}

/// Post-iteration incumbent recommendation, per optimizer semantics.
/// Model-based recommenders use hysteresis: the reported incumbent only
/// switches when the challenger's predicted accuracy beats the current
/// incumbent's *current* prediction by a margin (and the current one is
/// retained as long as it still clears the feasibility bar). This keeps the
/// recommendation stable under per-refit prediction jitter.
const SWITCH_MARGIN: f64 = 0.005;

fn recommend(
    optimizer: OptimizerKind,
    st: &mut State,
    constraints: &[Constraint],
    full_feats: &[Feat],
) -> Recommendation {
    match optimizer {
        // Model-based recommendation: TrimTuner (paper footnote 2) and the
        // CherryPick/Lynceus baselines (their GPs drive the final pick).
        OptimizerKind::TrimTuner(_)
        | OptimizerKind::Eic
        | OptimizerKind::EicUsd => {
            let inc = select_incumbent(&st.models, constraints, full_feats);
            let (chosen, pred_acc) = match st.incumbent_id {
                Some(prev) if prev != inc.config_id => {
                    let x_prev = &full_feats[prev];
                    let prev_feas = crate::acq::joint_feasibility(
                        &st.models,
                        constraints,
                        x_prev,
                    );
                    let (prev_acc, _) = st.models.acc.predict(x_prev);
                    if prev_feas >= crate::acq::FEAS_THRESHOLD_HYST
                        && inc.pred_acc < prev_acc + SWITCH_MARGIN
                    {
                        (prev, prev_acc)
                    } else {
                        (inc.config_id, inc.pred_acc)
                    }
                }
                _ => (inc.config_id, inc.pred_acc),
            };
            st.incumbent_id = Some(chosen);
            Recommendation {
                point: Point { config: Config::from_id(chosen), s_idx: 4 },
                acc_estimate: pred_acc,
                from_subsample: false,
            }
        }
        OptimizerKind::Fabolas => {
            // constraint-oblivious: predicted-accuracy argmax at s=1
            let mut best = (0usize, f64::NEG_INFINITY);
            for (id, x) in full_feats.iter().enumerate() {
                let (mu, _) = st.models.acc.predict(x);
                if mu > best.1 {
                    best = (id, mu);
                }
            }
            Recommendation {
                point: Point { config: Config::from_id(best.0), s_idx: 4 },
                acc_estimate: best.1,
                from_subsample: false,
            }
        }
        // Random search recommends the best tested feasible config
        OptimizerKind::RandomSearch => best_observed(st, constraints),
    }
}

/// Everything one [`IterRecord`] needs beyond the shared run state. The
/// cumulative totals are passed explicitly because a batched round absorbs
/// its whole slate before recording, yet each record reports the totals
/// *as of its own observation*.
struct RecordArgs {
    iter: usize,
    is_init: bool,
    round: usize,
    tested: Point,
    outcome: Outcome,
    explore_cost: f64,
    duration_s: f64,
    cum_cost: f64,
    cum_time: f64,
    rec_wall_s: f64,
    rec: Recommendation,
    n_alpha_evals: usize,
    /// record the round-level `IncumbentUpdated`/`IterationDone` events —
    /// once per round (the last record of a slate; every init record)
    log_events: bool,
}

fn push_record(
    st: &mut State,
    backend: &EvalBackend,
    constraints: &[Constraint],
    a: RecordArgs,
) {
    // Evaluation-only ground truth: never consumed by the optimizer or its
    // stop conditions. Present under replay; under live only when an
    // offline oracle was attached.
    let (inc_acc, inc_feasible, acc_c) = match backend.eval_dataset() {
        Some(d) => (
            d.outcome(&a.rec.point).acc,
            d.is_feasible(&a.rec.point, constraints),
            accuracy_c(d, &a.rec.point, constraints),
        ),
        None => (f64::NAN, false, f64::NAN),
    };
    if a.log_events {
        if let Some(log) = backend.event_log() {
            log.record(EventKind::IncumbentUpdated {
                config_id: a.rec.point.config.id(),
                pred_acc: a.rec.acc_estimate,
            });
            log.record(EventKind::IterationDone {
                iter: a.iter,
                cum_cost: a.cum_cost,
            });
        }
    }
    st.records.push(IterRecord {
        iter: a.iter,
        is_init: a.is_init,
        round: a.round,
        tested: a.tested,
        outcome: a.outcome,
        explore_cost: a.explore_cost,
        cum_cost: a.cum_cost,
        cum_time: a.cum_time,
        duration_s: a.duration_s,
        rec_wall_s: a.rec_wall_s,
        incumbent: a.rec.point,
        inc_pred_acc: a.rec.acc_estimate,
        inc_from_subsample: a.rec.from_subsample,
        inc_acc,
        inc_feasible,
        accuracy_c: acc_c,
        n_alpha_evals: a.n_alpha_evals,
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refit_policy_parses_cli_specs() {
        let p = RefitPolicy::parse("every=5").unwrap();
        assert_eq!(p.every, 5);
        assert_eq!(p.evidence_drop, 0.0);
        let p = RefitPolicy::parse("every=3, evidence-drop=0.5").unwrap();
        assert_eq!(p.every, 3);
        assert_eq!(p.evidence_drop, 0.5);
        let p = RefitPolicy::parse("evidence-drop=1.25").unwrap();
        assert_eq!(p.every, 1);
        assert_eq!(p.evidence_drop, 1.25);
        assert!(RefitPolicy::parse("every=x").is_err());
        assert!(RefitPolicy::parse("cadence=3").is_err());
        assert!(RefitPolicy::parse("every").is_err());
    }

    #[test]
    fn refit_policy_schedules_and_triggers() {
        let mut p = RefitPolicy::paper_default();
        // the paper default refits fully on every round
        assert!((0..5).all(|r| p.full_due(r)));
        p.every = 3;
        let due: Vec<usize> = (0..7).filter(|&r| p.full_due(r)).collect();
        assert_eq!(due, vec![0, 3, 6]);
        // cadence 0 disables scheduled refits entirely
        p.every = 0;
        assert!((0..5).all(|r| !p.full_due(r)));

        // evidence trigger: fires only when enabled, with both surprise
        // and baseline present, and the drop exceeded
        p.every = 3;
        p.evidence_drop = 0.5;
        assert!(p.full_refit(3, None, None), "scheduled round wins");
        assert!(!p.full_refit(1, None, None), "no surprise -> no trigger");
        assert!(!p.full_refit(1, Some(1.0), None), "no baseline yet");
        assert!(!p.full_refit(1, Some(1.4), Some(1.0)), "within tolerance");
        assert!(p.full_refit(1, Some(1.6), Some(1.0)), "drop exceeded");
        p.evidence_drop = 0.0;
        assert!(
            !p.full_refit(1, Some(9.0), Some(1.0)),
            "disabled trigger never fires"
        );
    }

    #[test]
    fn predictive_surprise_grows_with_model_miss() {
        use crate::models::ModelKind;
        use crate::space::{Config, Point};
        let mut models = Models::new(ModelKind::Trees, 7);
        let points: Vec<Point> = (0..12)
            .map(|i| Point { config: Config::from_id(i * 17 % 288), s_idx: 4 })
            .collect();
        let outcomes: Vec<Outcome> = points
            .iter()
            .map(|p| Outcome {
                acc: 0.5 + 0.001 * (p.config.id() % 7) as f64,
                cost_usd: 0.01,
                time_s: 10.0,
            })
            .collect();
        models.fit(&points, &outcomes, FitOptions::default());
        let close = predictive_surprise(&models, &points, &outcomes);
        let far: Vec<Outcome> = outcomes
            .iter()
            .map(|o| Outcome { acc: o.acc + 10.0, ..*o })
            .collect();
        let missed = predictive_surprise(&models, &points, &far);
        assert!(
            missed > close + 1.0,
            "surprise must grow with prediction error: {close} vs {missed}"
        );
    }
}
