//! Ablation studies for TrimTuner's own design knobs (DESIGN.md §4):
//! representative-set size and Monte-Carlo depth of the p_opt estimator,
//! and GP hyper-parameter refit cadence. Not part of the paper's figures —
//! these back the implementation choices the paper leaves implicit.
//!
//! `trimtuner repro ablation [--seeds 3] [--iters 25]`

use super::ExpOptions;
use crate::engine::{self, EngineConfig, OptimizerKind};
use crate::models::ModelKind;
use crate::sim::{Dataset, NetKind};
use crate::space::Constraint;
use crate::util::csv::CsvWriter;
use anyhow::Result;

pub fn ablation(opts: &ExpOptions) -> Result<()> {
    println!("== Ablations (RNN, TrimTuner-DT unless noted) ==");
    let dataset = Dataset::generate(NetKind::Rnn, opts.dataset_seed);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
    let seeds = opts.seeds.min(3);
    let iters = opts.max_iters.min(25);

    let mut w = CsvWriter::create(
        format!("{}/ablation.csv", opts.out_dir),
        &["knob", "value", "final_acc_c", "std", "mean_rec_ms"],
    )?;

    let mut sweep = |label: &str,
                     w: &mut CsvWriter,
                     configure: &dyn Fn(&mut EngineConfig, f64),
                     values: &[f64],
                     optimizer: OptimizerKind|
     -> Result<()> {
        for &v in values {
            let mut finals = Vec::new();
            let mut recs = Vec::new();
            for seed in 0..seeds {
                let mut cfg =
                    EngineConfig::paper_default(optimizer, seed as u64);
                cfg.max_iters = iters;
                configure(&mut cfg, v);
                let run = engine::run(&dataset, &caps, &cfg);
                finals.push(run.final_accuracy_c());
                recs.push(run.mean_rec_wall_s() * 1e3);
            }
            let (m, s) = crate::util::stats::mean_std_pop(&finals);
            let rec = crate::util::stats::mean(&recs);
            println!(
                "  {label:<22} = {v:<6} final Acc_C {m:.4}±{s:.4}  rec {rec:.1} ms"
            );
            w.row(&[
                label.to_string(),
                format!("{v}"),
                format!("{m:.4}"),
                format!("{s:.4}"),
                format!("{rec:.2}"),
            ])?;
        }
        Ok(())
    };

    let dt = OptimizerKind::TrimTuner(ModelKind::Trees);
    let gp = OptimizerKind::TrimTuner(ModelKind::Gp);
    sweep(
        "n_rep (p_opt set)",
        &mut w,
        &|cfg, v| cfg.n_rep = v as usize,
        &[10.0, 40.0, 80.0],
        dt,
    )?;
    sweep(
        "n_popt_samples",
        &mut w,
        &|cfg, v| cfg.n_popt_samples = v as usize,
        &[40.0, 160.0, 320.0],
        dt,
    )?;
    sweep(
        "refit.every (GP)",
        &mut w,
        &|cfg, v| cfg.refit.every = v as usize,
        &[1.0, 3.0, 10.0],
        gp,
    )?;
    sweep(
        "gp_hyper_samples (GP)",
        &mut w,
        &|cfg, v| cfg.gp_hyper_samples = v as usize,
        &[1.0, 8.0, 16.0],
        gp,
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_writes_csv() {
        let dir = std::env::temp_dir().join("trimtuner_ablation_test");
        std::fs::create_dir_all(&dir).unwrap();
        let opts = ExpOptions {
            out_dir: dir.to_str().unwrap().to_string(),
            seeds: 1,
            max_iters: 3,
            dataset_seed: 42,
            full: false,
        };
        ablation(&opts).unwrap();
        let t = crate::util::csv::CsvTable::read(dir.join("ablation.csv"))
            .unwrap();
        assert_eq!(t.header[0], "knob");
        assert_eq!(t.rows.len(), 12);
        std::fs::remove_dir_all(&dir).ok();
    }
}
