//! The TrimTuner search space (paper Table I): cloud parameters (VM type,
//! #VMs) × TensorFlow parameters (learning rate, batch size, training mode)
//! × sub-sampling rate.
//!
//! A [`Config`] is one of the 288 cloud/hyper-parameter combinations; a
//! [`Point`] pairs a config with a sub-sampling level (one of 5), giving the
//! 1440-point grid over which the optimizers search. [`encode`] maps a point
//! to the 7-dimensional normalized feature vector shared with the Layer-1
//! Pallas kernel (column 6 is `s` — keep in sync with
//! `python/compile/kernels/matern_fabolas.py`).

mod catalog;
mod constraint;
mod encode;

pub use catalog::*;
pub use constraint::*;
pub use encode::*;
