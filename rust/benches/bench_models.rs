//! Surrogate-model micro-benchmarks: GP (ML-II and marginalized) vs
//! Extra-Trees fit / predict / condition — the primitives whose cost ratio
//! drives paper Table III — plus the batched-vs-per-candidate slate
//! comparisons this crate's α_T sweep is built on:
//!
//! - `predict_many` vs a scalar `predict` loop (GP: one multi-RHS solve
//!   per hyper-sample; trees: tree-major traversal);
//! - the fantasy-slate conditioning paths: GP slate-primed rank-one views
//!   vs per-candidate priming, and trees incremental leaf-statistics
//!   conditioning vs the per-candidate seeded rebuild
//!   (`TRIMTUNER_TREES=rebuild`'s reference).
//!
//! - the refit sweep: per-observation incremental absorption
//!   ([`Surrogate::absorb`]) vs the from-scratch frozen refit
//!   (`TRIMTUNER_REFIT=full`'s reference, [`Surrogate::refit_frozen`])
//!   across observation-history sizes n ∈ {100, 1k, 10k} — the O(n²) vs
//!   O(n³) amortization the engine's `--refit every=K` cadence buys.
//!   GP rows stop at n = 1k: the one-time O(n³) baseline factorization
//!   needed just to *set up* the 10k fixture dominates the whole run, so
//!   only the trees rows cover the largest size.
//!
//! Results land in `BENCH_models.json` (override with `BENCH_JSON`). With
//! `BENCH_MODELS_SMOKE=1` (CI) the fixture shrinks and the harness exits
//! non-zero if either batched slate-conditioning path fails to beat its
//! per-candidate counterpart by >= 2x, or if incremental absorption fails
//! to beat the from-scratch frozen refit by >= 5x at n = 1k (best-of-run,
//! so shared-runner jitter cannot flip a correct build).
mod common;

use trimtuner::models::{
    Basis, ExtraTrees, FantasyScratch, FantasySurface, Feat, FitOptions, Gp,
    Surrogate, TreesMode, TreesOptions,
};
use trimtuner::space::encode;
use trimtuner::util::timer::{bench, BenchStats};

/// `speedup` rows store the mean-over-mean ratio in mean_s/p50_s/p99_s and
/// the best-of-run ratio (the gated quantity) in min_s/max_s.
fn speedup_row(
    name: String,
    iters: usize,
    base: (f64, f64),
    fast: (f64, f64),
) -> (BenchStats, f64) {
    let mean = base.0 / fast.0.max(1e-12);
    let best = base.1 / fast.1.max(1e-12);
    println!("{name:<44} {mean:.2}x (best-of {best:.2}x)");
    (
        BenchStats {
            name,
            iters,
            mean_s: mean,
            p50_s: mean,
            p99_s: mean,
            min_s: best,
            max_s: best,
        },
        best,
    )
}

fn main() {
    let smoke = std::env::var("BENCH_MODELS_SMOKE").is_ok();
    common::print_header(if smoke { "models (smoke)" } else { "models" });
    let (n_obs, slate_n, grid_n, iters) =
        if smoke { (36, 48, 20, 3) } else { (48, 96, 32, 10) };
    let (pts, outs) = common::observations(n_obs, 7);
    let xs: Vec<Feat> = pts.iter().map(encode).collect();
    let ys: Vec<f64> = outs.iter().map(|o| o.acc).collect();
    let probe = xs[0];
    // disjoint candidate slate + fused query grid, engine-sized
    let (slate_pts, _) = common::observations(slate_n, 83);
    let slate: Vec<Feat> = slate_pts.iter().map(encode).collect();
    let (grid_pts, _) = common::observations(grid_n, 19);
    let grid: Vec<Feat> = grid_pts.iter().map(encode).collect();
    let m_joint = grid_n / 2;

    let mut all: Vec<BenchStats> = Vec::new();
    let mut gate_failures: Vec<String> = Vec::new();

    for (label, k) in [("gp-ml2", 1usize), ("gp-mcmc8", 8)] {
        let mut gp = Gp::with_hyper_samples(Basis::Acc, 3, k);
        let stats =
            bench(&format!("{label} fit({n_obs}) w/ hyperopt"), 1, 3, || {
                gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
            });
        println!("{}", stats.report());
        all.push(stats);

        let stats = bench(
            &format!("{label} predict x{slate_n} scalar"),
            2,
            iters,
            || slate.iter().map(|x| gp.predict(x).0).sum::<f64>(),
        );
        println!("{}", stats.report());
        let t_scalar = (stats.mean_s, stats.min_s);
        all.push(stats);
        let stats = bench(
            &format!("{label} predict x{slate_n} batched"),
            2,
            iters,
            || {
                gp.predict_many(&slate)
                    .into_iter()
                    .map(|(mu, _)| mu)
                    .sum::<f64>()
            },
        );
        println!("{}", stats.report());
        let t_batch = (stats.mean_s, stats.min_s);
        all.push(stats);
        let (row, _) = speedup_row(
            format!("{label} predict batched-vs-scalar speedup"),
            iters,
            t_scalar,
            t_batch,
        );
        all.push(row);

        let stats =
            bench(&format!("{label} condition+predict"), 2, iters, || {
                let g = gp.condition(&probe, 0.9);
                g.predict(&probe).0
            });
        println!("{}", stats.report());
        all.push(stats);

        // fantasy-slate conditioning: slate-primed rank-one views (one
        // multi-RHS w solve per hyper-sample for the whole slate) vs
        // per-candidate views (each priming its own single-column solve)
        let surf = gp.fantasy_surface(&grid, m_joint);
        let stats = bench(
            &format!("{label} fantasy slate x{slate_n} per-candidate"),
            1,
            iters,
            || slate.iter().map(|x| surf.view(x).grid[0].0).sum::<f64>(),
        );
        println!("{}", stats.report());
        let t_per = (stats.mean_s, stats.min_s);
        all.push(stats);
        let stats = bench(
            &format!("{label} fantasy slate x{slate_n} primed"),
            1,
            iters,
            || {
                let primed = surf.prime(&slate);
                let mut scratch = FantasyScratch::new();
                (0..slate.len())
                    .map(|i| primed.view_at(i, &mut scratch).grid[0].0)
                    .sum::<f64>()
            },
        );
        println!("{}", stats.report());
        let t_primed = (stats.mean_s, stats.min_s);
        all.push(stats);
        let (row, best) = speedup_row(
            format!("{label} fantasy primed-vs-per-candidate speedup"),
            iters,
            t_per,
            t_primed,
        );
        all.push(row);
        if smoke && label == "gp-mcmc8" && best < 2.0 {
            gate_failures.push(format!(
                "{label}: primed fantasy slate best-of {best:.2}x < 2x"
            ));
        }
    }

    let mut et = ExtraTrees::new(TreesOptions::default());
    let stats =
        bench(&format!("extra-trees fit({n_obs}, 30 trees)"), 1, iters, || {
            et.fit(&xs, &ys, FitOptions::default());
        });
    println!("{}", stats.report());
    all.push(stats);

    let stats = bench(
        &format!("extra-trees predict x{slate_n} scalar"),
        2,
        iters,
        || slate.iter().map(|x| et.predict(x).0).sum::<f64>(),
    );
    println!("{}", stats.report());
    let t_scalar = (stats.mean_s, stats.min_s);
    all.push(stats);
    let stats = bench(
        &format!("extra-trees predict x{slate_n} batched"),
        2,
        iters,
        || {
            et.predict_many(&slate)
                .into_iter()
                .map(|(mu, _)| mu)
                .sum::<f64>()
        },
    );
    println!("{}", stats.report());
    let t_batch = (stats.mean_s, stats.min_s);
    all.push(stats);
    let (row, _) = speedup_row(
        "extra-trees predict batched-vs-scalar speedup".to_string(),
        iters,
        t_scalar,
        t_batch,
    );
    all.push(row);

    let stats = bench("extra-trees condition+predict", 2, iters, || {
        let t = et.condition(&probe, 0.9);
        t.predict(&probe).0
    });
    println!("{}", stats.report());
    all.push(stats);

    // trees fantasy-slate conditioning: the incremental leaf-statistics
    // path (structure + grid routes cached once per slate) vs the
    // per-candidate seeded rebuild reference
    let inc = et.fantasy_surface_mode(&grid, m_joint, TreesMode::Incremental);
    let reb = et.fantasy_surface_mode(&grid, m_joint, TreesMode::Rebuild);
    let stats = bench(
        &format!("extra-trees fantasy slate x{slate_n} rebuild"),
        1,
        iters,
        || slate.iter().map(|x| reb.view(x).grid[0].0).sum::<f64>(),
    );
    println!("{}", stats.report());
    let t_reb = (stats.mean_s, stats.min_s);
    all.push(stats);
    let stats = bench(
        &format!("extra-trees fantasy slate x{slate_n} incremental"),
        1,
        iters,
        || {
            let primed = inc.prime(&slate);
            let mut scratch = FantasyScratch::new();
            (0..slate.len())
                .map(|i| primed.view_at(i, &mut scratch).grid[0].0)
                .sum::<f64>()
        },
    );
    println!("{}", stats.report());
    let t_inc = (stats.mean_s, stats.min_s);
    all.push(stats);
    let (row, best) = speedup_row(
        "extra-trees fantasy incremental-vs-rebuild speedup".to_string(),
        iters,
        t_reb,
        t_inc,
    );
    all.push(row);
    if smoke && best < 2.0 {
        gate_failures.push(format!(
            "extra-trees: incremental fantasy slate best-of {best:.2}x < 2x"
        ));
    }

    // ---- refit sweep: incremental absorb vs from-scratch frozen refit --
    // Both paths maintain the same surrogate state (pinned by
    // tests/refit_parity.rs); this measures the O(n²)-vs-O(n³) gap the
    // engine's `--refit every=K` cadence amortizes. The history drifts by
    // a handful of observations while the absorb closure runs — at these
    // sizes that perturbs the per-call cost by well under the run-to-run
    // jitter.
    let refit_ns: &[usize] =
        if smoke { &[100, 1000] } else { &[100, 1000, 10_000] };
    for &n in refit_ns {
        let (pts_n, outs_n) = common::observations(n + 64, 29);
        let xs_n: Vec<Feat> = pts_n.iter().map(encode).collect();
        let ys_n: Vec<f64> = outs_n.iter().map(|o| o.acc).collect();

        // GP: hyper-parameters frozen throughout (absorb never re-learns
        // them); skipped at n = 10k — see the module docs
        if n <= 1000 {
            let mut gp = Gp::with_hyper_samples(Basis::Acc, 3, 1);
            // hyperopt off: the sweep measures the refit paths, not the
            // Nelder-Mead search (which would evaluate O(n^3) NLLs here)
            gp.fit(
                &xs_n[..n],
                &ys_n[..n],
                FitOptions { hyperopt: false, restarts: 0 },
            );
            let mut next = n;
            let stats =
                bench(&format!("gp-ml2 absorb(+1 obs) @n={n}"), 1, iters, || {
                    let i = next % xs_n.len();
                    next += 1;
                    gp.absorb(&xs_n[i], ys_n[i]);
                });
            println!("{}", stats.report());
            let t_inc = (stats.mean_s, stats.min_s);
            all.push(stats);
            let stats =
                bench(&format!("gp-ml2 refit_frozen @n={n}"), 1, iters, || {
                    gp.refit_frozen();
                });
            println!("{}", stats.report());
            let t_full = (stats.mean_s, stats.min_s);
            all.push(stats);
            let (row, best) = speedup_row(
                format!("gp-ml2 absorb-vs-refit_frozen speedup @n={n}"),
                iters,
                t_full,
                t_inc,
            );
            all.push(row);
            if smoke && n == 1000 && best < 5.0 {
                gate_failures.push(format!(
                    "gp-ml2: absorb best-of {best:.2}x < 5x refit_frozen @n={n}"
                ));
            }
        }

        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs_n[..n], &ys_n[..n], FitOptions::default());
        let mut next = n;
        let stats = bench(
            &format!("extra-trees absorb(+1 obs) @n={n}"),
            1,
            iters,
            || {
                let i = next % xs_n.len();
                next += 1;
                et.absorb(&xs_n[i], ys_n[i]);
            },
        );
        println!("{}", stats.report());
        let t_inc = (stats.mean_s, stats.min_s);
        all.push(stats);
        let stats = bench(
            &format!("extra-trees refit_frozen @n={n}"),
            1,
            iters,
            || et.refit_frozen(),
        );
        println!("{}", stats.report());
        let t_full = (stats.mean_s, stats.min_s);
        all.push(stats);
        let (row, best) = speedup_row(
            format!("extra-trees absorb-vs-refit_frozen speedup @n={n}"),
            iters,
            t_full,
            t_inc,
        );
        all.push(row);
        if smoke && n == 1000 && best < 5.0 {
            gate_failures.push(format!(
                "extra-trees: absorb best-of {best:.2}x < 5x refit_frozen @n={n}"
            ));
        }
    }

    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_models.json".to_string());
    common::write_bench_json("models", &path, &all);

    if !gate_failures.is_empty() {
        eprintln!("MODELS PERF GATE FAILED: {}", gate_failures.join("; "));
        std::process::exit(1);
    }
}
