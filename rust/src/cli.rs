//! Hand-rolled CLI argument parsing (offline registry has no `clap`).

use std::collections::HashMap;

/// Parsed command line: positional arguments + `--key value` flags
/// (`--flag` with no value is stored as "true").
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                let next_is_value =
                    argv.get(i + 1).map_or(false, |n| !n.starts_with("--"));
                if next_is_value {
                    a.flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    a.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Boolean switch: bare `--flag` (stored as "true") or an explicit
    /// `--flag true|false`.
    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            Some(v) => v != "false" && v != "0",
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = Args::parse(&argv(
            "repro fig1 --out results --seeds 5 --full",
        ));
        assert_eq!(a.positional, vec!["repro", "fig1"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get_usize("seeds", 0), 5);
        assert!(a.has("full"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("optimize"));
        assert_eq!(a.get_f64("beta", 0.1), 0.1);
        assert_eq!(a.get_or("net", "mlp"), "mlp");
    }

    #[test]
    fn bool_switches() {
        let a = Args::parse(&argv("optimize --live --workers 4"));
        assert!(a.get_bool("live"));
        assert!(!a.get_bool("replay"));
        let b = Args::parse(&argv("optimize --live false"));
        assert!(!b.get_bool("live"));
    }
}
