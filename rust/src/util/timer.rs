//! Timing helpers shared by the experiment harness and the custom benches.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Summary of a micro-benchmark run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>6} iters  mean {}  p50 {}  p99 {}  min {}",
            self.name,
            self.iters,
            fmt_dur(self.mean_s),
            fmt_dur(self.p50_s),
            fmt_dur(self.p99_s),
            fmt_dur(self.min_s),
        )
    }
}

pub fn fmt_dur(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:8.3} s")
    } else if s >= 1e-3 {
        format!("{:8.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:8.3} µs", s * 1e6)
    } else {
        format!("{:8.1} ns", s * 1e9)
    }
}

/// Criterion-free micro-bench: warm up, then time `iters` runs of `f`.
/// `f` should return something observable to prevent dead-code elimination;
/// we black-box it via `std::hint::black_box`.
pub fn bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pick = |p: f64| samples[((p * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
    BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        p50_s: pick(0.5),
        p99_s: pick(0.99),
        min_s: samples[0],
        max_s: *samples.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let stats = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..50_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(stats.mean_s > 0.0);
        assert!(stats.min_s <= stats.p50_s && stats.p50_s <= stats.max_s);
        assert_eq!(stats.iters, 5);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(2.0).contains('s'));
        assert!(fmt_dur(2e-3).contains("ms"));
        assert!(fmt_dur(2e-6).contains("µs"));
        assert!(fmt_dur(2e-9).contains("ns"));
    }
}
