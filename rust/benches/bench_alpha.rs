//! α_T slate-sweep benchmark: fantasy rank-one conditioning vs the
//! clone-and-extend baseline, at the engine's default slate size
//! (β = 0.1 of the 1440-point grid).
//!
//! Each measured unit is one *full per-iteration α_T sweep* — exactly what
//! an Algorithm-1 iteration spends between choosing candidates and probing
//! one — evaluated three ways:
//!
//! - `clone threads=1`   — per-candidate `Models::condition`
//!   (`TRIMTUNER_ALPHA=clone` path), the paper-faithful baseline;
//! - `fantasy threads=1` — shared per-iteration fantasy posteriors +
//!   rank-one conditioning per candidate (like-for-like speedup);
//! - `fantasy threads=N` — the same, sharded across all cores (what the
//!   engine actually runs).
//!
//! The fantasy path is slate-batched end to end (PR 5): the per-candidate
//! `w = L⁻¹k(X, x)` triangular solves ride one multi-RHS pass per GP
//! hyper-sample, the trees ensemble conditions incrementally off one
//! cached structure instead of a seeded rebuild per candidate, and the
//! per-candidate p_opt scratch is reused across the slate.
//!
//! The `speedup` rows store the threads=1 fantasy-vs-clone ratio in
//! `mean_s`. Results land in `BENCH_alpha.json` (override with
//! `BENCH_JSON`); CI runs the sweep with `BENCH_ALPHA_SMOKE=1` (smaller
//! fixture) and this harness exits non-zero if the best-of-run smoke
//! speedup drops below 2.5x for the hyper-marginalized GP variant or
//! below 2x for the trees variant.
mod common;

use trimtuner::acq::{
    joint_feasibility_many, AlphaMode, AlphaSlate, EntropyEstimator,
    TrimTunerAcq,
};
use trimtuner::models::{Feat, ModelKind};
use trimtuner::space::{encode, Config, Point};
use trimtuner::util::timer::{bench, BenchStats};
use trimtuner::util::Rng;

struct Sizes {
    n_obs: usize,
    n_rep: usize,
    n_mc: usize,
    shortlist: usize,
    slate_stride: usize,
    iters: usize,
}

fn main() {
    let smoke = std::env::var("BENCH_ALPHA_SMOKE").is_ok();
    common::print_header(if smoke { "alpha (smoke)" } else { "alpha" });
    let sz = if smoke {
        Sizes {
            n_obs: 28,
            n_rep: 16,
            n_mc: 60,
            shortlist: 16,
            slate_stride: 30, // 48-candidate slate
            iters: 3,
        }
    } else {
        Sizes {
            n_obs: 48,
            n_rep: 40,
            n_mc: 160,
            shortlist: 32,
            slate_stride: 10, // the default β = 0.1 slate: 144 candidates
            iters: 5,
        }
    };

    let mut all: Vec<BenchStats> = Vec::new();
    let caps = common::caps();
    let full_feats: Vec<Feat> = (0..288)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let slate: Vec<Point> = (0..1440)
        .step_by(sz.slate_stride)
        .map(Point::from_id)
        .collect();
    let slate_feats: Vec<Feat> = slate.iter().map(encode).collect();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut gate_failures = Vec::new();
    for (label, kind, k) in [
        ("dt", ModelKind::Trees, 1usize),
        ("gp-ml2", ModelKind::Gp, 1),
        ("gp-mcmc8", ModelKind::Gp, if smoke { 4 } else { 8 }),
    ] {
        let models = common::fitted(kind, sz.n_obs, k);
        let mut rng = Rng::new(5);
        let rep: Vec<Feat> =
            (0..sz.n_rep).map(|i| full_feats[(i * 7) % 288]).collect();
        let est = EntropyEstimator::new(rep, sz.n_mc, &mut rng);
        let baseline = EntropyEstimator::kl_from_uniform(
            &est.p_opt(models.acc.as_ref()),
        );
        let shortlist: Vec<usize> = (0..sz.shortlist).collect();
        let shortlist_feats: Vec<Feat> =
            shortlist.iter().map(|&id| full_feats[id]).collect();
        let feas = joint_feasibility_many(&models, &caps, &shortlist_feats);
        let ctx = TrimTunerAcq {
            models: &models,
            est: &est,
            constraints: &caps,
            inc_shortlist: &shortlist,
            inc_shortlist_feats: &shortlist_feats,
            inc_feas: if models.constraints_fixed_under_condition() {
                Some(feas.as_slice())
            } else {
                None
            },
            baseline,
        };

        // sanity: the two paths must agree before their timing means much
        let ref_alpha = AlphaSlate::with_mode(&ctx, AlphaMode::Clone)
            .with_threads(1)
            .eval_feats(&slate_feats);
        let fan_alpha = AlphaSlate::with_mode(&ctx, AlphaMode::Fantasy)
            .with_threads(1)
            .eval_feats(&slate_feats);
        let max_rel = ref_alpha
            .iter()
            .zip(&fan_alpha)
            .map(|(a, b)| (a - b).abs() / a.abs().max(1e-12))
            .fold(0.0, f64::max);
        println!(
            "{:<44} {:.2e}",
            format!("{label} fantasy-vs-clone max rel diff"),
            max_rel
        );
        // coarse sanity only — the strict bounds (bit-exact for dt,
        // <= 1e-9 for GPs) live in tests/alpha_parity.rs; this guard just
        // refuses to time two computations that disagree
        assert!(
            max_rel < 1e-6,
            "{label}: fantasy path diverged from clone ({max_rel:.2e})"
        );

        let mut sweep = |mode: AlphaMode, threads: usize, tag: &str| {
            let stats = bench(
                &format!("{label} alpha_T sweep x{} {tag}", slate.len()),
                1,
                sz.iters,
                || {
                    AlphaSlate::with_mode(&ctx, mode)
                        .with_threads(threads)
                        .eval_feats(&slate_feats)
                },
            );
            println!("{}", stats.report());
            let timing = (stats.mean_s, stats.min_s);
            all.push(stats);
            timing
        };
        let t_clone = sweep(AlphaMode::Clone, 1, "clone threads=1");
        let t_fan = sweep(AlphaMode::Fantasy, 1, "fantasy threads=1");
        let t_par = sweep(
            AlphaMode::Fantasy,
            workers,
            &format!("fantasy threads={workers}"),
        );
        let speedup = t_clone.0 / t_fan.0.max(1e-12);
        let speedup_par = t_clone.0 / t_par.0.max(1e-12);
        // gate on best-of-run times: p50/p99 jitter on shared CI runners
        // must not flip a pass into a failure
        let speedup_best = t_clone.1 / t_fan.1.max(1e-12);
        println!(
            "{:<44} {speedup:.2}x (threads=1), {speedup_par:.2}x \
             (threads={workers})",
            format!("{label} fantasy-vs-clone speedup"),
        );
        all.push(BenchStats {
            name: format!("{label} fantasy-vs-clone speedup"),
            iters: sz.iters,
            mean_s: speedup,
            p50_s: speedup,
            p99_s: speedup,
            min_s: speedup,
            max_s: speedup_par,
        });
        // smoke gates on best-of-run times (shared-runner jitter must not
        // flip a pass into a failure): the hyper-marginalized GP default
        // must clear 2.5x (nudged up from the PR 3-era 2x by the batched
        // multi-RHS solves), and the trees variant — whose per-candidate
        // rebuild the incremental conditioning eliminated — must clear
        // 2x. Both thresholds are deliberately conservative: no authoring
        // container has had a toolchain yet, so ratchet them to match the
        // first measured numbers CI prints, not the other way around.
        let gate = match label {
            "gp-mcmc8" => 2.5,
            "dt" => 2.0,
            _ => 0.0,
        };
        if smoke && speedup_best < gate {
            gate_failures.push(format!(
                "{label}: best-of {speedup_best:.2}x < {gate}x smoke gate"
            ));
        }
    }

    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_alpha.json".to_string());
    common::write_bench_json("alpha", &path, &all);

    if !gate_failures.is_empty() {
        eprintln!("ALPHA PERF GATE FAILED: {}", gate_failures.join("; "));
        std::process::exit(1);
    }
}
