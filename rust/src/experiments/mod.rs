//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§IV) as CSV series + printed rows. See DESIGN.md §4 for the
//! experiment index.

mod ablation;
mod aggregate;
mod faults;
mod figures;
mod tables;

pub use ablation::ablation;
pub use faults::faults;
pub use aggregate::{average_runs, average_runs_axis, budget_to_target, BudgetAxis, CurvePoint};
pub use figures::{fig1, fig2, fig3, fig4};
pub use tables::{table1, table2, table3, table4};

use crate::cli::Args;
use anyhow::Result;

/// Shared experiment options parsed from the CLI.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub out_dir: String,
    pub seeds: usize,
    pub max_iters: usize,
    pub dataset_seed: u64,
    /// full paper scale (10 seeds) vs quick default
    pub full: bool,
}

impl ExpOptions {
    pub fn from_args(args: &Args) -> ExpOptions {
        let full = args.has("full");
        ExpOptions {
            out_dir: args.get_or("out", "results"),
            seeds: args.get_usize("seeds", if full { 10 } else { 5 }),
            max_iters: args.get_usize("iters", 44),
            dataset_seed: args.get_u64("dataset-seed", 42),
            full,
        }
    }
}

pub fn cmd_repro(args: &Args) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let opts = ExpOptions::from_args(args);
    std::fs::create_dir_all(&opts.out_dir)?;
    let t0 = std::time::Instant::now();
    match what {
        "table1" => table1(&opts)?,
        "table2" => table2(&opts)?,
        "table3" => table3(&opts)?,
        "table4" => table4(&opts)?,
        "fig1" => {
            fig1(&opts)?;
        }
        "fig2" => fig2(&opts)?,
        "fig3" => fig3(&opts)?,
        "fig4" => fig4(&opts)?,
        "ablation" => ablation(&opts)?,
        "faults" => faults(&opts)?,
        "all" => {
            table1(&opts)?;
            table2(&opts)?;
            let store = fig1(&opts)?;
            figures::fig2_from(&opts, &store)?;
            tables::table3_from(&opts, Some(&store))?;
            fig3(&opts)?;
            fig4(&opts)?;
            table4(&opts)?;
        }
        other => anyhow::bail!("unknown experiment {other}"),
    }
    eprintln!("repro {what}: done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
