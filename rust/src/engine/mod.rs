//! The Bayesian-optimization engine: paper Algorithm 1 plus all baseline
//! optimizers, driven through an [`EvalBackend`] — trace replay over a
//! measured [`crate::sim::Dataset`] (the paper's evaluation methodology) or
//! live job deployments through the threaded coordinator.
//!
//! The loop is organized in selection *rounds*: each round maximizes the
//! acquisition function, launches the chosen probe slate through the
//! backend, absorbs the results in submission order and refits the
//! surrogates once. With [`EngineConfig`]'s `batch_size` = 1 (the default)
//! a round is exactly one iteration of the paper's sequential Algorithm 1;
//! with q > 1 the engine submits the top-q slate concurrently through the
//! worker pool, diversifying picks 2..q by conditioning on the pending
//! ones ([`BatchMode`]: kriging-believer fantasy by default, constant-liar
//! or plain top-q via `TRIMTUNER_BATCH`). Stop conditions
//! ([`StopCondition`]) are evaluated at round boundaries.
//!
//! `async_mode` replaces the round barrier with a continuously-fed
//! scheduler: selection re-enters the moment any pool slot frees,
//! conditioned on all in-flight probes, keeping the pool saturated at an
//! occupancy target derived from the worker count (or pinned via
//! `max_inflight`). Completions are absorbed in logical (submission)
//! order, so async trajectories are bitwise independent of physical
//! completion order; stop conditions are evaluated after every absorbed
//! observation instead of at round boundaries. See `docs/ARCHITECTURE.md`,
//! "Asynchronous selection".

mod backend;
mod loop_;
mod metrics;
mod pareto;
mod stop;

pub use backend::{
    EvalBackend, FaultStats, LiveEval, Probe, ProbeResult, ProbeTicket,
    RetryPolicy, Snapshot,
};
pub use loop_::{
    run, run_backend, BatchMode, EngineConfig, OptimizerKind, RefitMode,
    RefitPolicy,
};
pub use metrics::{accuracy_c, cost_to_quality, IterRecord, RunResult};
pub use pareto::{
    frontier_quality, hypervolume, pareto_front, recommend_pareto,
    true_frontier, ParetoPoint,
};
pub use stop::StopCondition;
