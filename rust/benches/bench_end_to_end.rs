//! End-to-end optimizer benchmark (paper Fig. 1 per-run cost): full short
//! Algorithm-1 runs for each optimizer on the RNN campaign.
mod common;

use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;
use trimtuner::util::timer::bench;

fn main() {
    common::print_header("end-to-end runs (Fig 1 unit)");
    let dataset = Dataset::generate(NetKind::Rnn, 42);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Eic,
        OptimizerKind::RandomSearch,
    ] {
        let stats =
            bench(&format!("{} 20-iter run", optimizer.name()), 0, 3, || {
                let mut cfg = EngineConfig::paper_default(optimizer, 5);
                cfg.max_iters = 20;
                engine::run(&dataset, &caps, &cfg).final_accuracy_c()
            });
        println!("{}", stats.report());
    }
}
