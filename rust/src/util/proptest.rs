//! Tiny property-testing harness (the offline registry has no `proptest`).
//!
//! A property is a closure over a seeded [`Rng`]; `check` runs it for `cases`
//! independent seeds and reports the first failing seed so the case can be
//! replayed deterministically:
//!
//! ```
//! use trimtuner::util::proptest::check;
//! check("addition commutes", 64, |rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeds; panic with the failing seed + message.
pub fn check(
    name: &str,
    cases: usize,
    mut prop: impl FnMut(&mut Rng) -> Result<(), String>,
) {
    // Base seed is fixed so CI is deterministic; override with
    // TRIMTUNER_PROPTEST_SEED to explore.
    let base: u64 = std::env::var("TRIMTUNER_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x7714);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("u64 below bound", 32, |rng| {
            let n = 1 + rng.below(100);
            let v = rng.below(n);
            if v < n {
                Ok(())
            } else {
                Err(format!("{v} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }
}
