// R5 fire: the exact WorkerPool shutdown deadlock fixed in PR 2 —
// joining the workers while the bounded result receiver is still alive
// in the same scope. A worker blocked in `send` on the full result
// channel only observes shutdown through the channel disconnecting, so
// the join below waits forever.
use std::sync::mpsc::{Receiver, SyncSender};
use std::thread::JoinHandle;

struct Pool {
    submit_tx: Option<SyncSender<u64>>,
    result_rx: Option<Receiver<u64>>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    fn close(&mut self) {
        self.submit_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // too late: workers blocked in `send` never saw the disconnect
        self.result_rx.take();
    }
}
