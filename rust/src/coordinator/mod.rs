//! Threaded job coordinator: the systems layer that deploys training jobs
//! (simulated cloud fleets or real PJRT-backed training), implements the
//! paper's snapshot semantics for sub-sampled probes, and feeds results back
//! to the optimization engine.
//!
//! This is the engine's *live* execution spine: `engine::EvalBackend::Live`
//! wraps a [`WorkerPool`] over any [`JobLauncher`], so the same Algorithm 1
//! loop that replays a measured `Dataset` can instead drive real
//! (simulated-latency, noisy) deployments — `trimtuner optimize --live`.
//! Launch failures carry job-id attribution ([`JobError`]) so the engine
//! requeues the exact probe that failed, and every submission / completion
//! / failure / incumbent update lands in an [`EventLog`].
//!
//! The classic BO loop is sequential (each acquisition depends on the
//! last observation), but the coordinator parallelizes what the paper's
//! testbed parallelized — the initialization batch (independent LHS
//! deployments) — and, since the batched-probe extension landed, the main
//! loop itself: `optimize --live --batch-size q` submits the top-q
//! acquisition slate per round as concurrent jobs (points sharing a
//! configuration ride one snapshot deployment), drains results in
//! submission order for determinism, and refits once per round. See
//! `engine::EvalBackend::probe_slate` and `engine::BatchMode`.

//!
//! The spine is hardened against the transient-cloud failure modes of
//! [`faults`]: stacking launcher decorators inject spot preemption (partial
//! cost still charged), heavy-tailed stragglers, transient launch failures,
//! and deadlines — all deterministic per (fault seed, job id) — while the
//! engine's `RetryPolicy` retries, and ultimately *abandons*, faulted
//! probes instead of aborting the campaign.

mod events;
pub mod faults;
mod launcher;
mod pool;
mod sync;

pub use events::{Event, EventKind, EventLog};
pub use faults::{
    FaultSpec, FlakyLauncher, Interrupted, PreemptingLauncher, SpotMarket,
    StragglerLauncher, TimeoutLauncher,
};
pub use launcher::{job_ids, Job, JobLauncher, JobResult, SimLauncher};
pub use pool::{JobError, WorkerPool};

use crate::cli::Args;
use crate::sim::NetKind;
use crate::space::{Config, N_CONFIGS, S_INIT};
use crate::util::timer::Timer;
use crate::util::Rng;
use anyhow::Result;

/// `trimtuner serve`: drive a batch of training jobs through the worker
/// pool on the simulated cloud and report throughput + event statistics.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let net = NetKind::from_name(&args.get_or("net", "mlp"))
        .ok_or_else(|| anyhow::anyhow!("unknown net"))?;
    let n_jobs = args.get_usize("jobs", 16);
    let workers = args.get_usize("workers", 4);
    let seed = args.get_u64("seed", 0);

    let launcher = SimLauncher::new(net, seed);
    let pool = WorkerPool::new(Box::new(launcher), workers);
    let log = EventLog::new();
    let mut rng = Rng::new(seed);

    let t0 = Timer::start();
    for i in 0..n_jobs {
        let config = Config::from_id(rng.below(N_CONFIGS));
        let job = Job { id: i as u64, config, s_levels: S_INIT.to_vec() };
        log.record(EventKind::JobSubmitted { job: i as u64 });
        pool.submit(job)?;
    }
    let mut total_cost = 0.0;
    let mut total_snapshots = 0usize;
    for _ in 0..n_jobs {
        let r = pool.recv()?;
        total_cost += r.charged_cost;
        total_snapshots += r.outcomes.len();
        log.record(EventKind::JobCompleted {
            job: r.job_id,
            cost: r.charged_cost,
        });
    }
    pool.shutdown();
    let wall = t0.elapsed_s();

    println!(
        "serve: {n_jobs} jobs x {} snapshots on {workers} workers in {wall:.3}s ({:.1} jobs/s)",
        S_INIT.len(),
        n_jobs as f64 / wall
    );
    println!(
        "       total charged cost ${total_cost:.4}, {total_snapshots} snapshot observations",
    );
    println!("       events recorded: {}", log.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_pipeline_completes_and_charges_snapshot_costs() {
        let net = NetKind::Rnn;
        let launcher = SimLauncher::new(net, 3);
        let pool = WorkerPool::new(Box::new(launcher), 3);
        for i in 0..8u64 {
            pool.submit(Job {
                id: i,
                config: Config::from_id((i as usize * 31) % N_CONFIGS),
                s_levels: S_INIT.to_vec(),
            })
            .unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for _ in 0..8 {
            let r = pool.recv().unwrap();
            assert!(seen.insert(r.job_id));
            assert_eq!(r.outcomes.len(), S_INIT.len());
            // snapshot accounting: charged == the largest-s outcome's cost
            let max_cost = r
                .outcomes
                .iter()
                .map(|(_, o)| o.cost_usd)
                .fold(0.0, f64::max);
            assert!((r.charged_cost - max_cost).abs() < 1e-12);
        }
        pool.shutdown();
    }

    #[test]
    fn sim_launcher_is_deterministic_per_job_id() {
        let l1 = SimLauncher::new(NetKind::Mlp, 9);
        let l2 = SimLauncher::new(NetKind::Mlp, 9);
        let job = Job {
            id: 5,
            config: Config::from_id(100),
            s_levels: vec![0, 2],
        };
        let a = l1.launch(&job).unwrap();
        let b = l2.launch(&job).unwrap();
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for ((_, oa), (_, ob)) in a.outcomes.iter().zip(&b.outcomes) {
            assert!((oa.acc - ob.acc).abs() < 1e-12);
        }
    }
}
