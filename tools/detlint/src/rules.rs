//! The determinism & concurrency contracts (rules R1–R5) and the hot-path
//! allocation contracts (rules A1–A3), matched over the token stream
//! produced by [`crate::lexer`].
//!
//! Every rule reports rustc-style `file:line:col` findings with a rule id,
//! and every finding is suppressible by an inline pragma
//! (`// detlint: allow(R?, reason="…")` on the same or previous line, or
//! `allow-file` for the whole file) or by the allowlist file. Malformed
//! pragmas surface as `P0` findings, which nothing can suppress.

use crate::lexer::{lex, Pragma, Tok, TokKind};

/// Modules whose runs must be bit-reproducible from the seed (R1/R3).
/// `coordinator` is in the set since the fault-injection layer landed:
/// fault decisions (preemption, stragglers, flaky launches) must be pure
/// functions of (fault seed, job id), never of thread timing or ambient
/// entropy.
pub const DET_MODULES: &[&str] =
    &["engine", "acq", "heuristics", "models", "opt", "linalg", "coordinator"];

/// Modules with real cross-thread state (R4/R5).
pub const CONCURRENT_MODULES: &[&str] = &["coordinator", "engine"];

/// Modules carrying the allocation-free slate-sweep machinery (A2/A3):
/// the blocked linalg kernels, both surrogate backends, and the α_T
/// acquisition sweep. A1 is marker/registry-gated, so it is on tree-wide
/// and stays inert wherever nothing is marked hot.
pub const ALLOC_MODULES: &[&str] = &["linalg", "models", "acq"];

/// Built-in A1 hot-function registry, mirrored by
/// `tools/detlint/hotpaths.toml` (which overrides it when present). Only
/// the final `::` segment is matched against `fn` names; the qualifier is
/// documentation.
pub const DEFAULT_HOT: &[&str] = &[
    "PrimedSlate::view_at",
    "PrimedSlate::view_into",
    "Cholesky::solve_lower_into",
    "Cholesky::solve_lower_t_into",
    "Cholesky::solve_lower_multi_into",
    "Cholesky::update_into",
    "Cholesky::downdate_into",
    "Cholesky::extend_into",
    "Cholesky::extend_in_place",
    "Mat::matmul_into",
    "Gp::absorb",
    "ExtraTrees::absorb",
    "AlphaSlate::eval_primed",
    "EntropyEstimator::info_gain_from_with",
    "EntropyEstimator::p_opt_into",
    "Posterior::sample_with",
    "Posterior::sample_component_with",
];

/// Rule id → one-line contract, as printed by `detlint --rules`.
pub const RULES: &[(&str, &str)] = &[
    (
        "R1",
        "no iteration over HashMap/HashSet in deterministic modules \
         (engine, acq, heuristics, models, opt, linalg, coordinator); keyed \
         lookups are fine, ordered drains take a BTreeMap or an explicit \
         sort",
    ),
    (
        "R2",
        "no partial_cmp ranking (NaN-unsafe); route comparisons through \
         util::stats::cmp_nan_low / cmp_nan_high",
    ),
    (
        "R3",
        "no ambient clock or entropy (Instant, SystemTime, RandomState, \
         thread_rng) in seeded modules; route timing through util::timer \
         and randomness through the run's seeded util::Rng",
    ),
    (
        "R4",
        "no .lock().unwrap()/.expect() in coordinator/engine library code; \
         tolerate poisoning (PoisonError::into_inner) or allow with a \
         reason",
    ),
    (
        "R5",
        "no JoinHandle::join while a result receiver is live in the same \
         scope; drop/take the receiver first (the WorkerPool shutdown \
         deadlock shape)",
    ),
    (
        "A1",
        "no allocating calls (Vec::new, vec![], with_capacity, to_vec, \
         clone, collect, Box::new, Mat::zeros) inside hot functions — \
         those marked `// detlint: hot` or listed in \
         tools/detlint/hotpaths.toml; thread caller-provided scratch \
         instead",
    ),
    (
        "A2",
        "no allocating wrappers where a `*_into`/scratch twin exists \
         (solve_lower → solve_lower_into, matmul → matmul_into, \
         p_opt_from → p_opt_into, …) in allocation-contract modules \
         (linalg, models, acq)",
    ),
    (
        "A3",
        "no fresh scratch temporaries (`&mut Vec::new()`, \
         `&mut X::default()`, `&mut Cholesky::scratch()`) in argument \
         position: a throwaway buffer defeats the scratch API — hoist it \
         to a reused binding",
    ),
    ("P0", "malformed `// detlint:` pragma (cannot be suppressed)"),
];

/// One reported violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// Which rules apply to one file, plus the A1 hot-function registry.
#[derive(Debug, Clone)]
pub struct RuleSet {
    pub r1: bool,
    pub r2: bool,
    pub r3: bool,
    pub r4: bool,
    pub r5: bool,
    pub a1: bool,
    pub a2: bool,
    pub a3: bool,
    /// Hot-function names for A1 (qualified; only the final `::` segment
    /// is matched against `fn` names). Defaults to [`DEFAULT_HOT`];
    /// `tools/detlint/hotpaths.toml` overrides it via
    /// [`RuleSet::with_hot_fns`].
    pub hot_fns: Vec<String>,
}

fn default_hot() -> Vec<String> {
    DEFAULT_HOT.iter().map(|s| s.to_string()).collect()
}

impl RuleSet {
    /// Every rule on — fixture/self-test mode.
    pub fn all() -> RuleSet {
        RuleSet {
            r1: true,
            r2: true,
            r3: true,
            r4: true,
            r5: true,
            a1: true,
            a2: true,
            a3: true,
            hot_fns: default_hot(),
        }
    }

    /// Scope rules by module path: R2 and A1 are tree-wide (A1 stays
    /// inert without hot markers or registry hits), R1/R3 cover the
    /// deterministic modules, R4/R5 the concurrent ones, A2/A3 the
    /// allocation-contract modules.
    pub fn for_path(rel: &str) -> RuleSet {
        let p = rel.replace('\\', "/");
        let in_any = |mods: &[&str]| {
            mods.iter().any(|m| {
                p.contains(&format!("src/{m}/"))
                    || p.ends_with(&format!("src/{m}.rs"))
            })
        };
        RuleSet {
            r1: in_any(DET_MODULES),
            r2: true,
            r3: in_any(DET_MODULES),
            r4: in_any(CONCURRENT_MODULES),
            r5: in_any(CONCURRENT_MODULES),
            a1: true,
            a2: in_any(ALLOC_MODULES),
            a3: in_any(ALLOC_MODULES),
            hot_fns: default_hot(),
        }
    }

    /// Replace the A1 registry (the parsed `hotpaths.toml` contents).
    pub fn with_hot_fns(mut self, hot: &[String]) -> RuleSet {
        self.hot_fns = hot.to_vec();
        self
    }
}

/// Scan result for one file: surviving findings plus the pragma-suppressed
/// ones (kept so `--json` can report them; `suppressed` is their count).
pub struct ScanOutcome {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub suppressed_findings: Vec<Finding>,
}

/// Lint one file's source under the given rule scope.
pub fn scan_source(rel: &str, src: &str, rules: RuleSet) -> ScanOutcome {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let excl = excluded_ranges(toks);
    let mut raw: Vec<Finding> = Vec::new();
    for (line, msg) in &lexed.malformed {
        raw.push(Finding {
            file: rel.to_string(),
            line: *line,
            col: 1,
            rule: "P0",
            msg: msg.clone(),
        });
    }
    if rules.r1 {
        r1_hash_iteration(rel, toks, &excl, &mut raw);
    }
    if rules.r2 {
        r2_partial_cmp(rel, toks, &excl, &mut raw);
    }
    if rules.r3 {
        r3_ambient_entropy(rel, toks, &excl, &mut raw);
    }
    if rules.r4 {
        r4_lock_unwrap(rel, toks, &excl, &mut raw);
    }
    if rules.r5 {
        r5_join_order(rel, toks, &excl, &mut raw);
    }
    if rules.a1 {
        a1_hot_allocations(
            rel,
            toks,
            &excl,
            &lexed.hot_marks,
            &rules.hot_fns,
            &mut raw,
        );
    }
    if rules.a2 {
        a2_allocating_wrappers(rel, toks, &excl, &mut raw);
    }
    if rules.a3 {
        a3_fresh_scratch_args(rel, toks, &excl, &mut raw);
    }
    let mut findings = Vec::new();
    let mut suppressed_findings = Vec::new();
    for f in raw {
        if f.rule != "P0" && pragma_suppresses(&lexed.pragmas, &f) {
            suppressed_findings.push(f);
        } else {
            findings.push(f);
        }
    }
    let order = |v: &mut Vec<Finding>| {
        v.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
        v.dedup_by(|a, b| {
            a.line == b.line && a.col == b.col && a.rule == b.rule
        });
    };
    order(&mut findings);
    order(&mut suppressed_findings);
    ScanOutcome {
        findings,
        suppressed: suppressed_findings.len(),
        suppressed_findings,
    }
}

fn pragma_suppresses(ps: &[Pragma], f: &Finding) -> bool {
    ps.iter().any(|p| {
        let rule_hit = p.rules.iter().any(|r| r == "ALL" || r == f.rule);
        rule_hit && (p.file_level || f.line == p.line || f.line == p.line + 1)
    })
}

// ---- token-stream helpers -------------------------------------------------

fn ident_at<'t>(toks: &'t [Tok], i: usize) -> Option<&'t str> {
    match toks.get(i).map(|t| &t.kind) {
        Some(TokKind::Ident(s)) => Some(s.as_str()),
        _ => None,
    }
}

fn is_ident(toks: &[Tok], i: usize, s: &str) -> bool {
    ident_at(toks, i) == Some(s)
}

fn is_punct(toks: &[Tok], i: usize, c: char) -> bool {
    matches!(toks.get(i).map(|t| &t.kind), Some(TokKind::Punct(p)) if *p == c)
}

/// Scan forward up to `limit` tokens for any of `targets`, stopping at
/// punctuation that ends a type or initializer position.
fn scan_for(toks: &[Tok], start: usize, limit: usize, targets: &[&str]) -> bool {
    for j in start..(start + limit).min(toks.len()) {
        match &toks[j].kind {
            TokKind::Ident(s) if targets.iter().any(|t| t == s) => {
                return true;
            }
            TokKind::Punct(';')
            | TokKind::Punct('{')
            | TokKind::Punct(',')
            | TokKind::Punct(')') => return false,
            _ => {}
        }
    }
    false
}

fn push(
    out: &mut Vec<Finding>,
    rel: &str,
    t: &Tok,
    rule: &'static str,
    msg: String,
) {
    out.push(Finding {
        file: rel.to_string(),
        line: t.line,
        col: t.col,
        rule,
        msg,
    });
}

/// Token ranges under a `#[cfg(...)]` whose arguments mention `test`
/// (covers `cfg(test)` and combinations like `cfg(all(test, not(loom)))`).
/// Test-only code is exempt from every rule: tests may iterate maps, take
/// wall-clock timestamps and join freely.
fn excluded_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(toks, i, '#')
            && is_punct(toks, i + 1, '[')
            && is_ident(toks, i + 2, "cfg")
            && is_punct(toks, i + 3, '('))
        {
            i += 1;
            continue;
        }
        let mut depth = 1usize;
        let mut j = i + 4;
        let mut has_test = false;
        while j < toks.len() && depth > 0 {
            if is_punct(toks, j, '(') {
                depth += 1;
            } else if is_punct(toks, j, ')') {
                depth -= 1;
            } else if is_ident(toks, j, "test") {
                has_test = true;
            }
            j += 1;
        }
        if !has_test || !is_punct(toks, j, ']') {
            i = j;
            continue;
        }
        // skip any further attributes on the same item
        let mut k = j + 1;
        while is_punct(toks, k, '#') && is_punct(toks, k + 1, '[') {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if is_punct(toks, k, '[') {
                    d += 1;
                } else if is_punct(toks, k, ']') {
                    d -= 1;
                }
                k += 1;
            }
        }
        // the item body: through the matching `}` of its first `{`, or to
        // a top-level `;` for brace-less items
        let mut d = 0usize;
        let end = loop {
            if k >= toks.len() {
                break toks.len();
            }
            if is_punct(toks, k, '{') {
                d += 1;
            } else if is_punct(toks, k, '}') {
                d = d.saturating_sub(1);
                if d == 0 {
                    break k + 1;
                }
            } else if is_punct(toks, k, ';') && d == 0 {
                break k + 1;
            }
            k += 1;
        };
        out.push((i, end));
        i = end;
    }
    out
}

fn in_excluded(excl: &[(usize, usize)], i: usize) -> bool {
    excl.iter().any(|&(a, b)| i >= a && i < b)
}

// ---- R1: seeded-order iteration -------------------------------------------

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_keys",
    "into_values",
    "retain",
];

fn note_name(names: &mut Vec<String>, n: &str) {
    if !names.iter().any(|x| x == n) {
        names.push(n.to_string());
    }
}

fn r1_hash_iteration(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let hash = &["HashMap", "HashSet"];
    // pass 1: names whose declared type or initializer is a hash container
    // (`name: HashMap<..>` in params/fields/lets, `let name = HashMap::..`)
    let mut names: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if let Some(name) = ident_at(toks, i) {
            let path_pos = i > 0
                && (is_punct(toks, i - 1, ':') || is_punct(toks, i - 1, '.'));
            if !path_pos
                && is_punct(toks, i + 1, ':')
                && !is_punct(toks, i + 2, ':')
                && scan_for(toks, i + 2, 10, hash)
            {
                note_name(&mut names, name);
            }
        }
        if is_ident(toks, i, "let") {
            let mut k = i + 1;
            if is_ident(toks, k, "mut") {
                k += 1;
            }
            if let Some(name) = ident_at(toks, k) {
                if is_punct(toks, k + 1, '=') && scan_for(toks, k + 2, 10, hash)
                {
                    note_name(&mut names, name);
                }
            }
        }
    }
    // pass 2: order-sensitive drains of those names
    for i in 0..toks.len() {
        if in_excluded(excl, i) {
            continue;
        }
        if let Some(name) = ident_at(toks, i) {
            if names.iter().any(|n| n == name)
                && is_punct(toks, i + 1, '.')
                && ident_at(toks, i + 2)
                    .is_some_and(|m| ITER_METHODS.contains(&m))
                && is_punct(toks, i + 3, '(')
            {
                let m = ident_at(toks, i + 2).unwrap_or("iter");
                push(
                    out,
                    rel,
                    &toks[i + 2],
                    "R1",
                    format!(
                        "`{name}.{m}()` iterates a HashMap/HashSet in a \
                         deterministic module; its order is seeded per \
                         instance — use a BTreeMap/BTreeSet, sort the drain \
                         explicitly, or keep access keyed"
                    ),
                );
            }
        }
        if is_ident(toks, i, "for") {
            if let Some((j, name)) = for_loop_target(toks, i) {
                if names.iter().any(|n| n == name) {
                    push(
                        out,
                        rel,
                        &toks[j],
                        "R1",
                        format!(
                            "`for … in {name}` iterates a HashMap/HashSet in \
                             a deterministic module; its order is seeded per \
                             instance — use a BTreeMap/BTreeSet or an \
                             explicit sort"
                        ),
                    );
                }
            }
        }
    }
}

/// For `for <pat> in <expr> {`, return the last identifier of a plain
/// path/field expression (`xs`, `self.cache`) and its token index — only
/// when the loop body opens immediately after, so iterator-adaptor chains
/// (`xs.iter().map(…)`) are left to the method matcher.
fn for_loop_target<'t>(
    toks: &'t [Tok],
    i: usize,
) -> Option<(usize, &'t str)> {
    let mut j = i + 1;
    let limit = (i + 16).min(toks.len());
    while j < limit && !is_ident(toks, j, "in") {
        j += 1;
    }
    if j >= limit {
        return None;
    }
    j += 1;
    while is_punct(toks, j, '&') || is_ident(toks, j, "mut") {
        j += 1;
    }
    let mut last: Option<(usize, &str)> = None;
    while let Some(s) = ident_at(toks, j) {
        last = Some((j, s));
        if is_punct(toks, j + 1, '.') && ident_at(toks, j + 2).is_some() {
            j += 2;
        } else {
            j += 1;
            break;
        }
    }
    if !is_punct(toks, j, '{') {
        return None;
    }
    last
}

// ---- R2: NaN-unsafe ranking -----------------------------------------------

fn r2_partial_cmp(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if in_excluded(excl, i) {
            continue;
        }
        if is_ident(toks, i, "partial_cmp") {
            push(
                out,
                rel,
                &toks[i],
                "R2",
                "`partial_cmp` ranking is NaN-unsafe (panics or silently \
                 misorders); route through util::stats::cmp_nan_low / \
                 cmp_nan_high"
                    .to_string(),
            );
        }
    }
}

// ---- R3: ambient clock / entropy ------------------------------------------

const AMBIENT: &[&str] = &[
    "Instant",
    "SystemTime",
    "UNIX_EPOCH",
    "RandomState",
    "thread_rng",
    "from_entropy",
];

fn r3_ambient_entropy(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if in_excluded(excl, i) {
            continue;
        }
        if let Some(name) = ident_at(toks, i) {
            if AMBIENT.contains(&name) {
                push(
                    out,
                    rel,
                    &toks[i],
                    "R3",
                    format!(
                        "ambient clock/entropy `{name}` in a seeded module \
                         breaks replayability; route timing through \
                         util::timer::Timer and randomness through the \
                         run's seeded util::Rng"
                    ),
                );
            }
        }
    }
}

// ---- R4: unhandled lock poisoning -----------------------------------------

fn r4_lock_unwrap(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if in_excluded(excl, i) {
            continue;
        }
        if is_punct(toks, i, '.')
            && is_ident(toks, i + 1, "lock")
            && is_punct(toks, i + 2, '(')
            && is_punct(toks, i + 3, ')')
            && is_punct(toks, i + 4, '.')
        {
            if let Some(m) = ident_at(toks, i + 5) {
                if (m == "unwrap" || m == "expect")
                    && is_punct(toks, i + 6, '(')
                {
                    push(
                        out,
                        rel,
                        &toks[i + 5],
                        "R4",
                        format!(
                            "`.lock().{m}(…)` propagates lock poisoning as \
                             a panic in library code; use \
                             `.unwrap_or_else(PoisonError::into_inner)` \
                             where continuing is sound, or allow with a \
                             reason pragma where crashing is the right \
                             response"
                        ),
                    );
                }
            }
        }
    }
}

// ---- R5: join while a result receiver is live ------------------------------

fn r5_join_order(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    let mut i = 0usize;
    while i < toks.len() {
        if !is_ident(toks, i, "fn") {
            i += 1;
            continue;
        }
        // find the body's opening brace (or `;` for bare signatures)
        let mut j = i + 1;
        let mut open = None;
        while j < toks.len() {
            if is_punct(toks, j, ';') {
                break;
            }
            if is_punct(toks, j, '{') {
                open = Some(j);
                break;
            }
            j += 1;
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let mut d = 0usize;
        let mut k = open;
        let mut close = toks.len();
        while k < toks.len() {
            if is_punct(toks, k, '{') {
                d += 1;
            } else if is_punct(toks, k, '}') {
                d -= 1;
                if d == 0 {
                    close = k;
                    break;
                }
            }
            k += 1;
        }
        check_join_body(rel, toks, excl, open, close, out);
        // step inside so nested fns are scanned too (duplicate findings
        // from overlapping scopes are deduped in scan_source)
        i = open + 1;
    }
}

fn check_join_body(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    start: usize,
    end: usize,
    out: &mut Vec<Finding>,
) {
    // `.join()` with no arguments — JoinHandle::join, not str/Path join
    let mut first_join = None;
    for t in start..end {
        if in_excluded(excl, t) {
            continue;
        }
        if is_punct(toks, t, '.')
            && is_ident(toks, t + 1, "join")
            && is_punct(toks, t + 2, '(')
            && is_punct(toks, t + 3, ')')
        {
            first_join = Some(t + 1);
            break;
        }
    }
    let Some(join_at) = first_join else {
        return;
    };
    // receiver-like bindings in scope: `rx`, `*_rx`, `receiver`, or any
    // name annotated with a `Receiver<…>` type
    let mut rxs: Vec<&str> = Vec::new();
    for t in start..end {
        if let Some(s) = ident_at(toks, t) {
            let rx_like = s == "rx"
                || s == "receiver"
                || s.ends_with("_rx")
                || (is_punct(toks, t + 1, ':')
                    && !is_punct(toks, t + 2, ':')
                    && scan_for(toks, t + 2, 10, &["Receiver"]));
            if rx_like && !rxs.contains(&s) {
                rxs.push(s);
            }
        }
    }
    for name in rxs {
        if released_before(toks, start, join_at, name) {
            continue;
        }
        push(
            out,
            rel,
            &toks[join_at],
            "R5",
            format!(
                "`join()` is reached while result receiver `{name}` is \
                 still live in this scope — drop/take the receiver before \
                 joining: a worker blocked in `send` on a full bounded \
                 channel only observes shutdown through the channel \
                 disconnecting (the PR 2 WorkerPool deadlock)"
            ),
        );
    }
}

// ---- A1: allocation inside hot functions -----------------------------------

/// Owner types whose `::` constructors allocate.
const ALLOC_TYPES: &[&str] = &[
    "Vec", "VecDeque", "Box", "String", "BTreeMap", "BTreeSet", "HashMap",
    "HashSet", "Mat",
];
/// Allocating constructor names on the types above.
const ALLOC_CTORS: &[&str] = &["new", "with_capacity", "from", "zeros"];
/// Allocating method calls banned in hot bodies.
const ALLOC_METHODS: &[&str] =
    &["clone", "to_vec", "to_owned", "to_string", "collect"];

/// Does a registry entry's final `::` segment name this `fn`?
fn hot_name(hot_fns: &[String], name: &str) -> bool {
    hot_fns.iter().any(|h| h.rsplit("::").next() == Some(name))
}

/// After a method ident, skip an optional `::<…>` turbofish and report
/// whether a call's `(` follows (so `.collect::<Vec<_>>()` still matches).
fn after_generics_is_call(toks: &[Tok], mut k: usize, end: usize) -> bool {
    if is_punct(toks, k, ':')
        && is_punct(toks, k + 1, ':')
        && is_punct(toks, k + 2, '<')
    {
        let mut d = 1usize;
        k += 3;
        while k < end && d > 0 {
            if is_punct(toks, k, '<') {
                d += 1;
            } else if is_punct(toks, k, '>') {
                d -= 1;
            }
            k += 1;
        }
    }
    is_punct(toks, k, '(')
}

fn a1_hot_allocations(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    hot_marks: &[u32],
    hot_fns: &[String],
    out: &mut Vec<Finding>,
) {
    let mut i = 0usize;
    while i < toks.len() {
        if !is_ident(toks, i, "fn") || in_excluded(excl, i) {
            i += 1;
            continue;
        }
        let fn_line = toks[i].line;
        let name = ident_at(toks, i + 1).unwrap_or("").to_string();
        // `// detlint: hot` on the `fn` line, the line above, or two above
        // (tolerating one attribute line between marker and signature)
        let marked =
            hot_marks.iter().any(|&m| fn_line >= m && fn_line <= m + 2);
        let registered = hot_name(hot_fns, &name);
        // body braces, as in r5
        let mut j = i + 1;
        let mut open = None;
        while j < toks.len() {
            if is_punct(toks, j, ';') {
                break;
            }
            if is_punct(toks, j, '{') {
                open = Some(j);
                break;
            }
            j += 1;
        }
        let Some(open) = open else {
            i = j + 1;
            continue;
        };
        let mut d = 0usize;
        let mut k = open;
        let mut close = toks.len();
        while k < toks.len() {
            if is_punct(toks, k, '{') {
                d += 1;
            } else if is_punct(toks, k, '}') {
                d -= 1;
                if d == 0 {
                    close = k;
                    break;
                }
            }
            k += 1;
        }
        if marked || registered {
            scan_hot_body(rel, toks, excl, open + 1, close, &name, out);
        }
        // step inside so nested/closure-captured fns are scanned too
        i = open + 1;
    }
}

fn scan_hot_body(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    start: usize,
    end: usize,
    fn_name: &str,
    out: &mut Vec<Finding>,
) {
    let mut t = start;
    while t < end {
        if in_excluded(excl, t) {
            t += 1;
            continue;
        }
        if let Some(ty) = ident_at(toks, t) {
            // `Vec::new`, `Vec::with_capacity`, `Box::new`, `Mat::zeros`, …
            // (also as a bare fn value, e.g. `unwrap_or_else(Vec::new)` —
            // still one allocation per call on the hot path)
            if ALLOC_TYPES.contains(&ty)
                && is_punct(toks, t + 1, ':')
                && is_punct(toks, t + 2, ':')
            {
                if let Some(m) = ident_at(toks, t + 3) {
                    if ALLOC_CTORS.contains(&m) {
                        push(
                            out,
                            rel,
                            &toks[t],
                            "A1",
                            format!(
                                "`{ty}::{m}` allocates inside hot function \
                                 `{fn_name}`; thread a caller-provided \
                                 scratch buffer instead"
                            ),
                        );
                        t += 4;
                        continue;
                    }
                }
            }
            if ty == "vec" && is_punct(toks, t + 1, '!') {
                push(
                    out,
                    rel,
                    &toks[t],
                    "A1",
                    format!(
                        "`vec![…]` allocates inside hot function \
                         `{fn_name}`; thread a caller-provided scratch \
                         buffer instead"
                    ),
                );
                t += 2;
                continue;
            }
        }
        // `.clone()`, `.to_vec()`, `.collect::<…>()`, …
        if is_punct(toks, t, '.') {
            if let Some(m) = ident_at(toks, t + 1) {
                if ALLOC_METHODS.contains(&m)
                    && after_generics_is_call(toks, t + 2, end)
                {
                    push(
                        out,
                        rel,
                        &toks[t + 1],
                        "A1",
                        format!(
                            "`.{m}()` allocates inside hot function \
                             `{fn_name}`; reuse a scratch buffer (`clear` + \
                             `extend`/`copy_from`) instead"
                        ),
                    );
                    t += 2;
                    continue;
                }
            }
        }
        t += 1;
    }
}

// ---- A2: allocating wrapper where a scratch twin exists ---------------------

/// (allocating wrapper, scratch twin). Call sites of the wrapper inside
/// allocation-contract modules must use the twin. `update`/`downdate` are
/// deliberately absent — the bare names are too generic to match safely —
/// and their throwaway-buffer misuse is caught by A3 at the call site.
const A2_PAIRS: &[(&str, &str)] = &[
    ("solve_lower", "solve_lower_into"),
    ("solve_lower_t", "solve_lower_t_into"),
    ("solve_lower_multi", "solve_lower_multi_into"),
    ("matmul", "matmul_into"),
    ("p_opt_from", "p_opt_into"),
    ("info_gain_from", "info_gain_from_with"),
];

fn a2_allocating_wrappers(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if in_excluded(excl, i) || !is_punct(toks, i, '.') {
            continue;
        }
        let Some(m) = ident_at(toks, i + 1) else {
            continue;
        };
        let Some(&(_, twin)) = A2_PAIRS.iter().find(|(w, _)| *w == m) else {
            continue;
        };
        if !is_punct(toks, i + 2, '(') {
            continue;
        }
        push(
            out,
            rel,
            &toks[i + 1],
            "A2",
            format!(
                "`.{m}(…)` allocates its result on every call; use the \
                 scratch twin `{twin}` with a reused output buffer"
            ),
        );
    }
}

// ---- A3: fresh scratch temporaries in argument position ---------------------

/// Constructor names whose empty-argument calls read as throwaway scratch.
const SCRATCH_CTORS: &[&str] = &["new", "default", "scratch"];

fn a3_fresh_scratch_args(
    rel: &str,
    toks: &[Tok],
    excl: &[(usize, usize)],
    out: &mut Vec<Finding>,
) {
    for i in 0..toks.len() {
        if in_excluded(excl, i) {
            continue;
        }
        if !(is_punct(toks, i, '(') || is_punct(toks, i, ',')) {
            continue;
        }
        if !is_punct(toks, i + 1, '&') || !is_ident(toks, i + 2, "mut") {
            continue;
        }
        // `&mut vec![…]`
        if is_ident(toks, i + 3, "vec") && is_punct(toks, i + 4, '!') {
            push(
                out,
                rel,
                &toks[i + 3],
                "A3",
                "`&mut vec![…]` builds a throwaway buffer in argument \
                 position, defeating the scratch API; hoist it to a binding \
                 reused across calls"
                    .to_string(),
            );
            continue;
        }
        // `&mut Path::to::{new,default,scratch}()` with an empty argument
        // list (`Rng::new(seed)`-style seeded constructors don't match)
        let mut k = i + 3;
        let mut segs: Vec<&str> = Vec::new();
        let Some(first) = ident_at(toks, k) else {
            continue;
        };
        segs.push(first);
        while is_punct(toks, k + 1, ':')
            && is_punct(toks, k + 2, ':')
            && ident_at(toks, k + 3).is_some()
        {
            k += 3;
            segs.push(ident_at(toks, k).unwrap_or(""));
        }
        let last = *segs.last().unwrap_or(&"");
        if segs.len() >= 2
            && SCRATCH_CTORS.contains(&last)
            && is_punct(toks, k + 1, '(')
            && is_punct(toks, k + 2, ')')
        {
            let path = segs.join("::");
            push(
                out,
                rel,
                &toks[i + 3],
                "A3",
                format!(
                    "`&mut {path}()` builds a throwaway scratch value in \
                     argument position, defeating the scratch API; hoist it \
                     to a binding reused across calls"
                ),
            );
        }
    }
}

/// Was `name` released (`name.take(…)`, `name = None`, `drop(… name …)`)
/// anywhere before the join?
fn released_before(
    toks: &[Tok],
    start: usize,
    before: usize,
    name: &str,
) -> bool {
    for t in start..before {
        if ident_at(toks, t) == Some(name) {
            if is_punct(toks, t + 1, '.')
                && is_ident(toks, t + 2, "take")
                && is_punct(toks, t + 3, '(')
            {
                return true;
            }
            if is_punct(toks, t + 1, '=') && is_ident(toks, t + 2, "None") {
                return true;
            }
        }
        if is_ident(toks, t, "drop") && is_punct(toks, t + 1, '(') {
            let mut d = 1usize;
            let mut k = t + 2;
            while k < before && d > 0 {
                if is_punct(toks, k, '(') {
                    d += 1;
                } else if is_punct(toks, k, ')') {
                    d -= 1;
                } else if ident_at(toks, k) == Some(name) {
                    return true;
                }
                k += 1;
            }
        }
    }
    false
}
