//! Adaptive stop conditions (paper §III: "it would be relatively
//! straightforward to incorporate more sophisticated, adaptive
//! stop-conditions that, e.g., interrupt the optimization if the new
//! predicted incumbent does not improve significantly over the best known
//! optimum" — implemented here as a first-class extension).

use super::metrics::IterRecord;

/// When to terminate the main optimization loop (evaluated after every
/// iteration, in addition to `max_iters`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopCondition {
    /// paper default: fixed number of cycles only
    Never,
    /// stop when the incumbent's *model-predicted* accuracy
    /// (`IterRecord::inc_pred_acc`) has not improved by at least
    /// `min_delta` over the last `window` iterations. Deliberately blind
    /// to ground truth: the decision must be computable in a live run,
    /// where the true incumbent accuracy is unknown.
    ///
    /// The window counts *observations* only: probes abandoned under
    /// faults produce no record, and the engine skips the stop check
    /// entirely after a round whose every probe was abandoned — a round
    /// that observed nothing is no evidence of a plateau (see the main
    /// loop in `loop_`; pinned by `tests/fault_parity.rs`).
    ///
    /// Async mode needs no redefinition beyond that: without round
    /// boundaries the window is simply a sliding window over *absorbed
    /// observations* in logical order — the engine re-judges the condition
    /// after every absorption, and an abandoned pick contributes no record
    /// and triggers no check (pinned by `tests/async_parity.rs`).
    NoImprovement { window: usize, min_delta: f64 },
    /// stop once cumulative exploration cost exceeds the budget (USD)
    CostBudget(f64),
    /// stop once cumulative exploration time exceeds the budget (seconds)
    TimeBudget(f64),
}

impl StopCondition {
    /// Should the loop stop after producing `records` (init + main)?
    pub fn should_stop(&self, records: &[IterRecord]) -> bool {
        match *self {
            StopCondition::Never => false,
            StopCondition::CostBudget(max) => {
                records.last().map_or(false, |r| r.cum_cost >= max)
            }
            StopCondition::TimeBudget(max) => {
                records.last().map_or(false, |r| r.cum_time >= max)
            }
            StopCondition::NoImprovement { window, min_delta } => {
                let main: Vec<&IterRecord> =
                    records.iter().filter(|r| !r.is_init).collect();
                if main.len() <= window {
                    return false;
                }
                // best *predicted* incumbent accuracy before the window vs
                // within it (never the ground-truth `inc_acc`, which a
                // live tuner does not have)
                let split = main.len() - window;
                let before = main[..split]
                    .iter()
                    .map(|r| r.inc_pred_acc)
                    .fold(f64::NEG_INFINITY, f64::max);
                let within = main[split..]
                    .iter()
                    .map(|r| r.inc_pred_acc)
                    .fold(f64::NEG_INFINITY, f64::max);
                within - before < min_delta
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Dataset, NetKind, Outcome};
    use crate::space::Point;

    fn rec(is_init: bool, cum_cost: f64, cum_time: f64, pred: f64) -> IterRecord {
        let p = Point::from_id(4);
        let _ = Dataset::generate as usize; // keep imports honest
        IterRecord {
            iter: 0,
            is_init,
            round: 0,
            tested: p,
            outcome: Outcome { acc: 0.5, time_s: 1.0, cost_usd: 0.01 },
            explore_cost: 0.0,
            cum_cost,
            cum_time,
            duration_s: 1.0,
            rec_wall_s: 0.0,
            incumbent: p,
            inc_pred_acc: pred,
            inc_from_subsample: false,
            // ground truth deliberately disagrees with the prediction: a
            // correct NoImprovement must never read it
            inc_acc: f64::NAN,
            inc_feasible: true,
            accuracy_c: pred,
            n_alpha_evals: 0,
        }
    }

    #[test]
    fn never_never_stops() {
        let rs = vec![rec(false, 1e9, 1e9, 0.0)];
        assert!(!StopCondition::Never.should_stop(&rs));
    }

    #[test]
    fn budgets_trigger() {
        let rs = vec![rec(false, 0.5, 100.0, 0.9)];
        assert!(StopCondition::CostBudget(0.4).should_stop(&rs));
        assert!(!StopCondition::CostBudget(0.6).should_stop(&rs));
        assert!(StopCondition::TimeBudget(99.0).should_stop(&rs));
        assert!(!StopCondition::TimeBudget(101.0).should_stop(&rs));
    }

    #[test]
    fn no_improvement_waits_for_window_then_triggers() {
        let cond = StopCondition::NoImprovement { window: 3, min_delta: 0.01 };
        // improving run: never stops
        let rs: Vec<IterRecord> = (0..8)
            .map(|i| rec(i < 2, i as f64, i as f64, 0.5 + 0.05 * i as f64))
            .collect();
        assert!(!cond.should_stop(&rs));
        // plateaued run: stops once the window shows no gain
        let mut rs: Vec<IterRecord> = (0..3)
            .map(|i| rec(false, i as f64, i as f64, 0.8))
            .collect();
        assert!(!cond.should_stop(&rs), "window not full yet");
        for i in 3..7 {
            rs.push(rec(false, i as f64, i as f64, 0.8));
        }
        assert!(cond.should_stop(&rs));
        // init records are ignored
        let rs: Vec<IterRecord> =
            (0..10).map(|i| rec(true, i as f64, 0.0, 0.8)).collect();
        assert!(!cond.should_stop(&rs));
    }

    #[test]
    fn no_improvement_sees_every_observation_of_batched_rounds() {
        // Batched rounds (q > 1) record one observation per record but a
        // single recommendation per round, so consecutive records share
        // inc_pred_acc. The window is counted in *observations*: two
        // plateaued q=3 rounds must trip a window-3 condition.
        let cond = StopCondition::NoImprovement { window: 3, min_delta: 0.01 };
        let mut rs: Vec<IterRecord> = Vec::new();
        for _ in 0..3 {
            rs.push(rec(false, 0.0, 0.0, 0.8));
        }
        assert!(!cond.should_stop(&rs), "window not exceeded yet");
        for _ in 0..3 {
            rs.push(rec(false, 0.0, 0.0, 0.8));
        }
        assert!(cond.should_stop(&rs), "plateaued batched rounds must stop");
        // an improving second round keeps the run alive
        let mut rs: Vec<IterRecord> = Vec::new();
        for _ in 0..3 {
            rs.push(rec(false, 0.0, 0.0, 0.8));
        }
        for _ in 0..3 {
            rs.push(rec(false, 0.0, 0.0, 0.9));
        }
        assert!(!cond.should_stop(&rs));
    }

    #[test]
    fn integration_cost_budget_truncates_run() {
        use crate::engine::{self, EngineConfig, OptimizerKind};
        use crate::models::ModelKind;
        use crate::space::Constraint;
        let dataset = Dataset::generate(NetKind::Rnn, 42);
        let caps = [Constraint::cost_max(0.02)];
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            1,
        );
        cfg.max_iters = 40;
        cfg.stop = StopCondition::CostBudget(0.02);
        let run = engine::run(&dataset, &caps, &cfg);
        assert!(run.records.len() < 44, "stop condition never fired");
        assert!(run.total_cost() >= 0.02);
        // and it stops promptly: at most the init charge + one main
        // iteration can land past the budget
        let over: Vec<_> = run
            .records
            .iter()
            .filter(|r| r.cum_cost > 0.02)
            .collect();
        assert!(over.len() <= 2, "{} records past budget", over.len());
    }
}
