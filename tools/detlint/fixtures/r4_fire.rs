// R4 fire: lock poisoning propagated as a panic from library code — one
// panicking worker takes every later caller down with it.
use std::sync::Mutex;

fn record(events: &Mutex<Vec<u64>>, e: u64) {
    events.lock().unwrap().push(e);
}

fn len(events: &Mutex<Vec<u64>>) -> usize {
    events.lock().expect("poisoned").len()
}
