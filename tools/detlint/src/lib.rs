//! detlint — the repo's determinism & concurrency contracts (rules R1–R5)
//! as a source-level lint over `rust/src/**`.
//!
//! The engine's value rests on invariants the compiler cannot see:
//! bit-exact parity between sequential and sharded slate sweeps,
//! submission-order determinism across worker counts, and seeded RNG
//! streams that make live runs replayable. detlint encodes those as
//! named, individually-suppressible rules; `docs/ARCHITECTURE.md`
//! ("Determinism contracts") maps each invariant to its rule, and this
//! crate's README documents every rule with fire/allow examples.
//!
//! Suppression, most local first:
//! - `// detlint: allow(R1, reason="…")` on the finding's line or the
//!   line above;
//! - `// detlint: allow-file(R3, reason="…")` anywhere in the file;
//! - an entry in `tools/detlint/detlint.allow` (`<rule> <path> <reason>`).
//!
//! Malformed pragmas are themselves findings (`P0`) and cannot be
//! suppressed.

pub mod lexer;
pub mod rules;

use rules::{Finding, RuleSet};
use std::path::{Path, PathBuf};

/// Tree-scan result.
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub files: usize,
}

/// One `detlint.allow` entry: suppress `rule` everywhere in `path`.
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
}

/// Parse the allowlist file: `<rule> <path> <reason…>` per line, `#`
/// comments and blank lines ignored. The reason column is mandatory for
/// the same reason pragmas require one.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let rule = parts.next().unwrap_or_default();
        let path = parts.next().unwrap_or_default();
        let reason = parts.next();
        if path.is_empty() || reason.is_none() {
            return Err(format!(
                "detlint.allow:{}: expected `<rule> <path> <reason…>`, got `{line}`",
                idx + 1
            ));
        }
        out.push(AllowEntry { rule: rule.to_string(), path: path.to_string() });
    }
    Ok(out)
}

/// Recursively collect `*.rs` files, sorted for deterministic output.
pub fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn normalize(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Lint every `.rs` file under `paths` (files or directories), applying
/// path-scoped rules and the allowlist.
pub fn scan_tree(
    paths: &[PathBuf],
    allow: &[AllowEntry],
) -> std::io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(p, &mut files)?;
        } else {
            files.push(p.clone());
        }
    }
    files.sort();
    files.dedup();
    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = normalize(f);
        let mut out = rules::scan_source(&rel, &src, RuleSet::for_path(&rel));
        suppressed += out.suppressed;
        out.findings.retain(|fi| {
            let hit = allow.iter().any(|a| {
                a.rule.eq_ignore_ascii_case(fi.rule)
                    && (a.path == fi.file || fi.file.ends_with(&a.path))
            });
            if hit {
                suppressed += 1;
            }
            !hit
        });
        findings.append(&mut out.findings);
    }
    Ok(Report { findings, suppressed, files: files.len() })
}

/// Rustc-style rendering: `file:line:col: [rule] message`.
pub fn fmt_finding(f: &Finding) -> String {
    format!("{}:{}:{}: [{}] {}", f.file, f.line, f.col, f.rule, f.msg)
}

/// Run the fixture self-test: every rule R1–R5 must fire on its `*_fire.rs`
/// fixture and stay silent on its `*_allow.rs` variant (which contains
/// both a compliant rewrite and a pragma-suppressed violation, proving the
/// suppression machinery too). Returns one human-readable line per check.
pub fn self_test(fixtures: &Path) -> Result<Vec<String>, String> {
    let mut lines = Vec::new();
    for n in 1..=5u32 {
        let rule = format!("R{n}");
        for (suffix, expect_fire) in [("fire", true), ("allow", false)] {
            let name = format!("r{n}_{suffix}.rs");
            let path = fixtures.join(&name);
            let src = std::fs::read_to_string(&path)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let out = rules::scan_source(
                &format!("fixtures/{name}"),
                &src,
                RuleSet::all(),
            );
            if expect_fire {
                let hits =
                    out.findings.iter().filter(|f| f.rule == rule).count();
                if hits == 0 {
                    return Err(format!(
                        "{name}: expected {rule} to fire, got: {:?}",
                        out.findings
                            .iter()
                            .map(fmt_finding)
                            .collect::<Vec<_>>()
                    ));
                }
                lines.push(format!("{rule} fires on {name} ({hits}x)"));
            } else if let Some(f) = out.findings.first() {
                return Err(format!(
                    "{name}: expected a clean pass, got: {}",
                    fmt_finding(f)
                ));
            } else {
                lines.push(format!(
                    "{rule} passes {name} ({} pragma-suppressed)",
                    out.suppressed
                ));
            }
        }
    }
    Ok(lines)
}
