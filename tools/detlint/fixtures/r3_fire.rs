// R3 fire: ambient wall-clock in a seeded module — replaying the same
// seed can no longer reproduce the run.
fn stamp_s() -> f64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}
