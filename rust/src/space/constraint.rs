//! QoS constraints (paper Eq. 4): each constraint is `q_i(x, s=1) >= 0`
//! over an observable metric of the training run.

/// Metrics observable when a configuration is tested.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Metric {
    /// Cloud cost of the training run (USD).
    Cost,
    /// Wall-clock duration of the training run (seconds).
    Time,
}

impl Metric {
    pub fn name(&self) -> &'static str {
        match self {
            Metric::Cost => "cost",
            Metric::Time => "time",
        }
    }
}

/// Upper-bound constraint `metric <= max`, i.e. `q = max - metric >= 0`.
///
/// Constraint metrics are modeled in log space (they are positive with
/// multiplicative noise), so feasibility probabilities are evaluated as
/// `P(log metric <= log max)` under the surrogate's Gaussian posterior.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Constraint {
    pub metric: Metric,
    pub max: f64,
}

impl Constraint {
    pub fn cost_max(max_usd: f64) -> Constraint {
        Constraint { metric: Metric::Cost, max: max_usd }
    }
    pub fn time_max(max_s: f64) -> Constraint {
        Constraint { metric: Metric::Time, max: max_s }
    }

    /// q-value of an observation (>= 0 iff feasible).
    pub fn q(&self, obs_value: f64) -> f64 {
        self.max - obs_value
    }

    pub fn is_satisfied(&self, obs_value: f64) -> bool {
        self.q(obs_value) >= 0.0
    }

    pub fn describe(&self) -> String {
        match self.metric {
            Metric::Cost => format!("cost <= ${:.3}", self.max),
            Metric::Time => format!("time <= {:.0}s", self.max),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_sign_convention() {
        let c = Constraint::cost_max(0.06);
        assert!(c.is_satisfied(0.05));
        assert!(c.is_satisfied(0.06));
        assert!(!c.is_satisfied(0.061));
        assert!(c.q(0.01) > 0.0 && c.q(0.10) < 0.0);
    }

    #[test]
    fn describe_mentions_bound() {
        assert!(Constraint::cost_max(0.1).describe().contains("0.100"));
        assert!(Constraint::time_max(120.0).describe().contains("120"));
    }
}
