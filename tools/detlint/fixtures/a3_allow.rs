// A3 allow: scratch hoisted to bindings that live across the loop — each
// call reuses the grown buffer — plus one pragma'd temporary on an
// init-only path.

pub struct Scratch {
    pub work: Vec<f64>,
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch { work: Vec::new() }
    }
}

pub struct Factor {
    n: usize,
}

impl Factor {
    pub fn downdate_into(&self, u: &[f64], out: &mut [f64], work: &mut Vec<f64>) {
        work.clear();
        work.extend_from_slice(u);
        for i in 0..self.n {
            out[i] -= work[i];
        }
    }
}

pub fn sweep(factor: &Factor, us: &[Vec<f64>], out: &mut [f64]) {
    let mut work = Vec::new();
    for u in us {
        factor.downdate_into(u, out, &mut work);
    }
}

pub fn sweep_scored(factor: &Factor, us: &[Vec<f64>], out: &mut [f64], score: fn(&mut Scratch) -> f64) -> f64 {
    let mut acc = 0.0;
    let mut work = Vec::new();
    let mut s = Scratch::default();
    for u in us {
        factor.downdate_into(u, out, &mut work);
        acc += score(&mut s);
    }
    acc
}

pub fn init_check(factor: &Factor, u: &[f64], out: &mut [f64]) {
    // detlint: allow(A3, reason="init-only path, runs once per campaign")
    factor.downdate_into(u, out, &mut Vec::new());
}
