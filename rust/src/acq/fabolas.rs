//! FABOLAS acquisition (paper Eq. 3): information gain on the full-data-set
//! optimum per unit of (predicted) evaluation cost.

use super::entropy::EntropyEstimator;
use super::models::Models;
use crate::models::Feat;

/// α_F(x, s) = IG(p_opt after simulated observation at (x,s)) / C(x,s).
///
/// The expectation over the unknown outcome y is collapsed to the
/// single-root Gauss–Hermite approximation the paper adopts for α_T: the
/// simulated observation is the model's own predictive mean at (x, s)
/// (`Models::condition`). `baseline` is KL(p_opt ‖ u) of the *current*
/// accuracy model, computed once per iteration by the caller.
pub fn fabolas_alpha(
    models: &Models,
    est: &EntropyEstimator,
    baseline: f64,
    x: &Feat,
) -> f64 {
    let after = models.acc.condition(x, models.acc.predict(x).0);
    let gain = est.info_gain(after.as_ref(), baseline);
    gain / models.predicted_cost(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{encode, Config, Point};
    use crate::util::Rng;

    fn setup() -> (Models, EntropyEstimator, f64) {
        let sim = CloudSim::new(NetKind::Mlp);
        let mut rng = Rng::new(11);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..24 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut m = Models::new(ModelKind::Gp, 5);
        m.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
        let rep: Vec<_> = (0..24)
            .map(|i| {
                encode(&Point { config: Config::from_id(i * 12), s_idx: 4 })
            })
            .collect();
        let est = EntropyEstimator::new(rep, 200, &mut rng);
        let baseline = EntropyEstimator::kl_from_uniform(&est.p_opt(m.acc.as_ref()));
        (m, est, baseline)
    }

    #[test]
    fn alpha_nonnegative_and_finite() {
        let (m, est, baseline) = setup();
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            let a = fabolas_alpha(&m, &est, baseline, &encode(&p));
            assert!(a.is_finite() && a >= 0.0, "{a}");
        }
    }

    #[test]
    fn cheap_subsampled_probes_win_on_equal_gain() {
        // For the same config, testing at s=1/60 divides by a much smaller
        // predicted cost than s=1; unless the gain collapses, alpha should
        // usually favor cheaper probes. We check the cost denominators
        // directly to keep the test deterministic.
        let (m, _, _) = setup();
        let c = Config::from_id(100);
        let cheap = m.predicted_cost(&encode(&Point { config: c, s_idx: 0 }));
        let dear = m.predicted_cost(&encode(&Point { config: c, s_idx: 4 }));
        assert!(cheap < dear, "cheap {cheap} dear {dear}");
    }
}
