//! Multi-objective extension (paper §V future work): instead of a single
//! constrained incumbent, recommend the *Pareto front* of (training cost,
//! accuracy) over full-data-set configurations, as predicted by the fitted
//! surrogates. A user can then pick any operating point on the frontier —
//! the constrained incumbent of Algorithm 1 is one particular point of it.

use crate::acq::Models;
use crate::models::Feat;
use crate::sim::Dataset;
use crate::space::{encode, Config, Point, N_CONFIGS};
use crate::util::stats::{cmp_nan_high, cmp_nan_low};

/// One point of the predicted cost/accuracy frontier.
#[derive(Debug, Clone, Copy)]
pub struct ParetoPoint {
    pub config_id: usize,
    /// predicted accuracy at s = 1
    pub pred_acc: f64,
    /// predicted training cost at s = 1 (USD)
    pub pred_cost: f64,
}

/// Non-dominated (maximize accuracy, minimize cost) subset of points.
/// Input order is irrelevant; output is sorted by ascending cost.
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut sorted: Vec<ParetoPoint> = points.to_vec();
    // ascending cost, ties broken by descending accuracy; NaN predictions
    // sort last on both axes (and can never enter the front below)
    sorted.sort_by(|a, b| {
        cmp_nan_high(a.pred_cost, b.pred_cost)
            .then_with(|| cmp_nan_low(b.pred_acc, a.pred_acc))
    });
    let mut front = Vec::new();
    let mut best_acc = f64::NEG_INFINITY;
    for p in sorted {
        if p.pred_acc > best_acc {
            best_acc = p.pred_acc;
            front.push(p);
        }
    }
    front
}

/// Predict the cost/accuracy frontier over all full-data-set configs under
/// the current surrogate models.
pub fn recommend_pareto(models: &Models) -> Vec<ParetoPoint> {
    let xs: Vec<Feat> = (0..N_CONFIGS)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let accs = models.acc.predict_many(&xs);
    let costs = models.predicted_cost_many(&xs);
    let pts: Vec<ParetoPoint> = accs
        .into_iter()
        .zip(costs)
        .enumerate()
        .map(|(id, ((acc, _), cost))| ParetoPoint {
            config_id: id,
            pred_acc: acc,
            pred_cost: cost,
        })
        .collect();
    pareto_front(&pts)
}

/// 2D hypervolume of a (cost ↓, accuracy ↑) point set w.r.t. the reference
/// point `(ref_cost, 0)`: the area its Pareto front dominates inside the
/// box `cost ≤ ref_cost, acc ≥ 0`. Points costlier than the reference
/// contribute nothing.
pub fn hypervolume(points: &[ParetoPoint], ref_cost: f64) -> f64 {
    let front = pareto_front(points);
    let mut hv = 0.0;
    let mut prev_acc = 0.0;
    // the front is ascending in both cost and accuracy: each point adds
    // the rectangle from its cost to the reference, for its accuracy gain
    for p in &front {
        if p.pred_cost >= ref_cost {
            break;
        }
        let da = p.pred_acc.max(0.0) - prev_acc;
        if da > 0.0 {
            hv += da * (ref_cost - p.pred_cost);
            prev_acc = p.pred_acc;
        }
    }
    hv
}

/// The dataset's *measured* (cost, accuracy) frontier over full-data-set
/// configurations — the ground truth a predicted frontier is judged
/// against in replay mode.
pub fn true_frontier(dataset: &Dataset) -> Vec<ParetoPoint> {
    let pts: Vec<ParetoPoint> = (0..N_CONFIGS)
        .map(|id| {
            let o = dataset
                .outcome(&Point { config: Config::from_id(id), s_idx: 4 });
            ParetoPoint {
                config_id: id,
                pred_acc: o.acc,
                pred_cost: o.cost_usd,
            }
        })
        .collect();
    pareto_front(&pts)
}

/// Frontier-quality metric for replay evaluation: look up the *measured*
/// outcomes of the predicted frontier's configurations and compare their
/// hypervolume to the measured true frontier's (shared reference point
/// just beyond the costliest point of either set). 1.0 means the
/// recommendation recovers the true frontier; lower values mean dominated
/// or mispredicted configs.
pub fn frontier_quality(dataset: &Dataset, predicted: &[ParetoPoint]) -> f64 {
    let truth = true_frontier(dataset);
    let measured: Vec<ParetoPoint> = predicted
        .iter()
        .map(|p| {
            let o = dataset.outcome(&Point {
                config: Config::from_id(p.config_id),
                s_idx: 4,
            });
            ParetoPoint {
                config_id: p.config_id,
                pred_acc: o.acc,
                pred_cost: o.cost_usd,
            }
        })
        .collect();
    let ref_cost = truth
        .iter()
        .chain(&measured)
        .map(|p| p.pred_cost)
        .fold(0.0, f64::max)
        * 1.05
        + 1e-12;
    let hv_true = hypervolume(&truth, ref_cost);
    if hv_true <= 0.0 {
        return f64::NAN;
    }
    hypervolume(&measured, ref_cost) / hv_true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::util::Rng;

    fn pp(id: usize, acc: f64, cost: f64) -> ParetoPoint {
        ParetoPoint { config_id: id, pred_acc: acc, pred_cost: cost }
    }

    #[test]
    fn front_drops_dominated_points() {
        let pts = vec![
            pp(0, 0.9, 1.0),
            pp(1, 0.8, 2.0),  // dominated by 0 (worse acc, higher cost)
            pp(2, 0.95, 3.0),
            pp(3, 0.95, 4.0), // dominated by 2 (same acc, higher cost)
            pp(4, 0.5, 0.1),
        ];
        let front = pareto_front(&pts);
        let ids: Vec<usize> = front.iter().map(|p| p.config_id).collect();
        assert_eq!(ids, vec![4, 0, 2]);
        // frontier is monotone: cost up, accuracy up
        assert!(front.windows(2).all(|w| {
            w[0].pred_cost <= w[1].pred_cost && w[0].pred_acc < w[1].pred_acc
        }));
    }

    #[test]
    fn front_of_empty_and_singleton() {
        assert!(pareto_front(&[]).is_empty());
        let one = pareto_front(&[pp(7, 0.5, 0.5)]);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].config_id, 7);
    }

    #[test]
    fn hypervolume_of_simple_staircase() {
        // front: (cost 1, acc 0.5), (cost 2, acc 0.8); ref cost 4
        // area = 0.5·(4−1) + 0.3·(4−2) = 2.1; dominated points change nothing
        let pts =
            vec![pp(0, 0.5, 1.0), pp(1, 0.8, 2.0), pp(2, 0.4, 3.0)];
        assert!((hypervolume(&pts, 4.0) - 2.1).abs() < 1e-12);
        // points beyond the reference contribute nothing
        assert!((hypervolume(&pts, 1.5) - 0.25).abs() < 1e-12);
        assert_eq!(hypervolume(&[], 4.0), 0.0);
    }

    #[test]
    fn frontier_quality_perfect_for_true_frontier() {
        let d = crate::sim::Dataset::generate(NetKind::Mlp, 42);
        let truth = true_frontier(&d);
        assert!(!truth.is_empty());
        let q = frontier_quality(&d, &truth);
        assert!((q - 1.0).abs() < 1e-9, "quality {q}");
    }

    #[test]
    fn frontier_quality_penalizes_incomplete_recommendations() {
        let d = crate::sim::Dataset::generate(NetKind::Mlp, 42);
        let truth = true_frontier(&d);
        assert!(truth.len() >= 2, "degenerate frontier");
        // drop the most accurate point: the recommendation misses the top
        // of the staircase, so its hypervolume ratio must fall below 1
        let partial = &truth[..truth.len() - 1];
        let q = frontier_quality(&d, partial);
        assert!(q < 1.0 - 1e-12, "quality {q} not penalized");
        assert!(q > 0.0);
    }

    #[test]
    fn model_driven_frontier_is_consistent() {
        let sim = CloudSim::new(NetKind::Mlp);
        let mut rng = Rng::new(3);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..32 {
            let p = Point {
                config: Config::from_id(rng.below(N_CONFIGS)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut models = Models::new(ModelKind::Trees, 2);
        models.fit(&pts, &outs, FitOptions::default());
        let front = recommend_pareto(&models);
        assert!(!front.is_empty() && front.len() <= N_CONFIGS);
        assert!(front.windows(2).all(|w| {
            w[0].pred_cost <= w[1].pred_cost && w[0].pred_acc <= w[1].pred_acc
        }));
        // the most accurate predicted config must be the frontier's last
        let max_acc = front.last().unwrap().pred_acc;
        for id in 0..N_CONFIGS {
            let x = encode(&Point { config: Config::from_id(id), s_idx: 4 });
            assert!(models.acc.predict(&x).0 <= max_acc + 1e-9);
        }
    }
}
