//! Evaluation substrate for Algorithm 1: where "Train M in configuration
//! ⟨x, s⟩" actually happens.
//!
//! The paper evaluates trace-driven (replaying a measured lookup table),
//! but the algorithm itself tunes a *live* job — each probe is a real cloud
//! deployment with snapshot semantics for sub-sampled levels. [`EvalBackend`]
//! abstracts the two so the same engine loop drives both:
//!
//! - [`EvalBackend::Replay`] looks outcomes up in a pre-materialized
//!   [`Dataset`] (the paper's simulation methodology, deterministic and
//!   instant);
//! - [`EvalBackend::Live`] submits every probe as a [`Job`] through the
//!   threaded [`WorkerPool`] to any [`JobLauncher`] — the simulated cloud,
//!   or a real trainer. Sub-sampled levels of one config ride a single
//!   snapshot deployment charged at the largest level (paper §III), failed
//!   launches are requeued with job-id attribution, and every submission /
//!   completion / failure lands in an [`EventLog`].
//!
//! Ground truth is quarantined: the optimizer only ever sees [`Probe`] /
//! [`Snapshot`] observations. Evaluation-only record fields (the incumbent's
//! *true* accuracy, Accuracy_C) come from [`EvalBackend::eval_dataset`],
//! which is `None` for a live run unless an offline oracle is attached
//! explicitly via [`LiveEval::with_eval`].

use crate::coordinator::{
    EventKind, EventLog, Job, JobLauncher, JobResult, WorkerPool,
};
use crate::sim::{Dataset, Outcome};
use crate::space::{Config, Point};
use anyhow::{anyhow, Result};
// BTreeMap, not HashMap: the engine is a deterministic module (detlint
// R1) — even though today's access is keyed-only, an ordered container
// keeps any future drain of these books reproducible by construction.
use std::collections::BTreeMap;

/// One evaluated probe: the observation the optimizer sees, plus the
/// accounting of the deployment that produced it.
#[derive(Debug, Clone, Copy)]
pub struct Probe {
    pub outcome: Outcome,
    /// USD actually charged for the deployment
    pub charged_cost: f64,
    /// measured wall-clock duration of the deployment (s)
    pub duration_s: f64,
}

/// A snapshot deployment: one training run of `config`, observed at several
/// ascending sub-sampling levels, charged once at the largest level.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub outcomes: Vec<(usize, Outcome)>,
    pub charged_cost: f64,
    pub duration_s: f64,
}

/// How many times a failed launch is requeued before the run aborts.
const LAUNCH_RETRIES: usize = 3;

/// Live evaluation state: the worker pool, job-id bookkeeping, and the
/// observability log.
pub struct LiveEval<'a> {
    pool: WorkerPool,
    next_job: u64,
    pub log: EventLog,
    /// Optional ground-truth oracle for *evaluation-only* record fields
    /// (`inc_acc`, `accuracy_c`, `optimum_acc`). A real deployment has
    /// none; without it those fields are NaN and the optimizer still runs.
    eval: Option<&'a Dataset>,
}

impl<'a> LiveEval<'a> {
    pub fn new(launcher: Box<dyn JobLauncher>, workers: usize) -> LiveEval<'a> {
        LiveEval {
            pool: WorkerPool::new(launcher, workers),
            next_job: 0,
            log: EventLog::new(),
            eval: None,
        }
    }

    /// Attach an offline ground-truth oracle so records carry the same
    /// evaluation metrics a replay run would (for experiments/parity only —
    /// nothing on the optimization path reads it).
    pub fn with_eval(mut self, dataset: &'a Dataset) -> LiveEval<'a> {
        self.eval = Some(dataset);
        self
    }

    fn submit(&mut self, config: Config, s_levels: Vec<usize>) -> Result<u64> {
        let id = self.next_job;
        self.next_job += 1;
        self.submit_with_id(id, config, s_levels)?;
        Ok(id)
    }

    fn submit_with_id(
        &mut self,
        id: u64,
        config: Config,
        s_levels: Vec<usize>,
    ) -> Result<()> {
        self.log.record(EventKind::JobSubmitted { job: id });
        self.pool.submit(Job { id, config, s_levels })
    }

    /// Deterministic id for the `attempt`-th retry of job `original`:
    /// a function of (original id, attempt) rather than of the shared
    /// counter, so which of two concurrently-failed jobs reports first
    /// cannot swap the ids (and hence the launcher's per-id noise draws)
    /// between otherwise-identical runs. The high marker bit keeps retry
    /// ids disjoint from the sequential primary ids.
    fn retry_id(original: u64, attempt: usize) -> u64 {
        (1u64 << 63) | ((attempt as u64) << 48) | (original & 0xFFFF_FFFF_FFFF)
    }

    /// Drive a batch of deployments to completion and return their results
    /// in *submission order* (not completion order), so multi-worker runs
    /// stay deterministic. Failed launches are requeued up to
    /// [`LAUNCH_RETRIES`] times using the job id the pool attributes to the
    /// error.
    fn run_jobs(
        &mut self,
        specs: &[(Config, Vec<usize>)],
    ) -> Result<Vec<JobResult>> {
        let mut slot_of: BTreeMap<u64, usize> = BTreeMap::new();
        let mut attempts = vec![0usize; specs.len()];
        let mut primary = vec![0u64; specs.len()];
        for (slot, (config, levels)) in specs.iter().enumerate() {
            let id = self.submit(*config, levels.clone())?;
            primary[slot] = id;
            slot_of.insert(id, slot);
        }
        let mut results: Vec<Option<JobResult>> = vec![None; specs.len()];
        let mut pending = specs.len();
        while pending > 0 {
            match self.pool.recv() {
                Ok(r) => {
                    let slot = slot_of.remove(&r.job_id).ok_or_else(|| {
                        anyhow!("pool returned unknown job id {}", r.job_id)
                    })?;
                    self.log.record(EventKind::JobCompleted {
                        job: r.job_id,
                        cost: r.charged_cost,
                    });
                    results[slot] = Some(r);
                    pending -= 1;
                }
                Err(e) => {
                    // job-id attribution lets us requeue the exact probe
                    let slot = slot_of.remove(&e.job_id).ok_or_else(|| {
                        anyhow!("unattributable launcher failure: {e}")
                    })?;
                    self.log.record(EventKind::JobFailed {
                        job: e.job_id,
                        reason: e.error.to_string(),
                    });
                    attempts[slot] += 1;
                    if attempts[slot] > LAUNCH_RETRIES {
                        return Err(anyhow!(
                            "deployment of {} failed {} times, giving up: {e}",
                            specs[slot].0.describe(),
                            attempts[slot]
                        ));
                    }
                    let (config, levels) = &specs[slot];
                    let id =
                        LiveEval::retry_id(primary[slot], attempts[slot]);
                    self.submit_with_id(id, *config, levels.clone())?;
                    slot_of.insert(id, slot);
                }
            }
        }
        Ok(results.into_iter().map(|r| r.expect("all slots filled")).collect())
    }
}

/// Replay-side snapshot accounting, shared by [`EvalBackend::snapshot`]
/// and the grouped slates of [`EvalBackend::probe_slate`]: look up each
/// level's measured outcome and charge the one training run that would
/// have produced every snapshot — the largest (last, levels ascending)
/// level's cost and time. This is the single place the replay charging
/// rule lives; the live side's equivalent is the launcher's own
/// accounting ([`crate::coordinator::SimLauncher`]).
fn replay_snapshot(
    d: &Dataset,
    config: Config,
    levels: &[usize],
) -> (Vec<(usize, Outcome)>, f64, f64) {
    let outcomes: Vec<(usize, Outcome)> = levels
        .iter()
        .map(|&s| (s, d.outcome(&Point { config, s_idx: s })))
        .collect();
    let (_, largest) = *outcomes.last().expect("nonempty levels");
    (outcomes, largest.cost_usd, largest.time_s)
}

/// The engine's evaluation substrate: trace replay or live deployments.
pub enum EvalBackend<'a> {
    /// The paper's methodology: every probe is a lookup in a
    /// pre-materialized measurement campaign.
    Replay(&'a Dataset),
    /// Every probe is a (simulated-latency, noisy, or real) deployment
    /// through the worker pool.
    Live(LiveEval<'a>),
}

impl<'a> EvalBackend<'a> {
    /// Evaluate one (config, s) probe.
    pub fn probe(&mut self, p: Point) -> Result<Probe> {
        let mut probes = self.probe_batch(&[p])?;
        Ok(probes.pop().expect("one probe per point"))
    }

    /// Evaluate a batch of independent probes (parallel across the worker
    /// pool under `Live`); results are in input order.
    pub fn probe_batch(&mut self, points: &[Point]) -> Result<Vec<Probe>> {
        match self {
            EvalBackend::Replay(d) => Ok(points
                .iter()
                .map(|p| {
                    let o = d.outcome(p);
                    Probe {
                        outcome: o,
                        charged_cost: o.cost_usd,
                        duration_s: o.time_s,
                    }
                })
                .collect()),
            EvalBackend::Live(live) => {
                let specs: Vec<(Config, Vec<usize>)> = points
                    .iter()
                    .map(|p| (p.config, vec![p.s_idx]))
                    .collect();
                let results = live.run_jobs(&specs)?;
                points
                    .iter()
                    .zip(&results)
                    .map(|(p, r)| {
                        let o = r
                            .outcomes
                            .iter()
                            .find(|(s, _)| *s == p.s_idx)
                            .map(|(_, o)| *o)
                            .ok_or_else(|| {
                                anyhow!(
                                    "launcher returned no snapshot at level {}",
                                    p.s_idx
                                )
                            })?;
                        Ok(Probe {
                            outcome: o,
                            charged_cost: r.charged_cost,
                            duration_s: r.duration_s,
                        })
                    })
                    .collect()
            }
        }
    }

    /// Evaluate one acquisition slate (a round's probes). Points sharing a
    /// configuration ride a single snapshot deployment (ascending levels,
    /// charged once at the largest — paper §III snapshot semantics), while
    /// distinct configurations launch as independent jobs, concurrent
    /// across the worker pool under `Live`. Results come back in slate
    /// order regardless of completion order. Within a config group the
    /// group's charge and duration are attributed to its largest-level
    /// point and the remaining points cost 0, mirroring the init batch's
    /// accounting. A slate of one point is exactly [`EvalBackend::probe`].
    pub fn probe_slate(&mut self, points: &[Point]) -> Result<Vec<Probe>> {
        anyhow::ensure!(!points.is_empty(), "empty probe slate");
        // group slate indices by config, preserving first-appearance order
        let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut groups: Vec<(Config, Vec<usize>)> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let g = *group_of.entry(p.config.id()).or_insert_with(|| {
                groups.push((p.config, Vec::new()));
                groups.len() - 1
            });
            groups[g].1.push(i);
        }
        if groups.len() == points.len() {
            // every config distinct: plain independent probes
            return self.probe_batch(points);
        }
        let specs: Vec<(Config, Vec<usize>)> = groups
            .iter()
            .map(|(config, idxs)| {
                let mut levels: Vec<usize> =
                    idxs.iter().map(|&i| points[i].s_idx).collect();
                levels.sort_unstable();
                levels.dedup();
                (*config, levels)
            })
            .collect();
        // (outcomes per level, charged cost, duration) per group — replay
        // emulates the launcher's snapshot accounting on the lookup table
        let results = match self {
            EvalBackend::Replay(d) => specs
                .iter()
                .map(|(config, levels)| replay_snapshot(d, *config, levels))
                .collect::<Vec<_>>(),
            EvalBackend::Live(live) => live
                .run_jobs(&specs)?
                .into_iter()
                .map(|r| (r.outcomes, r.charged_cost, r.duration_s))
                .collect(),
        };
        // redistribute to slate order with snapshot accounting per group
        let mut probes: Vec<Option<Probe>> = vec![None; points.len()];
        for ((_, idxs), (outcomes, charged, duration)) in
            groups.iter().zip(&results)
        {
            // the group's largest-level point carries the whole charge
            let payer = *idxs
                .iter()
                .max_by_key(|&&i| points[i].s_idx)
                .expect("nonempty group");
            for &i in idxs {
                let s = points[i].s_idx;
                let o = outcomes
                    .iter()
                    .find(|(lvl, _)| *lvl == s)
                    .map(|(_, o)| *o)
                    .ok_or_else(|| {
                        anyhow!("launcher returned no snapshot at level {s}")
                    })?;
                probes[i] = Some(Probe {
                    outcome: o,
                    charged_cost: if i == payer { *charged } else { 0.0 },
                    duration_s: if i == payer { *duration } else { 0.0 },
                });
            }
        }
        Ok(probes
            .into_iter()
            .map(|p| p.expect("all slate slots filled"))
            .collect())
    }

    /// Snapshot deployment of one config at several *ascending*
    /// sub-sampling levels, charged once at the largest level (paper §III).
    /// Replay emulates the same accounting on the lookup table: the charge
    /// is the last (largest) level's measured cost — the one training run
    /// that would have produced every snapshot.
    pub fn snapshot(
        &mut self,
        config: Config,
        s_levels: &[usize],
    ) -> Result<Snapshot> {
        anyhow::ensure!(!s_levels.is_empty(), "snapshot without levels");
        anyhow::ensure!(
            s_levels.windows(2).all(|w| w[0] < w[1]),
            "snapshot levels must be strictly ascending: {s_levels:?}"
        );
        match self {
            EvalBackend::Replay(d) => {
                let (outcomes, charged_cost, duration_s) =
                    replay_snapshot(d, config, s_levels);
                Ok(Snapshot { outcomes, charged_cost, duration_s })
            }
            EvalBackend::Live(live) => {
                let results =
                    live.run_jobs(&[(config, s_levels.to_vec())])?;
                let r = results.into_iter().next().expect("one job");
                Ok(Snapshot {
                    outcomes: r.outcomes,
                    charged_cost: r.charged_cost,
                    duration_s: r.duration_s,
                })
            }
        }
    }

    /// Ground truth for evaluation-only metrics, when available (always in
    /// replay; in live runs only if an oracle was attached).
    pub fn eval_dataset(&self) -> Option<&Dataset> {
        match self {
            EvalBackend::Replay(d) => Some(*d),
            EvalBackend::Live(live) => live.eval,
        }
    }

    /// The live event log (`None` under replay).
    pub fn event_log(&self) -> Option<&EventLog> {
        match self {
            EvalBackend::Replay(_) => None,
            EvalBackend::Live(live) => Some(&live.log),
        }
    }

    /// Tear down the live worker pool (no-op for replay). Dropping the
    /// backend does the same — the pool's `Drop` joins its workers.
    pub fn shutdown(self) {
        if let EvalBackend::Live(live) = self {
            live.pool.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimLauncher;
    use crate::sim::NetKind;
    use crate::space::{S_INIT, S_VALUES};

    fn backend_pair(net: NetKind) -> (Dataset, LiveEval<'static>) {
        let truth = Dataset::ground_truth(net);
        let live =
            LiveEval::new(Box::new(SimLauncher::noiseless(net)), 2);
        (truth, live)
    }

    #[test]
    fn replay_and_noiseless_live_probes_agree_exactly() {
        let (truth, live) = backend_pair(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        for id in [3usize, 600, 1204] {
            let p = Point::from_id(id);
            let a = replay.probe(p).unwrap();
            let b = live.probe(p).unwrap();
            assert_eq!(a.outcome, b.outcome);
            assert_eq!(a.charged_cost, b.charged_cost);
            assert_eq!(a.duration_s, b.duration_s);
        }
    }

    #[test]
    fn snapshot_accounting_matches_across_backends() {
        let (truth, live) = backend_pair(NetKind::Mlp);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        let config = Config::from_id(42);
        let a = replay.snapshot(config, &S_INIT).unwrap();
        let b = live.snapshot(config, &S_INIT).unwrap();
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for ((sa, oa), (sb, ob)) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(sa, sb);
            assert_eq!(oa, ob);
        }
        assert_eq!(a.charged_cost, b.charged_cost);
        // charged at the largest level, not the sum
        let largest = truth
            .outcome(&Point { config, s_idx: S_INIT[S_INIT.len() - 1] })
            .cost_usd;
        assert_eq!(a.charged_cost, largest);
        let sum: f64 = a.outcomes.iter().map(|(_, o)| o.cost_usd).sum();
        assert!(a.charged_cost < sum);
    }

    #[test]
    fn live_batch_results_come_back_in_submission_order() {
        let (_, live) = backend_pair(NetKind::Rnn);
        let mut live = EvalBackend::Live(live);
        let points: Vec<Point> = (0..12)
            .map(|i| Point { config: Config::from_id(i * 20), s_idx: 4 })
            .collect();
        let probes = live.probe_batch(&points).unwrap();
        let truth = Dataset::ground_truth(NetKind::Rnn);
        for (p, pr) in points.iter().zip(&probes) {
            assert_eq!(pr.outcome, truth.outcome(p));
        }
        // and the log saw every submission + completion
        let log = live.event_log().unwrap();
        let submitted = log
            .count(|k| matches!(k, EventKind::JobSubmitted { .. }));
        let completed = log
            .count(|k| matches!(k, EventKind::JobCompleted { .. }));
        assert_eq!((submitted, completed), (12, 12));
    }

    /// Launcher that fails the first `fail_first` launches (by a global
    /// counter), then succeeds — exercises the requeue path end to end.
    struct FlakyLauncher {
        inner: SimLauncher,
        remaining_failures: std::sync::atomic::AtomicUsize,
    }

    impl JobLauncher for FlakyLauncher {
        fn launch(&self, job: &Job) -> Result<JobResult> {
            use std::sync::atomic::Ordering;
            let prev = self
                .remaining_failures
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                    v.checked_sub(1)
                })
                .unwrap_or(0);
            if prev > 0 {
                anyhow::bail!("transient launch failure");
            }
            self.inner.launch(job)
        }
    }

    #[test]
    fn failed_launches_are_requeued_and_the_run_completes() {
        let launcher = FlakyLauncher {
            inner: SimLauncher::noiseless(NetKind::Rnn),
            remaining_failures: std::sync::atomic::AtomicUsize::new(2),
        };
        let mut live =
            EvalBackend::Live(LiveEval::new(Box::new(launcher), 2));
        let points: Vec<Point> = (0..6)
            .map(|i| Point { config: Config::from_id(i * 40), s_idx: 4 })
            .collect();
        let probes = live.probe_batch(&points).unwrap();
        assert_eq!(probes.len(), 6);
        let truth = Dataset::ground_truth(NetKind::Rnn);
        for (p, pr) in points.iter().zip(&probes) {
            assert_eq!(pr.outcome, truth.outcome(p));
        }
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::JobFailed { .. })),
            2
        );
    }

    #[test]
    fn probe_slate_groups_shared_configs_into_one_snapshot() {
        let (truth, live) = backend_pair(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        // two picks share config 7 (levels 1 and 3, deliberately not in
        // slate order), one pick is a distinct config
        let shared = Config::from_id(7);
        let slate = [
            Point { config: shared, s_idx: 3 },
            Point { config: Config::from_id(100), s_idx: 4 },
            Point { config: shared, s_idx: 1 },
        ];
        let a = replay.probe_slate(&slate).unwrap();
        let b = live.probe_slate(&slate).unwrap();
        assert_eq!(a.len(), 3);
        for ((p, ra), rb) in slate.iter().zip(&a).zip(&b) {
            assert_eq!(ra.outcome, truth.outcome(p));
            assert_eq!(ra.outcome, rb.outcome);
            assert_eq!(ra.charged_cost, rb.charged_cost);
            assert_eq!(ra.duration_s, rb.duration_s);
        }
        // snapshot accounting: the s=3 pick (largest level of its group)
        // pays the one training run, the s=1 rider is free
        assert_eq!(
            a[0].charged_cost,
            truth.outcome(&Point { config: shared, s_idx: 3 }).cost_usd
        );
        assert_eq!(a[2].charged_cost, 0.0);
        assert_eq!(a[2].duration_s, 0.0);
        assert_eq!(
            a[1].charged_cost,
            truth.outcome(&slate[1]).cost_usd,
            "independent config pays its own probe"
        );
        // only two jobs were deployed for the three observations
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::JobSubmitted { .. })),
            2
        );
    }

    #[test]
    fn probe_slate_of_one_matches_probe_exactly() {
        let truth = Dataset::ground_truth(NetKind::Mlp);
        let mut replay = EvalBackend::Replay(&truth);
        let p = Point::from_id(777);
        let a = replay.probe(p).unwrap();
        let b = replay.probe_slate(&[p]).unwrap();
        assert_eq!(a.outcome, b[0].outcome);
        assert_eq!(a.charged_cost, b[0].charged_cost);
        assert_eq!(a.duration_s, b[0].duration_s);
    }

    #[test]
    fn snapshot_rejects_empty_levels_everywhere() {
        let truth = Dataset::ground_truth(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        assert!(replay.snapshot(Config::from_id(0), &[]).is_err());
        assert_eq!(S_VALUES.len(), 5); // levels referenced above stay valid
    }
}
