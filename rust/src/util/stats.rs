//! Scalar statistics and Gaussian special functions.

use std::cmp::Ordering;

/// Total order for ranking scores: ascending, with every NaN below every
/// real value. `max_by(|a, b| cmp_nan_low(*a, *b))` never picks a NaN over a
/// number, and descending sorts (`|a, b| cmp_nan_low(s[b], s[a])`) push NaN
/// to the end. Built on [`f64::total_cmp`] so it never panics — a single
/// NaN surrogate prediction degrades a ranking gracefully instead of
/// crashing the engine mid-run.
pub fn cmp_nan_low(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => a.total_cmp(&b),
    }
}

/// Ascending total order with every NaN *above* every real value, so
/// ascending sorts over costs/latencies push NaN (unknown = worst) last.
pub fn cmp_nan_high(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// Abramowitz & Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal PDF.
pub fn normal_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Population mean/std in one pass (Welford). Returns (mean, std_pop).
pub fn mean_std_pop(xs: &[f64]) -> (f64, f64) {
    let mut m = 0.0;
    let mut m2 = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        let d = x - m;
        m += d / (i + 1) as f64;
        m2 += d * (x - m);
    }
    if xs.is_empty() {
        (f64::NAN, 0.0)
    } else {
        (m, (m2 / xs.len() as f64).sqrt())
    }
}

/// p-th percentile (linear interpolation) of an *unsorted* slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| cmp_nan_high(*a, *b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// argmax with ties broken by lowest index; None for empty/NaN-only input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        if best.map_or(true, |(_, b)| x > b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-8); // A&S 7.1.26: |err| <= 1.5e-7
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(3.0) - 0.9999779095).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry_and_tails() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-9);
        for x in [0.3, 1.0, 2.5] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-6);
        }
        assert!(normal_cdf(8.0) > 0.999999);
        assert!(normal_cdf(-8.0) < 1e-6);
    }

    #[test]
    fn pdf_integrates_to_one() {
        let mut acc = 0.0;
        let h = 0.001;
        let mut x = -8.0;
        while x < 8.0 {
            acc += normal_pdf(x) * h;
            x += h;
        }
        assert!((acc - 1.0).abs() < 1e-3, "integral {acc}");
    }

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        let (m, s) = mean_std_pop(&xs);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.0).abs() < 1e-12);
        assert!((std_dev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argmax_handles_ties_and_nan() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), Some(1));
        assert_eq!(argmax(&[f64::NAN, 2.0]), Some(1));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn nan_safe_comparators_rank_nan_as_worst() {
        use std::cmp::Ordering;
        // max_by with cmp_nan_low never picks NaN over a real number
        let best = [f64::NAN, 1.0, 3.0, f64::NAN, 2.0]
            .into_iter()
            .max_by(|a, b| cmp_nan_low(*a, *b))
            .unwrap();
        assert_eq!(best, 3.0);
        assert_eq!(cmp_nan_low(f64::NAN, f64::NAN), Ordering::Equal);
        assert_eq!(cmp_nan_low(f64::NAN, f64::NEG_INFINITY), Ordering::Less);
        // descending sort by score pushes NaN to the end
        let scores = [0.5, f64::NAN, 0.9, 0.1];
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| cmp_nan_low(scores[b], scores[a]));
        assert_eq!(order, vec![2, 0, 3, 1]);
        // ascending sort by cost pushes NaN to the end
        let mut costs = vec![2.0, f64::NAN, 1.0];
        costs.sort_by(|a, b| cmp_nan_high(*a, *b));
        assert_eq!(&costs[..2], &[1.0, 2.0]);
        assert!(costs[2].is_nan());
    }
}
