//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at optimization time: `make artifacts` lowers the
//! Layer-2 JAX graphs (which embed the Layer-1 Pallas covariance kernel)
//! once; this module compiles them on the PJRT CPU client and exposes typed
//! entry points:
//!
//! - [`XlaGp`] — batched GP posterior (mean, var) over query tiles, used as
//!   the accelerated backend for batched candidate scoring;
//! - [`MlpTrainer`] — the end-to-end real workload: SGD training of an MLP
//!   entirely through compiled artifacts, driven by the Rust coordinator.

mod artifacts;
mod gpx;
mod json;
mod mlp;

pub use artifacts::{Manifest, Runtime};
pub use gpx::{cov_parity_check, gp_parity_check, XlaGp};
pub use json::JsonValue;
pub use mlp::{train_smoke as mlp_train_smoke, MlpParams, MlpTrainer, SyntheticMnist};

use crate::cli::Args;
use anyhow::Result;

/// `trimtuner runtime-check`: load every artifact, verify numerics against
/// the native implementations, print a summary.
pub fn cmd_runtime_check(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let rt = Runtime::load(&dir)?;
    println!(
        "runtime: platform={} artifacts={}",
        rt.platform(),
        rt.names().len()
    );

    // 1. covariance kernel parity: XLA (Pallas lowering) vs native f64
    let (max_err, n) = gpx::cov_parity_check(&rt)?;
    println!("cov_acc parity: {n} entries, max |err| = {max_err:.3e}");
    anyhow::ensure!(max_err < 1e-4, "covariance parity failed");

    // 2. GP posterior parity vs the native Rust GP
    let (mu_err, var_err) = gpx::gp_parity_check(&rt)?;
    println!("gp_predict parity: max |mu err| = {mu_err:.3e}, max |var err| = {var_err:.3e}");
    anyhow::ensure!(mu_err < 1e-3 && var_err < 1e-3, "gp parity failed");

    // 3. MLP training: loss must fall on a separable toy problem
    let (first, last, acc) = mlp::train_smoke(&rt, 30)?;
    println!("mlp train: loss {first:.4} -> {last:.4}, eval acc {acc:.3}");
    anyhow::ensure!(last < first, "mlp loss did not decrease");

    println!("runtime-check OK");
    Ok(())
}
