//! Integration: threaded coordinator on the simulated cloud, including the
//! snapshot-semantics contract the engine's init phase relies on.

use trimtuner::coordinator::{Job, JobLauncher, SimLauncher, WorkerPool};
use trimtuner::sim::{CloudSim, NetKind};
use trimtuner::space::{Config, Point, S_INIT};

#[test]
fn pool_processes_many_jobs_across_workers() {
    let pool = WorkerPool::new(Box::new(SimLauncher::new(NetKind::Mlp, 1)), 3);
    let n = 24u64;
    for i in 0..n {
        pool.submit(Job {
            id: i,
            config: Config::from_id((i as usize * 13) % 288),
            s_levels: S_INIT.to_vec(),
        })
        .unwrap();
    }
    let mut ids: Vec<u64> = (0..n).map(|_| pool.recv().unwrap().job_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());
    pool.shutdown();
}

#[test]
fn snapshot_outcomes_are_consistent_with_direct_simulation() {
    // The launcher's noisy observations must stay centered on the same
    // ground truth the engine's replay datasets are drawn from.
    let net = NetKind::Rnn;
    let launcher = SimLauncher::new(net, 7);
    let sim = CloudSim::new(net);
    let config = Config::from_id(120);
    let job = Job { id: 0, config, s_levels: S_INIT.to_vec() };
    let r = launcher.launch(&job).unwrap();
    for (s_idx, o) in &r.outcomes {
        let gt = sim.ground_truth(&Point { config, s_idx: *s_idx });
        assert!(
            (o.acc - gt.acc).abs() < 0.05,
            "snapshot s{} acc {} vs gt {}",
            s_idx,
            o.acc,
            gt.acc
        );
        assert!(o.time_s > 0.3 * gt.time_s && o.time_s < 3.0 * gt.time_s);
    }
}

#[test]
fn charged_cost_is_cheaper_than_individual_tests() {
    // the paper's init-phase claim: 4 snapshot levels for the price of the
    // largest one
    let launcher = SimLauncher::new(NetKind::Cnn, 9);
    let job = Job {
        id: 1,
        config: Config::from_id(200),
        s_levels: S_INIT.to_vec(),
    };
    let r = launcher.launch(&job).unwrap();
    let sum: f64 = r.outcomes.iter().map(|(_, o)| o.cost_usd).sum();
    assert!(r.charged_cost < 0.75 * sum, "{} vs {}", r.charged_cost, sum);
}
