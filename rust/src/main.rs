//! TrimTuner CLI — leader entrypoint.
//!
//! Subcommands:
//!   optimize           run one optimizer on one network and print the trace
//!                      (--live drives real simulated deployments through
//!                      the threaded coordinator instead of trace replay;
//!                      --batch-size q launches the top-q acquisition slate
//!                      per round as concurrent jobs)
//!   generate-datasets  materialize the 3 measurement campaigns as CSV
//!   repro <exp>        regenerate a paper table/figure (table1..4, fig1..4, all)
//!   runtime-check      load the AOT artifacts via PJRT and verify numerics
//!   serve              run the threaded coordinator on the simulated cloud

use anyhow::{bail, Result};
use trimtuner::cli::Args;
use trimtuner::coordinator::{EventKind, FaultSpec, SimLauncher};
use trimtuner::engine::{
    self, EngineConfig, EvalBackend, LiveEval, OptimizerKind, RetryPolicy,
};
use trimtuner::experiments;
use trimtuner::heuristics::FilterKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::{Config, Constraint};

const USAGE: &str = "\
trimtuner — TrimTuner (Mendes et al. 2020) reproduction

USAGE:
  trimtuner optimize [--net rnn|mlp|cnn|multilayer]
                     [--optimizer trimtuner-dt|trimtuner-gp|eic|eic-usd|fabolas|random]
                     [--beta 0.1] [--filter cea|random|nofilter|direct|cmaes]
                     [--iters 44] [--seed 0] [--cost-cap <usd>] [--pareto]
                     [--live] [--workers 4] [--batch-size 1]
                     [--async] [--max-inflight N]
                     [--refit every=K,evidence-drop=X]
                     [--launcher-noise 1.0] [--launcher-seed <seed>]
                     [--faults spot:0.3,straggle:2.0,flaky:0.1,timeout:600]
                     [--retry max=3,base=0,factor=2,cap=30,jitter=0.1,deadline=600]
                     [--fault-seed <seed>]
  trimtuner generate-datasets [--out data] [--seed 42]
  trimtuner repro <table1|table2|table3|table4|fig1|fig2|fig3|fig4|faults|all>
                  [--out results] [--seeds 5] [--full] [--iters 44]
  trimtuner runtime-check [--artifacts artifacts]
  trimtuner serve [--net mlp] [--jobs 16] [--workers 4]

  --live submits every probe as a snapshot job through the worker pool
  (coordinator::WorkerPool over a SimLauncher) instead of replaying the
  pre-materialized dataset; the dataset is still generated and attached
  as an evaluation-only oracle so Accuracy_C stays comparable.

  --workers N sizes the live pool. With the default --batch-size 1 it only
  parallelizes the LHS init batch; raise --batch-size to keep the pool busy
  during the main loop too.

  --batch-size q submits the top-q acquisition slate per selection round as
  concurrent deployments, conditioning each pick on the pending ones so the
  slate stays diverse (TRIMTUNER_BATCH=liar|topq selects the constant-liar
  or unconditioned strategy). q = 1 reproduces the paper's sequential
  Algorithm 1 bit-exactly. Points of the slate that share a configuration
  ride one snapshot deployment, charged once at the largest level.

  --async removes the round barrier entirely: whenever the in-flight count
  drops below the target the engine re-selects a single probe conditioned
  on everything still pending and submits it immediately, keeping the pool
  saturated. The effective parallelism adapts to pool occupancy instead of
  a fixed --batch-size; completions are absorbed in logical (submission)
  order, so traces are bit-identical at any worker count, and --async with
  one worker reproduces the sequential Algorithm 1 bit-exactly.
  --max-inflight N pins the occupancy target (default: the live pool
  width, 1 under replay) — pin it to compare trajectories across worker
  counts.

  --launcher-noise X scales the simulated launcher's observation noise
  (1.0 = calibrated, 0 = exact ground truth — live runs then replay
  bit-identically); --launcher-seed pins its per-job noise stream.

  --faults injects transient-cloud failures into the live launcher stack
  (requires --live): spot:RATE preempts jobs with the given per-attempt
  probability (add the bare token `fallback` to run retries on-demand,
  immune to further preemption), straggle:SEV multiplies
  durations by a seeded heavy-tailed factor, flaky:RATE fails launches
  before any cost accrues, timeout:SECS kills jobs at a per-attempt
  deadline with pro-rata charging. All decisions are deterministic per
  (--fault-seed, job id), so fault traces replay bit-identically at any
  worker count.

  --retry max=N,base=S,factor=F,cap=S,jitter=J,deadline=S tunes the
  engine's retry/abandonment policy: N retries with exponential backoff
  (base S seconds, seeded jitter J), then the probe is *abandoned* — its
  partial cost stays charged, a ProbeAbandoned event is logged, and the
  campaign re-plans around the hole instead of aborting.

  --refit every=K,evidence-drop=X pays the full surrogate refit (GP
  hyper-parameter re-optimization + tree structural rebuild) only every K
  selection rounds; in between, fresh observations are absorbed
  incrementally in amortized O(n²) with hyper-parameters and tree
  structure frozen. evidence-drop=X additionally forces a full refit when
  the fresh observations' mean predictive surprise exceeds the post-refit
  baseline by X nats. The default every=1 is the paper's cadence
  (bit-identical trajectories to prior releases);
  TRIMTUNER_REFIT=full makes the cheap rounds recompute the same frozen
  state from scratch — the parity-test reference.

  --pareto additionally reports the predicted (cost, accuracy) Pareto
  frontier under the final surrogates; in replay mode it is scored against
  the dataset's measured frontier (hypervolume ratio, 1.0 = recovered).

  Env knobs: TRIMTUNER_SLATE_THREADS (α-sweep worker count),
  TRIMTUNER_ALPHA=clone (per-candidate clone-conditioning escape hatch),
  TRIMTUNER_TREES=rebuild (per-candidate seeded tree rebuilds instead of
  incremental leaf-statistics conditioning),
  TRIMTUNER_BATCH=fantasy|liar|topq (batched-slate strategy),
  TRIMTUNER_REFIT=full (from-scratch frozen refit on non-hyperopt rounds).
";

fn main() -> Result<()> {
    let args = Args::from_env();
    if args.get_bool("help") {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional.first().map(|s| s.as_str()) {
        Some("optimize") => cmd_optimize(&args),
        Some("generate-datasets") => cmd_generate(&args),
        Some("repro") => experiments::cmd_repro(&args),
        #[cfg(feature = "xla")]
        Some("runtime-check") => trimtuner::runtime::cmd_runtime_check(&args),
        #[cfg(not(feature = "xla"))]
        Some("runtime-check") => {
            bail!("runtime-check requires a build with `--features xla`")
        }
        Some("serve") => trimtuner::coordinator::cmd_serve(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let net = NetKind::from_name(&args.get_or("net", "rnn"))
        .ok_or_else(|| anyhow::anyhow!("unknown net"))?;
    let Some(optimizer) =
        OptimizerKind::from_name(&args.get_or("optimizer", "trimtuner-dt"))
    else {
        bail!("unknown optimizer");
    };
    let seed = args.get_u64("seed", 0);
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.beta = args.get_f64("beta", cfg.beta);
    cfg.max_iters = args.get_usize("iters", cfg.max_iters);
    if let Some(f) = args.get("filter") {
        cfg.filter = FilterKind::from_name(f)
            .ok_or_else(|| anyhow::anyhow!("unknown filter"))?;
    }
    let cap = args.get_f64("cost-cap", net.paper_cost_cap());
    let constraints = vec![Constraint::cost_max(cap)];
    let live = args.get_bool("live");
    cfg.pareto = args.get_bool("pareto");
    cfg.batch_size = args.get_usize("batch-size", cfg.batch_size).max(1);
    cfg.async_mode = args.get_bool("async");
    cfg.max_inflight = args.get("max-inflight").and_then(|s| s.parse().ok());
    if let Some(spec) = args.get("refit") {
        cfg.refit = engine::RefitPolicy::parse(spec)?;
    }
    let faults = match args.get("faults") {
        Some(spec) => FaultSpec::parse(spec)?,
        None => FaultSpec::default(),
    };
    if !faults.is_empty() && !live {
        bail!("--faults injects failures into the live launcher stack; add --live");
    }
    let retry = match args.get("retry") {
        Some(spec) => RetryPolicy::parse(spec)?,
        None => RetryPolicy::default(),
    };

    let sched = if cfg.async_mode {
        match cfg.max_inflight {
            Some(n) => format!("async(inflight={n})"),
            None => "async(inflight=pool)".to_string(),
        }
    } else {
        format!("q={}", cfg.batch_size)
    };
    eprintln!(
        "optimize: net={} optimizer={} filter={} beta={} iters={} cap=${cap} mode={} {sched} batch={}",
        net.name(),
        optimizer.name(),
        cfg.filter.name(),
        cfg.beta,
        cfg.max_iters,
        if live { "live" } else { "replay" },
        cfg.batch_mode.name(),
    );
    let dataset = Dataset::generate(net, args.get_u64("dataset-seed", 42));
    let run = if live {
        // Live tuning: every probe is a snapshot deployment through the
        // worker pool. The generated dataset is attached purely as an
        // evaluation oracle (accC column); the optimizer never reads it.
        let workers = args.get_usize("workers", 4);
        let noise = args.get_f64("launcher-noise", 1.0);
        let launcher = SimLauncher::with_options(
            net,
            args.get_u64("launcher-seed", seed ^ 0x11FE),
            noise,
            0.0,
        );
        let fault_seed = args.get_u64("fault-seed", seed ^ 0xFA17);
        let launcher = faults.wrap(Box::new(launcher), fault_seed);
        let mut backend = EvalBackend::Live(
            LiveEval::new(launcher, workers)
                .with_eval(&dataset)
                .with_retry(retry, seed ^ 0xB0FF),
        );
        let run = engine::run_backend(&mut backend, &constraints, &cfg)?;
        if let Some(log) = backend.event_log() {
            eprintln!(
                "live: {} jobs submitted, {} completed, {} failed, {} abandoned on {workers} workers",
                log.count(|k| matches!(k, EventKind::JobSubmitted { .. })),
                log.count(|k| matches!(k, EventKind::JobCompleted { .. })),
                log.count(|k| matches!(k, EventKind::JobFailed { .. })),
                log.count(|k| matches!(k, EventKind::ProbeAbandoned { .. })),
            );
        }
        let f = run.faults;
        if f.n_failures > 0 || f.n_abandoned > 0 {
            eprintln!(
                "faults: {} failed attempts, {} probes abandoned, ${:.4} wasted cost, {:.1}s wasted time",
                f.n_failures, f.n_abandoned, f.wasted_cost, f.wasted_time,
            );
        }
        backend.shutdown();
        run
    } else {
        engine::run(&dataset, &constraints, &cfg)
    };

    println!(
        "{:>4} {:>4} {:>5} {:>30} {:>8} {:>9} {:>9} {:>9} {:>8} {:>9} {:>6}",
        "iter", "rnd", "phase", "tested", "acc", "cost$", "cum$", "dur_s",
        "accC", "rec_ms", "evals"
    );
    for r in &run.records {
        println!(
            "{:>4} {:>4} {:>5} {:>30} {:>8.4} {:>9.5} {:>9.4} {:>9.2} {:>8.4} {:>9.1} {:>6}",
            r.iter,
            r.round,
            if r.is_init { "init" } else { "opt" },
            format!("{} s={:.3}", r.tested.config.describe(), r.tested.s()),
            r.outcome.acc,
            r.explore_cost,
            r.cum_cost,
            r.duration_s,
            r.accuracy_c,
            r.rec_wall_s * 1e3,
            r.n_alpha_evals,
        );
    }
    println!(
        "optimum_acc={:.4} final_accuracy_c={:.4} total_cost=${:.4} rounds={} mean_rec={:.1}ms wall={:.2}s",
        run.optimum_acc,
        run.final_accuracy_c(),
        run.total_cost(),
        run.n_rounds(),
        run.mean_rec_wall_s() * 1e3,
        run.total_wall_s(),
    );
    if let Some(front) = &run.pareto {
        println!(
            "\npredicted (cost, accuracy) frontier — {} points:",
            front.len()
        );
        println!("{:>4} {:>26} {:>10} {:>8}", "id", "config", "cost$", "acc");
        for p in front {
            println!(
                "{:>4} {:>26} {:>10.5} {:>8.4}",
                p.config_id,
                Config::from_id(p.config_id).describe(),
                p.pred_cost,
                p.pred_acc
            );
        }
        if !live {
            // replay mode: score the recommendation against the dataset's
            // measured frontier
            println!(
                "frontier_quality (hypervolume ratio vs true frontier): {:.4}",
                engine::frontier_quality(&dataset, front)
            );
        }
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let out = args.get_or("out", "data");
    let seed = args.get_u64("seed", 42);
    std::fs::create_dir_all(&out)?;
    for net in NetKind::ALL {
        let d = Dataset::generate(net, seed);
        let path = format!("{out}/{}.csv", net.name());
        d.save_csv(&path)?;
        let stats =
            d.feasibility_stats(&[Constraint::cost_max(net.paper_cost_cap())]);
        println!(
            "{path}: {} points, feasible {:.1}%, near-optimal {:.1}%",
            d.len(),
            stats.feasible_pct,
            stats.near_optimal_pct
        );
    }
    Ok(())
}
