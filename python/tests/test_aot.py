"""AOT pipeline: artifacts lower to valid HLO text with the expected shapes."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model
from compile.kernels.matern_fabolas import D_IN, N_HYP


def test_artifact_specs_shapes():
    specs = aot.artifact_specs()
    n, q = model.N_TRAIN, model.N_QUERY
    assert set(specs) == {
        "gp_predict_acc",
        "gp_predict_cost",
        "gp_mll_acc",
        "gp_mll_cost",
        "cov_acc",
        "cov_cost",
        "mlp_train_step",
        "mlp_eval",
    }
    _, args = specs["gp_predict_acc"]
    assert [tuple(a.shape) for a in args] == [
        (n, D_IN),
        (n,),
        (n,),
        (q, D_IN),
        (N_HYP,),
    ]


def test_lower_one_artifact_to_hlo_text(tmp_path):
    """Lower the cheapest artifact end-to-end and check it is HLO text."""
    specs = aot.artifact_specs()
    fn, args = specs["mlp_eval"]
    import jax

    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.startswith("HloModule")
    assert "ROOT" in text


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.dirname(os.path.dirname(__file__))
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--only",
            "mlp_eval",
        ],
        check=True,
        cwd=os.path.dirname(os.path.dirname(__file__)),
        env=env,
    )
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["d_in"] == D_IN
    assert "mlp_eval" in manifest["artifacts"]
    assert (tmp_path / "mlp_eval.hlo.txt").exists()
