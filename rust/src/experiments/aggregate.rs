//! Aggregation of per-seed optimizer runs into averaged curves.
//!
//! Fig. 1/3/4 plot Accuracy_C against cumulative optimization *cost* (the
//! independent variable). Runs with different seeds spend different costs
//! per iteration, so we resample every run onto a common cost grid (step
//! interpolation: the incumbent between observations is the last one) and
//! average point-wise — the same procedure the paper's plotting uses.

use crate::engine::RunResult;

/// One point of an averaged curve.
#[derive(Debug, Clone, Copy)]
pub struct CurvePoint {
    pub cost: f64,
    pub mean_accuracy_c: f64,
    pub std_accuracy_c: f64,
    /// fraction of runs already past their init phase at this cost
    pub main_phase_frac: f64,
}

/// Which budget axis a curve is parameterized by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BudgetAxis {
    Cost,
    Time,
}

impl BudgetAxis {
    fn of(&self, r: &crate::engine::IterRecord) -> f64 {
        match self {
            BudgetAxis::Cost => r.cum_cost,
            BudgetAxis::Time => r.cum_time,
        }
    }
}

/// Step-interpolate a run's Accuracy_C at a given cumulative budget.
fn value_at(run: &RunResult, axis: BudgetAxis, budget: f64) -> (f64, bool) {
    let mut acc = 0.0;
    let mut in_main = false;
    for r in &run.records {
        if axis.of(r) <= budget + 1e-12 {
            acc = r.accuracy_c;
            in_main = !r.is_init;
        } else {
            break;
        }
    }
    (acc, in_main)
}

/// Average `runs` onto `n_grid` log-spaced budget points spanning all runs.
pub fn average_runs_axis(
    runs: &[RunResult],
    axis: BudgetAxis,
    n_grid: usize,
) -> Vec<CurvePoint> {
    assert!(!runs.is_empty());
    let min_b = runs
        .iter()
        .filter_map(|r| r.records.iter().map(|x| axis.of(x)).find(|&c| c > 0.0))
        .fold(f64::INFINITY, f64::min);
    let max_b = runs
        .iter()
        .map(|r| r.records.last().map_or(0.0, |x| axis.of(x)))
        .fold(0.0f64, f64::max);
    assert!(min_b.is_finite() && max_b > min_b);

    let mut out = Vec::with_capacity(n_grid);
    for i in 0..n_grid {
        let t = i as f64 / (n_grid - 1) as f64;
        let budget = min_b * (max_b / min_b).powf(t);
        let vals: Vec<(f64, bool)> =
            runs.iter().map(|r| value_at(r, axis, budget)).collect();
        let accs: Vec<f64> = vals.iter().map(|v| v.0).collect();
        let (mean, std) = crate::util::stats::mean_std_pop(&accs);
        let main_frac = vals.iter().filter(|v| v.1).count() as f64
            / vals.len() as f64;
        out.push(CurvePoint {
            cost: budget,
            mean_accuracy_c: mean,
            std_accuracy_c: std,
            main_phase_frac: main_frac,
        });
    }
    out
}

/// Average over the cost axis (Fig. 1/3/4 plotting).
pub fn average_runs(runs: &[RunResult], n_grid: usize) -> Vec<CurvePoint> {
    average_runs_axis(runs, BudgetAxis::Cost, n_grid)
}

/// Budget at which the *averaged* curve first reaches `target` Accuracy_C —
/// the quantity read off the paper's Fig. 1-style plots. `None` if the
/// averaged curve never reaches the target.
pub fn budget_to_target(
    runs: &[RunResult],
    axis: BudgetAxis,
    target: f64,
) -> Option<f64> {
    let curve = average_runs_axis(runs, axis, 240);
    curve
        .iter()
        .find(|pt| pt.mean_accuracy_c >= target)
        .map(|pt| pt.cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::IterRecord;
    use crate::sim::{Dataset, NetKind};
    use crate::space::Point;

    fn mk_run(costs_accs: &[(f64, f64, bool)]) -> RunResult {
        let d = Dataset::generate(NetKind::Rnn, 1);
        let p = Point::from_id(0);
        RunResult {
            records: costs_accs
                .iter()
                .map(|&(c, a, is_init)| IterRecord {
                    iter: 0,
                    is_init,
                    round: 0,
                    tested: p,
                    outcome: d.outcome(&p),
                    explore_cost: 0.0,
                    cum_cost: c,
                    cum_time: c,
                    duration_s: 0.0,
                    rec_wall_s: 0.0,
                    incumbent: p,
                    inc_pred_acc: a,
                    inc_from_subsample: false,
                    inc_acc: a,
                    inc_feasible: true,
                    accuracy_c: a,
                    n_alpha_evals: 0,
                })
                .collect(),
            optimum_acc: 1.0,
            optimum: None,
            pareto: None,
            faults: crate::engine::FaultStats::default(),
        }
    }

    #[test]
    fn step_interpolation_holds_last_value() {
        let run = mk_run(&[(0.1, 0.2, true), (1.0, 0.8, false)]);
        assert_eq!(value_at(&run, BudgetAxis::Cost, 0.5).0, 0.2);
        assert_eq!(value_at(&run, BudgetAxis::Cost, 1.5).0, 0.8);
        assert_eq!(value_at(&run, BudgetAxis::Cost, 0.01).0, 0.0);
    }

    #[test]
    fn averaging_two_runs() {
        let a = mk_run(&[(0.1, 0.4, false), (1.0, 0.8, false)]);
        let b = mk_run(&[(0.1, 0.6, false), (1.0, 1.0, false)]);
        let curve = average_runs(&[a, b], 8);
        assert_eq!(curve.len(), 8);
        // at max cost both runs have settled
        let last = curve.last().unwrap();
        assert!((last.mean_accuracy_c - 0.9).abs() < 1e-9);
        assert!((last.std_accuracy_c - 0.1).abs() < 1e-9);
        // costs monotone increasing
        assert!(curve.windows(2).all(|w| w[0].cost < w[1].cost));
    }
}
