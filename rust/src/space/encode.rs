//! Feature encoding: `Point` → normalized 7-dim vector (6 config features +
//! sub-sampling rate). Must stay byte-compatible with the Layer-1 kernel
//! (`python/compile/kernels/matern_fabolas.py`: D_FEAT=6, column 6 is s).

use super::catalog::*;

pub const D_FEAT: usize = 6;
pub const D_IN: usize = D_FEAT + 1;

/// Normalized feature vector for a (config, s) point.
///
/// All features are log-scaled where the underlying parameter spans orders
/// of magnitude, then min-max normalized to [0, 1]:
///   0: log10(learning rate)      (1e-5..1e-3)
///   1: log2(batch size)          (16..256)
///   2: training mode             (async=0, sync=1)
///   3: log2(vCPUs per VM)        (1..8)
///   4: log2(RAM GB per VM)       (2..32)
///   5: log2(#VMs)                (1..80)
///   6: sub-sampling rate s       (raw — consumed by the FABOLAS basis
///                                 kernel, not the Matérn distance)
pub fn encode(p: &Point) -> [f64; D_IN] {
    let c = &p.config;
    let lr = (c.learning_rate().log10() + 5.0) / 2.0; // {-5,-4,-3} -> {0,.5,1}
    let batch = ((c.batch_size() as f64).log2() - 4.0) / 4.0; // {16,256} -> {0,1}
    let sync = c.sync as u8 as f64;
    let vcpus = (c.vm().vcpus as f64).log2() / 3.0; // {1..8} -> {0..1}
    let ram = ((c.vm().ram_gb as f64).log2() - 1.0) / 4.0; // {2..32} -> {0..1}
    let nvms = (c.nvms() as f64).log2() / (80f64).log2();
    [lr, batch, sync, vcpus, ram, nvms, p.s()]
}

/// Encode as f32 for the XLA artifacts (Layer-2 graphs are f32).
pub fn encode_f32(p: &Point) -> [f32; D_IN] {
    let e = encode(p);
    [
        e[0] as f32, e[1] as f32, e[2] as f32, e[3] as f32, e[4] as f32,
        e[5] as f32, e[6] as f32,
    ]
}

/// Nearest catalog point to an arbitrary feature vector — used by the
/// continuous-relaxation heuristics (DIRECT, CMA-ES) to snap their iterates
/// back onto the discrete grid.
pub fn nearest_point(feat: &[f64]) -> Point {
    assert_eq!(feat.len(), D_IN);
    let mut best = Point::from_id(0);
    let mut best_d = f64::INFINITY;
    for p in all_points() {
        let e = encode(&p);
        let d: f64 = e.iter().zip(feat).map(|(a, b)| (a - b) * (a - b)).sum();
        if d < best_d {
            best_d = d;
            best = p;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn features_in_unit_interval() {
        for p in all_points() {
            let e = encode(&p);
            for (i, v) in e.iter().enumerate() {
                assert!(
                    (0.0..=1.0).contains(v),
                    "feature {i} = {v} for {p:?}"
                );
            }
        }
    }

    #[test]
    fn encoding_is_injective() {
        let mut seen = std::collections::HashSet::new();
        for p in all_points() {
            let e = encode(&p);
            let key: Vec<u64> = e.iter().map(|v| v.to_bits()).collect();
            assert!(seen.insert(key), "duplicate encoding for {p:?}");
        }
    }

    #[test]
    fn nearest_point_round_trips() {
        check("nearest(encode(p)) == p", 24, |rng| {
            let p = Point::from_id(rng.below(N_POINTS));
            let e = encode(&p);
            let q = nearest_point(&e);
            if q == p {
                Ok(())
            } else {
                Err(format!("{p:?} -> {q:?}"))
            }
        });
    }

    #[test]
    fn s_column_is_raw_rate() {
        let p = Point { config: Config::from_id(7), s_idx: 2 };
        assert_eq!(encode(&p)[6], S_VALUES[2]);
    }
}
