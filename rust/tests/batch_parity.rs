//! Batched-probe parity: `--batch-size 1` must reproduce the sequential
//! Algorithm 1 exactly (replay and zero-noise live), batched rounds must be
//! deterministic in the worker count, and the round bookkeeping (record
//! grouping, per-round events, round-boundary stop checks) must hold for
//! every optimizer and batch mode.

use trimtuner::coordinator::{EventKind, SimLauncher};
use trimtuner::engine::{
    self, BatchMode, EngineConfig, EvalBackend, LiveEval, OptimizerKind,
    RunResult, StopCondition,
};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

/// Paper defaults shrunk like `live_parity`'s so the GP variants stay fast.
fn small_cfg(optimizer: OptimizerKind, seed: u64, iters: usize) -> EngineConfig {
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.max_iters = iters;
    cfg.n_rep = 10;
    cfg.n_popt_samples = 40;
    cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
    // pin the batch mode: an ambient TRIMTUNER_BATCH must not change what
    // these tests exercise
    cfg.batch_mode = BatchMode::Fantasy;
    cfg
}

fn live_run(
    launcher: SimLauncher,
    workers: usize,
    eval: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> RunResult {
    let mut backend = EvalBackend::Live(
        LiveEval::new(Box::new(launcher), workers).with_eval(eval),
    );
    let run = engine::run_backend(&mut backend, constraints, cfg)
        .expect("live run failed");
    backend.shutdown();
    run
}

fn assert_same_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(ra.round, rb.round, "{label}: round id");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.explore_cost.to_bits(),
            rb.explore_cost.to_bits(),
            "{label}: charged cost"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(
            ra.incumbent.id(),
            rb.incumbent.id(),
            "{label}: incumbent"
        );
    }
}

/// ISSUE acceptance: with `batch_size = 1` a zero-noise live run is
/// bit-identical to the replay trace for both TrimTuner model kinds — the
/// round-based loop is an exact refactoring of the sequential one.
#[test]
fn batch_size_one_is_bit_identical_to_replay() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (optimizer, iters) in [
        (OptimizerKind::TrimTuner(ModelKind::Gp), 3),
        (OptimizerKind::TrimTuner(ModelKind::Trees), 6),
    ] {
        let mut cfg = small_cfg(optimizer, 5, iters);
        cfg.batch_size = 1;
        let replay = engine::run(&truth, &constraints, &cfg);
        let live = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg,
        );
        assert_same_trajectory(&replay, &live, &optimizer.name());
        // q = 1: every main record is its own round
        for r in replay.records.iter().filter(|r| !r.is_init) {
            assert_eq!(r.round, r.iter + 1, "round ids drifted at q=1");
        }
    }
}

/// ISSUE acceptance: zero-noise live runs with q = 4 are deterministic
/// across worker counts, and agree with the replay backend's batched
/// rounds observation for observation.
#[test]
fn zero_noise_q4_is_deterministic_across_worker_counts() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    let mut cfg =
        small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 7, 8);
    cfg.batch_size = 4;
    let replay = engine::run(&truth, &constraints, &cfg);
    let one = live_run(
        SimLauncher::noiseless(net),
        1,
        &truth,
        &constraints,
        &cfg,
    );
    let four = live_run(
        SimLauncher::noiseless(net),
        4,
        &truth,
        &constraints,
        &cfg,
    );
    assert_same_trajectory(&one, &four, "workers 1 vs 4");
    assert_same_trajectory(&replay, &one, "replay vs live q=4");
    assert!(replay.n_rounds() >= 3, "init round + at least 2 main rounds");
}

/// Round bookkeeping: records of one round share a round id, the round
/// ids are contiguous, per-round quantities land on the round's last
/// record, nothing is retested and the accounting stays monotone.
#[test]
fn batched_round_records_group_and_account_correctly() {
    let truth = Dataset::ground_truth(NetKind::Mlp);
    let mut cfg =
        small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 3, 12);
    cfg.batch_size = 3;
    let run = engine::run(&truth, &caps(NetKind::Mlp), &cfg);
    assert_eq!(run.records.len(), 4 + 12, "record count");
    assert_eq!(run.n_rounds(), 1 + 4, "init round + 12/3 main rounds");
    let mut seen = std::collections::HashSet::new();
    let mut last_cost = 0.0;
    for r in &run.records {
        assert!(seen.insert(r.tested.id()), "retested {}", r.tested.id());
        assert!(r.cum_cost >= last_cost - 1e-12, "cost regressed");
        last_cost = r.cum_cost;
    }
    for round in 1..=4usize {
        let members: Vec<_> = run
            .records
            .iter()
            .filter(|r| !r.is_init && r.round == round)
            .collect();
        assert_eq!(members.len(), 3, "round {round} size");
        // selection wall-clock and α-eval accounting attributed once,
        // on the round's last record
        for r in &members[..2] {
            assert_eq!(r.rec_wall_s, 0.0);
            assert_eq!(r.n_alpha_evals, 0);
        }
        assert!(members[2].n_alpha_evals > 0, "round {round} spent no α");
        // consecutive iters within the round
        assert_eq!(members[2].iter - members[0].iter, 2);
    }
}

/// Every optimizer survives batched rounds (this drives the
/// pending-conditioned selection path for each acquisition family).
#[test]
fn all_optimizers_run_batched_rounds() {
    let truth = Dataset::ground_truth(NetKind::Rnn);
    let constraints = caps(NetKind::Rnn);
    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::TrimTuner(ModelKind::Gp),
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
        OptimizerKind::Fabolas,
        OptimizerKind::RandomSearch,
    ] {
        let mut cfg = small_cfg(optimizer, 11, 4);
        cfg.batch_size = 2;
        let run = engine::run(&truth, &constraints, &cfg);
        assert_eq!(
            run.records.len(),
            4 + 4,
            "{}: record count",
            optimizer.name()
        );
        let mut seen = std::collections::HashSet::new();
        for r in &run.records {
            assert!(
                seen.insert(r.tested.id()),
                "{}: retested a point",
                optimizer.name()
            );
            assert!(r.incumbent.is_full());
        }
    }
}

/// The constant-liar and top-q escape hatches produce valid, distinct
/// slates too (`TRIMTUNER_BATCH` is modelled by `EngineConfig::batch_mode`
/// so the test needs no process-global env mutation).
#[test]
fn liar_and_topq_batch_modes_run_clean() {
    let truth = Dataset::ground_truth(NetKind::Mlp);
    let constraints = caps(NetKind::Mlp);
    for mode in [BatchMode::ConstantLiar, BatchMode::TopQ] {
        let mut cfg =
            small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 13, 6);
        cfg.batch_size = 3;
        cfg.batch_mode = mode;
        let run = engine::run(&truth, &constraints, &cfg);
        assert_eq!(run.records.len(), 4 + 6, "{mode:?}: record count");
        let mut seen = std::collections::HashSet::new();
        for r in &run.records {
            assert!(
                seen.insert(r.tested.id()),
                "{mode:?}: duplicate probe in slate"
            );
        }
    }
}

/// ISSUE satellite: `EventLog` ordering under q > 1 — submissions are
/// recorded in slate (= submission) order, every job completes, and the
/// engine-level `IncumbentUpdated`/`IterationDone` events fire once per
/// round, after the round's deployments.
#[test]
fn event_log_records_batched_rounds_in_submission_order() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let mut cfg =
        small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 17, 12);
    cfg.batch_size = 4;
    let mut backend = EvalBackend::Live(
        LiveEval::new(Box::new(SimLauncher::noiseless(net)), 1)
            .with_eval(&truth),
    );
    let run = engine::run_backend(&mut backend, &caps(net), &cfg)
        .expect("live run failed");
    let events = backend.event_log().unwrap().snapshot();
    backend.shutdown();

    // submissions appear in submission order (ids are assigned
    // sequentially at submit time; no failures -> no retry ids)
    let submitted: Vec<u64> = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::JobSubmitted { job } => Some(job),
            _ => None,
        })
        .collect();
    assert!(
        submitted.windows(2).all(|w| w[0] < w[1]),
        "submission ids out of order: {submitted:?}"
    );
    let completed = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::JobCompleted { .. }))
        .count();
    assert_eq!(submitted.len(), completed, "every job completes");
    assert_eq!(
        events
            .iter()
            .filter(|e| matches!(e.kind, EventKind::JobFailed { .. }))
            .count(),
        0
    );
    // engine-level round events: once per init record, once per main round
    let n_main_rounds = run.n_rounds() - 1;
    let iteration_done = events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::IterationDone { .. }))
        .count();
    assert_eq!(iteration_done, 4 + n_main_rounds, "one per round");
    // with a single worker, completions drain in submission order too
    let completed_ids: Vec<u64> = events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::JobCompleted { job, .. } => Some(job),
            _ => None,
        })
        .collect();
    assert!(
        completed_ids.windows(2).all(|w| w[0] < w[1]),
        "single-worker completions out of order: {completed_ids:?}"
    );
}

/// ISSUE satellite: `NoImprovement` with multiple observations landing in
/// one round — the stop check runs at round boundaries only, so a batched
/// run terminates with complete rounds.
#[test]
fn no_improvement_stops_at_round_boundaries() {
    let truth = Dataset::ground_truth(NetKind::Rnn);
    let mut cfg =
        small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 19, 12);
    cfg.batch_size = 3;
    // an impossible improvement bar: stop fires at the first check whose
    // window is full — i.e. after the second round (6 > window 4)
    cfg.stop = StopCondition::NoImprovement { window: 4, min_delta: 10.0 };
    let run = engine::run(&truth, &caps(NetKind::Rnn), &cfg);
    assert_eq!(
        run.records.len(),
        4 + 6,
        "must stop after exactly two complete rounds"
    );
    let main: Vec<_> = run.records.iter().filter(|r| !r.is_init).collect();
    let rounds: Vec<usize> = main.iter().map(|r| r.round).collect();
    assert_eq!(rounds, vec![1, 1, 1, 2, 2, 2], "partial round recorded");
}
