//! TrimTuner's acquisition function α_T (paper Eq. 5): FABOLAS's
//! information-gain-per-dollar, additionally weighted by the probability
//! that the incumbent recommended *after* the simulated observation
//! satisfies every QoS constraint.

use super::entropy::EntropyEstimator;
use super::models::{
    select_incumbent_over, select_incumbent_over_with_feas, Models,
};
use crate::models::Feat;
use crate::space::Constraint;

/// Precomputed per-iteration context for evaluating α_T on many candidates.
pub struct TrimTunerAcq<'a> {
    pub models: &'a Models,
    pub est: &'a EntropyEstimator,
    pub constraints: &'a [Constraint],
    /// CEA-ranked shortlist of config ids scanned for the simulated
    /// incumbent (perf: O(shortlist) instead of O(288 configs) per
    /// candidate)
    pub inc_shortlist: &'a [usize],
    /// `encode(config at s=1)` for each shortlist id, gathered once per
    /// iteration so the per-candidate incumbent scan allocates nothing
    pub inc_shortlist_feats: &'a [Feat],
    /// Joint feasibility of each shortlist entry under the *current*
    /// models, precomputed once per iteration by the engine. Only valid
    /// when conditioning leaves the constraint models untouched
    /// ([`Models::constraints_fixed_under_condition`] — tree surrogates);
    /// `None` recomputes per candidate (GPs, whose conditioning shifts the
    /// cost/time posteriors).
    pub inc_feas: Option<&'a [f64]>,
    /// KL(p_opt ‖ u) of the current accuracy model
    pub baseline: f64,
}

/// α_T(x, s) following the paper's simulation recipe (§III, steps 1–4):
///
/// 1. extend every surrogate with the predicted outcome at (x, s)
///    (single-root Gauss–Hermite collapse of the outer expectation);
/// 2. re-select the incumbent x* under the updated models;
/// 3. weight by Π_i P(q_i(x*, s=1) ≥ 0 | updated models);
/// 4. multiply by the information gain on p_opt and divide by the
///    predicted cost C(x, s) of the probe.
pub fn trimtuner_alpha(ctx: &TrimTunerAcq<'_>, x: &Feat) -> f64 {
    // 1. simulate testing (x, s)
    let updated = ctx.models.condition(x);
    // 2. incumbent under updated models (shortlist scan; the precomputed
    //    per-iteration feasibility is used when conditioning cannot move it)
    let inc = match ctx.inc_feas {
        Some(feas) => select_incumbent_over_with_feas(
            &updated,
            ctx.inc_shortlist,
            ctx.inc_shortlist_feats,
            feas,
        ),
        None => select_incumbent_over(
            &updated,
            ctx.constraints,
            ctx.inc_shortlist,
            ctx.inc_shortlist_feats,
        ),
    };
    // 3. probability the new incumbent is actually feasible — already
    //    computed by the shortlist scan for exactly this config
    let p_feas = inc.feas_prob;
    // 4. information gain per dollar
    let gain = ctx.est.info_gain(updated.acc.as_ref(), ctx.baseline);
    p_feas * gain / ctx.models.predicted_cost(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{encode, Config, Point};
    use crate::util::Rng;

    struct Fixture {
        models: Models,
        est: EntropyEstimator,
        shortlist: Vec<usize>,
        shortlist_feats: Vec<Feat>,
        constraints: Vec<Constraint>,
        baseline: f64,
    }

    fn setup(kind: ModelKind, cap: f64) -> Fixture {
        let sim = CloudSim::new(NetKind::Rnn);
        let mut rng = Rng::new(21);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..20 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut models = Models::new(kind, 9);
        models.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
        let full_feats: Vec<Feat> = (0..288)
            .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
            .collect();
        let rep: Vec<Feat> =
            (0..20).map(|i| full_feats[i * 14]).collect();
        let est = EntropyEstimator::new(rep, 150, &mut rng);
        let baseline =
            EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));
        let constraints = vec![Constraint::cost_max(cap)];
        let shortlist: Vec<usize> = (0..288).step_by(4).collect();
        let shortlist_feats: Vec<Feat> =
            shortlist.iter().map(|&id| full_feats[id]).collect();
        Fixture {
            models,
            est,
            shortlist,
            shortlist_feats,
            constraints,
            baseline,
        }
    }

    fn ctx(f: &Fixture) -> TrimTunerAcq<'_> {
        TrimTunerAcq {
            models: &f.models,
            est: &f.est,
            constraints: &f.constraints,
            inc_shortlist: &f.shortlist,
            inc_shortlist_feats: &f.shortlist_feats,
            inc_feas: None,
            baseline: f.baseline,
        }
    }

    #[test]
    fn alpha_nonnegative_finite_both_model_kinds() {
        for kind in [ModelKind::Gp, ModelKind::Trees] {
            let f = setup(kind, 0.02);
            let c = ctx(&f);
            let mut rng = Rng::new(31);
            for _ in 0..8 {
                let p = Point {
                    config: Config::from_id(rng.below(288)),
                    s_idx: rng.below(5),
                };
                let a = trimtuner_alpha(&c, &encode(&p));
                assert!(a.is_finite() && a >= 0.0, "{kind:?}: {a}");
            }
        }
    }

    #[test]
    fn impossible_constraints_crush_alpha() {
        // With an impossible cap the feasibility factor should push α_T
        // towards zero relative to a loose cap, point-by-point.
        let f_loose = setup(ModelKind::Gp, 1e9);
        let f_tight = Fixture {
            constraints: vec![Constraint::cost_max(1e-9)],
            ..setup(ModelKind::Gp, 1e9)
        };
        let (cl, ct) = (ctx(&f_loose), ctx(&f_tight));
        let mut rng = Rng::new(41);
        let mut sum_loose = 0.0;
        let mut sum_tight = 0.0;
        for _ in 0..10 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            let x = encode(&p);
            sum_loose += trimtuner_alpha(&cl, &x);
            sum_tight += trimtuner_alpha(&ct, &x);
        }
        assert!(
            sum_tight < 0.05 * sum_loose + 1e-12,
            "tight {sum_tight} vs loose {sum_loose}"
        );
    }

    #[test]
    fn alpha_is_deterministic() {
        let f = setup(ModelKind::Gp, 0.02);
        let c = ctx(&f);
        let x = encode(&Point { config: Config::from_id(33), s_idx: 1 });
        assert_eq!(trimtuner_alpha(&c, &x), trimtuner_alpha(&c, &x));
    }

    #[test]
    fn precomputed_shortlist_feasibility_is_bit_identical_for_trees() {
        // For tree surrogates, conditioning shares the constraint models,
        // so the engine's precomputed shortlist feasibility must reproduce
        // the recompute-inside-α_T path exactly.
        let f = setup(ModelKind::Trees, 0.02);
        let feas = crate::acq::joint_feasibility_many(
            &f.models,
            &f.constraints,
            &f.shortlist_feats,
        );
        let slow = ctx(&f);
        let fast = TrimTunerAcq { inc_feas: Some(feas.as_slice()), ..ctx(&f) };
        let mut rng = Rng::new(51);
        for _ in 0..6 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            let x = encode(&p);
            let a = trimtuner_alpha(&slow, &x);
            let b = trimtuner_alpha(&fast, &x);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }
}
