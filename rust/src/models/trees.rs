//! Ensemble of extremely randomized trees (Extra-Trees, Geurts et al. 2006)
//! — the paper's lightweight alternative to GPs (§III-A).
//!
//! Diversity comes from (i) bootstrap resampling of the training set per
//! tree (Breiman bagging, as the paper describes) and (ii) the Extra-Trees
//! split rule: at each node, draw one *uniformly random* cut-point per
//! candidate feature and keep the best by variance reduction. The ensemble's
//! per-point mean/std define a Gaussian predictive distribution.
//!
//! **Conditioning** (the α_T "simulate one observation" step) draws a fresh
//! seeded bootstrap over the n + 1 observations, builds each tree's
//! *structure* from the resample's existing observations only, and folds
//! the new observation into the leaf statistics it lands in (weighted by
//! its bootstrap multiplicity). A single self-predicted fantasy point
//! carries no split information — keeping it out of the structure is what
//! lets the slate evaluator cache the conditioned structure once per
//! round and pay one root-to-leaf traversal per tree per candidate
//! ([`TreesMode::Incremental`]) instead of a full per-candidate rebuild
//! (`TRIMTUNER_TREES=rebuild` re-derives it from scratch per candidate —
//! the bit-exact reference path).

use super::surrogate::{
    FantasyScratch, FantasySurface, FantasyView, Feat, FitOptions, Posterior,
    PrimedSlate, Surrogate,
};
use crate::space::D_IN;
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TreesOptions {
    pub n_trees: usize,
    /// features tried per split (<= D_IN)
    pub k_features: usize,
    pub min_samples_split: usize,
    pub bootstrap: bool,
}

impl Default for TreesOptions {
    fn default() -> Self {
        TreesOptions {
            n_trees: 30,
            k_features: D_IN,
            min_samples_split: 2,
            bootstrap: true,
        }
    }
}

/// Which conditioning strategy [`Surrogate::fantasy_surface`] uses for
/// tree ensembles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreesMode {
    /// Cache the conditioned structure (and the query grid's per-tree leaf
    /// routes) once per slate; each candidate then costs one root-to-leaf
    /// traversal per tree plus a table-lookup grid sweep. The default.
    Incremental,
    /// Re-derive the conditioned ensemble from scratch for every candidate
    /// — the seeded-rebuild reference the incremental path is verified
    /// bit-exact against (`TRIMTUNER_TREES=rebuild`).
    Rebuild,
}

impl TreesMode {
    /// `TRIMTUNER_TREES=rebuild` is the escape hatch back to per-candidate
    /// seeded rebuilds; anything else (or unset) is the incremental path.
    pub fn from_env() -> TreesMode {
        match std::env::var("TRIMTUNER_TREES") {
            Ok(v) if v.eq_ignore_ascii_case("rebuild") => TreesMode::Rebuild,
            _ => TreesMode::Incremental,
        }
    }
}

/// Flat-array binary regression tree.
#[derive(Debug, Clone)]
struct Tree {
    /// (feature, threshold, left, right) per internal node; leaf when
    /// feature == usize::MAX, then threshold stores the leaf mean.
    nodes: Vec<(usize, f64, u32, u32)>,
    /// per-node (Σy, count) over the training rows that reached it —
    /// recorded for leaves ((0, 0) on internal nodes). Conditioning folds
    /// a fantasy observation into exactly one leaf's statistic per tree.
    stats: Vec<(f64, u32)>,
}

const LEAF: usize = usize::MAX;

impl Tree {
    fn build(
        xs: &[Feat],
        ys: &[f64],
        idx: &mut Vec<usize>,
        opts: &TreesOptions,
        rng: &mut Rng,
    ) -> Tree {
        let mut t = Tree {
            nodes: Vec::with_capacity(idx.len() * 2),
            stats: Vec::with_capacity(idx.len() * 2),
        };
        let len = idx.len();
        t.build_node(xs, ys, idx, 0, len, opts, rng);
        t
    }

    /// A degenerate single-leaf tree over zero training rows — the
    /// conditioned-bootstrap edge case where every resample draw hit the
    /// new observation (its multiplicity is then >= 1, so the conditioned
    /// leaf value is always well defined).
    fn solo_leaf() -> Tree {
        Tree { nodes: vec![(LEAF, 0.0, 0, 0)], stats: vec![(0.0, 0)] }
    }

    /// Recursively build over idx[lo..hi]; returns node index.
    fn build_node(
        &mut self,
        xs: &[Feat],
        ys: &[f64],
        idx: &mut Vec<usize>,
        lo: usize,
        hi: usize,
        opts: &TreesOptions,
        rng: &mut Rng,
    ) -> u32 {
        let n = hi - lo;
        let sum: f64 = idx[lo..hi].iter().map(|&i| ys[i]).sum();
        let mean = sum / n as f64;
        // leaf conditions: small node or zero variance
        let var: f64 = idx[lo..hi]
            .iter()
            .map(|&i| (ys[i] - mean) * (ys[i] - mean))
            .sum::<f64>();
        if n < opts.min_samples_split || var < 1e-18 {
            let id = self.nodes.len() as u32;
            self.nodes.push((LEAF, mean, 0, 0));
            self.stats.push((sum, n as u32));
            return id;
        }

        // Extra-Trees split: k random features, one random threshold each.
        // Perf (EXPERIMENTS.md §Perf): feature ranges for all dimensions in
        // one fused pass; avoid the per-node index-sampling allocation when
        // every feature is a candidate (the default).
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)
        let mut fmin = [f64::INFINITY; D_IN];
        let mut fmax = [f64::NEG_INFINITY; D_IN];
        for &i in &idx[lo..hi] {
            let row = &xs[i];
            for f in 0..D_IN {
                let v = row[f];
                if v < fmin[f] {
                    fmin[f] = v;
                }
                if v > fmax[f] {
                    fmax[f] = v;
                }
            }
        }
        let k = opts.k_features.min(D_IN);
        let all_feats = k == D_IN;
        let sampled;
        let feats: &[usize] = if all_feats {
            const ALL: [usize; D_IN] = {
                let mut a = [0usize; D_IN];
                let mut i = 0;
                while i < D_IN {
                    a[i] = i;
                    i += 1;
                }
                a
            };
            &ALL
        } else {
            sampled = rng.sample_indices(D_IN, k);
            &sampled
        };
        for &f in feats {
            if fmax[f] - fmin[f] < 1e-12 {
                continue;
            }
            let thr = rng.uniform(fmin[f], fmax[f]);
            // variance reduction score
            let (mut nl, mut sl, mut ssl) = (0.0, 0.0, 0.0);
            let (mut nr, mut sr, mut ssr) = (0.0, 0.0, 0.0);
            for &i in &idx[lo..hi] {
                let y = ys[i];
                if xs[i][f] <= thr {
                    nl += 1.0;
                    sl += y;
                    ssl += y * y;
                } else {
                    nr += 1.0;
                    sr += y;
                    ssr += y * y;
                }
            }
            if nl == 0.0 || nr == 0.0 {
                continue;
            }
            let score = (ssl - sl * sl / nl) + (ssr - sr * sr / nr);
            if best.map_or(true, |(_, _, b)| score < b) {
                best = Some((f, thr, score));
            }
        }

        let Some((f, thr, _)) = best else {
            // all candidate features constant -> leaf
            let id = self.nodes.len() as u32;
            self.nodes.push((LEAF, mean, 0, 0));
            self.stats.push((sum, n as u32));
            return id;
        };

        // partition idx[lo..hi] in place
        let mut mid = lo;
        for i in lo..hi {
            if xs[idx[i]][f] <= thr {
                idx.swap(i, mid);
                mid += 1;
            }
        }
        debug_assert!(mid > lo && mid < hi);

        let id = self.nodes.len() as u32;
        self.nodes.push((f, thr, 0, 0));
        self.stats.push((0.0, 0));
        let left = self.build_node(xs, ys, idx, lo, mid, opts, rng);
        let right = self.build_node(xs, ys, idx, mid, hi, opts, rng);
        self.nodes[id as usize].2 = left;
        self.nodes[id as usize].3 = right;
        id
    }

    #[inline]
    fn predict(&self, x: &Feat) -> f64 {
        self.nodes[self.leaf_of(x) as usize].1
    }

    /// Index of the leaf node `x` routes to.
    #[inline]
    fn leaf_of(&self, x: &Feat) -> u32 {
        let mut node = 0usize;
        loop {
            let (f, thr, l, r) = self.nodes[node];
            if f == LEAF {
                return node as u32;
            }
            node = if x[f] <= thr { l as usize } else { r as usize };
        }
    }

    /// The value of `leaf` after absorbing `mult` bootstrap copies of an
    /// observation with target `y`: (Σy + mult·y) / (count + mult). The
    /// single shared implementation keeps the incremental path and the
    /// per-candidate rebuild reference bit-identical by construction.
    #[inline]
    fn conditioned_leaf_value(&self, leaf: u32, mult: u32, y: f64) -> f64 {
        if mult == 0 {
            return self.nodes[leaf as usize].1;
        }
        let (sum, cnt) = self.stats[leaf as usize];
        (sum + mult as f64 * y) / (cnt + mult) as f64
    }

    /// Fold one *real* observation into the leaf `x` routes to
    /// (multiplicity 1, no fresh bootstrap — the structure is reused): the
    /// absorption counterpart of [`Tree::conditioned_leaf_value`], updating
    /// both the leaf mean and its (Σy, count) statistic in place. The fold
    /// arithmetic is the single code path both refit modes replay, which is
    /// what makes incremental absorption and the `TRIMTUNER_REFIT=full`
    /// rebuild-and-replay reference bit-identical by construction.
    // detlint: hot
    fn fold(&mut self, x: &Feat, y: f64) {
        let leaf = self.leaf_of(x) as usize;
        let (sum, cnt) = self.stats[leaf];
        let (sum, cnt) = (sum + y, cnt + 1);
        self.stats[leaf] = (sum, cnt);
        self.nodes[leaf].1 = sum / cnt as f64;
    }
}

#[derive(Clone)]
pub struct ExtraTrees {
    pub opts: TreesOptions,
    trees: Vec<Tree>,
    xs: Vec<Feat>,
    ys: Vec<f64>,
    seed: u64,
    /// observations the current *structure* was built over: xs[..base_n]
    /// seeded the bootstrap of the last structural rebuild; xs[base_n..]
    /// were folded in leaf-incrementally since ([`ExtraTrees::absorb`]).
    base_n: usize,
}

impl ExtraTrees {
    pub fn new(opts: TreesOptions) -> ExtraTrees {
        ExtraTrees {
            opts,
            trees: Vec::new(),
            xs: Vec::new(),
            ys: Vec::new(),
            seed: 0xd7_5eed,
            base_n: 0,
        }
    }

    pub fn with_seed(opts: TreesOptions, seed: u64) -> ExtraTrees {
        ExtraTrees { seed, ..ExtraTrees::new(opts) }
    }

    fn rebuild(&mut self) {
        self.rebuild_base(self.xs.len());
    }

    /// Rebuild the ensemble structure over the first `base` observations
    /// (seed keyed on `base` — exactly the historic full-rebuild stream,
    /// so campaigns that never absorb are bit-identical to before), then
    /// replay xs[base..] as leaf-incremental folds in absorption order.
    /// This is the deterministic state function both refit modes share:
    /// the incremental path maintains it observation by observation,
    /// [`ExtraTrees::refit_frozen`] recomputes it from scratch.
    fn rebuild_base(&mut self, base: usize) {
        // Seed depends on data size only -> deterministic runs, fresh trees
        // after every structural rebuild.
        let mut rng = Rng::new(self.seed ^ ((base as u64) << 20));
        self.trees = (0..self.opts.n_trees)
            .map(|_| {
                let mut idx: Vec<usize> = if self.opts.bootstrap {
                    (0..base).map(|_| rng.below(base)).collect()
                } else {
                    (0..base).collect()
                };
                Tree::build(
                    &self.xs[..base],
                    &self.ys[..base],
                    &mut idx,
                    &self.opts,
                    &mut rng,
                )
            })
            .collect();
        self.base_n = base;
        for i in base..self.xs.len() {
            let x = self.xs[i];
            let y = self.ys[i];
            for t in &mut self.trees {
                t.fold(&x, y);
            }
        }
    }

    /// Candidate-independent template for conditioning the ensemble on one
    /// extra observation: for each tree, a seeded bootstrap over the n + 1
    /// indices, the tree built from the resample's *existing* rows, and the
    /// multiplicity with which the new index was drawn. Structure and
    /// multiplicities depend only on (seed, n, existing data), so the slate
    /// evaluator computes this once and shares it across every candidate.
    fn cond_template(&self) -> CondTemplate {
        let n_new = self.xs.len() + 1;
        // Seed depends on data size only -> deterministic runs, fresh
        // conditioned trees after every observation.
        let mut rng = Rng::new(self.seed ^ ((n_new as u64) << 20));
        let mut trees = Vec::with_capacity(self.opts.n_trees);
        let mut mult = Vec::with_capacity(self.opts.n_trees);
        for _ in 0..self.opts.n_trees {
            let (mut idx, c) = if self.opts.bootstrap {
                let mut old = Vec::with_capacity(n_new);
                let mut c = 0u32;
                for _ in 0..n_new {
                    let i = rng.below(n_new);
                    if i + 1 == n_new {
                        c += 1;
                    } else {
                        old.push(i);
                    }
                }
                (old, c)
            } else {
                ((0..self.xs.len()).collect::<Vec<usize>>(), 1)
            };
            let tree = if idx.is_empty() {
                Tree::solo_leaf()
            } else {
                Tree::build(&self.xs, &self.ys, &mut idx, &self.opts, &mut rng)
            };
            trees.push(tree);
            mult.push(c);
        }
        CondTemplate { trees, mult }
    }

    /// [`Surrogate::condition`] for tree ensembles (see the module docs):
    /// the conditioned structure from [`ExtraTrees::cond_template`], with
    /// the new observation folded into the one leaf per tree it routes to.
    fn conditioned(&self, x: &Feat, y: f64) -> ExtraTrees {
        let CondTemplate { mut trees, mult } = self.cond_template();
        for (t, &c) in trees.iter_mut().zip(&mult) {
            if c == 0 {
                continue;
            }
            let leaf = t.leaf_of(x) as usize;
            let v = t.conditioned_leaf_value(leaf as u32, c, y);
            t.nodes[leaf].1 = v;
            let (sum, cnt) = t.stats[leaf];
            t.stats[leaf] = (sum + c as f64 * y, cnt + c);
        }
        let mut xs = Vec::with_capacity(self.xs.len() + 1);
        xs.extend_from_slice(&self.xs);
        xs.push(*x);
        let mut ys = Vec::with_capacity(self.ys.len() + 1);
        ys.extend_from_slice(&self.ys);
        ys.push(y);
        ExtraTrees {
            opts: self.opts,
            trees,
            xs,
            ys,
            seed: self.seed,
            // the conditioned structure was derived from the n existing
            // observations; the fantasy clone never absorbs or refits
            base_n: self.xs.len(),
        }
    }

    /// [`Surrogate::fantasy_surface`] with the conditioning strategy
    /// pinned explicitly (tests and benches compare the two modes without
    /// touching the process environment).
    pub fn fantasy_surface_mode(
        &self,
        grid: &[Feat],
        m_joint: usize,
        mode: TreesMode,
    ) -> Box<dyn FantasySurface> {
        assert!(m_joint <= grid.len());
        let (tpl, routes) = match mode {
            TreesMode::Rebuild => (None, Vec::new()),
            TreesMode::Incremental => {
                let tpl = self.cond_template();
                // every grid point's (leaf, value) per template tree: the
                // per-candidate grid sweep becomes table lookups
                let routes: Vec<Vec<(u32, f64)>> = tpl
                    .trees
                    .iter()
                    .map(|t| {
                        grid.iter()
                            .map(|q| {
                                let leaf = t.leaf_of(q);
                                (leaf, t.nodes[leaf as usize].1)
                            })
                            .collect()
                    })
                    .collect();
                (Some(tpl), routes)
            }
        };
        Box::new(TreesFantasy {
            base: self.clone(),
            grid: grid.to_vec(),
            m_joint,
            tpl,
            routes,
        })
    }
}

/// The shared conditioned structure: one bootstrap-resampled tree per
/// ensemble member, built from the existing observations, plus the
/// bootstrap multiplicity of the (yet unknown) new observation.
struct CondTemplate {
    trees: Vec<Tree>,
    mult: Vec<u32>,
}

/// Fantasy surface for tree ensembles. The conditioned structure never
/// depends on the candidate (module docs), so the incremental default
/// builds it once per slate together with the query grid's per-tree leaf
/// routes; each view then routes the candidate down every tree, adjusts
/// the one leaf statistic its fantasy observation lands in, and sweeps the
/// grid via lookups. `TRIMTUNER_TREES=rebuild` re-derives the conditioned
/// ensemble from scratch per candidate instead — bit-identical, and also
/// exactly what clone-and-condition (`TRIMTUNER_ALPHA=clone`) does.
struct TreesFantasy {
    base: ExtraTrees,
    grid: Vec<Feat>,
    m_joint: usize,
    /// `Some` in incremental mode: the cached conditioned structure
    tpl: Option<CondTemplate>,
    /// incremental mode: per tree, each grid point's (leaf, value)
    routes: Vec<Vec<(u32, f64)>>,
}

impl TreesFantasy {
    /// The conditioned view for candidate `x` with simulated outcome `y`,
    /// written into `out` without per-candidate allocation on the
    /// incremental path (the rebuild hatch allocates by design).
    // detlint: hot
    fn view_for_into(
        &self,
        x: &Feat,
        y: f64,
        scratch: &mut FantasyScratch,
        out: &mut FantasyView,
    ) {
        match &self.tpl {
            Some(tpl) => {
                let nq = self.grid.len();
                let sum = &mut scratch.acc;
                sum.clear();
                sum.resize(nq, 0.0);
                let sumsq = &mut scratch.acc2;
                sumsq.clear();
                sumsq.resize(nq, 0.0);
                // tree-major accumulation, same order as `predict_many`
                // over a materialized conditioned ensemble
                for ((tree, &c), routes) in
                    tpl.trees.iter().zip(&tpl.mult).zip(&self.routes)
                {
                    let leaf = tree.leaf_of(x);
                    let v_new = tree.conditioned_leaf_value(leaf, c, y);
                    for ((&(l, v), s), ss) in
                        routes.iter().zip(sum.iter_mut()).zip(sumsq.iter_mut())
                    {
                        let p = if l == leaf { v_new } else { v };
                        *s += p;
                        *ss += p * p;
                    }
                }
                let n = tpl.trees.len() as f64;
                out.grid.clear();
                out.grid.extend(sum.iter().zip(sumsq.iter()).map(
                    |(&s, &ss)| {
                        let mean = s / n;
                        let var = (ss / n - mean * mean).max(0.0);
                        (mean, var.sqrt().max(1e-4))
                    },
                ));
            }
            // rebuild hatch: per-candidate seeded rebuild, the reference
            None => {
                out.grid.clear();
                out.grid.extend(
                    self.base.conditioned(x, y).predict_many(&self.grid),
                );
            }
        }
        if self.m_joint > 0 {
            // rebuild the single diagonal component in place; finish()
            // recomputes the mixture mean bit-identically to the
            // Posterior::diagonal constructor
            let post = out.joint.get_or_insert_with(Posterior::new_empty);
            post.clear_components();
            let comp = post.push_component();
            comp.mean.clear();
            comp.mean
                .extend(out.grid[..self.m_joint].iter().map(|&(m, _)| m));
            let std = comp.diag_mut();
            std.clear();
            std.extend(out.grid[..self.m_joint].iter().map(|&(_, s)| s));
            post.finish();
        } else {
            out.joint = None;
        }
    }
}

/// A [`TreesFantasy`] surface primed for one candidate slate: the
/// simulated outcomes ŷ(x_c) come from one tree-major `predict_many` pass
/// instead of a scalar prediction per candidate.
struct TreesPrimed<'s> {
    surf: &'s TreesFantasy,
    xs: &'s [Feat],
    y_hat: Vec<f64>,
}

impl PrimedSlate for TreesPrimed<'_> {
    fn view_into(
        &self,
        i: usize,
        scratch: &mut FantasyScratch,
        out: &mut FantasyView,
    ) {
        self.surf.view_for_into(&self.xs[i], self.y_hat[i], scratch, out);
    }
}

impl FantasySurface for TreesFantasy {
    fn view_with(&self, x: &Feat, scratch: &mut FantasyScratch) -> FantasyView {
        let (y, _) = self.base.predict(x);
        let mut out = FantasyView::new();
        self.view_for_into(x, y, scratch, &mut out);
        out
    }

    fn prime<'s>(&'s self, xs: &'s [Feat]) -> Box<dyn PrimedSlate + 's> {
        let y_hat: Vec<f64> = self
            .base
            .predict_many(xs)
            .into_iter()
            .map(|(mu, _)| mu)
            .collect();
        Box::new(TreesPrimed { surf: self, xs, y_hat })
    }
}

impl Surrogate for ExtraTrees {
    fn fit(&mut self, xs: &[Feat], ys: &[f64], _opts: FitOptions) {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.rebuild();
    }

    fn predict(&self, x: &Feat) -> (f64, f64) {
        debug_assert!(!self.trees.is_empty(), "predict before fit");
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for t in &self.trees {
            let p = t.predict(x);
            sum += p;
            sumsq += p * p;
        }
        let n = self.trees.len() as f64;
        let mean = sum / n;
        let var = (sumsq / n - mean * mean).max(0.0);
        // Floor the ensemble spread: identical leaves would otherwise
        // claim zero uncertainty and freeze exploration.
        (mean, var.sqrt().max(1e-4))
    }

    /// Native batch prediction: all trees walk the whole candidate slate in
    /// one tree-major pass, so each tree's node array stays hot in cache
    /// instead of being re-faulted per candidate. Per-point accumulation
    /// order matches [`ExtraTrees::predict`] (tree order), so results are
    /// bit-identical to the scalar path.
    fn predict_many(&self, xs: &[Feat]) -> Vec<(f64, f64)> {
        debug_assert!(!self.trees.is_empty(), "predict before fit");
        let mut sum = vec![0.0; xs.len()];
        let mut sumsq = vec![0.0; xs.len()];
        for t in &self.trees {
            for ((x, s), ss) in
                xs.iter().zip(sum.iter_mut()).zip(sumsq.iter_mut())
            {
                let p = t.predict(x);
                *s += p;
                *ss += p * p;
            }
        }
        let n = self.trees.len() as f64;
        sum.into_iter()
            .zip(sumsq)
            .map(|(s, ss)| {
                let mean = s / n;
                let var = (ss / n - mean * mean).max(0.0);
                (mean, var.sqrt().max(1e-4))
            })
            .collect()
    }

    fn posterior(&self, xs: &[Feat]) -> Posterior {
        let (mean, std): (Vec<f64>, Vec<f64>) =
            self.predict_many(xs).into_iter().unzip();
        Posterior::diagonal(mean, std)
    }

    fn condition(&self, x: &Feat, y: f64) -> Box<dyn Surrogate> {
        Box::new(self.conditioned(x, y))
    }

    /// Leaf-incremental absorption: push the observation and fold it into
    /// the one leaf per tree it routes to — O(trees · depth) per
    /// observation, structure untouched (no bootstrap draw for the new
    /// row: a staleness-bounded approximation, since the engine's refit
    /// policy rebuilds the structure through `fit` every k rounds). No
    /// allocation beyond the amortized xs/ys pushes.
    // detlint: hot
    fn absorb(&mut self, x: &Feat, y: f64) {
        debug_assert!(!self.trees.is_empty(), "absorb before fit");
        self.xs.push(*x);
        self.ys.push(y);
        for t in &mut self.trees {
            t.fold(x, y);
        }
    }

    /// The from-scratch twin of [`ExtraTrees::absorb`]
    /// (`TRIMTUNER_REFIT=full`): rebuild the structure anchored at the
    /// last structural fit and replay the absorbed tail in order. Shares
    /// the fold arithmetic with the incremental path, so the two are
    /// bit-identical — `tests/refit_parity.rs` pins that.
    fn refit_frozen(&mut self) {
        self.rebuild_base(self.base_n);
    }

    fn n_obs(&self) -> usize {
        self.xs.len()
    }

    fn clone_box(&self) -> Box<dyn Surrogate> {
        Box::new(self.clone())
    }

    fn fantasy_surface(
        &self,
        grid: &[Feat],
        m_joint: usize,
    ) -> Box<dyn FantasySurface> {
        self.fantasy_surface_mode(grid, m_joint, TreesMode::from_env())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn toy(n: usize, rng: &mut Rng) -> (Vec<Feat>, Vec<f64>) {
        let xs: Vec<Feat> = (0..n)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let ys =
            xs.iter().map(|x| 2.0 * x[0] - x[3] + 0.5 * x[6]).collect();
        (xs, ys)
    }

    #[test]
    fn fits_linear_function() {
        let mut rng = Rng::new(1);
        let (xs, ys) = toy(200, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let mut err = 0.0;
        for _ in 0..50 {
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            let truth = 2.0 * f[0] - f[3] + 0.5 * f[6];
            let (mu, _) = et.predict(&f);
            err += (mu - truth).abs();
        }
        err /= 50.0;
        assert!(err < 0.25, "mean abs err {err}");
    }

    #[test]
    fn constant_target_zero_spread() {
        let mut rng = Rng::new(2);
        let (xs, _) = toy(30, &mut rng);
        let ys = vec![1.5; 30];
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let (mu, std) = et.predict(&xs[7]);
        assert!((mu - 1.5).abs() < 1e-9);
        assert!(std <= 1e-4 + 1e-12); // floored
    }

    #[test]
    fn deterministic_given_same_data() {
        let mut rng = Rng::new(3);
        let (xs, ys) = toy(40, &mut rng);
        let mut a = ExtraTrees::new(TreesOptions::default());
        let mut b = ExtraTrees::new(TreesOptions::default());
        a.fit(&xs, &ys, FitOptions::default());
        b.fit(&xs, &ys, FitOptions::default());
        let (ma, sa) = a.predict(&xs[0]);
        let (mb, sb) = b.predict(&xs[0]);
        assert_eq!(ma, mb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn uncertainty_positive_off_data() {
        check("DT spread > 0 away from data", 16, |rng| {
            let (xs, ys) = toy(20 + rng.below(30), rng);
            let mut et = ExtraTrees::new(TreesOptions::default());
            et.fit(&xs, &ys, FitOptions::default());
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            let (_, std) = et.predict(&f);
            if std > 0.0 {
                Ok(())
            } else {
                Err("zero spread".into())
            }
        });
    }

    #[test]
    fn predict_many_bitwise_matches_scalar() {
        let mut rng = Rng::new(9);
        let (xs, ys) = toy(60, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let probes: Vec<Feat> = (0..40)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let batch = et.predict_many(&probes);
        for (p, (bm, bs)) in probes.iter().zip(&batch) {
            let (m, s) = et.predict(p);
            assert_eq!(m.to_bits(), bm.to_bits());
            assert_eq!(s.to_bits(), bs.to_bits());
        }
    }

    fn rand_feat(rng: &mut Rng) -> Feat {
        let mut f = [0.0; D_IN];
        for v in f.iter_mut() {
            *v = rng.f64();
        }
        f
    }

    #[test]
    fn fantasy_view_bit_identical_to_clone_path() {
        // incremental conditioning (the default surface) vs the clone
        // path (`condition` + `predict_many`, which rebuilds the
        // conditioned ensemble from scratch): bit-exact.
        let mut rng = Rng::new(13);
        let (xs, ys) = toy(40, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let grid: Vec<Feat> = (0..12).map(|_| rand_feat(&mut rng)).collect();
        let m_joint = 5;
        let surf =
            et.fantasy_surface_mode(&grid, m_joint, TreesMode::Incremental);
        for _ in 0..3 {
            let x = rand_feat(&mut rng);
            let view = surf.view(&x);
            let (y, _) = et.predict(&x);
            let cond = et.condition(&x, y);
            let want = cond.predict_many(&grid);
            for ((vm, vs), (wm, ws)) in view.grid.iter().zip(&want) {
                assert_eq!(vm.to_bits(), wm.to_bits());
                assert_eq!(vs.to_bits(), ws.to_bits());
            }
            let post_f = view.joint.expect("joint prefix");
            let post_c = cond.posterior(&grid[..m_joint]);
            let z: Vec<f64> = (0..m_joint).map(|_| rng.normal()).collect();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            post_f.sample_with(&z, &mut a);
            post_c.sample_with(&z, &mut b);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn incremental_and_rebuild_surfaces_bit_identical() {
        // the TRIMTUNER_TREES=rebuild reference (per-candidate seeded
        // rebuild) vs the cached-structure incremental default, including
        // the primed batched-ŷ entry point
        let mut rng = Rng::new(29);
        let (xs, ys) = toy(35, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let grid: Vec<Feat> = (0..14).map(|_| rand_feat(&mut rng)).collect();
        let inc = et.fantasy_surface_mode(&grid, 6, TreesMode::Incremental);
        let reb = et.fantasy_surface_mode(&grid, 6, TreesMode::Rebuild);
        let slate: Vec<Feat> = (0..5).map(|_| rand_feat(&mut rng)).collect();
        let primed = inc.prime(&slate);
        let mut scratch = FantasyScratch::new();
        for (i, x) in slate.iter().enumerate() {
            let a = inc.view(x);
            let b = reb.view(x);
            let c = primed.view_at(i, &mut scratch);
            for (((am, astd), (bm, bstd)), (cm, cstd)) in
                a.grid.iter().zip(&b.grid).zip(&c.grid)
            {
                assert_eq!(am.to_bits(), bm.to_bits(), "inc vs rebuild");
                assert_eq!(astd.to_bits(), bstd.to_bits(), "inc vs rebuild");
                assert_eq!(am.to_bits(), cm.to_bits(), "inc vs primed");
                assert_eq!(astd.to_bits(), cstd.to_bits(), "inc vs primed");
            }
        }
    }

    #[test]
    fn conditioning_on_tiny_datasets_is_well_defined() {
        // with n = 1 the conditioned bootstrap can resample the new index
        // exclusively (Tree::solo_leaf): predictions must stay finite and
        // the incremental/rebuild modes must still agree bit for bit
        let xs = vec![[0.4; D_IN]];
        let ys = vec![1.0];
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let cond = et.conditioned(&[0.6; D_IN], 3.0);
        let (mu, std) = cond.predict(&[0.5; D_IN]);
        assert!(mu.is_finite() && std.is_finite(), "{mu} {std}");
        let grid = vec![[0.2; D_IN], [0.8; D_IN]];
        let inc = et.fantasy_surface_mode(&grid, 2, TreesMode::Incremental);
        let reb = et.fantasy_surface_mode(&grid, 2, TreesMode::Rebuild);
        let x = [0.6; D_IN];
        for ((am, astd), (bm, bstd)) in
            inc.view(&x).grid.iter().zip(&reb.view(&x).grid)
        {
            assert!(am.is_finite() && astd.is_finite());
            assert_eq!(am.to_bits(), bm.to_bits());
            assert_eq!(astd.to_bits(), bstd.to_bits());
        }
    }

    #[test]
    fn condition_incorporates_new_point() {
        let mut rng = Rng::new(5);
        let (xs, ys) = toy(30, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        // inject an outlier at a fresh location; prediction must move
        let mut xnew = [0.9; D_IN];
        xnew[6] = 0.5;
        let (before, _) = et.predict(&xnew);
        let cond = et.condition(&xnew, before + 5.0);
        let (after, _) = cond.predict(&xnew);
        assert!(
            (after - before).abs() > 0.5,
            "prediction didn't move: {before} -> {after}"
        );
        assert_eq!(cond.n_obs(), 31);
    }

    #[test]
    fn single_point_dataset() {
        let xs = vec![[0.5; D_IN]];
        let ys = vec![2.0];
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let (mu, _) = et.predict(&[0.1; D_IN]);
        assert!((mu - 2.0).abs() < 1e-9);
    }
}
