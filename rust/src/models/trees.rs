//! Ensemble of extremely randomized trees (Extra-Trees, Geurts et al. 2006)
//! — the paper's lightweight alternative to GPs (§III-A).
//!
//! Diversity comes from (i) bootstrap resampling of the training set per
//! tree (Breiman bagging, as the paper describes) and (ii) the Extra-Trees
//! split rule: at each node, draw one *uniformly random* cut-point per
//! candidate feature and keep the best by variance reduction. The ensemble's
//! per-point mean/std define a Gaussian predictive distribution.

use super::surrogate::{
    FantasySurface, FantasyView, Feat, FitOptions, Posterior, Surrogate,
};
use crate::space::D_IN;
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct TreesOptions {
    pub n_trees: usize,
    /// features tried per split (<= D_IN)
    pub k_features: usize,
    pub min_samples_split: usize,
    pub bootstrap: bool,
}

impl Default for TreesOptions {
    fn default() -> Self {
        TreesOptions {
            n_trees: 30,
            k_features: D_IN,
            min_samples_split: 2,
            bootstrap: true,
        }
    }
}

/// Flat-array binary regression tree.
#[derive(Debug, Clone)]
struct Tree {
    /// (feature, threshold, left, right) per internal node; leaf when
    /// feature == usize::MAX, then threshold stores the leaf mean.
    nodes: Vec<(usize, f64, u32, u32)>,
}

const LEAF: usize = usize::MAX;

impl Tree {
    fn build(
        xs: &[Feat],
        ys: &[f64],
        idx: &mut Vec<usize>,
        opts: &TreesOptions,
        rng: &mut Rng,
    ) -> Tree {
        let mut nodes = Vec::with_capacity(idx.len() * 2);
        let len = idx.len();
        let mut t = Tree { nodes };
        t.build_node(xs, ys, idx, 0, len, opts, rng);
        nodes = std::mem::take(&mut t.nodes);
        Tree { nodes }
    }

    /// Recursively build over idx[lo..hi]; returns node index.
    fn build_node(
        &mut self,
        xs: &[Feat],
        ys: &[f64],
        idx: &mut Vec<usize>,
        lo: usize,
        hi: usize,
        opts: &TreesOptions,
        rng: &mut Rng,
    ) -> u32 {
        let n = hi - lo;
        let mean: f64 =
            idx[lo..hi].iter().map(|&i| ys[i]).sum::<f64>() / n as f64;
        // leaf conditions: small node or zero variance
        let var: f64 = idx[lo..hi]
            .iter()
            .map(|&i| (ys[i] - mean) * (ys[i] - mean))
            .sum::<f64>();
        if n < opts.min_samples_split || var < 1e-18 {
            let id = self.nodes.len() as u32;
            self.nodes.push((LEAF, mean, 0, 0));
            return id;
        }

        // Extra-Trees split: k random features, one random threshold each.
        // Perf (EXPERIMENTS.md §Perf): feature ranges for all dimensions in
        // one fused pass; avoid the per-node index-sampling allocation when
        // every feature is a candidate (the default).
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, score)
        let mut fmin = [f64::INFINITY; D_IN];
        let mut fmax = [f64::NEG_INFINITY; D_IN];
        for &i in &idx[lo..hi] {
            let row = &xs[i];
            for f in 0..D_IN {
                let v = row[f];
                if v < fmin[f] {
                    fmin[f] = v;
                }
                if v > fmax[f] {
                    fmax[f] = v;
                }
            }
        }
        let k = opts.k_features.min(D_IN);
        let all_feats = k == D_IN;
        let sampled;
        let feats: &[usize] = if all_feats {
            const ALL: [usize; D_IN] = {
                let mut a = [0usize; D_IN];
                let mut i = 0;
                while i < D_IN {
                    a[i] = i;
                    i += 1;
                }
                a
            };
            &ALL
        } else {
            sampled = rng.sample_indices(D_IN, k);
            &sampled
        };
        for &f in feats {
            if fmax[f] - fmin[f] < 1e-12 {
                continue;
            }
            let thr = rng.uniform(fmin[f], fmax[f]);
            // variance reduction score
            let (mut nl, mut sl, mut ssl) = (0.0, 0.0, 0.0);
            let (mut nr, mut sr, mut ssr) = (0.0, 0.0, 0.0);
            for &i in &idx[lo..hi] {
                let y = ys[i];
                if xs[i][f] <= thr {
                    nl += 1.0;
                    sl += y;
                    ssl += y * y;
                } else {
                    nr += 1.0;
                    sr += y;
                    ssr += y * y;
                }
            }
            if nl == 0.0 || nr == 0.0 {
                continue;
            }
            let score = (ssl - sl * sl / nl) + (ssr - sr * sr / nr);
            if best.map_or(true, |(_, _, b)| score < b) {
                best = Some((f, thr, score));
            }
        }

        let Some((f, thr, _)) = best else {
            // all candidate features constant -> leaf
            let id = self.nodes.len() as u32;
            self.nodes.push((LEAF, mean, 0, 0));
            return id;
        };

        // partition idx[lo..hi] in place
        let mut mid = lo;
        for i in lo..hi {
            if xs[idx[i]][f] <= thr {
                idx.swap(i, mid);
                mid += 1;
            }
        }
        debug_assert!(mid > lo && mid < hi);

        let id = self.nodes.len() as u32;
        self.nodes.push((f, thr, 0, 0));
        let left = self.build_node(xs, ys, idx, lo, mid, opts, rng);
        let right = self.build_node(xs, ys, idx, mid, hi, opts, rng);
        self.nodes[id as usize].2 = left;
        self.nodes[id as usize].3 = right;
        id
    }

    #[inline]
    fn predict(&self, x: &Feat) -> f64 {
        let mut node = 0usize;
        loop {
            let (f, thr, l, r) = self.nodes[node];
            if f == LEAF {
                return thr;
            }
            node = if x[f] <= thr { l as usize } else { r as usize };
        }
    }
}

#[derive(Clone)]
pub struct ExtraTrees {
    pub opts: TreesOptions,
    trees: Vec<Tree>,
    xs: Vec<Feat>,
    ys: Vec<f64>,
    seed: u64,
}

impl ExtraTrees {
    pub fn new(opts: TreesOptions) -> ExtraTrees {
        ExtraTrees {
            opts,
            trees: Vec::new(),
            xs: Vec::new(),
            ys: Vec::new(),
            seed: 0xd7_5eed,
        }
    }

    pub fn with_seed(opts: TreesOptions, seed: u64) -> ExtraTrees {
        ExtraTrees { seed, ..ExtraTrees::new(opts) }
    }

    fn rebuild(&mut self) {
        let n = self.xs.len();
        // Seed depends on data size only -> deterministic runs, fresh trees
        // after every observation.
        let mut rng = Rng::new(self.seed ^ ((n as u64) << 20));
        self.trees = (0..self.opts.n_trees)
            .map(|_| {
                let mut idx: Vec<usize> = if self.opts.bootstrap {
                    (0..n).map(|_| rng.below(n)).collect()
                } else {
                    (0..n).collect()
                };
                Tree::build(&self.xs, &self.ys, &mut idx, &self.opts, &mut rng)
            })
            .collect();
    }

    /// [`Surrogate::condition`] without cloning the stale tree array (the
    /// rebuild overwrites it anyway) — the fantasy hot path's variant.
    fn conditioned(&self, x: &Feat, y: f64) -> ExtraTrees {
        let mut xs = Vec::with_capacity(self.xs.len() + 1);
        xs.extend_from_slice(&self.xs);
        xs.push(*x);
        let mut ys = Vec::with_capacity(self.ys.len() + 1);
        ys.extend_from_slice(&self.ys);
        ys.push(y);
        let mut t = ExtraTrees {
            opts: self.opts,
            trees: Vec::new(),
            xs,
            ys,
            seed: self.seed,
        };
        t.rebuild();
        t
    }
}

/// Fantasy surface for tree ensembles. There is no closed-form conditioned
/// posterior for a seeded ensemble rebuild, so each view still rebuilds
/// once — but on a single fused query grid (one tree-major pass instead of
/// separate shortlist and representer sweeps), without cloning the stale
/// ensemble, and with the joint prefix reusing the grid predictions
/// directly. Bit-identical to clone-and-condition.
struct TreesFantasy {
    base: ExtraTrees,
    grid: Vec<Feat>,
    m_joint: usize,
}

impl FantasySurface for TreesFantasy {
    fn view(&self, x: &Feat) -> FantasyView {
        let (y, _) = self.base.predict(x);
        let cond = self.base.conditioned(x, y);
        let grid = cond.predict_many(&self.grid);
        let joint = (self.m_joint > 0).then(|| {
            let (mean, std): (Vec<f64>, Vec<f64>) =
                grid[..self.m_joint].iter().copied().unzip();
            Posterior::diagonal(mean, std)
        });
        FantasyView { grid, joint }
    }
}

impl Surrogate for ExtraTrees {
    fn fit(&mut self, xs: &[Feat], ys: &[f64], _opts: FitOptions) {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        self.xs = xs.to_vec();
        self.ys = ys.to_vec();
        self.rebuild();
    }

    fn predict(&self, x: &Feat) -> (f64, f64) {
        debug_assert!(!self.trees.is_empty(), "predict before fit");
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for t in &self.trees {
            let p = t.predict(x);
            sum += p;
            sumsq += p * p;
        }
        let n = self.trees.len() as f64;
        let mean = sum / n;
        let var = (sumsq / n - mean * mean).max(0.0);
        // Floor the ensemble spread: identical leaves would otherwise
        // claim zero uncertainty and freeze exploration.
        (mean, var.sqrt().max(1e-4))
    }

    /// Native batch prediction: all trees walk the whole candidate slate in
    /// one tree-major pass, so each tree's node array stays hot in cache
    /// instead of being re-faulted per candidate. Per-point accumulation
    /// order matches [`ExtraTrees::predict`] (tree order), so results are
    /// bit-identical to the scalar path.
    fn predict_many(&self, xs: &[Feat]) -> Vec<(f64, f64)> {
        debug_assert!(!self.trees.is_empty(), "predict before fit");
        let mut sum = vec![0.0; xs.len()];
        let mut sumsq = vec![0.0; xs.len()];
        for t in &self.trees {
            for ((x, s), ss) in
                xs.iter().zip(sum.iter_mut()).zip(sumsq.iter_mut())
            {
                let p = t.predict(x);
                *s += p;
                *ss += p * p;
            }
        }
        let n = self.trees.len() as f64;
        sum.into_iter()
            .zip(sumsq)
            .map(|(s, ss)| {
                let mean = s / n;
                let var = (ss / n - mean * mean).max(0.0);
                (mean, var.sqrt().max(1e-4))
            })
            .collect()
    }

    fn posterior(&self, xs: &[Feat]) -> Posterior {
        let (mean, std): (Vec<f64>, Vec<f64>) =
            self.predict_many(xs).into_iter().unzip();
        Posterior::diagonal(mean, std)
    }

    fn condition(&self, x: &Feat, y: f64) -> Box<dyn Surrogate> {
        Box::new(self.conditioned(x, y))
    }

    fn n_obs(&self) -> usize {
        self.xs.len()
    }

    fn clone_box(&self) -> Box<dyn Surrogate> {
        Box::new(self.clone())
    }

    fn fantasy_surface(
        &self,
        grid: &[Feat],
        m_joint: usize,
    ) -> Box<dyn FantasySurface> {
        assert!(m_joint <= grid.len());
        Box::new(TreesFantasy {
            base: self.clone(),
            grid: grid.to_vec(),
            m_joint,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn toy(n: usize, rng: &mut Rng) -> (Vec<Feat>, Vec<f64>) {
        let xs: Vec<Feat> = (0..n)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let ys =
            xs.iter().map(|x| 2.0 * x[0] - x[3] + 0.5 * x[6]).collect();
        (xs, ys)
    }

    #[test]
    fn fits_linear_function() {
        let mut rng = Rng::new(1);
        let (xs, ys) = toy(200, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let mut err = 0.0;
        for _ in 0..50 {
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            let truth = 2.0 * f[0] - f[3] + 0.5 * f[6];
            let (mu, _) = et.predict(&f);
            err += (mu - truth).abs();
        }
        err /= 50.0;
        assert!(err < 0.25, "mean abs err {err}");
    }

    #[test]
    fn constant_target_zero_spread() {
        let mut rng = Rng::new(2);
        let (xs, _) = toy(30, &mut rng);
        let ys = vec![1.5; 30];
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let (mu, std) = et.predict(&xs[7]);
        assert!((mu - 1.5).abs() < 1e-9);
        assert!(std <= 1e-4 + 1e-12); // floored
    }

    #[test]
    fn deterministic_given_same_data() {
        let mut rng = Rng::new(3);
        let (xs, ys) = toy(40, &mut rng);
        let mut a = ExtraTrees::new(TreesOptions::default());
        let mut b = ExtraTrees::new(TreesOptions::default());
        a.fit(&xs, &ys, FitOptions::default());
        b.fit(&xs, &ys, FitOptions::default());
        let (ma, sa) = a.predict(&xs[0]);
        let (mb, sb) = b.predict(&xs[0]);
        assert_eq!(ma, mb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn uncertainty_positive_off_data() {
        check("DT spread > 0 away from data", 16, |rng| {
            let (xs, ys) = toy(20 + rng.below(30), rng);
            let mut et = ExtraTrees::new(TreesOptions::default());
            et.fit(&xs, &ys, FitOptions::default());
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            let (_, std) = et.predict(&f);
            if std > 0.0 {
                Ok(())
            } else {
                Err("zero spread".into())
            }
        });
    }

    #[test]
    fn predict_many_bitwise_matches_scalar() {
        let mut rng = Rng::new(9);
        let (xs, ys) = toy(60, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let probes: Vec<Feat> = (0..40)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let batch = et.predict_many(&probes);
        for (p, (bm, bs)) in probes.iter().zip(&batch) {
            let (m, s) = et.predict(p);
            assert_eq!(m.to_bits(), bm.to_bits());
            assert_eq!(s.to_bits(), bs.to_bits());
        }
    }

    #[test]
    fn fantasy_view_bit_identical_to_clone_path() {
        let mut rng = Rng::new(13);
        let (xs, ys) = toy(40, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let rand_feat = |rng: &mut Rng| {
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            f
        };
        let grid: Vec<Feat> = (0..12).map(|_| rand_feat(&mut rng)).collect();
        let m_joint = 5;
        let surf = et.fantasy_surface(&grid, m_joint);
        for _ in 0..3 {
            let x = rand_feat(&mut rng);
            let view = surf.view(&x);
            let (y, _) = et.predict(&x);
            let cond = et.condition(&x, y);
            let want = cond.predict_many(&grid);
            for ((vm, vs), (wm, ws)) in view.grid.iter().zip(&want) {
                assert_eq!(vm.to_bits(), wm.to_bits());
                assert_eq!(vs.to_bits(), ws.to_bits());
            }
            let post_f = view.joint.expect("joint prefix");
            let post_c = cond.posterior(&grid[..m_joint]);
            let z: Vec<f64> = (0..m_joint).map(|_| rng.normal()).collect();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            post_f.sample_with(&z, &mut a);
            post_c.sample_with(&z, &mut b);
            for (va, vb) in a.iter().zip(&b) {
                assert_eq!(va.to_bits(), vb.to_bits());
            }
        }
    }

    #[test]
    fn condition_incorporates_new_point() {
        let mut rng = Rng::new(5);
        let (xs, ys) = toy(30, &mut rng);
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        // inject an outlier at a fresh location; prediction must move
        let mut xnew = [0.9; D_IN];
        xnew[6] = 0.5;
        let (before, _) = et.predict(&xnew);
        let cond = et.condition(&xnew, before + 5.0);
        let (after, _) = cond.predict(&xnew);
        assert!(
            (after - before).abs() > 0.5,
            "prediction didn't move: {before} -> {after}"
        );
        assert_eq!(cond.n_obs(), 31);
    }

    #[test]
    fn single_point_dataset() {
        let xs = vec![[0.5; D_IN]];
        let ys = vec![2.0];
        let mut et = ExtraTrees::new(TreesOptions::default());
        et.fit(&xs, &ys, FitOptions::default());
        let (mu, _) = et.predict(&[0.1; D_IN]);
        assert!((mu - 2.0).abs() < 1e-9);
    }
}
