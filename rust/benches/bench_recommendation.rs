//! Recommendation-latency benchmark (paper Table III): wall-clock time of
//! one full choose-next + refit + recommend iteration per optimizer.
mod common;

use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;
use trimtuner::util::timer::bench;

fn main() {
    common::print_header("recommendation latency (Table III)");
    let dataset = Dataset::generate(NetKind::Rnn, 42);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];

    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Gp),
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Fabolas,
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
    ] {
        // benches a short run and reports the measured per-iteration mean
        // (engine already timers each iteration)
        let stats = bench(&format!("{} 8-iter run", optimizer.name()), 0, 3, || {
            let mut cfg = EngineConfig::paper_default(optimizer, 1);
            cfg.max_iters = 8;
            engine::run(&dataset, &caps, &cfg)
        });
        println!("{}", stats.report());
        let mut cfg = EngineConfig::paper_default(optimizer, 1);
        cfg.max_iters = 8;
        let run = engine::run(&dataset, &caps, &cfg);
        println!(
            "{:<44} mean rec latency {:8.1} ms",
            format!("{} per-iteration", optimizer.name()),
            run.mean_rec_wall_s() * 1e3
        );
    }
}
