// A1 allow: the same hot paths threading caller-provided scratch —
// buffers are cleared and refilled, never reallocated — plus one pragma'd
// warm-up allocation with a reason.

pub struct View {
    pub grid: Vec<(f64, f64)>,
}

pub struct Scratch {
    pub acc: Vec<f64>,
}

pub struct Slate {
    mus: Vec<f64>,
    vars: Vec<f64>,
}

impl Slate {
    // registry-hot via hotpaths.toml (`PrimedSlate::view_at`): writes into
    // the caller's view, reusing its allocation across candidates
    fn view_at(&self, i: usize, out: &mut View) {
        out.grid.clear();
        for (&m, &v) in self.mus.iter().zip(&self.vars) {
            out.grid.push((m + i as f64, v.sqrt()));
        }
    }
}

// detlint: hot
fn score_candidate(slate: &Slate, i: usize, view: &mut View, s: &mut Scratch) -> f64 {
    slate.view_at(i, view);
    s.acc.clear();
    for (m, _) in &view.grid {
        s.acc.push(*m);
    }
    s.acc.iter().fold(f64::MIN, |a, &b| a.max(b))
}

// detlint: hot
fn prime(slate: &Slate, s: &mut Scratch) {
    // detlint: allow(A1, reason="one-time per-slate warm-up, amortized over all candidates")
    let mut warm = Vec::with_capacity(slate.mus.len());
    warm.extend(slate.mus.iter().map(|m| m + 1.0));
    s.acc.clear();
    s.acc.extend(warm);
}
