//! Dynamic side of the allocation contracts (detlint's A1–A3 are the
//! static side — see `docs/ARCHITECTURE.md` § Allocation contracts).
//!
//! This binary registers the counting `#[global_allocator]` from
//! `util::alloc_count` — production binaries and every other test target
//! keep the plain system allocator — and asserts the contracts directly:
//!
//! * the primed fantasy sweep (GP and trees) performs **zero** heap
//!   allocations per candidate once its scratch is warm;
//! * the `_into` linalg kernels (triangular solves, matmul, rank-one
//!   update/downdate) allocate nothing once their outputs are sized;
//! * the p_opt Monte-Carlo (`info_gain_from_with`) allocates nothing per
//!   candidate with a warm `EntropyScratch`;
//! * the per-slate `prime` is *allowed* to allocate (it is amortized over
//!   the whole slate) but its count is tracked against a headroom bound so
//!   regressions surface here instead of in a profile.
//!
//! Warm-up rule: the first pass over a candidate may grow scratch buffers;
//! the contract is on the steady state, so every measurement below runs
//! after one full warm pass over the same inputs (determinism makes the
//! warm and measured passes take identical branches).

use std::hint::black_box;

use trimtuner::acq::{EntropyEstimator, EntropyScratch};
use trimtuner::linalg::{Cholesky, Mat};
use trimtuner::models::{
    Basis, ExtraTrees, FantasyScratch, FantasyView, Feat, FitOptions, Gp,
    Surrogate, TreesMode, TreesOptions,
};
use trimtuner::space::D_IN;
use trimtuner::util::alloc_count::{thread_allocations, CountingAlloc};
use trimtuner::util::Rng;

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Allocations performed by `f` on this thread. The closure runs inline on
/// the measuring thread — worker pools would count on their own threads,
/// so the contracts below exercise the single-threaded cores directly.
fn allocs(f: impl FnOnce()) -> u64 {
    let before = thread_allocations();
    f();
    thread_allocations() - before
}

fn rand_feat(rng: &mut Rng) -> Feat {
    let mut f = [0.0; D_IN];
    for v in f.iter_mut() {
        *v = rng.f64();
    }
    f
}

fn toy(n: usize, rng: &mut Rng) -> (Vec<Feat>, Vec<f64>) {
    let xs: Vec<Feat> = (0..n).map(|_| rand_feat(rng)).collect();
    let ys = xs.iter().map(|x| 2.0 * x[0] - x[3] + 0.5 * x[6]).collect();
    (xs, ys)
}

/// Zero allocations per candidate view on a primed hyper-marginalized GP
/// slate (the α_T inner loop), after one warm pass.
#[test]
fn gp_primed_sweep_is_allocation_free_per_candidate() {
    let mut rng = Rng::new(7);
    let (xs, ys) = toy(20, &mut rng);
    let mut gp = Gp::with_hyper_samples(Basis::Acc, 5, 3);
    gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
    let grid: Vec<Feat> = (0..14).map(|_| rand_feat(&mut rng)).collect();
    let surf = gp.fantasy_surface(&grid, 8);
    let slate: Vec<Feat> = (0..12).map(|_| rand_feat(&mut rng)).collect();
    let primed = surf.prime(&slate);
    let mut scratch = FantasyScratch::new();
    let mut view = FantasyView::new();
    for i in 0..slate.len() {
        primed.view_into(i, &mut scratch, &mut view); // warm
    }
    for i in 0..slate.len() {
        let n = allocs(|| primed.view_into(i, &mut scratch, &mut view));
        assert_eq!(n, 0, "GP view_into allocated {n}x for candidate {i}");
    }
    black_box(&view);
}

/// Zero allocations per candidate view on a primed incremental trees
/// slate, after one warm pass.
#[test]
fn trees_primed_sweep_is_allocation_free_per_candidate() {
    let mut rng = Rng::new(11);
    let (xs, ys) = toy(40, &mut rng);
    let mut et = ExtraTrees::new(TreesOptions::default());
    et.fit(&xs, &ys, FitOptions::default());
    let grid: Vec<Feat> = (0..14).map(|_| rand_feat(&mut rng)).collect();
    let surf = et.fantasy_surface_mode(&grid, 6, TreesMode::Incremental);
    let slate: Vec<Feat> = (0..12).map(|_| rand_feat(&mut rng)).collect();
    let primed = surf.prime(&slate);
    let mut scratch = FantasyScratch::new();
    let mut view = FantasyView::new();
    for i in 0..slate.len() {
        primed.view_into(i, &mut scratch, &mut view); // warm
    }
    for i in 0..slate.len() {
        let n = allocs(|| primed.view_into(i, &mut scratch, &mut view));
        assert_eq!(n, 0, "trees view_into allocated {n}x for candidate {i}");
    }
    black_box(&view);
}

/// The p_opt Monte-Carlo sweep allocates nothing per candidate with a warm
/// scratch — the other half of the α_T inner loop.
#[test]
fn info_gain_is_allocation_free_with_warm_scratch() {
    let mut rng = Rng::new(17);
    let (xs, ys) = toy(20, &mut rng);
    let mut gp = Gp::with_hyper_samples(Basis::Acc, 5, 2);
    gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
    let grid: Vec<Feat> = (0..10).map(|_| rand_feat(&mut rng)).collect();
    let m_joint = 8;
    let surf = gp.fantasy_surface(&grid, m_joint);
    let slate: Vec<Feat> = (0..4).map(|_| rand_feat(&mut rng)).collect();
    let primed = surf.prime(&slate);
    let mut scratch = FantasyScratch::new();
    let mut view = FantasyView::new();
    primed.view_into(0, &mut scratch, &mut view);
    let joint = view.joint.as_ref().expect("joint prefix present");

    let est = EntropyEstimator::new(grid[..m_joint].to_vec(), 40, &mut rng);
    let mut escratch = EntropyScratch::new();
    let warm = est.info_gain_from_with(joint, 0.0, &mut escratch);
    let mut got = 0.0;
    let n = allocs(|| {
        got = est.info_gain_from_with(joint, 0.0, &mut escratch);
    });
    assert_eq!(n, 0, "info_gain_from_with allocated {n}x when warm");
    assert_eq!(warm.to_bits(), got.to_bits(), "warm/measured must agree");
}

/// The `_into` linalg kernels allocate nothing once their outputs have
/// reached steady-state capacity.
#[test]
fn into_kernels_are_allocation_free_when_warm() {
    let mut rng = Rng::new(23);
    let n = 12;
    let a = Mat::from_fn(n, n, |_, _| rng.f64());
    let mut k = a.matmul(&a.transpose());
    for i in 0..n {
        k.row_mut(i)[i] += n as f64;
    }
    let c = Cholesky::factor(&k).expect("SPD factor");
    let b: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let u: Vec<f64> = (0..n).map(|_| 0.1 * rng.f64()).collect();

    let mut x = Vec::new();
    c.solve_lower_into(&b, &mut x);
    assert_eq!(allocs(|| c.solve_lower_into(&b, &mut x)), 0, "solve_lower");
    let mut xt = Vec::new();
    c.solve_lower_t_into(&b, &mut xt);
    assert_eq!(
        allocs(|| c.solve_lower_t_into(&b, &mut xt)),
        0,
        "solve_lower_t"
    );

    let bm = Mat::from_fn(n, 5, |_, _| rng.f64());
    let mut xm = Mat::zeros(0, 0);
    c.solve_lower_multi_into(&bm, &mut xm);
    assert_eq!(
        allocs(|| c.solve_lower_multi_into(&bm, &mut xm)),
        0,
        "solve_lower_multi"
    );

    let mut prod = Mat::zeros(0, 0);
    a.matmul_into(&bm, &mut prod);
    assert_eq!(allocs(|| a.matmul_into(&bm, &mut prod)), 0, "matmul");

    let mut up = Cholesky::scratch();
    let mut w = Vec::new();
    c.update_into(&u, &mut up, &mut w);
    assert_eq!(allocs(|| c.update_into(&u, &mut up, &mut w)), 0, "update");

    let mut down = Cholesky::scratch();
    let mut sweep = Vec::new();
    up.downdate_into(&u, &mut down, &mut sweep).expect("downdate");
    assert_eq!(
        allocs(|| {
            up.downdate_into(&u, &mut down, &mut sweep).expect("downdate");
        }),
        0,
        "downdate"
    );

    // append-row extension: k22 dominates the appended row, so the pivot
    // stays safely positive and the warm call takes the success path
    let k12: Vec<f64> = (0..n).map(|_| 0.1 * rng.f64()).collect();
    let mut ext = Cholesky::scratch();
    let mut ew = Vec::new();
    c.extend_into(&k12, 100.0, &mut ext, &mut ew).expect("extend");
    assert_eq!(
        allocs(|| {
            c.extend_into(&k12, 100.0, &mut ext, &mut ew).expect("extend");
        }),
        0,
        "extend"
    );
    black_box((&x, &xt, &xm, &prod, &down, &ext));
}

/// ISSUE acceptance: per-observation GP absorption is allocation-free once
/// the factor, history and scratch vectors have steady-state capacity —
/// the Vec growth that remains is amortized doubling, so a warm window
/// between capacity boundaries measures exactly zero.
#[test]
fn gp_absorption_is_allocation_free_when_warm() {
    let mut rng = Rng::new(41);
    let (xs, ys) = toy(45, &mut rng);
    let mut gp = Gp::with_hyper_samples(Basis::Acc, 5, 3);
    gp.fit(&xs[..32], &ys[..32], FitOptions { hyperopt: true, restarts: 1 });
    // warm: cross the 32 -> 64 capacity doublings of the history vectors
    // and the factor's (n+1)^2 resize headroom
    for i in 32..40 {
        gp.absorb(&xs[i], ys[i]);
    }
    // 45^2 stays under the factor capacity doubled at the first warm
    // absorb (2 * 32^2), so no measured absorb crosses a boundary
    for i in 40..45 {
        let n = allocs(|| gp.absorb(&xs[i], ys[i]));
        assert_eq!(n, 0, "gp absorb allocated {n}x at observation {i}");
    }
    black_box(gp.n_obs());
}

/// ISSUE acceptance: per-observation tree absorption (leaf fold into every
/// tree) is allocation-free once the observation history has steady-state
/// capacity.
#[test]
fn trees_absorption_is_allocation_free_when_warm() {
    let mut rng = Rng::new(43);
    let (xs, ys) = toy(45, &mut rng);
    let mut et = ExtraTrees::new(TreesOptions::default());
    et.fit(&xs[..32], &ys[..32], FitOptions::default());
    for i in 32..40 {
        et.absorb(&xs[i], ys[i]);
    }
    for i in 40..45 {
        let n = allocs(|| et.absorb(&xs[i], ys[i]));
        assert_eq!(n, 0, "trees absorb allocated {n}x at observation {i}");
    }
    black_box(et.n_obs());
}

/// Per-slate `prime` is the amortized allocation budget: it must allocate
/// (it materializes the multi-RHS solves) but stay within generous
/// headroom, so a regression to per-candidate allocation patterns shows up
/// as a count explosion here.
#[test]
fn per_slate_prime_allocates_within_headroom() {
    let mut rng = Rng::new(31);
    let (xs, ys) = toy(20, &mut rng);
    let mut gp = Gp::with_hyper_samples(Basis::Acc, 5, 3);
    gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
    let grid: Vec<Feat> = (0..14).map(|_| rand_feat(&mut rng)).collect();
    let surf = gp.fantasy_surface(&grid, 8);
    let slate: Vec<Feat> = (0..32).map(|_| rand_feat(&mut rng)).collect();

    let before = thread_allocations();
    let primed = surf.prime(&slate);
    let count = thread_allocations() - before;
    drop(primed);
    assert!(count > 0, "prime materializes buffers, must allocate");
    // ~3 hyper components x a handful of matrices/vectors each, plus the
    // boxed slate handle: orders of magnitude below per-candidate costs
    assert!(count < 10_000, "per-slate prime allocated {count}x");
    println!("per-slate prime allocations: {count}");
}
