//! Materialized measurement campaign: the 1440-point lookup table the
//! optimizers replay (the paper's public data-sets, regenerated).

use super::oracle::{CloudSim, NetKind, Outcome};
use crate::space::{all_points, Constraint, Point, N_POINTS, S_VALUES};
use crate::util::csv::{CsvTable, CsvWriter};
use crate::util::Rng;
use anyhow::Result;
use std::path::Path;

/// Repetitions averaged per grid point (paper: 3).
pub const REPS: usize = 3;

/// Full lookup table for one network.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub net: NetKind,
    /// outcome per `Point::id()`
    rows: Vec<Outcome>,
}

/// One row of paper Table II.
#[derive(Debug, Clone, Copy)]
pub struct FeasibilityStats {
    pub feasible: usize,
    pub feasible_pct: f64,
    pub near_optimal: usize,
    pub near_optimal_pct: f64,
    pub best_feasible_acc: f64,
    pub n_full: usize,
}

impl Dataset {
    /// Run the simulated measurement campaign (REPS noisy runs averaged).
    pub fn generate(net: NetKind, seed: u64) -> Dataset {
        let sim = CloudSim::new(net);
        let mut rng = Rng::new(seed ^ (net as u64).wrapping_mul(0xD1B5_4A32));
        let rows = all_points()
            .map(|p| sim.observe_avg(&p, &mut rng, REPS))
            .collect();
        Dataset { net, rows }
    }

    /// Materialize the *noiseless* ground-truth surface. This is the
    /// replay-side reference for live-vs-replay parity: a zero-noise
    /// `SimLauncher` observes exactly these outcomes.
    pub fn ground_truth(net: NetKind) -> Dataset {
        let sim = CloudSim::new(net);
        let rows = all_points().map(|p| sim.ground_truth(&p)).collect();
        Dataset { net, rows }
    }

    pub fn outcome(&self, p: &Point) -> Outcome {
        self.rows[p.id()]
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Metric value used by a constraint.
    pub fn metric(&self, p: &Point, c: &Constraint) -> f64 {
        let o = self.outcome(p);
        match c.metric {
            crate::space::Metric::Cost => o.cost_usd,
            crate::space::Metric::Time => o.time_s,
        }
    }

    pub fn is_feasible(&self, p: &Point, constraints: &[Constraint]) -> bool {
        constraints.iter().all(|c| c.is_satisfied(self.metric(p, c)))
    }

    /// The true optimum: feasible full-data-set config with max accuracy.
    pub fn best_feasible_full(
        &self,
        constraints: &[Constraint],
    ) -> Option<(Point, f64)> {
        all_points()
            .filter(|p| p.is_full() && self.is_feasible(p, constraints))
            .map(|p| (p, self.outcome(&p).acc))
            .max_by(|a, b| crate::util::stats::cmp_nan_low(a.1, b.1))
    }

    /// Paper Table II: feasible + near-optimal (within 5% of best) counts
    /// over full-data-set configurations.
    pub fn feasibility_stats(
        &self,
        constraints: &[Constraint],
    ) -> FeasibilityStats {
        let full: Vec<Point> = all_points().filter(|p| p.is_full()).collect();
        let n_full = full.len();
        let feasible: Vec<&Point> = full
            .iter()
            .filter(|p| self.is_feasible(p, constraints))
            .collect();
        let best = feasible
            .iter()
            .map(|p| self.outcome(p).acc)
            .fold(f64::NEG_INFINITY, f64::max);
        let near = feasible
            .iter()
            .filter(|p| self.outcome(p).acc >= 0.95 * best)
            .count();
        FeasibilityStats {
            feasible: feasible.len(),
            feasible_pct: 100.0 * feasible.len() as f64 / n_full as f64,
            near_optimal: near,
            near_optimal_pct: 100.0 * near as f64 / n_full as f64,
            best_feasible_acc: best,
            n_full,
        }
    }

    // ---------------------------------------------------------------- CSV

    pub fn save_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["point_id", "config", "s", "acc", "time_s", "cost_usd"],
        )?;
        w.comment(&format!(
            "net={} points={} reps={}",
            self.net.name(),
            self.rows.len(),
            REPS
        ))?;
        for p in all_points() {
            let o = self.outcome(&p);
            w.row(&[
                p.id().to_string(),
                p.config.describe().replace(',', ";"),
                format!("{:.6}", p.s()),
                format!("{:.6}", o.acc),
                format!("{:.3}", o.time_s),
                format!("{:.6}", o.cost_usd),
            ])?;
        }
        w.flush()
    }

    pub fn load_csv<P: AsRef<Path>>(net: NetKind, path: P) -> Result<Dataset> {
        let t = CsvTable::read(path)?;
        let ids = t.f64_col("point_id")?;
        let acc = t.f64_col("acc")?;
        let time = t.f64_col("time_s")?;
        let cost = t.f64_col("cost_usd")?;
        let mut rows =
            vec![Outcome { acc: 0.0, time_s: 0.0, cost_usd: 0.0 }; N_POINTS];
        for i in 0..ids.len() {
            rows[ids[i] as usize] =
                Outcome { acc: acc[i], time_s: time[i], cost_usd: cost[i] };
        }
        Ok(Dataset { net, rows })
    }

    /// Average sub-sampling cost ratio: mean cost(s)/cost(1) per level —
    /// used to sanity-check the "up to 60× smaller data-sets, 50× cheaper"
    /// headline structure.
    pub fn cost_ratio_per_level(&self) -> Vec<f64> {
        let mut ratios = vec![0.0; S_VALUES.len()];
        let mut count = 0usize;
        for p in all_points().filter(|p| p.is_full()) {
            let full_cost = self.outcome(&p).cost_usd;
            for s_idx in 0..S_VALUES.len() {
                let q = Point { config: p.config, s_idx };
                ratios[s_idx] += self.outcome(&q).cost_usd / full_cost;
            }
            count += 1;
        }
        for r in &mut ratios {
            *r /= count as f64;
        }
        ratios
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn caps(net: NetKind) -> Vec<Constraint> {
        vec![Constraint::cost_max(net.paper_cost_cap())]
    }

    /// `cargo test --release -- --ignored print_calibration --nocapture`
    #[test]
    #[ignore]
    fn print_calibration() {
        for net in NetKind::ALL {
            let d = Dataset::generate(net, 42);
            let s = d.feasibility_stats(&caps(net));
            let ratios = d.cost_ratio_per_level();
            let costs: Vec<f64> = crate::space::all_points()
                .filter(|p| p.is_full())
                .map(|p| d.outcome(&p).cost_usd)
                .collect();
            let times: Vec<f64> = crate::space::all_points()
                .filter(|p| p.is_full())
                .map(|p| d.outcome(&p).time_s)
                .collect();
            println!(
                "{:>4}: feasible {:3} ({:4.1}%) near-opt {:3} ({:4.1}%) best_acc {:.4}",
                net.name(),
                s.feasible,
                s.feasible_pct,
                s.near_optimal,
                s.near_optimal_pct,
                s.best_feasible_acc
            );
            println!(
                "      cost p10/p50/p90 = {:.4}/{:.4}/{:.4} cap {:.3} | time p50 {:.0}s | s-ratios {:?}",
                crate::util::stats::percentile(&costs, 10.0),
                crate::util::stats::percentile(&costs, 50.0),
                crate::util::stats::percentile(&costs, 90.0),
                net.paper_cost_cap(),
                crate::util::stats::percentile(&times, 50.0),
                ratios.iter().map(|r| (r * 1000.0).round() / 1000.0).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn generate_is_deterministic_per_seed() {
        let a = Dataset::generate(NetKind::Rnn, 1);
        let b = Dataset::generate(NetKind::Rnn, 1);
        let c = Dataset::generate(NetKind::Rnn, 2);
        let p = Point::from_id(77);
        assert_eq!(a.outcome(&p), b.outcome(&p));
        assert_ne!(a.outcome(&p), c.outcome(&p));
    }

    #[test]
    fn table2_structure_matches_paper_bands() {
        // Paper Table II: RNN 61.8% feasible / 9.7% near-opt; MLP 55.8/10.1;
        // CNN 38.5/13.5. We require the same ordering and loose bands.
        let stats: Vec<(NetKind, FeasibilityStats)> = NetKind::ALL
            .iter()
            .map(|&net| {
                let d = Dataset::generate(net, 42);
                (net, d.feasibility_stats(&caps(net)))
            })
            .collect();
        for (net, s) in &stats {
            assert_eq!(s.n_full, 288);
            assert!(
                (20.0..=75.0).contains(&s.feasible_pct),
                "{net:?}: feasible {:.1}%",
                s.feasible_pct
            );
            assert!(
                (3.0..=25.0).contains(&s.near_optimal_pct),
                "{net:?}: near-opt {:.1}%",
                s.near_optimal_pct
            );
            // near-optimal is a small fraction of feasible -> non-trivial
            assert!(s.near_optimal * 2 < s.feasible, "{net:?}: {s:?}");
        }
        // ordering of feasibility: RNN > MLP > CNN (paper Table II)
        let pct: Vec<f64> =
            stats.iter().map(|(_, s)| s.feasible_pct).collect();
        assert!(pct[0] > pct[1] && pct[1] > pct[2], "{pct:?}");
    }

    #[test]
    fn sub_sampling_cost_ratios_are_steep() {
        let d = Dataset::generate(NetKind::Cnn, 42);
        let r = d.cost_ratio_per_level();
        // smallest level must be dramatically cheaper than full
        assert!(r[0] < 0.15, "s=1/60 ratio {}", r[0]);
        assert!(r[4] > 0.999 && r[4] < 1.001);
        assert!(r.windows(2).all(|w| w[0] < w[1]), "{r:?}");
    }

    #[test]
    fn csv_round_trip() {
        let d = Dataset::generate(NetKind::Mlp, 7);
        let path = std::env::temp_dir().join("trimtuner_ds_test.csv");
        d.save_csv(&path).unwrap();
        let d2 = Dataset::load_csv(NetKind::Mlp, &path).unwrap();
        for id in [0usize, 33, 700, 1439] {
            let p = Point::from_id(id);
            let (a, b) = (d.outcome(&p), d2.outcome(&p));
            assert!((a.acc - b.acc).abs() < 1e-5);
            assert!((a.cost_usd - b.cost_usd).abs() < 1e-5);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn ground_truth_table_matches_oracle_pointwise() {
        let d = Dataset::ground_truth(NetKind::Rnn);
        let sim = CloudSim::new(NetKind::Rnn);
        for id in [0usize, 77, 700, 1439] {
            let p = Point::from_id(id);
            assert_eq!(d.outcome(&p), sim.ground_truth(&p));
        }
        // and it still has a feasible optimum under the paper's cap
        let (p, acc) = d.best_feasible_full(&caps(NetKind::Rnn)).unwrap();
        assert!(p.is_full() && acc > 0.8);
    }

    #[test]
    fn multilayer_extension_net_is_well_formed() {
        // Not part of the paper's Table II (NetKind::ALL), but the live
        // path accepts it: a non-trivial feasibility structure must exist.
        let d = Dataset::generate(NetKind::Multilayer, 42);
        let cap = NetKind::Multilayer.paper_cost_cap();
        let s = d.feasibility_stats(&[Constraint::cost_max(cap)]);
        assert_eq!(s.n_full, 288);
        assert!(
            s.feasible > 10 && s.feasible < 280,
            "degenerate feasibility: {s:?}"
        );
        assert!(s.best_feasible_acc > 0.7, "{s:?}");
    }

    #[test]
    fn optimum_exists_and_is_feasible() {
        for net in NetKind::ALL {
            let d = Dataset::generate(net, 42);
            let (p, acc) = d.best_feasible_full(&caps(net)).unwrap();
            assert!(p.is_full());
            assert!(acc > 0.8, "{net:?} best acc {acc}");
            assert!(d.is_feasible(&p, &caps(net)));
        }
    }
}
