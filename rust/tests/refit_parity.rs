//! Incremental-refit parity (ISSUE acceptance): campaigns run with the
//! default incremental absorption (`RefitMode::Incremental`) must match the
//! `TRIMTUNER_REFIT=full` from-scratch reference (`RefitMode::Full`, which
//! recomputes the same frozen-hyperparameter state every round) — trees
//! bit-exact, GPs to ≤1e-9 relative — for both TrimTuner model kinds, in
//! trace replay and zero-noise live runs, at q = 1 and q = 4, on campaigns
//! whose `refit.every > 1` cadence crosses a mid-campaign full-refit
//! (hyperopt + structural rebuild) round.
//!
//! The modes are selected programmatically via `EngineConfig::refit.mode`
//! (the `TRIMTUNER_REFIT` env hatch maps onto the same field; the env
//! parsing itself is covered in `tests/env_hatches.rs`), so these tests
//! need no process-global env mutation. The evidence-drop trigger is pure
//! logic and is unit-tested next to `RefitPolicy` in `engine::loop_`.

use trimtuner::coordinator::SimLauncher;
use trimtuner::engine::{
    self, BatchMode, EngineConfig, EvalBackend, LiveEval, OptimizerKind,
    RefitMode, RunResult,
};
use trimtuner::models::{
    Basis, ExtraTrees, Feat, FitOptions, Gp, ModelKind, Surrogate,
    TreesOptions,
};
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::{Constraint, D_IN};
use trimtuner::util::Rng;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

/// Paper defaults shrunk like `batch_parity`'s so the GP variants stay
/// fast, with the refit cadence under test dialed in: `every` defaults to
/// 3 so rounds 0, 3, 6, … are full (hyperopt) rounds and the rounds in
/// between exercise pure absorption.
fn refit_cfg(
    optimizer: OptimizerKind,
    seed: u64,
    iters: usize,
    q: usize,
    every: usize,
    mode: RefitMode,
) -> EngineConfig {
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.max_iters = iters;
    cfg.n_rep = 10;
    cfg.n_popt_samples = 40;
    cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
    // pin the batch mode: an ambient TRIMTUNER_BATCH must not change what
    // these tests exercise
    cfg.batch_mode = BatchMode::Fantasy;
    cfg.batch_size = q;
    cfg.refit.every = every;
    cfg.refit.mode = mode;
    cfg
}

fn live_run(
    launcher: SimLauncher,
    workers: usize,
    eval: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> RunResult {
    let mut backend = EvalBackend::Live(
        LiveEval::new(Box::new(launcher), workers).with_eval(eval),
    );
    let run = engine::run_backend(&mut backend, constraints, cfg)
        .expect("live run failed");
    backend.shutdown();
    run
}

/// The campaign must actually cross a full-refit round *after* at least
/// one absorption-only round — otherwise the test never leaves the warmup
/// regime and proves nothing about the incremental path.
fn assert_crosses_full_round(run: &RunResult, every: usize, label: &str) {
    let last_round = run
        .records
        .iter()
        .filter(|r| !r.is_init)
        .map(|r| r.round)
        .max()
        .unwrap_or(0);
    // round_idx = round - 1; full rounds are idx 0, every, 2*every, ...
    assert!(
        last_round - 1 >= every,
        "{label}: {last_round} rounds never cross the round-{every} full refit"
    );
}

/// Trees contract: absorption replays the exact arithmetic of the
/// rebuild-and-replay reference, so the whole trajectory — including the
/// model-predicted floats — is bit-identical.
fn assert_bitwise_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(ra.round, rb.round, "{label}: round id");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.explore_cost.to_bits(),
            rb.explore_cost.to_bits(),
            "{label}: charged cost"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(ra.incumbent.id(), rb.incumbent.id(), "{label}: incumbent");
        assert_eq!(
            ra.inc_pred_acc.to_bits(),
            rb.inc_pred_acc.to_bits(),
            "{label}: predicted incumbent accuracy"
        );
        assert_eq!(
            ra.accuracy_c.to_bits(),
            rb.accuracy_c.to_bits(),
            "{label}: Acc_C"
        );
    }
}

fn assert_rel_close(a: f64, b: f64, tol: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() <= tol * denom,
        "{what}: {a} vs {b} differ by more than {tol} relative"
    );
}

/// GP contract: the incrementally extended Cholesky factor agrees with the
/// from-scratch refactorization to floating-point roundoff, so the two
/// modes visit the same points and charge the same (observation-derived)
/// costs exactly, while the model-predicted floats agree to ≤1e-9
/// relative.
fn assert_close_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(ra.round, rb.round, "{label}: round id");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(ra.incumbent.id(), rb.incumbent.id(), "{label}: incumbent");
        assert_rel_close(
            ra.inc_pred_acc,
            rb.inc_pred_acc,
            1e-9,
            &format!("{label}: predicted incumbent accuracy"),
        );
        assert_rel_close(
            ra.accuracy_c,
            rb.accuracy_c,
            1e-9,
            &format!("{label}: Acc_C"),
        );
    }
}

/// ISSUE acceptance (trees, replay): incremental absorption is bit-exact
/// against the full rebuild-and-replay reference at q = 1 and q = 4,
/// crossing full-refit rounds mid-campaign.
#[test]
fn trees_incremental_matches_full_bitwise_in_replay() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (q, iters, every) in [(1, 8, 3), (4, 16, 3)] {
        let dt = OptimizerKind::TrimTuner(ModelKind::Trees);
        let cfg_inc =
            refit_cfg(dt, 5, iters, q, every, RefitMode::Incremental);
        let cfg_full = refit_cfg(dt, 5, iters, q, every, RefitMode::Full);
        let inc = engine::run(&truth, &constraints, &cfg_inc);
        let full = engine::run(&truth, &constraints, &cfg_full);
        assert_crosses_full_round(&inc, every, &format!("dt replay q={q}"));
        assert_bitwise_trajectory(&inc, &full, &format!("dt replay q={q}"));
    }
}

/// ISSUE acceptance (trees, zero-noise live): same bit-exact contract
/// through the threaded coordinator, q = 1 and q = 4.
#[test]
fn trees_incremental_matches_full_bitwise_live() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (q, iters, every) in [(1, 8, 3), (4, 12, 2)] {
        let dt = OptimizerKind::TrimTuner(ModelKind::Trees);
        let cfg_inc =
            refit_cfg(dt, 7, iters, q, every, RefitMode::Incremental);
        let cfg_full = refit_cfg(dt, 7, iters, q, every, RefitMode::Full);
        let inc = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg_inc,
        );
        let full = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg_full,
        );
        assert_crosses_full_round(&inc, every, &format!("dt live q={q}"));
        assert_bitwise_trajectory(&inc, &full, &format!("dt live q={q}"));
    }
}

/// ISSUE acceptance (GP, replay): incremental Cholesky extension agrees
/// with the from-scratch refactorization to ≤1e-9 relative on the model
/// floats and exactly on the visited trajectory, q = 1 and q = 4.
#[test]
fn gp_incremental_matches_full_in_replay() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (q, iters, every) in [(1, 8, 3), (4, 16, 3)] {
        let gp = OptimizerKind::TrimTuner(ModelKind::Gp);
        let cfg_inc =
            refit_cfg(gp, 5, iters, q, every, RefitMode::Incremental);
        let cfg_full = refit_cfg(gp, 5, iters, q, every, RefitMode::Full);
        let inc = engine::run(&truth, &constraints, &cfg_inc);
        let full = engine::run(&truth, &constraints, &cfg_full);
        assert_crosses_full_round(&inc, every, &format!("gp replay q={q}"));
        assert_close_trajectory(&inc, &full, &format!("gp replay q={q}"));
    }
}

/// ISSUE acceptance (GP, zero-noise live): the same ≤1e-9 contract through
/// the threaded coordinator, q = 1 and q = 4.
#[test]
fn gp_incremental_matches_full_live() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (q, iters, every) in [(1, 6, 3), (4, 12, 2)] {
        let gp = OptimizerKind::TrimTuner(ModelKind::Gp);
        let cfg_inc =
            refit_cfg(gp, 9, iters, q, every, RefitMode::Incremental);
        let cfg_full = refit_cfg(gp, 9, iters, q, every, RefitMode::Full);
        let inc = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg_inc,
        );
        let full = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg_full,
        );
        assert_crosses_full_round(&inc, every, &format!("gp live q={q}"));
        assert_close_trajectory(&inc, &full, &format!("gp live q={q}"));
    }
}

// ---- model-level parity (no engine): absorb vs refit_frozen directly ----

fn rand_feat(rng: &mut Rng) -> Feat {
    let mut f = [0.0; D_IN];
    for v in f.iter_mut() {
        *v = rng.f64();
    }
    f
}

fn toy(n: usize, rng: &mut Rng) -> (Vec<Feat>, Vec<f64>) {
    let xs: Vec<Feat> = (0..n).map(|_| rand_feat(rng)).collect();
    let ys = xs.iter().map(|x| 2.0 * x[0] - x[3] + 0.5 * x[6]).collect();
    (xs, ys)
}

/// The hyper-marginalized GP after a run of `absorb`s agrees with the
/// from-scratch frozen refit of the same data to ≤1e-9 relative on the
/// posterior — the model-level core of the campaign contracts above.
#[test]
fn gp_absorb_matches_refit_frozen_posterior() {
    let mut rng = Rng::new(42);
    let (xs, ys) = toy(26, &mut rng);
    let mut inc = Gp::with_hyper_samples(Basis::Acc, 5, 3);
    inc.fit(&xs[..16], &ys[..16], FitOptions { hyperopt: true, restarts: 1 });
    let mut full = inc.clone_box();
    for i in 16..26 {
        inc.absorb(&xs[i], ys[i]);
        full.absorb(&xs[i], ys[i]);
    }
    // the reference path: same absorbed state, recomputed from scratch
    // with the hyper-parameters kept frozen
    full.refit_frozen();
    assert_eq!(inc.n_obs(), 26);
    assert_eq!(full.n_obs(), 26);
    for _ in 0..20 {
        let g = rand_feat(&mut rng);
        let (m_inc, s_inc) = inc.predict(&g);
        let (m_full, s_full) = full.predict(&g);
        assert_rel_close(m_inc, m_full, 1e-9, "posterior mean");
        assert_rel_close(s_inc, s_full, 1e-9, "posterior std");
    }
}

/// Tree ensembles share the single `fold` code path between absorption and
/// the rebuild-and-replay reference, so the two are bit-identical — means
/// and stds both.
#[test]
fn trees_absorb_matches_refit_frozen_bitwise() {
    let mut rng = Rng::new(43);
    let (xs, ys) = toy(40, &mut rng);
    let mut inc = ExtraTrees::new(TreesOptions::default());
    inc.fit(&xs[..30], &ys[..30], FitOptions::default());
    let mut full = inc.clone_box();
    for i in 30..40 {
        inc.absorb(&xs[i], ys[i]);
        full.absorb(&xs[i], ys[i]);
    }
    full.refit_frozen();
    assert_eq!(inc.n_obs(), 40);
    assert_eq!(full.n_obs(), 40);
    for _ in 0..20 {
        let g = rand_feat(&mut rng);
        let (m_inc, s_inc) = inc.predict(&g);
        let (m_full, s_full) = full.predict(&g);
        assert_eq!(m_inc.to_bits(), m_full.to_bits(), "leaf mean drifted");
        assert_eq!(s_inc.to_bits(), s_full.to_bits(), "leaf std drifted");
    }
}
