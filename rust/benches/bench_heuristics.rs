//! Filtering-heuristic benchmarks (paper Table IV): the cost of choosing
//! the next candidate under each heuristic and filtering level, with a
//! fixed-price stand-in acquisition so heuristic overhead is isolated.
mod common;

use trimtuner::heuristics::{cea_scores, select_next, AlphaCache, FilterKind};
use trimtuner::models::ModelKind;
use trimtuner::space::{all_points, encode, Point};
use trimtuner::util::timer::bench;
use trimtuner::util::Rng;

fn main() {
    common::print_header("heuristics (Table IV)");
    let models = common::fitted(ModelKind::Trees, 48, 1);
    let caps = common::caps();
    let untested: Vec<Point> = all_points().collect();

    let stats = bench("cea_scores x1440", 2, 20, || {
        cea_scores(&models, &caps, &untested)
    });
    println!("{}", stats.report());

    for (label, kind, beta) in [
        ("nofilter", FilterKind::NoFilter, 1.0f64),
        ("cea 1%", FilterKind::Cea, 0.01),
        ("cea 10%", FilterKind::Cea, 0.10),
        ("cea 20%", FilterKind::Cea, 0.20),
        ("direct 10%", FilterKind::Direct, 0.10),
        ("cmaes 10%", FilterKind::Cmaes, 0.10),
        ("random 10%", FilterKind::RandomFilter, 0.10),
    ] {
        let budget = ((beta * untested.len() as f64).ceil() as usize).max(1);
        let stats = bench(&format!("select_next {label}"), 1, 5, || {
            let mut rng = Rng::new(3);
            // cheap alpha stand-in: isolates the heuristic's own overhead
            let mut alpha = AlphaCache::new(|p: &Point| encode(p)[0]);
            select_next(kind, &models, &caps, &untested, budget, &mut alpha, &mut rng)
        });
        println!("{}", stats.report());
    }
}
