//! A minimal Rust lexer: identifiers, punctuation and literals with
//! line/column positions, comments stripped, `// detlint:` pragmas
//! collected.
//!
//! The offline crate registry for this build carries no `syn`, so detlint
//! scans token streams with this small self-contained lexer instead of a
//! full-fidelity AST (the same constraint that left the main crate
//! hand-rolling its RNG and CSV I/O — see `rust/src/util/mod.rs`). The
//! rules in [`crate::rules`] are written against these token sequences;
//! the crate README documents the approximations that implies.

/// One source token. Whitespace and comments never produce tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident(String),
    /// Single punctuation character (`::` is two `:` tokens).
    Punct(char),
    /// Any literal — string, raw string, byte string, char, number.
    /// Contents are irrelevant to every rule; only the position matters.
    Lit,
}

/// A token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub line: u32,
    pub col: u32,
}

/// A parsed `// detlint: allow(R1, reason="…")` suppression comment.
#[derive(Debug, Clone)]
pub struct Pragma {
    pub line: u32,
    /// Upper-cased rule ids, or `ALL`.
    pub rules: Vec<String>,
    /// `allow-file(..)` suppresses across the whole file.
    pub file_level: bool,
}

/// Lexer output: tokens, well-formed pragmas, hot-path markers, and
/// malformed pragmas. The malformed ones are surfaced as unsuppressible
/// `P0` findings — a suppression that silently failed to parse would
/// otherwise *hide* whatever violation it sat next to.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub pragmas: Vec<Pragma>,
    /// Lines carrying a `// detlint: hot` marker: the next `fn` (same line
    /// or the line below) gets the A1 allocation contract.
    pub hot_marks: Vec<u32>,
    pub malformed: Vec<(u32, String)>,
}

pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    // per-position line/col lookup so the scanner can move freely
    let mut pos_line = Vec::with_capacity(chars.len() + 1);
    let mut pos_col = Vec::with_capacity(chars.len() + 1);
    {
        let (mut l, mut c) = (1u32, 1u32);
        for &ch in &chars {
            pos_line.push(l);
            pos_col.push(c);
            if ch == '\n' {
                l += 1;
                c = 1;
            } else {
                c += 1;
            }
        }
        pos_line.push(l);
        pos_col.push(c);
    }

    let mut toks = Vec::new();
    let mut pragmas = Vec::new();
    let mut hot_marks = Vec::new();
    let mut malformed = Vec::new();
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comments (the only place pragmas live)
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i;
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            scan_pragma(
                &text,
                pos_line[start],
                &mut pragmas,
                &mut hot_marks,
                &mut malformed,
            );
            continue;
        }
        // block comments, nesting included
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            i += 2;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // string-ish literals: plain, raw (r"", r#""#), byte (b"", br"")
        if let Some(end) = string_end(&chars, i) {
            toks.push(Tok { kind: TokKind::Lit, line: pos_line[i], col: pos_col[i] });
            i = end;
            continue;
        }
        // lifetimes vs char literals
        if c == '\'' {
            let next = chars.get(i + 1).copied().unwrap_or(' ');
            let after = chars.get(i + 2).copied().unwrap_or(' ');
            if (next.is_alphabetic() || next == '_') && after != '\'' {
                // lifetime: `'a`, `'static`, `'_` — no token
                i += 1;
                while i < chars.len()
                    && (chars[i].is_alphanumeric() || chars[i] == '_')
                {
                    i += 1;
                }
            } else {
                // char literal, escapes included
                toks.push(Tok {
                    kind: TokKind::Lit,
                    line: pos_line[i],
                    col: pos_col[i],
                });
                i += 1; // opening quote
                if chars.get(i) == Some(&'\\') {
                    i += 1; // escape head, so `'\''` cannot end early
                }
                i += 1;
                while i < chars.len() && chars[i] != '\'' {
                    i += 1;
                }
                i += 1; // closing quote
            }
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < chars.len()
                && (chars[i].is_ascii_alphanumeric()
                    || chars[i] == '_'
                    || chars[i] == '.')
            {
                // `0..10`: a `.` followed by `.` is a range, not a float
                if chars[i] == '.' && chars.get(i + 1) == Some(&'.') {
                    break;
                }
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                line: pos_line[start],
                col: pos_col[start],
            });
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < chars.len()
                && (chars[i].is_alphanumeric() || chars[i] == '_')
            {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident(chars[start..i].iter().collect()),
                line: pos_line[start],
                col: pos_col[start],
            });
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct(c),
            line: pos_line[i],
            col: pos_col[i],
        });
        i += 1;
    }
    Lexed { toks, pragmas, hot_marks, malformed }
}

/// If position `i` starts a string literal (plain/raw/byte), return the
/// index one past its end.
fn string_end(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    let raw = chars.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    let mut hashes = 0usize;
    if raw {
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    if raw {
        loop {
            if j >= chars.len() {
                return Some(j);
            }
            if chars[j] == '"' {
                let mut k = 0usize;
                while k < hashes && chars.get(j + 1 + k) == Some(&'#') {
                    k += 1;
                }
                if k == hashes {
                    return Some(j + 1 + hashes);
                }
            }
            j += 1;
        }
    }
    loop {
        if j >= chars.len() {
            return Some(j);
        }
        match chars[j] {
            '\\' => j += 2,
            '"' => return Some(j + 1),
            _ => j += 1,
        }
    }
}

/// Parse a line comment for the pragma grammar:
///   `// detlint: allow(R1 [, R2…], reason="…")`
///   `// detlint: allow-file(R3, reason="…")`
///   `// detlint: hot`                (A1 hot-path marker)
fn scan_pragma(
    text: &str,
    line: u32,
    pragmas: &mut Vec<Pragma>,
    hot_marks: &mut Vec<u32>,
    malformed: &mut Vec<(u32, String)>,
) {
    let t = text.trim_start_matches('/').trim_start_matches('!').trim();
    let Some(rest) = t.strip_prefix("detlint:") else {
        return;
    };
    let rest = rest.trim();
    // the bare hot marker: no arguments, nothing to validate
    if rest == "hot" {
        hot_marks.push(line);
        return;
    }
    // `allow-file` first: `allow` is its prefix
    let (file_level, args) = if let Some(a) = rest.strip_prefix("allow-file") {
        (true, a)
    } else if let Some(a) = rest.strip_prefix("allow") {
        (false, a)
    } else {
        malformed.push((
            line,
            format!("unknown pragma `{rest}` (expected allow(...), allow-file(...) or hot)"),
        ));
        return;
    };
    let args = args.trim();
    let inner = match args.strip_prefix('(').and_then(|a| a.strip_suffix(')')) {
        Some(x) => x,
        None => {
            malformed
                .push((line, "pragma arguments must be parenthesized".into()));
            return;
        }
    };
    let (rule_part, reason_ok) = match inner.find("reason=") {
        Some(k) => {
            let v = inner[k + "reason=".len()..].trim();
            let quoted =
                v.len() >= 2 && v.starts_with('"') && v.ends_with('"');
            (&inner[..k], quoted)
        }
        None => (inner, false),
    };
    if !reason_ok {
        malformed.push((
            line,
            "pragma requires a quoted reason: allow(R?, reason=\"…\")".into(),
        ));
        return;
    }
    let rules: Vec<String> = rule_part
        .split(|ch: char| ch == ',' || ch.is_whitespace())
        .filter(|s| !s.is_empty())
        .map(|s| s.to_ascii_uppercase())
        .collect();
    let valid = !rules.is_empty()
        && rules.iter().all(|r| {
            r == "ALL"
                || (r.len() > 1
                    && (r.starts_with('R') || r.starts_with('A'))
                    && r[1..].chars().all(|c| c.is_ascii_digit()))
        });
    if !valid {
        malformed.push((
            line,
            format!("pragma names no valid rules: `{}`", rule_part.trim()),
        ));
        return;
    }
    pragmas.push(Pragma { line, rules, file_level });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_emit_no_idents() {
        let src = r##"
            let a = "HashMap in a string"; // HashMap in a comment
            /* HashMap /* nested */ still a comment */
            let b = r#"raw "HashMap" here"#;
            let c = b"bytes";
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert_eq!(ids, vec!["let", "a", "let", "b", "let", "c"]);
    }

    #[test]
    fn lifetimes_do_not_eat_the_following_tokens() {
        let ids = idents("fn f<'a>(x: &'a str) -> &'a str { x }");
        assert_eq!(ids, vec!["fn", "f", "x", "str", "str", "x"]);
    }

    #[test]
    fn char_literals_with_escaped_quote() {
        let ids = idents(r"let q = '\''; let n = '\n'; next");
        assert_eq!(ids, vec!["let", "q", "let", "n", "next"]);
    }

    #[test]
    fn positions_are_one_based_line_and_col() {
        let lexed = lex("a\n  bc");
        assert_eq!(lexed.toks[0].line, 1);
        assert_eq!(lexed.toks[0].col, 1);
        assert_eq!(lexed.toks[1].line, 2);
        assert_eq!(lexed.toks[1].col, 3);
    }

    #[test]
    fn pragma_roundtrip() {
        let lexed = lex(
            "// detlint: allow(R1, R3, reason=\"seeded by test\")\nlet x = 1;",
        );
        assert_eq!(lexed.pragmas.len(), 1);
        let p = &lexed.pragmas[0];
        assert_eq!(p.rules, vec!["R1", "R3"]);
        assert!(!p.file_level);
        assert_eq!(p.line, 1);
        assert!(lexed.malformed.is_empty());
    }

    #[test]
    fn file_level_pragma_and_case_normalization() {
        let lexed =
            lex("// detlint: allow-file(r2, reason=\"finite by input\")");
        assert_eq!(lexed.pragmas.len(), 1);
        assert!(lexed.pragmas[0].file_level);
        assert_eq!(lexed.pragmas[0].rules, vec!["R2"]);
    }

    #[test]
    fn pragma_without_reason_is_malformed() {
        let lexed = lex("// detlint: allow(R1)");
        assert!(lexed.pragmas.is_empty());
        assert_eq!(lexed.malformed.len(), 1);
    }

    #[test]
    fn non_pragma_comments_are_ignored() {
        let lexed = lex("// just a note about detlint rules\nfn f() {}");
        assert!(lexed.pragmas.is_empty());
        assert!(lexed.malformed.is_empty());
    }

    #[test]
    fn hot_marker_records_its_line_without_a_pragma() {
        let lexed = lex("// detlint: hot\nfn sweep() {}\n");
        assert_eq!(lexed.hot_marks, vec![1]);
        assert!(lexed.pragmas.is_empty());
        assert!(lexed.malformed.is_empty());
        // trailing same-line form
        let lexed = lex("fn sweep() { // detlint: hot\n}\n");
        assert_eq!(lexed.hot_marks, vec![1]);
        // `hot` with arguments is not the marker grammar
        let lexed = lex("// detlint: hot(sweep)\n");
        assert!(lexed.hot_marks.is_empty());
        assert_eq!(lexed.malformed.len(), 1);
    }

    #[test]
    fn a_rule_ids_are_valid_in_pragmas() {
        let lexed =
            lex("// detlint: allow(A1, a3, reason=\"prime path\")\nlet x;");
        assert_eq!(lexed.pragmas.len(), 1);
        assert_eq!(lexed.pragmas[0].rules, vec!["A1", "A3"]);
        assert!(lexed.malformed.is_empty());
    }
}
