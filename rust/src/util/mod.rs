//! Substrate utilities: RNG, statistics, CSV I/O, timing, property testing.
//!
//! The offline crate registry for this build has no `rand`, `serde`,
//! `criterion` or `proptest`, so these are small, self-contained
//! implementations with unit tests of their own (see DESIGN.md §2,
//! "Environment deviations").

pub mod csv;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
