//! Minimal CSV writer/reader for experiment outputs and datasets.
//!
//! Only what the harness needs: plain comma separation, no quoting of
//! numeric cells, header row, `#`-prefixed comment lines ignored on read.

use anyhow::{bail, Context, Result};
use std::fs::{self, File};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(
            File::create(&path)
                .with_context(|| format!("create {:?}", path.as_ref()))?,
        );
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, ncols: header.len() })
    }

    pub fn comment(&mut self, text: &str) -> Result<()> {
        writeln!(self.out, "# {text}")?;
        Ok(())
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        if cells.len() != self.ncols {
            bail!("row has {} cells, header has {}", cells.len(), self.ncols);
        }
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, label: &str, vals: &[f64]) -> Result<()> {
        let mut cells = vec![label.to_string()];
        cells.extend(vals.iter().map(|v| format!("{v}")));
        self.row(&cells)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Fully-parsed CSV table.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn read<P: AsRef<Path>>(path: P) -> Result<Self> {
        let f = File::open(&path)
            .with_context(|| format!("open {:?}", path.as_ref()))?;
        let mut lines = BufReader::new(f).lines();
        let header = loop {
            match lines.next() {
                Some(l) => {
                    let l = l?;
                    if l.trim().is_empty() || l.starts_with('#') {
                        continue;
                    }
                    break l.split(',').map(|s| s.trim().to_string()).collect();
                }
                None => bail!("empty csv {:?}", path.as_ref()),
            }
        };
        let mut rows = Vec::new();
        for l in lines {
            let l = l?;
            if l.trim().is_empty() || l.starts_with('#') {
                continue;
            }
            rows.push(l.split(',').map(|s| s.trim().to_string()).collect());
        }
        Ok(CsvTable { header, rows })
    }

    pub fn col_index(&self, name: &str) -> Result<usize> {
        self.header
            .iter()
            .position(|h| h == name)
            .with_context(|| format!("missing column {name}"))
    }

    pub fn f64_col(&self, name: &str) -> Result<Vec<f64>> {
        let i = self.col_index(name)?;
        self.rows
            .iter()
            .map(|r| {
                r[i].parse::<f64>()
                    .with_context(|| format!("parse {:?} in col {name}", r[i]))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("trimtuner_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["name", "x", "y"]).unwrap();
            w.comment("a comment").unwrap();
            w.row_mixed("a", &[1.5, 2.0]).unwrap();
            w.row_mixed("b", &[3.0, -4.25]).unwrap();
            w.flush().unwrap();
        }
        let t = CsvTable::read(&path).unwrap();
        assert_eq!(t.header, vec!["name", "x", "y"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.f64_col("y").unwrap(), vec![2.0, -4.25]);
        assert_eq!(t.rows[1][0], "b");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn row_arity_enforced() {
        let dir = std::env::temp_dir().join("trimtuner_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.row(&["1".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
