//! Parity tests for the slate-wide fantasy-posterior α_T evaluator
//! (`acq::AlphaSlate` / `alpha_slate`): the fantasy path must agree with
//! per-candidate clone-conditioning (`trimtuner_alpha`) — bit-exactly for
//! tree surrogates, within 1e-9 relative for GPs (hyper-sample mixtures
//! included) — and drive every filtering heuristic to the same selection
//! at the default β budget.
//!
//! For trees the clone path *is* the per-candidate seeded rebuild of the
//! conditioned ensemble, so the bit-exactness contract here is exactly
//! "incremental conditioning ≡ seeded rebuild"; the explicit
//! incremental-vs-rebuild surface comparison lives alongside
//! (`trees_incremental_alpha_bit_identical_to_rebuild_surfaces`), and the
//! `TRIMTUNER_ALPHA` / `TRIMTUNER_TREES` env hatches are exercised in
//! `tests/env_hatches.rs` (its own process, so the env mutation cannot
//! race these tests).

use trimtuner::acq::{
    joint_feasibility_many, trimtuner_alpha, AlphaMode, AlphaSlate,
    EntropyEstimator, Models, TrimTunerAcq,
};
use trimtuner::heuristics::{select_next, AlphaCache, FilterKind};
use trimtuner::models::{
    ExtraTrees, FantasyScratch, FantasySurface, Feat, FitOptions, ModelKind,
    PrimedSlate, Surrogate, TreesMode, TreesOptions,
};
use trimtuner::sim::{CloudSim, NetKind};
use trimtuner::space::{all_points, encode, Config, Constraint, Point};
use trimtuner::util::Rng;

const ALL_FILTERS: [FilterKind; 5] = [
    FilterKind::Cea,
    FilterKind::RandomFilter,
    FilterKind::NoFilter,
    FilterKind::Direct,
    FilterKind::Cmaes,
];

struct Fixture {
    models: Models,
    est: EntropyEstimator,
    shortlist: Vec<usize>,
    shortlist_feats: Vec<Feat>,
    constraints: Vec<Constraint>,
    baseline: f64,
    untested: Vec<Point>,
}

fn fixture(kind: ModelKind, gp_k: usize) -> Fixture {
    let sim = CloudSim::new(NetKind::Mlp);
    let mut rng = Rng::new(17);
    let mut pts = Vec::new();
    let mut outs = Vec::new();
    for _ in 0..20 {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        pts.push(p);
        outs.push(sim.observe(&p, &mut rng));
    }
    let mut models = Models::with_gp_hyper_samples(kind, 3, gp_k);
    models.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
    let full_feats: Vec<Feat> = (0..288)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let rep: Vec<Feat> = (0..12).map(|i| full_feats[i * 23]).collect();
    let est = EntropyEstimator::new(rep, 60, &mut rng);
    let baseline =
        EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));
    let shortlist: Vec<usize> = (0..288).step_by(12).collect();
    let shortlist_feats: Vec<Feat> =
        shortlist.iter().map(|&id| full_feats[id]).collect();
    let tested: std::collections::HashSet<usize> =
        pts.iter().map(|p| p.id()).collect();
    // a slice of the grid keeps the NoFilter sweeps fast while still
    // exercising hundreds of candidates
    let untested: Vec<Point> = all_points()
        .filter(|p| !tested.contains(&p.id()))
        .take(220)
        .collect();
    Fixture {
        models,
        est,
        shortlist,
        shortlist_feats,
        constraints: vec![Constraint::cost_max(0.06)],
        baseline,
        untested,
    }
}

fn ctx<'a>(f: &'a Fixture, feas: Option<&'a [f64]>) -> TrimTunerAcq<'a> {
    TrimTunerAcq {
        models: &f.models,
        est: &f.est,
        constraints: &f.constraints,
        inc_shortlist: &f.shortlist,
        inc_shortlist_feats: &f.shortlist_feats,
        inc_feas: feas,
        baseline: f.baseline,
    }
}

/// Default-β acquisition budget for the fixture's untested set.
fn default_budget(f: &Fixture) -> usize {
    ((0.1 * f.untested.len() as f64).ceil() as usize).max(1)
}

/// Batched α_T with the fantasy path pinned explicitly, so an ambient
/// `TRIMTUNER_ALPHA=clone` cannot silently turn these parity tests into
/// clone-vs-clone no-ops.
fn fantasy_slate(c: &TrimTunerAcq<'_>, slate: &[Point]) -> Vec<f64> {
    AlphaSlate::with_mode(c, AlphaMode::Fantasy).eval_points(slate)
}

#[test]
fn fantasy_bit_identical_to_clone_for_trees() {
    let f = fixture(ModelKind::Trees, 1);
    let feas =
        joint_feasibility_many(&f.models, &f.constraints, &f.shortlist_feats);
    for with_feas in [false, true] {
        let c = ctx(&f, with_feas.then_some(feas.as_slice()));
        let slate: Vec<Point> =
            f.untested.iter().step_by(5).copied().collect();
        let batch = fantasy_slate(&c, &slate);
        for (p, b) in slate.iter().zip(&batch) {
            let a = trimtuner_alpha(&c, &encode(p));
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "with_feas={with_feas}: clone {a} vs fantasy {b}"
            );
        }
    }
}

#[test]
fn trees_incremental_alpha_bit_identical_to_rebuild_surfaces() {
    // The two fantasy-surface modes, compared at view granularity over an
    // α-sized fused grid (representer set ++ shortlist) and a real slate:
    // cached-structure incremental conditioning must reproduce the
    // per-candidate seeded rebuild bit for bit, through both the scalar
    // view and the primed (batched-ŷ) entry point.
    let sim = CloudSim::new(NetKind::Mlp);
    let mut rng = Rng::new(47);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..24 {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        let o = sim.observe(&p, &mut rng);
        xs.push(encode(&p));
        ys.push(o.acc);
    }
    let mut et = ExtraTrees::new(TreesOptions::default());
    et.fit(&xs, &ys, FitOptions::default());
    let grid: Vec<Feat> = (0..288)
        .step_by(9)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let m_joint = 12;
    let inc = et.fantasy_surface_mode(&grid, m_joint, TreesMode::Incremental);
    let reb = et.fantasy_surface_mode(&grid, m_joint, TreesMode::Rebuild);
    let slate: Vec<Feat> = (0..10)
        .map(|_| {
            encode(&Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            })
        })
        .collect();
    let primed = inc.prime(&slate);
    let mut scratch = FantasyScratch::new();
    for (i, x) in slate.iter().enumerate() {
        let a = inc.view(x);
        let b = reb.view(x);
        let c = primed.view_at(i, &mut scratch);
        for (((am, astd), (bm, bstd)), (cm, cstd)) in
            a.grid.iter().zip(&b.grid).zip(&c.grid)
        {
            assert_eq!(am.to_bits(), bm.to_bits(), "view {i}: inc vs rebuild");
            assert_eq!(astd.to_bits(), bstd.to_bits(), "view {i}");
            assert_eq!(am.to_bits(), cm.to_bits(), "view {i}: inc vs primed");
            assert_eq!(astd.to_bits(), cstd.to_bits(), "view {i}");
        }
        // joint prefix: identical CRN draws must agree exactly
        let (pa, pb) = (a.joint.unwrap(), b.joint.unwrap());
        let z: Vec<f64> = (0..m_joint).map(|_| rng.normal()).collect();
        let (mut da, mut db) = (Vec::new(), Vec::new());
        pa.sample_with(&z, &mut da);
        pb.sample_with(&z, &mut db);
        for (va, vb) in da.iter().zip(&db) {
            assert_eq!(va.to_bits(), vb.to_bits(), "joint draw {i}");
        }
    }
}

#[test]
fn gp_primed_slate_alpha_bit_identical_to_per_candidate_eval() {
    // The batched multi-RHS w priming at α granularity: one whole-slate
    // eval_feats (slate-primed) vs one eval_one per candidate (primed on a
    // single-column slate) must be bitwise identical — any divergence
    // would be a layout or accumulation-order bug in the batched solves.
    for gp_k in [1usize, 3] {
        let f = fixture(ModelKind::Gp, gp_k);
        let c = ctx(&f, None);
        let slate: Vec<Point> =
            f.untested.iter().step_by(11).copied().collect();
        let feats: Vec<Feat> = slate.iter().map(encode).collect();
        let evaluator = AlphaSlate::with_mode(&c, AlphaMode::Fantasy);
        let batch = evaluator.eval_feats(&feats);
        for (x, b) in feats.iter().zip(&batch) {
            let a = evaluator.eval_one(x);
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "gp_k={gp_k}: per-candidate {a} vs slate {b}"
            );
        }
    }
}

#[test]
fn fantasy_matches_clone_within_1e9_for_gp_mixtures() {
    for gp_k in [1usize, 3] {
        let f = fixture(ModelKind::Gp, gp_k);
        let c = ctx(&f, None);
        let slate: Vec<Point> =
            f.untested.iter().step_by(8).copied().collect();
        let batch = fantasy_slate(&c, &slate);
        for (p, b) in slate.iter().zip(&batch) {
            let a = trimtuner_alpha(&c, &encode(p));
            assert!(
                (a - b).abs() <= 1e-9 * a.abs().max(1e-12),
                "gp_k={gp_k}: clone {a} vs fantasy {b}"
            );
        }
    }
}

/// Drive `select_next` through both evaluation paths and return
/// (chosen id, unique evals, cached entries).
fn run_filter(
    f: &Fixture,
    filter: FilterKind,
    fantasy: bool,
    feas: Option<&[f64]>,
) -> (usize, usize, Vec<(usize, f64)>) {
    let c = ctx(f, feas);
    let slate = AlphaSlate::with_mode(&c, AlphaMode::Fantasy);
    let mut alpha = if fantasy {
        AlphaCache::batch(move |pts: &[Point]| slate.eval_points(pts))
    } else {
        AlphaCache::shared(|p: &Point| trimtuner_alpha(&c, &encode(p)))
    };
    let mut rng = Rng::new(99);
    let (chosen, evals) = select_next(
        filter,
        &f.models,
        &f.constraints,
        &f.untested,
        default_budget(f),
        &mut alpha,
        &mut rng,
    );
    (chosen.id(), evals, alpha.entries())
}

#[test]
fn every_filter_selects_identically_for_trees() {
    let f = fixture(ModelKind::Trees, 1);
    let feas =
        joint_feasibility_many(&f.models, &f.constraints, &f.shortlist_feats);
    for filter in ALL_FILTERS {
        let (id_c, n_c, ent_c) =
            run_filter(&f, filter, false, Some(&feas));
        let (id_f, n_f, ent_f) = run_filter(&f, filter, true, Some(&feas));
        assert_eq!(id_c, id_f, "{filter:?}: chosen point diverged");
        assert_eq!(n_c, n_f, "{filter:?}: eval count diverged");
        assert_eq!(ent_c.len(), ent_f.len(), "{filter:?}: cache size");
        for ((ia, va), (ib, vb)) in ent_c.iter().zip(&ent_f) {
            assert_eq!(ia, ib, "{filter:?}: evaluated set diverged");
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{filter:?}: α diverged at id {ia}"
            );
        }
    }
}

#[test]
fn every_filter_agrees_within_1e9_for_gp() {
    let f = fixture(ModelKind::Gp, 2);
    let near = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(1e-12);
    for filter in ALL_FILTERS {
        let (id_c, _, ent_c) = run_filter(&f, filter, false, None);
        let (id_f, _, ent_f) = run_filter(&f, filter, true, None);
        // α parity on every commonly-evaluated candidate (the adaptive
        // searches may in principle wander differently on sub-1e-9
        // differences, so the evaluated sets are compared as sets)
        let clone_map: std::collections::HashMap<usize, f64> =
            ent_c.iter().copied().collect();
        let mut common = 0;
        for (id, vf) in &ent_f {
            if let Some(vc) = clone_map.get(id) {
                common += 1;
                assert!(
                    near(*vc, *vf),
                    "{filter:?}: α diverged at id {id}: {vc} vs {vf}"
                );
            }
        }
        assert!(common > 0, "{filter:?}: no common evaluations");
        // the fantasy choice must be as good as the clone choice under
        // the clone path's own scoring
        let best_c = ent_c
            .iter()
            .map(|&(_, v)| v)
            .fold(f64::NEG_INFINITY, f64::max);
        match clone_map.get(&id_f) {
            // 1e-9 per-value parity compounds across the two argmaxes, so
            // the "as good" margin is a few times looser
            Some(&v) => assert!(
                best_c - v <= 5e-9 * best_c.abs().max(1e-12),
                "{filter:?}: fantasy chose a worse point ({v} < {best_c})"
            ),
            // chosen point never scored by the clone run (adaptive search
            // divergence): accept as long as values agreed where shared
            None => assert!(
                matches!(filter, FilterKind::Direct | FilterKind::Cmaes),
                "{filter:?}: slate filters must evaluate the same set \
                 (clone chose {id_c}, fantasy {id_f})"
            ),
        }
    }
}
