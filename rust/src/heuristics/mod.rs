//! Filtering heuristics (paper §III-B, Fig. 3, Table IV): given the set of
//! untested (config, s) points and an acquisition-evaluation budget
//! k = β·|T|, pick the next point to test while evaluating the (expensive)
//! acquisition function at most k times.
//!
//! - **CEA** — the paper's contribution: rank all untested points by the
//!   cheap Constrained-Expected-Accuracy score, evaluate α only on the
//!   top-k.
//! - **Random** — evaluate α on k uniformly-sampled untested points.
//! - **NoFilter** — evaluate α everywhere (Table IV "No filter" row).
//! - **DIRECT** / **CMA-ES** — generic black-box optimizers (as used by
//!   FABOLAS) maximizing α over the continuous relaxation of the feature
//!   space, snapping iterates to the nearest untested grid point, capped at
//!   k unique α evaluations.

mod cea;
mod cmaes;
mod direct;

pub use cea::{cea_scores, cea_scores_feats, cea_scores_feats_with_feas};
pub use cmaes::CmaesSearch;
pub use direct::DirectSearch;

use crate::acq::Models;
use crate::models::Feat;
use crate::space::{encode, Constraint, Point};
use crate::util::stats::{argmax, cmp_nan_low};
use crate::util::Rng;
// BTreeMap, not HashMap: the α cache is drained in ranking (`best`,
// `top_k`, `entries`), and an ordered container makes those drains
// reproducible by construction (detlint R1). HashSet stays — `seen` is
// insert/contains-only, never iterated.
use std::collections::{BTreeMap, HashSet};

/// Which heuristic an optimizer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterKind {
    Cea,
    RandomFilter,
    NoFilter,
    Direct,
    Cmaes,
}

impl FilterKind {
    pub fn name(&self) -> &'static str {
        match self {
            FilterKind::Cea => "cea",
            FilterKind::RandomFilter => "random",
            FilterKind::NoFilter => "nofilter",
            FilterKind::Direct => "direct",
            FilterKind::Cmaes => "cmaes",
        }
    }

    pub fn from_name(s: &str) -> Option<FilterKind> {
        match s.to_ascii_lowercase().as_str() {
            "cea" => Some(FilterKind::Cea),
            "random" => Some(FilterKind::RandomFilter),
            "nofilter" | "none" => Some(FilterKind::NoFilter),
            "direct" => Some(FilterKind::Direct),
            "cmaes" | "cma-es" => Some(FilterKind::Cmaes),
            _ => None,
        }
    }
}

/// Memoizing α evaluator: unique grid evaluations count against the budget.
///
/// Three construction modes:
/// - [`AlphaCache::new`] wraps any `FnMut` — sequential evaluation only
///   (adaptive searches and tests that count calls);
/// - [`AlphaCache::shared`] wraps a pure `Fn + Sync`, which additionally
///   lets [`AlphaCache::eval_slate`] shard a whole candidate slate across
///   `std::thread::scope` workers. Results are merged back in slate order,
///   so cache contents, unique-eval count and the id-tie-broken argmax are
///   bit-identical to the sequential path regardless of worker count;
/// - [`AlphaCache::batch`] wraps a slate-wide evaluator (e.g.
///   [`crate::acq::AlphaSlate`]): the whole fresh slate is scored in one
///   call, letting the evaluator amortize per-iteration precomputation
///   and do its own sharding.
pub struct AlphaCache<'a> {
    f: AlphaFn<'a>,
    cache: BTreeMap<usize, f64>,
    threads: usize,
}

enum AlphaFn<'a> {
    Serial(Box<dyn FnMut(&Point) -> f64 + 'a>),
    Shared(Box<dyn Fn(&Point) -> f64 + Sync + 'a>),
    Batch(Box<dyn Fn(&[Point]) -> Vec<f64> + 'a>),
}

impl<'a> AlphaCache<'a> {
    /// Sequential evaluator (the closure may capture mutable state).
    pub fn new(f: impl FnMut(&Point) -> f64 + 'a) -> Self {
        AlphaCache {
            f: AlphaFn::Serial(Box::new(f)),
            cache: BTreeMap::new(),
            threads: 1,
        }
    }

    /// Thread-shareable evaluator: `f` must be a pure function of the
    /// point (all TrimTuner acquisition functions are — they only read
    /// fitted models and per-iteration context).
    pub fn shared(f: impl Fn(&Point) -> f64 + Sync + 'a) -> Self {
        AlphaCache {
            f: AlphaFn::Shared(Box::new(f)),
            cache: BTreeMap::new(),
            threads: crate::util::slate_threads(),
        }
    }

    /// Slate-batched evaluator: `f` scores every point of a slate in one
    /// call and parallelizes internally if it wants to.
    /// [`AlphaCache::eval`] passes single-point slates, so the adaptive
    /// searches (DIRECT, CMA-ES) drive it unchanged.
    pub fn batch(f: impl Fn(&[Point]) -> Vec<f64> + 'a) -> Self {
        AlphaCache {
            f: AlphaFn::Batch(Box::new(f)),
            cache: BTreeMap::new(),
            threads: 1,
        }
    }

    /// Override the slate worker count (1 forces sequential evaluation).
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn eval(&mut self, p: &Point) -> f64 {
        let id = p.id();
        if let Some(&v) = self.cache.get(&id) {
            return v;
        }
        let v = match &mut self.f {
            AlphaFn::Serial(f) => f(p),
            AlphaFn::Shared(f) => f(p),
            AlphaFn::Batch(f) => f(std::slice::from_ref(p))[0],
        };
        self.cache.insert(id, v);
        v
    }

    /// Evaluate α on every point of `slate` (cached points are skipped,
    /// duplicates deduplicated). With a [`AlphaCache::shared`] evaluator
    /// and more than one worker the fresh points are sharded across scoped
    /// threads; α must then be order-independent, which holds for every
    /// acquisition function here (fixed common random numbers, no RNG).
    pub fn eval_slate(&mut self, slate: &[Point]) {
        let mut seen = HashSet::new();
        let fresh: Vec<Point> = slate
            .iter()
            .filter(|p| {
                let id = p.id();
                !self.cache.contains_key(&id) && seen.insert(id)
            })
            .copied()
            .collect();
        if fresh.is_empty() {
            return;
        }
        match &mut self.f {
            AlphaFn::Serial(f) => {
                for p in &fresh {
                    let v = f(p);
                    self.cache.insert(p.id(), v);
                }
            }
            AlphaFn::Batch(f) => {
                let vals = f(&fresh);
                assert_eq!(vals.len(), fresh.len(), "batch α arity");
                for (p, v) in fresh.iter().zip(vals) {
                    self.cache.insert(p.id(), v);
                }
            }
            AlphaFn::Shared(f) => {
                let f: &(dyn Fn(&Point) -> f64 + Sync) = &**f;
                let results =
                    crate::util::shard_map(&fresh, self.threads, f);
                for (p, v) in fresh.iter().zip(results) {
                    self.cache.insert(p.id(), v);
                }
            }
        }
    }

    pub fn unique_evals(&self) -> usize {
        self.cache.len()
    }

    /// Cached (point id, α) pairs sorted by id — parity-test
    /// introspection. The `BTreeMap` already iterates id-ascending, so
    /// this is a plain drain.
    pub fn entries(&self) -> Vec<(usize, f64)> {
        self.cache.iter().map(|(&id, &a)| (id, a)).collect()
    }

    pub fn best(&self) -> Option<(Point, f64)> {
        // deterministic argmax: ties break towards the lowest point id,
        // and the BTreeMap's id-ascending iteration keeps the scan order
        // itself reproducible (detlint R1 — a seeded-order map here would
        // make equal-α runs non-reproducible); NaN α ranks below every
        // real value instead of panicking
        self.cache
            .iter()
            .max_by(|a, b| {
                cmp_nan_low(*a.1, *b.1).then_with(|| b.0.cmp(a.0))
            })
            .map(|(&id, &v)| (Point::from_id(id), v))
    }

    /// Ranked top-`k` cached entries: α-descending with the same
    /// deterministic ordering as [`AlphaCache::best`] (ties break towards
    /// the lowest point id, NaN α ranks below every real value), so
    /// `top_k(1)` and `best()` always agree. This is the batched-probe
    /// entry point: one filter pass scores a slate, and the engine submits
    /// the whole ranked prefix through the worker pool.
    pub fn top_k(&self, k: usize) -> Vec<(Point, f64)> {
        let mut v: Vec<(usize, f64)> =
            self.cache.iter().map(|(&id, &a)| (id, a)).collect();
        v.sort_by(|a, b| cmp_nan_low(b.1, a.1).then_with(|| a.0.cmp(&b.0)));
        v.truncate(k);
        v.into_iter().map(|(id, a)| (Point::from_id(id), a)).collect()
    }
}

/// Run one candidate-selection round: pick the untested point maximizing α,
/// spending at most `budget` unique α evaluations (plus the heuristic's own
/// cheap work). Returns the chosen point and the number of α evaluations.
///
/// The slate-based heuristics (CEA / random filter / no filter) know their
/// whole candidate set up front and hand it to [`AlphaCache::eval_slate`],
/// which shards the expensive α evaluations across threads; the adaptive
/// searches (DIRECT, CMA-ES) pick each iterate from the previous values and
/// stay sequential.
pub fn select_next(
    kind: FilterKind,
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
    budget: usize,
    alpha: &mut AlphaCache<'_>,
    rng: &mut Rng,
) -> (Point, usize) {
    run_filter(kind, models, constraints, untested, budget, alpha, rng);
    let (p, _) = alpha.best().expect("at least one alpha evaluation");
    (p, alpha.unique_evals())
}

/// [`select_next`] generalized to a ranked slate: one filter pass, then the
/// top-`q` scored points in α-descending order (deterministic tie-break as
/// in [`AlphaCache::best`]). `select_slate(.., 1)` picks exactly the point
/// `select_next` would, consuming the same RNG draws — the engine's
/// batched-probe rounds rely on that equivalence for `q = 1` parity. The
/// slate may be shorter than `q` when the filter evaluated fewer points.
#[allow(clippy::too_many_arguments)]
pub fn select_slate(
    kind: FilterKind,
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
    budget: usize,
    alpha: &mut AlphaCache<'_>,
    rng: &mut Rng,
    q: usize,
) -> (Vec<(Point, f64)>, usize) {
    run_filter(kind, models, constraints, untested, budget, alpha, rng);
    (alpha.top_k(q.max(1)), alpha.unique_evals())
}

/// One filter pass: populate `alpha`'s cache with at most `budget` unique
/// evaluations over `untested`, per the heuristic's selection policy.
fn run_filter(
    kind: FilterKind,
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
    budget: usize,
    alpha: &mut AlphaCache<'_>,
    rng: &mut Rng,
) {
    assert!(!untested.is_empty(), "nothing left to test");
    let budget = budget.clamp(1, untested.len());
    match kind {
        FilterKind::NoFilter => {
            alpha.eval_slate(untested);
        }
        FilterKind::Cea => {
            let scores = cea_scores(models, constraints, untested);
            let mut order: Vec<usize> = (0..untested.len()).collect();
            order.sort_by(|&a, &b| cmp_nan_low(scores[b], scores[a]));
            let slate: Vec<Point> =
                order.iter().take(budget).map(|&i| untested[i]).collect();
            alpha.eval_slate(&slate);
        }
        FilterKind::RandomFilter => {
            let idx = rng.sample_indices(untested.len(), budget);
            let slate: Vec<Point> =
                idx.into_iter().map(|i| untested[i]).collect();
            alpha.eval_slate(&slate);
        }
        FilterKind::Direct => {
            // the adaptive searches snap every iterate to the nearest
            // untested grid point: encode the grid once per round instead
            // of once per snap inside the search loop
            let feats: Vec<Feat> = untested.iter().map(encode).collect();
            DirectSearch::new().run(untested, &feats, budget, alpha);
        }
        FilterKind::Cmaes => {
            let feats: Vec<Feat> = untested.iter().map(encode).collect();
            CmaesSearch::new(rng.fork(0xC3A))
                .run(untested, &feats, budget, alpha);
        }
    }
}

/// Snap a continuous feature vector to the nearest *untested* grid point.
/// `untested_feats[i]` must be `encode(&untested[i])` — callers encode the
/// grid once per selection round and reuse it across every snap.
pub(crate) fn nearest_untested(
    feat: &[f64],
    untested: &[Point],
    untested_feats: &[Feat],
) -> Point {
    debug_assert_eq!(untested.len(), untested_feats.len());
    let mut best = untested[0];
    let mut best_d = f64::INFINITY;
    for (p, e) in untested.iter().zip(untested_feats) {
        let mut d = 0.0;
        for (a, b) in e.iter().zip(feat) {
            d += (a - b) * (a - b);
        }
        if d < best_d {
            best_d = d;
            best = *p;
        }
    }
    best
}

pub(crate) use crate::space::D_IN;

/// Helper for tests: index of max CEA score.
pub fn argmax_cea(
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
) -> Option<usize> {
    argmax(&cea_scores(models, constraints, untested))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{all_points, Config};

    pub(crate) fn fixture() -> (Models, Vec<Constraint>, Vec<Point>) {
        let sim = CloudSim::new(NetKind::Mlp);
        let mut rng = Rng::new(17);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..24 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut m = Models::new(ModelKind::Trees, 3);
        m.fit(&pts, &outs, FitOptions::default());
        let tested: std::collections::HashSet<usize> =
            pts.iter().map(|p| p.id()).collect();
        let untested: Vec<Point> =
            all_points().filter(|p| !tested.contains(&p.id())).collect();
        (m, vec![Constraint::cost_max(0.06)], untested)
    }

    #[test]
    fn all_filters_respect_budget_and_return_untested() {
        let (m, cs, untested) = fixture();
        for kind in [
            FilterKind::Cea,
            FilterKind::RandomFilter,
            FilterKind::Direct,
            FilterKind::Cmaes,
        ] {
            let mut rng = Rng::new(5);
            // cheap stand-in acquisition: predicted accuracy
            let mut alpha =
                AlphaCache::new(|p: &Point| m.acc.predict(&encode(p)).0);
            let budget = 40;
            let (chosen, evals) =
                select_next(kind, &m, &cs, &untested, budget, &mut alpha, &mut rng);
            assert!(evals <= budget, "{kind:?} used {evals} > {budget}");
            assert!(
                untested.iter().any(|p| p.id() == chosen.id()),
                "{kind:?} returned tested point"
            );
        }
    }

    #[test]
    fn no_filter_evaluates_everything() {
        let (m, cs, untested) = fixture();
        let small: Vec<Point> = untested.into_iter().take(50).collect();
        let mut rng = Rng::new(6);
        let mut alpha = AlphaCache::new(|p: &Point| encode(p)[0]);
        let (_, evals) = select_next(
            FilterKind::NoFilter,
            &m,
            &cs,
            &small,
            usize::MAX.min(small.len()),
            &mut alpha,
            &mut rng,
        );
        assert_eq!(evals, 50);
    }

    #[test]
    fn eval_slate_parallel_matches_sequential_bitwise() {
        let objective = |p: &Point| {
            // arbitrary deterministic, irrational-ish surface
            let e = encode(p);
            (e[0] * 31.7).sin() + e[5] / (1.0 + e[3])
        };
        let slate: Vec<Point> = (0..400).map(Point::from_id).collect();
        let mut seq = AlphaCache::shared(objective).with_threads(1);
        seq.eval_slate(&slate);
        let mut par = AlphaCache::shared(objective).with_threads(7);
        par.eval_slate(&slate);
        assert_eq!(seq.unique_evals(), par.unique_evals());
        let (ps, vs) = seq.best().unwrap();
        let (pp, vp) = par.best().unwrap();
        assert_eq!(ps.id(), pp.id());
        assert_eq!(vs.to_bits(), vp.to_bits());
        for p in &slate {
            assert_eq!(seq.eval(p).to_bits(), par.eval(p).to_bits());
        }
    }

    #[test]
    fn eval_slate_skips_cached_and_duplicate_points() {
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let mut cache = AlphaCache::shared(|p: &Point| {
            calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            p.id() as f64
        })
        .with_threads(4);
        cache.eval(&Point::from_id(3));
        let slate: Vec<Point> =
            [0, 1, 3, 1, 2, 0].into_iter().map(Point::from_id).collect();
        cache.eval_slate(&slate);
        assert_eq!(cache.unique_evals(), 4);
        assert_eq!(calls.load(std::sync::atomic::Ordering::SeqCst), 4);
    }

    #[test]
    fn top_k_ranks_descending_and_agrees_with_best() {
        let mut cache = AlphaCache::new(|p: &Point| {
            // deliberate ties (id % 7) and one NaN to exercise ordering
            if p.id() == 5 {
                f64::NAN
            } else {
                (p.id() % 7) as f64
            }
        });
        for id in 0..20 {
            cache.eval(&Point::from_id(id));
        }
        let ranked = cache.top_k(20);
        assert_eq!(ranked.len(), 20);
        let (bp, bv) = cache.best().unwrap();
        assert_eq!(ranked[0].0.id(), bp.id());
        assert_eq!(ranked[0].1.to_bits(), bv.to_bits());
        // α-descending; ties towards the lower id; NaN last
        for w in ranked.windows(2) {
            let ((pa, va), (pb, vb)) = (w[0], w[1]);
            assert!(
                cmp_nan_low(va, vb).is_ge(),
                "{va} before {vb} is not descending"
            );
            if va == vb {
                assert!(pa.id() < pb.id(), "tie broke towards higher id");
            }
        }
        assert!(ranked[19].1.is_nan(), "NaN must rank last");
        // truncation keeps the prefix
        let top3 = cache.top_k(3);
        assert_eq!(top3.len(), 3);
        for (a, b) in top3.iter().zip(&ranked) {
            assert_eq!(a.0.id(), b.0.id());
        }
    }

    #[test]
    fn select_slate_q1_matches_select_next() {
        let (m, cs, untested) = fixture();
        for kind in [
            FilterKind::Cea,
            FilterKind::RandomFilter,
            FilterKind::NoFilter,
            FilterKind::Direct,
            FilterKind::Cmaes,
        ] {
            let objective =
                |p: &Point| m.acc.predict(&encode(p)).0 + (p.id() % 3) as f64;
            let small: Vec<Point> =
                untested.iter().take(120).copied().collect();
            let mut rng_a = Rng::new(11);
            let mut alpha_a = AlphaCache::new(objective);
            let (next, evals_a) = select_next(
                kind, &m, &cs, &small, 30, &mut alpha_a, &mut rng_a,
            );
            let mut rng_b = Rng::new(11);
            let mut alpha_b = AlphaCache::new(objective);
            let (slate, evals_b) = select_slate(
                kind, &m, &cs, &small, 30, &mut alpha_b, &mut rng_b, 1,
            );
            assert_eq!(evals_a, evals_b, "{kind:?}: eval count");
            assert_eq!(slate.len(), 1);
            assert_eq!(slate[0].0.id(), next.id(), "{kind:?}: chosen point");
            // and both RNGs advanced identically
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{kind:?}: rng");
        }
    }

    #[test]
    fn select_slate_returns_distinct_ranked_points() {
        let (m, cs, untested) = fixture();
        let mut rng = Rng::new(13);
        let mut alpha = AlphaCache::new(|p: &Point| (p.id() % 11) as f64);
        let (slate, evals) = select_slate(
            FilterKind::Cea, &m, &cs, &untested, 40, &mut alpha, &mut rng, 6,
        );
        assert_eq!(slate.len(), 6);
        assert!(evals <= 40);
        let ids: std::collections::HashSet<usize> =
            slate.iter().map(|(p, _)| p.id()).collect();
        assert_eq!(ids.len(), 6, "slate points must be distinct");
        for w in slate.windows(2) {
            assert!(cmp_nan_low(w[0].1, w[1].1).is_ge());
        }
    }

    #[test]
    fn alpha_cache_best_survives_nan() {
        let mut cache = AlphaCache::new(|p: &Point| {
            if p.id() == 1 {
                f64::NAN
            } else {
                p.id() as f64
            }
        });
        for id in 0..4 {
            cache.eval(&Point::from_id(id));
        }
        let (best, v) = cache.best().unwrap();
        assert_eq!(best.id(), 3);
        assert_eq!(v, 3.0);
    }

    #[test]
    fn alpha_cache_deduplicates() {
        let mut calls = 0usize;
        let mut cache = AlphaCache::new(|_: &Point| {
            calls += 1;
            1.0
        });
        let p = Point::from_id(3);
        cache.eval(&p);
        cache.eval(&p);
        assert_eq!(cache.unique_evals(), 1);
        drop(cache);
        assert_eq!(calls, 1);
    }

    #[test]
    fn nearest_untested_prefers_exact_match() {
        let untested: Vec<Point> = (0..100).map(Point::from_id).collect();
        let feats: Vec<Feat> = untested.iter().map(encode).collect();
        let target = Point::from_id(42);
        let snapped = nearest_untested(&encode(&target), &untested, &feats);
        assert_eq!(snapped.id(), 42);
    }

    #[test]
    fn batch_cache_matches_shared_and_respects_dedup() {
        let objective = |p: &Point| {
            let e = encode(p);
            (e[1] * 17.3).cos() + e[6]
        };
        let slate: Vec<Point> = (0..50).map(Point::from_id).collect();
        let mut shared = AlphaCache::shared(objective).with_threads(1);
        shared.eval_slate(&slate);
        let calls = std::sync::atomic::AtomicUsize::new(0);
        let mut batch = AlphaCache::batch(|pts: &[Point]| {
            calls.fetch_add(pts.len(), std::sync::atomic::Ordering::SeqCst);
            pts.iter().map(objective).collect()
        });
        batch.eval(&Point::from_id(3));
        batch.eval_slate(&slate);
        batch.eval_slate(&slate); // all cached: no further calls
        assert_eq!(
            calls.load(std::sync::atomic::Ordering::SeqCst),
            slate.len()
        );
        assert_eq!(shared.unique_evals(), batch.unique_evals());
        for (a, b) in shared.entries().iter().zip(batch.entries()) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
        let (ps, vs) = shared.best().unwrap();
        let (pb, vb) = batch.best().unwrap();
        assert_eq!(ps.id(), pb.id());
        assert_eq!(vs.to_bits(), vb.to_bits());
    }
}
