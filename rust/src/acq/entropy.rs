//! Entropy-Search machinery (paper §II Eq. 2): Monte-Carlo estimation of
//! p_opt — the probability that each candidate is the accuracy-optimal
//! full-data-set configuration — and the information gain (KL divergence
//! from uniform) that a hypothetical observation induces on it.
//!
//! Following FABOLAS's practical recipe, p_opt is estimated over a small
//! *representative set* R of full-data-set configurations, by sampling the
//! accuracy surrogate's joint posterior on R and counting arg-maxes.
//! Common random numbers (one fixed z-matrix per optimizer iteration) keep
//! the candidate ranking free of MC jitter — see DESIGN.md §6.

use crate::models::{Feat, Posterior, Surrogate};
use crate::util::Rng;

/// Reusable buffers for the p_opt Monte-Carlo sweep. The α_T slate
/// evaluator scores hundreds of candidates per iteration, each needing a
/// counts vector and a draw vector — one scratch per worker (reset on
/// every use) replaces two heap allocations per candidate.
#[derive(Default)]
pub struct EntropyScratch {
    /// arg-max counts, normalized in place into p_opt
    counts: Vec<f64>,
    /// one joint posterior draw
    draw: Vec<f64>,
}

impl EntropyScratch {
    pub fn new() -> EntropyScratch {
        EntropyScratch::default()
    }
}

pub struct EntropyEstimator {
    /// representative full-data-set feature vectors
    pub rep_feats: Vec<Feat>,
    /// common random numbers: n_samples × |rep| standard normals
    z: Vec<Vec<f64>>,
    /// Laplace smoothing constant added to each candidate's arg-max count
    /// (keeps p_opt strictly positive so the KL terms stay finite)
    laplace: f64,
}

impl EntropyEstimator {
    pub fn new(rep_feats: Vec<Feat>, n_samples: usize, rng: &mut Rng) -> Self {
        let m = rep_feats.len();
        assert!(m >= 2, "representative set too small");
        let z = (0..n_samples)
            .map(|_| (0..m).map(|_| rng.normal()).collect())
            .collect();
        EntropyEstimator { rep_feats, z, laplace: 1e-4 }
    }

    pub fn n_samples(&self) -> usize {
        self.z.len()
    }

    /// p_opt over the representative set under `acc_model`'s posterior.
    /// The joint posterior is built through the models' batched prediction
    /// cores (GP: one multi-RHS triangular solve over the representative
    /// set; trees: one tree-major slate pass), not per-point predictions.
    pub fn p_opt(&self, acc_model: &dyn Surrogate) -> Vec<f64> {
        let mut scratch = EntropyScratch::new();
        self.p_opt_into(&acc_model.posterior(&self.rep_feats), &mut scratch);
        scratch.counts
    }

    /// p_opt from a precomputed joint posterior over the representative
    /// set — the fantasy α_T path builds each candidate's conditioned
    /// posterior by rank-one algebra and hands it in directly, without
    /// materializing a conditioned surrogate.
    pub fn p_opt_from(&self, post: &Posterior) -> Vec<f64> {
        let mut scratch = EntropyScratch::new();
        self.p_opt_into(post, &mut scratch);
        scratch.counts
    }

    /// [`EntropyEstimator::p_opt_from`] into reusable scratch: after the
    /// call `scratch.counts` holds p_opt. Both buffers are reset here, so
    /// a scratch can be shared across an arbitrary candidate sweep.
    fn p_opt_into(&self, post: &Posterior, scratch: &mut EntropyScratch) {
        let m = self.rep_feats.len();
        assert_eq!(post.len(), m, "posterior not over the representative set");
        let counts = &mut scratch.counts;
        counts.clear();
        counts.resize(m, self.laplace);
        let draw = &mut scratch.draw;
        for z in &self.z {
            post.sample_with(z, draw);
            let mut arg = 0;
            let mut best = f64::NEG_INFINITY;
            for (i, &v) in draw.iter().enumerate() {
                if v > best {
                    best = v;
                    arg = i;
                }
            }
            counts[arg] += 1.0;
        }
        let total: f64 = counts.iter().sum();
        counts.iter_mut().for_each(|c| *c /= total);
    }

    /// KL(p_opt ‖ uniform) = log m − H(p_opt)  (≥ 0, 0 iff uniform).
    pub fn kl_from_uniform(p: &[f64]) -> f64 {
        let m = p.len() as f64;
        p.iter()
            .filter(|&&pi| pi > 0.0)
            .map(|&pi| pi * (pi * m).ln())
            .sum::<f64>()
            .max(0.0)
    }

    /// Information gain of `model_after` relative to the baseline KL of the
    /// current model (pass `baseline = kl_from_uniform(p_opt(current))`).
    pub fn info_gain(&self, model_after: &dyn Surrogate, baseline: f64) -> f64 {
        let p = self.p_opt(model_after);
        (Self::kl_from_uniform(&p) - baseline).max(0.0)
    }

    /// [`EntropyEstimator::info_gain`] from a precomputed conditioned
    /// posterior over the representative set.
    pub fn info_gain_from(&self, post: &Posterior, baseline: f64) -> f64 {
        let mut scratch = EntropyScratch::new();
        self.info_gain_from_with(post, baseline, &mut scratch)
    }

    /// [`EntropyEstimator::info_gain_from`] with caller-provided scratch —
    /// the slate sweep's allocation-free entry point (bit-identical to the
    /// allocating call; the scratch is reset on every use).
    pub fn info_gain_from_with(
        &self,
        post: &Posterior,
        baseline: f64,
        scratch: &mut EntropyScratch,
    ) -> f64 {
        self.p_opt_into(post, scratch);
        (Self::kl_from_uniform(&scratch.counts) - baseline).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{
        Basis, FitOptions, Gp, Posterior, Surrogate,
    };
    use crate::space::D_IN;

    /// Surrogate stub with a fixed diagonal posterior (for exact tests).
    struct Stub {
        mean: Vec<f64>,
        std: Vec<f64>,
    }

    impl Surrogate for Stub {
        fn fit(&mut self, _: &[Feat], _: &[f64], _: FitOptions) {}
        fn predict(&self, _: &Feat) -> (f64, f64) {
            (0.0, 1.0)
        }
        fn posterior(&self, xs: &[Feat]) -> Posterior {
            assert_eq!(xs.len(), self.mean.len());
            Posterior::diagonal(self.mean.clone(), self.std.clone())
        }
        fn condition(&self, _: &Feat, _: f64) -> Box<dyn Surrogate> {
            unimplemented!()
        }
        fn n_obs(&self) -> usize {
            0
        }
        fn clone_box(&self) -> Box<dyn Surrogate> {
            unimplemented!()
        }
    }

    fn feats(m: usize) -> Vec<Feat> {
        (0..m)
            .map(|i| {
                let mut f = [0.0; D_IN];
                f[0] = i as f64 / m as f64;
                f[6] = 1.0;
                f
            })
            .collect()
    }

    #[test]
    fn p_opt_sums_to_one_and_tracks_dominance() {
        let mut rng = Rng::new(1);
        let est = EntropyEstimator::new(feats(5), 400, &mut rng);
        // candidate 2 dominates by 10 sigma
        let stub = Stub {
            mean: vec![0.0, 0.0, 10.0, 0.0, 0.0],
            std: vec![1.0; 5],
        };
        let p = est.p_opt(&stub);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p[2] > 0.99, "{p:?}");
    }

    #[test]
    fn kl_zero_for_uniform_max_for_point_mass() {
        let m = 8;
        let uniform = vec![1.0 / m as f64; m];
        assert!(EntropyEstimator::kl_from_uniform(&uniform).abs() < 1e-12);
        let mut point = vec![0.0; m];
        point[3] = 1.0;
        let kl = EntropyEstimator::kl_from_uniform(&point);
        assert!((kl - (m as f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn identical_candidates_give_flat_p_opt() {
        let mut rng = Rng::new(2);
        let est = EntropyEstimator::new(feats(4), 2000, &mut rng);
        let stub = Stub { mean: vec![1.0; 4], std: vec![0.5; 4] };
        let p = est.p_opt(&stub);
        for pi in &p {
            assert!((pi - 0.25).abs() < 0.05, "{p:?}");
        }
    }

    #[test]
    fn scratch_info_gain_matches_allocating_path_across_reuse() {
        // a single dirty scratch reused across posteriors of DIFFERENT
        // sizes must reproduce the allocating path bit for bit — the
        // grow-and-shrink alternation exercises the clear+resize reset
        // (stale counts/draw entries from the larger posterior must never
        // leak into the smaller one)
        let mut rng = Rng::new(9);
        let est_big = EntropyEstimator::new(feats(7), 250, &mut rng);
        let est_small = EntropyEstimator::new(feats(3), 250, &mut rng);
        let mut scratch = EntropyScratch::new();
        for round in 0..4 {
            let (est, m) =
                if round % 2 == 0 { (&est_big, 7) } else { (&est_small, 3) };
            let mean: Vec<f64> =
                (0..m).map(|i| (i as f64) * 0.1 + round as f64).collect();
            let post = Posterior::diagonal(mean, vec![0.4; m]);
            let want = est.info_gain_from(&post, 0.01);
            // cursor state differs between the two calls only if the
            // posterior were a mixture; diagonal posteriors have one
            // component, so the comparison is exact
            let got = est.info_gain_from_with(&post, 0.01, &mut scratch);
            assert_eq!(want.to_bits(), got.to_bits(), "round {round}");
        }
    }

    #[test]
    fn crn_makes_p_opt_deterministic() {
        let mut rng = Rng::new(3);
        let est = EntropyEstimator::new(feats(6), 200, &mut rng);
        let stub = Stub {
            mean: vec![0.1, 0.5, 0.3, 0.7, 0.2, 0.4],
            std: vec![0.3; 6],
        };
        assert_eq!(est.p_opt(&stub), est.p_opt(&stub));
    }

    #[test]
    fn observing_reduces_uncertainty_and_gains_information() {
        // Real GP: info gain of conditioning on a point near the optimum
        // should be positive.
        let mut rng = Rng::new(4);
        let rep = feats(6);
        let est = EntropyEstimator::new(rep.clone(), 300, &mut rng);
        // Flat training signal -> near-uniform p_opt (baseline ~ 0), so a
        // strong simulated observation at one representative must
        // concentrate p_opt and yield positive information gain.
        let train: Vec<Feat> = (0..10)
            .map(|i| {
                let mut f = [0.0; D_IN];
                f[0] = i as f64 / 10.0;
                f[6] = 0.25;
                f
            })
            .collect();
        let ys: Vec<f64> = train.iter().map(|_| 0.5).collect();
        let mut gp = Gp::new(Basis::Acc);
        gp.fit(&train, &ys, FitOptions { hyperopt: false, restarts: 0 });
        let baseline =
            EntropyEstimator::kl_from_uniform(&est.p_opt(&gp));
        // condition on a strong observation at the top representative
        let after = gp.condition(&rep[5], 2.0);
        let gain = est.info_gain(after.as_ref(), baseline);
        assert!(gain > 0.0, "gain {gain}");
    }
}
