//! (μ/μ_w, λ)-CMA-ES (Hansen 2006) over the [0,1]^7 continuous relaxation,
//! used as a generic acquisition-maximization heuristic (paper Fig. 3 /
//! Table IV baselines).
//!
//! Full covariance adaptation with rank-one + rank-μ updates; iterates are
//! snapped to the nearest untested grid point for evaluation, and the run
//! stops after `budget` unique acquisition evaluations.

use super::{nearest_untested, AlphaCache, D_IN};
use crate::linalg::{Cholesky, Mat};
use crate::models::Feat;
use crate::space::Point;
use crate::util::Rng;

pub struct CmaesSearch {
    rng: Rng,
}

impl CmaesSearch {
    pub fn new(rng: Rng) -> CmaesSearch {
        CmaesSearch { rng }
    }

    /// `untested_feats[i]` must be `encode(&untested[i])` — encoded once by
    /// the caller, reused across every offspring snap.
    pub fn run(
        &mut self,
        untested: &[Point],
        untested_feats: &[Feat],
        budget: usize,
        alpha: &mut AlphaCache<'_>,
    ) {
        let n = D_IN;
        let lambda = 4 + (3.0 * (n as f64).ln()).floor() as usize; // ~9
        let mu = lambda / 2;
        // log-rank weights
        let mut w: Vec<f64> = (0..mu)
            .map(|i| ((mu as f64 + 0.5).ln() - ((i + 1) as f64).ln()).max(0.0))
            .collect();
        let wsum: f64 = w.iter().sum();
        w.iter_mut().for_each(|x| *x /= wsum);
        let mu_eff = 1.0 / w.iter().map(|x| x * x).sum::<f64>();

        let nf = n as f64;
        let cc = (4.0 + mu_eff / nf) / (nf + 4.0 + 2.0 * mu_eff / nf);
        let cs = (mu_eff + 2.0) / (nf + mu_eff + 5.0);
        let c1 = 2.0 / ((nf + 1.3).powi(2) + mu_eff);
        let cmu = (1.0 - c1)
            .min(2.0 * (mu_eff - 2.0 + 1.0 / mu_eff)
                / ((nf + 2.0).powi(2) + mu_eff));
        let damps =
            1.0 + 2.0 * ((mu_eff - 1.0) / (nf + 1.0)).sqrt().max(0.0) + cs;
        let chi_n = nf.sqrt() * (1.0 - 1.0 / (4.0 * nf) + 1.0 / (21.0 * nf * nf));

        let mut mean = vec![0.5; n];
        let mut sigma = 0.3;
        let mut cov = Mat::eye(n);
        let mut p_c = vec![0.0; n];
        let mut p_s = vec![0.0; n];
        let mut gen = 0usize;

        while alpha.unique_evals() < budget && gen < 200 {
            let chol = match Cholesky::factor(&cov) {
                Ok(c) => c,
                Err(_) => {
                    cov = Mat::eye(n);
                    Cholesky::factor(&cov).unwrap()
                }
            };
            // sample λ offspring
            let mut pop: Vec<(Vec<f64>, f64)> = Vec::with_capacity(lambda);
            for _ in 0..lambda {
                let z: Vec<f64> = (0..n).map(|_| self.rng.normal()).collect();
                // y = L z ; x = mean + sigma y, clipped to the cube
                let mut y = vec![0.0; n];
                for i in 0..n {
                    let row = chol.l().row(i);
                    for j in 0..=i {
                        y[i] += row[j] * z[j];
                    }
                }
                let x: Vec<f64> = (0..n)
                    .map(|i| (mean[i] + sigma * y[i]).clamp(0.0, 1.0))
                    .collect();
                let mut feat = [0.0; D_IN];
                feat.copy_from_slice(&x);
                let p = nearest_untested(&feat, untested, untested_feats);
                let v = alpha.eval(&p);
                pop.push((x, v));
                if alpha.unique_evals() >= budget {
                    break;
                }
            }
            if pop.len() < 2 {
                break;
            }
            // maximize: sort descending by value (NaN α ranked last)
            pop.sort_by(|a, b| crate::util::stats::cmp_nan_low(b.1, a.1));
            let old_mean = mean.clone();
            for i in 0..n {
                mean[i] = pop
                    .iter()
                    .take(mu.min(pop.len()))
                    .zip(&w)
                    .map(|((x, _), wi)| wi * x[i])
                    .sum();
            }
            // evolution paths
            let mut delta: Vec<f64> =
                (0..n).map(|i| (mean[i] - old_mean[i]) / sigma).collect();
            // C^{-1/2} delta ≈ solve L z = delta
            let cinv_half_delta = chol.solve_lower(&delta);
            for i in 0..n {
                p_s[i] = (1.0 - cs) * p_s[i]
                    + (cs * (2.0 - cs) * mu_eff).sqrt() * cinv_half_delta[i];
            }
            let ps_norm =
                p_s.iter().map(|v| v * v).sum::<f64>().sqrt();
            let hsig = ps_norm
                / (1.0 - (1.0 - cs).powi(2 * (gen as i32 + 1))).sqrt()
                / chi_n
                < 1.4 + 2.0 / (nf + 1.0);
            for i in 0..n {
                p_c[i] = (1.0 - cc) * p_c[i]
                    + if hsig {
                        (cc * (2.0 - cc) * mu_eff).sqrt() * delta[i]
                    } else {
                        0.0
                    };
            }
            // covariance update (rank-1 + rank-mu)
            let mut new_cov = Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut v = (1.0 - c1 - cmu) * cov[(i, j)]
                        + c1 * p_c[i] * p_c[j];
                    for (k, (x, _)) in
                        pop.iter().take(mu.min(pop.len())).enumerate()
                    {
                        let yi = (x[i] - old_mean[i]) / sigma;
                        let yj = (x[j] - old_mean[j]) / sigma;
                        v += cmu * w[k] * yi * yj;
                    }
                    new_cov[(i, j)] = v;
                }
            }
            // symmetrize + regularize
            for i in 0..n {
                for j in 0..i {
                    let v = 0.5 * (new_cov[(i, j)] + new_cov[(j, i)]);
                    new_cov[(i, j)] = v;
                    new_cov[(j, i)] = v;
                }
                new_cov[(i, i)] = new_cov[(i, i)].max(1e-8);
            }
            cov = new_cov;
            sigma *= ((cs / damps) * (ps_norm / chi_n - 1.0)).exp();
            sigma = sigma.clamp(1e-4, 1.0);
            delta.clear();
            gen += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{all_points, encode};

    #[test]
    fn cmaes_improves_over_random_start() {
        let untested: Vec<Point> = all_points().collect();
        let feats: Vec<Feat> = untested.iter().map(encode).collect();
        let target = encode(&Point::from_id(1000));
        let objective = |p: &Point| {
            let e = encode(p);
            -e.iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        };
        let mut alpha = AlphaCache::new(objective);
        CmaesSearch::new(Rng::new(8)).run(&untested, &feats, 120, &mut alpha);
        let (_, v) = alpha.best().unwrap();
        assert!(alpha.unique_evals() <= 120);
        assert!(v > -0.4, "best {v}");
    }

    #[test]
    fn cmaes_respects_budget() {
        let untested: Vec<Point> = all_points().take(300).collect();
        let feats: Vec<Feat> = untested.iter().map(encode).collect();
        let mut alpha = AlphaCache::new(|p: &Point| encode(p)[0]);
        CmaesSearch::new(Rng::new(9)).run(&untested, &feats, 7, &mut alpha);
        assert!(alpha.unique_evals() <= 7);
    }
}
