//! Recommendation-latency benchmark (paper Table III): wall-clock time of
//! one full choose-next + refit + recommend iteration per optimizer, plus
//! the sequential-vs-parallel candidate-sweep comparison (the engine's
//! slate evaluator honours `TRIMTUNER_SLATE_THREADS`).
mod common;

use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;
use trimtuner::util::timer::bench;

fn main() {
    common::print_header("recommendation latency (Table III)");
    let dataset = Dataset::generate(NetKind::Rnn, 42);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];

    // per-iteration recommendation latency, serial slate vs all cores
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut means = Vec::new();
    for threads in [1usize, workers] {
        std::env::set_var("TRIMTUNER_SLATE_THREADS", threads.to_string());
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            1,
        );
        cfg.max_iters = 6;
        let run = engine::run(&dataset, &caps, &cfg);
        let mean = run.mean_rec_wall_s();
        println!(
            "{:<44} mean rec latency {:8.1} ms",
            format!("trimtuner-dt threads={threads}"),
            mean * 1e3
        );
        means.push(mean);
    }
    std::env::remove_var("TRIMTUNER_SLATE_THREADS");
    if means.len() == 2 && means[1] > 0.0 {
        println!(
            "{:<44} {:.2}x speedup ({workers} workers)",
            "trimtuner-dt parallel vs sequential",
            means[0] / means[1],
        );
    }

    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Gp),
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Fabolas,
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
    ] {
        // benches a short run and reports the measured per-iteration mean
        // (engine already timers each iteration)
        let stats = bench(&format!("{} 8-iter run", optimizer.name()), 0, 3, || {
            let mut cfg = EngineConfig::paper_default(optimizer, 1);
            cfg.max_iters = 8;
            engine::run(&dataset, &caps, &cfg)
        });
        println!("{}", stats.report());
        let mut cfg = EngineConfig::paper_default(optimizer, 1);
        cfg.max_iters = 8;
        let run = engine::run(&dataset, &caps, &cfg);
        println!(
            "{:<44} mean rec latency {:8.1} ms",
            format!("{} per-iteration", optimizer.name()),
            run.mean_rec_wall_s() * 1e3
        );
    }
}
