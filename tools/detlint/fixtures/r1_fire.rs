// R1 fire: ordered drains of seeded-order hash containers in a
// deterministic module. FP accumulation order follows the map's
// per-instance iteration seed, so the total differs run to run.
use std::collections::HashMap;

fn sum_costs(costs: &HashMap<usize, f64>) -> f64 {
    let mut total = 0.0;
    for (_, c) in costs {
        total += c;
    }
    total
}

fn first_ids(costs: &HashMap<usize, f64>) -> Vec<usize> {
    costs.keys().take(3).copied().collect()
}
