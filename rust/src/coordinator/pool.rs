//! Worker pool over std threads + channels (the offline registry has no
//! tokio; the coordinator's work units are coarse training jobs, for which
//! OS threads are the right granularity anyway). Channels come through
//! [`super::sync`], the shim `tools/loom-models` rebuilds under
//! `--cfg loom` so the shutdown protocol below is model-checked across
//! interleavings, not just tested on lucky schedules.
//!
//! Shutdown contract: `shutdown()`/`Drop` first close the submit queue and
//! *drop the result receiver*, then join the workers. Dropping the receiver
//! is load-bearing — a worker blocked in `tx.send` on a full result channel
//! can only observe shutdown through the channel disconnecting; joining
//! while still holding the receiver would deadlock forever (each worker
//! waiting for a `recv` that never comes, the join waiting for the worker).
//! detlint rule R5 flags any regression to the bad ordering.

use super::launcher::{Job, JobLauncher, JobResult};
use super::sync::{bounded, Receiver, Sender, TryRecvError};
use anyhow::{anyhow, Result};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;

/// A launcher failure with the job attached, so a live engine can requeue
/// the exact probe that failed instead of losing it.
#[derive(Debug)]
pub struct JobError {
    /// id of the job whose launch failed; [`JobError::NO_JOB`] when the
    /// failure is channel-level (pool shut down) rather than per-job.
    pub job_id: u64,
    pub error: anyhow::Error,
}

impl JobError {
    /// Sentinel job id for failures not attributable to any single job.
    pub const NO_JOB: u64 = u64::MAX;

    fn pool_level(error: anyhow::Error) -> JobError {
        JobError { job_id: JobError::NO_JOB, error }
    }
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.job_id == JobError::NO_JOB {
            write!(f, "worker pool failure: {}", self.error)
        } else {
            write!(f, "job {} failed: {}", self.job_id, self.error)
        }
    }
}

impl std::error::Error for JobError {}

/// Default bound of the completed-results channel.
const RESULT_QUEUE_CAP: usize = 1024;

/// Fixed-size worker pool executing [`Job`]s through a shared launcher.
/// The bounded submit queue (2× workers) provides natural backpressure.
pub struct WorkerPool {
    submit_tx: Option<Sender<Job>>,
    result_rx: Option<Receiver<Result<JobResult, JobError>>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(launcher: Box<dyn JobLauncher>, workers: usize) -> WorkerPool {
        WorkerPool::with_result_capacity(launcher, workers, RESULT_QUEUE_CAP)
    }

    /// [`WorkerPool::new`] with an explicit result-channel bound (tests use
    /// a tiny bound to exercise the workers-blocked-in-send shutdown path).
    pub fn with_result_capacity(
        launcher: Box<dyn JobLauncher>,
        workers: usize,
        result_cap: usize,
    ) -> WorkerPool {
        assert!(workers > 0);
        assert!(result_cap > 0);
        let launcher: Arc<dyn JobLauncher> = Arc::from(launcher);
        let (submit_tx, submit_rx) = bounded::<Job>(workers * 2);
        let submit_rx = Arc::new(Mutex::new(submit_rx));
        let (result_tx, result_rx) =
            bounded::<Result<JobResult, JobError>>(result_cap);

        let handles = (0..workers)
            .map(|_| {
                let rx = submit_rx.clone();
                let tx = result_tx.clone();
                let launcher = launcher.clone();
                std::thread::spawn(move || loop {
                    // take one job while holding the lock, then release.
                    // Poisoning is survivable: the guard only covers a
                    // `recv` on the submit queue — there is no multi-step
                    // invariant a panicking worker could have torn.
                    let job = match rx
                        .lock()
                        .unwrap_or_else(PoisonError::into_inner)
                        .recv()
                    {
                        Ok(j) => j,
                        Err(_) => break, // queue closed -> shut down
                    };
                    let job_id = job.id;
                    // A panicking launcher must not unwind the worker: the
                    // job's result would never arrive and the engine would
                    // block on it forever. AssertUnwindSafe is justified —
                    // the closure borrows only the shared launcher (a Sync
                    // implementor already accountable for its own internal
                    // consistency) and `job`, which dies with the closure.
                    let result = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| launcher.launch(&job)),
                    )
                    .unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into());
                        Err(anyhow!("launcher panicked: {msg}"))
                    })
                    .map_err(|error| JobError { job_id, error });
                    if tx.send(result).is_err() {
                        break; // receiver dropped
                    }
                })
            })
            .collect();

        WorkerPool {
            submit_tx: Some(submit_tx),
            result_rx: Some(result_rx),
            handles,
        }
    }

    /// Submit a job (blocks when the queue is full — backpressure).
    pub fn submit(&self, job: Job) -> Result<()> {
        self.submit_tx
            .as_ref()
            .ok_or_else(|| anyhow!("pool already shut down"))?
            .send(job)
            .map_err(|e| anyhow!("submit failed: {e}"))
    }

    /// Receive the next completed job (blocking, completion order). Launch
    /// failures come back as [`JobError`] with the failing job's id, so the
    /// caller can requeue that exact probe.
    pub fn recv(&self) -> Result<JobResult, JobError> {
        let rx = self.result_rx.as_ref().ok_or_else(|| {
            JobError::pool_level(anyhow!("pool already shut down"))
        })?;
        rx.recv()
            .map_err(|e| JobError::pool_level(anyhow!("pool hung up: {e}")))?
    }

    /// Non-blocking variant of [`WorkerPool::recv`]: `None` when no
    /// completed job is ready *right now* (the caller keeps doing useful
    /// work and polls again), `Some` carrying the completion — or a
    /// pool-level [`JobError`] when the pool is shut down or its workers
    /// hung up. The asynchronous engine drains opportunistically through
    /// this between selections so the pool never idles behind a barrier.
    pub fn try_recv(&self) -> Option<Result<JobResult, JobError>> {
        let rx = match self.result_rx.as_ref() {
            Some(rx) => rx,
            None => {
                return Some(Err(JobError::pool_level(anyhow!(
                    "pool already shut down"
                ))))
            }
        };
        match rx.try_recv() {
            Ok(result) => Some(result),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(Err(JobError::pool_level(anyhow!("pool hung up"))))
            }
        }
    }

    /// Close the queues and join all workers. Un-received results are
    /// discarded; workers blocked sending one exit instead of deadlocking.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        self.submit_tx.take(); // closes the submit queue
        // Drop the receiver *before* joining: a worker blocked in `send`
        // on a full result channel only unblocks when the channel
        // disconnects.
        self.result_rx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Outcome;
    use crate::space::Config;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Launcher that counts concurrent executions and can fail on demand.
    struct TestLauncher {
        active: std::sync::Arc<AtomicUsize>,
        max_seen: std::sync::Arc<AtomicUsize>,
        fail_ids: Vec<u64>,
    }

    impl TestLauncher {
        fn new(fail_ids: Vec<u64>) -> TestLauncher {
            TestLauncher {
                active: std::sync::Arc::new(AtomicUsize::new(0)),
                max_seen: std::sync::Arc::new(AtomicUsize::new(0)),
                fail_ids,
            }
        }
    }

    impl JobLauncher for TestLauncher {
        fn launch(&self, job: &Job) -> Result<JobResult> {
            let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
            self.max_seen.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            self.active.fetch_sub(1, Ordering::SeqCst);
            if self.fail_ids.contains(&job.id) {
                anyhow::bail!("injected failure for job {}", job.id);
            }
            Ok(JobResult {
                job_id: job.id,
                outcomes: vec![(
                    0,
                    Outcome { acc: 0.5, time_s: 1.0, cost_usd: 0.01 },
                )],
                charged_cost: 0.01,
                duration_s: 1.0,
            })
        }
    }

    fn job(i: u64) -> Job {
        Job { id: i, config: Config::from_id(0), s_levels: vec![0] }
    }

    #[test]
    fn executes_concurrently_up_to_worker_count() {
        let launcher = TestLauncher::new(vec![]);
        let max_seen = launcher.max_seen.clone();
        let pool = WorkerPool::new(Box::new(launcher), 4);
        for i in 0..16 {
            pool.submit(job(i)).unwrap();
        }
        for _ in 0..16 {
            pool.recv().unwrap();
        }
        let max_seen = max_seen.load(Ordering::SeqCst);
        assert!(max_seen >= 2, "no concurrency observed ({max_seen})");
        assert!(max_seen <= 4, "exceeded worker count ({max_seen})");
        pool.shutdown();
    }

    #[test]
    fn failure_injection_propagates_with_job_id_attribution() {
        let launcher = TestLauncher::new(vec![3]);
        let pool = WorkerPool::new(Box::new(launcher), 2);
        for i in 0..6 {
            pool.submit(job(i)).unwrap();
        }
        let mut ok = 0;
        let mut err = 0;
        for _ in 0..6 {
            match pool.recv() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert_eq!(e.job_id, 3, "wrong attribution: {e}");
                    err += 1;
                }
            }
        }
        assert_eq!((ok, err), (5, 1));
        pool.shutdown();
    }

    /// `try_recv` never blocks: it reports nothing-ready on an idle pool,
    /// hands back a completion once one lands, and drains in the same
    /// completion order `recv` would.
    #[test]
    fn try_recv_is_non_blocking_and_drains_completions() {
        let pool = WorkerPool::new(Box::new(TestLauncher::new(vec![])), 2);
        assert!(pool.try_recv().is_none(), "idle pool must report empty");
        for i in 0..4 {
            pool.submit(job(i)).unwrap();
        }
        let mut got = 0;
        let deadline = std::time::Instant::now()
            + std::time::Duration::from_secs(10);
        while got < 4 {
            match pool.try_recv() {
                Some(r) => {
                    r.expect("injected no failures");
                    got += 1;
                }
                None => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "completions never arrived through try_recv"
                    );
                    std::thread::yield_now();
                }
            }
        }
        assert!(pool.try_recv().is_none(), "drained pool must report empty");
        pool.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly_with_pending_nothing() {
        let pool = WorkerPool::new(Box::new(TestLauncher::new(vec![])), 3);
        pool.shutdown(); // no jobs at all
    }

    /// Regression: shutting down (or dropping) the pool while workers are
    /// blocked in `tx.send` on a *full* result channel used to join-hang
    /// forever, because the receiver was still alive during the join.
    #[test]
    fn shutdown_with_full_result_channel_does_not_hang() {
        let pool = WorkerPool::with_result_capacity(
            Box::new(TestLauncher::new(vec![])),
            2,
            1, // tiny bound: the 2nd completed job blocks its worker in send
        );
        for i in 0..6 {
            pool.submit(job(i)).unwrap();
        }
        // let the workers fill the result channel and block in send
        std::thread::sleep(std::time::Duration::from_millis(100));
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            pool.shutdown();
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("shutdown deadlocked with workers blocked on result send");
    }

    /// Regression: a launcher that panicked mid-`launch` used to unwind its
    /// worker thread — the job's result never arrived, later probes starved
    /// on the dead worker's queue share, and the submit-mutex could be
    /// poisoned. The panic must come back as a job-id-attributed
    /// [`JobError`], subsequent jobs must still run, and shutdown must
    /// complete.
    #[test]
    fn panicking_launcher_yields_attributed_error_and_clean_shutdown() {
        struct PanickingLauncher {
            panic_ids: Vec<u64>,
            inner: TestLauncher,
        }
        impl JobLauncher for PanickingLauncher {
            fn launch(&self, job: &Job) -> Result<JobResult> {
                if self.panic_ids.contains(&job.id) {
                    panic!("boom on job {}", job.id);
                }
                self.inner.launch(job)
            }
        }
        let pool = WorkerPool::new(
            Box::new(PanickingLauncher {
                panic_ids: vec![2, 4],
                inner: TestLauncher::new(vec![]),
            }),
            2,
        );
        for i in 0..8 {
            pool.submit(job(i)).unwrap();
        }
        let mut ok = 0;
        let mut panicked = vec![];
        for _ in 0..8 {
            match pool.recv() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(
                        e.error.to_string().contains("panicked"),
                        "expected a panic-attributed error, got: {e}"
                    );
                    panicked.push(e.job_id);
                }
            }
        }
        panicked.sort_unstable();
        assert_eq!((ok, panicked), (6, vec![2, 4]));
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            pool.shutdown();
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("shutdown hung after a launcher panic");
    }

    /// Same scenario through the `Drop` path instead of `shutdown()`.
    #[test]
    fn drop_with_full_result_channel_does_not_hang() {
        let pool = WorkerPool::with_result_capacity(
            Box::new(TestLauncher::new(vec![])),
            2,
            1,
        );
        for i in 0..5 {
            pool.submit(job(i)).unwrap();
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            drop(pool);
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("drop deadlocked with workers blocked on result send");
    }
}
