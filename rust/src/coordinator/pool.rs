//! Worker pool over std threads + channels (the offline registry has no
//! tokio; the coordinator's work units are coarse training jobs, for which
//! OS threads are the right granularity anyway).

use super::launcher::{Job, JobLauncher, JobResult};
use anyhow::{anyhow, Result};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Fixed-size worker pool executing [`Job`]s through a shared launcher.
/// The bounded submit queue (2× workers) provides natural backpressure.
pub struct WorkerPool {
    submit_tx: Option<SyncSender<Job>>,
    result_rx: Receiver<Result<JobResult>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(launcher: Box<dyn JobLauncher>, workers: usize) -> WorkerPool {
        assert!(workers > 0);
        let launcher: Arc<dyn JobLauncher> = Arc::from(launcher);
        let (submit_tx, submit_rx) = sync_channel::<Job>(workers * 2);
        let submit_rx = Arc::new(Mutex::new(submit_rx));
        let (result_tx, result_rx) = sync_channel::<Result<JobResult>>(1024);

        let handles = (0..workers)
            .map(|_| {
                let rx = submit_rx.clone();
                let tx = result_tx.clone();
                let launcher = launcher.clone();
                std::thread::spawn(move || loop {
                    // take one job while holding the lock, then release
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break, // queue closed -> shut down
                    };
                    let result = launcher.launch(&job);
                    if tx.send(result).is_err() {
                        break; // receiver dropped
                    }
                })
            })
            .collect();

        WorkerPool { submit_tx: Some(submit_tx), result_rx, handles }
    }

    /// Submit a job (blocks when the queue is full — backpressure).
    pub fn submit(&self, job: Job) -> Result<()> {
        self.submit_tx
            .as_ref()
            .ok_or_else(|| anyhow!("pool already shut down"))?
            .send(job)
            .map_err(|e| anyhow!("submit failed: {e}"))
    }

    /// Receive the next completed job (blocking, completion order).
    pub fn recv(&self) -> Result<JobResult> {
        self.result_rx
            .recv()
            .map_err(|e| anyhow!("pool hung up: {e}"))?
    }

    /// Close the queue and join all workers.
    pub fn shutdown(mut self) {
        self.submit_tx.take(); // closes the channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.submit_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Outcome;
    use crate::space::Config;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Launcher that counts concurrent executions and can fail on demand.
    struct TestLauncher {
        active: std::sync::Arc<AtomicUsize>,
        max_seen: std::sync::Arc<AtomicUsize>,
        fail_ids: Vec<u64>,
    }

    impl TestLauncher {
        fn new(fail_ids: Vec<u64>) -> TestLauncher {
            TestLauncher {
                active: std::sync::Arc::new(AtomicUsize::new(0)),
                max_seen: std::sync::Arc::new(AtomicUsize::new(0)),
                fail_ids,
            }
        }
    }

    impl JobLauncher for TestLauncher {
        fn launch(&self, job: &Job) -> Result<JobResult> {
            let now = self.active.fetch_add(1, Ordering::SeqCst) + 1;
            self.max_seen.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(10));
            self.active.fetch_sub(1, Ordering::SeqCst);
            if self.fail_ids.contains(&job.id) {
                anyhow::bail!("injected failure for job {}", job.id);
            }
            Ok(JobResult {
                job_id: job.id,
                outcomes: vec![(
                    0,
                    Outcome { acc: 0.5, time_s: 1.0, cost_usd: 0.01 },
                )],
                charged_cost: 0.01,
                duration_s: 1.0,
            })
        }
    }

    #[test]
    fn executes_concurrently_up_to_worker_count() {
        let launcher = TestLauncher::new(vec![]);
        let max_seen = launcher.max_seen.clone();
        let pool = WorkerPool::new(Box::new(launcher), 4);
        for i in 0..16 {
            pool.submit(Job {
                id: i,
                config: Config::from_id(0),
                s_levels: vec![0],
            })
            .unwrap();
        }
        for _ in 0..16 {
            pool.recv().unwrap();
        }
        let max_seen = max_seen.load(Ordering::SeqCst);
        assert!(max_seen >= 2, "no concurrency observed ({max_seen})");
        assert!(max_seen <= 4, "exceeded worker count ({max_seen})");
        pool.shutdown();
    }

    #[test]
    fn failure_injection_propagates_as_error_not_panic() {
        let launcher = TestLauncher::new(vec![3]);
        let pool = WorkerPool::new(Box::new(launcher), 2);
        for i in 0..6 {
            pool.submit(Job {
                id: i,
                config: Config::from_id(0),
                s_levels: vec![0],
            })
            .unwrap();
        }
        let mut ok = 0;
        let mut err = 0;
        for _ in 0..6 {
            match pool.recv() {
                Ok(_) => ok += 1,
                Err(_) => err += 1,
            }
        }
        assert_eq!((ok, err), (5, 1));
        pool.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly_with_pending_nothing() {
        let pool = WorkerPool::new(Box::new(TestLauncher::new(vec![])), 3);
        pool.shutdown(); // no jobs at all
    }
}
