//! Evaluation substrate for Algorithm 1: where "Train M in configuration
//! ⟨x, s⟩" actually happens.
//!
//! The paper evaluates trace-driven (replaying a measured lookup table),
//! but the algorithm itself tunes a *live* job — each probe is a real cloud
//! deployment with snapshot semantics for sub-sampled levels. [`EvalBackend`]
//! abstracts the two so the same engine loop drives both:
//!
//! - [`EvalBackend::Replay`] looks outcomes up in a pre-materialized
//!   [`Dataset`] (the paper's simulation methodology, deterministic and
//!   instant);
//! - [`EvalBackend::Live`] submits every probe as a [`Job`] through the
//!   threaded [`WorkerPool`] to any [`JobLauncher`] — the simulated cloud,
//!   or a real trainer. Sub-sampled levels of one config ride a single
//!   snapshot deployment charged at the largest level (paper §III), failed
//!   launches are requeued with job-id attribution per a configurable
//!   [`RetryPolicy`] — and *abandoned* ([`ProbeResult::Abandoned`]) with
//!   partial-cost charging once the budget runs out, so a faulty cloud
//!   degrades the campaign instead of aborting it — and every submission /
//!   completion / failure / abandonment lands in an [`EventLog`].
//!
//! Ground truth is quarantined: the optimizer only ever sees [`Probe`] /
//! [`Snapshot`] observations. Evaluation-only record fields (the incumbent's
//! *true* accuracy, Accuracy_C) come from [`EvalBackend::eval_dataset`],
//! which is `None` for a live run unless an offline oracle is attached
//! explicitly via [`LiveEval::with_eval`].

use crate::coordinator::{
    job_ids, EventKind, EventLog, Interrupted, Job, JobLauncher, JobResult,
    WorkerPool,
};
use crate::sim::{Dataset, Outcome};
use crate::space::{Config, Point};
use crate::util::Rng;
use anyhow::{anyhow, ensure, Result};
// BTreeMap, not HashMap: the engine is a deterministic module (detlint
// R1) — even though today's access is keyed-only, an ordered container
// keeps any future drain of these books reproducible by construction.
use std::collections::BTreeMap;

/// One evaluated probe: the observation the optimizer sees, plus the
/// accounting of the deployment that produced it.
#[derive(Debug, Clone, Copy)]
pub struct Probe {
    pub outcome: Outcome,
    /// USD actually charged for the deployment
    pub charged_cost: f64,
    /// measured wall-clock duration of the deployment (s)
    pub duration_s: f64,
}

/// A snapshot deployment: one training run of `config`, observed at several
/// ascending sub-sampling levels, charged once at the largest level.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub outcomes: Vec<(usize, Outcome)>,
    pub charged_cost: f64,
    pub duration_s: f64,
}

/// Outcome of one slate entry under fault tolerance: either an observation
/// or a hole the round must re-plan around. An abandoned probe exhausted
/// its [`RetryPolicy`] budget; the partial cost its interrupted attempts
/// consumed is still charged (`charged_cost`) even though no observation
/// exists.
#[derive(Debug, Clone, Copy)]
pub enum ProbeResult {
    Observed(Probe),
    Abandoned { charged_cost: f64, duration_s: f64, attempts: usize },
}

impl ProbeResult {
    /// The observation, if the probe produced one.
    pub fn observed(&self) -> Option<&Probe> {
        match self {
            ProbeResult::Observed(p) => Some(p),
            ProbeResult::Abandoned { .. } => None,
        }
    }
}

/// Fault counters accumulated by a live backend across a run (always zero
/// under replay): failed launch attempts, probes abandoned after the retry
/// budget, and the partial cost/time those faults consumed without
/// producing an observation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    pub n_failures: usize,
    pub n_abandoned: usize,
    pub wasted_cost: f64,
    pub wasted_time: f64,
}

/// How failed launches are retried before a probe is abandoned: the retry
/// budget, an exponential-backoff schedule whose jitter comes from a
/// seeded [`Rng`] (detlint R3: no ambient entropy — the delay only shifts
/// wall time, every observable outcome is already fixed by the
/// deterministic retry ids), and an optional per-probe deadline treating
/// over-long deployments (stragglers) as failures with pro-rata charging.
///
/// The default reproduces the engine's historic behavior: 3 retries, no
/// backoff sleep, no deadline — except that exhausting the budget now
/// *abandons* the probe instead of aborting the campaign.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// failed attempts tolerated per probe beyond the first launch
    pub max_retries: usize,
    /// base backoff delay in seconds (0 disables sleeping entirely)
    pub backoff_base_s: f64,
    /// multiplier applied per additional failure
    pub backoff_factor: f64,
    /// ceiling on a single backoff delay
    pub backoff_max_s: f64,
    /// ± relative jitter on each delay, drawn from the seeded retry rng
    pub jitter: f64,
    /// a completed deployment whose duration exceeds this is treated as
    /// failed at the deadline, charging `cost · deadline/duration`
    pub probe_deadline_s: Option<f64>,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 3,
            backoff_base_s: 0.0,
            backoff_factor: 2.0,
            backoff_max_s: 30.0,
            jitter: 0.1,
            probe_deadline_s: None,
        }
    }
}

impl RetryPolicy {
    /// Parse a `--retry` spec: comma-separated `key=value` with keys
    /// `max` (retries), `base` (s), `factor`, `cap` (s), `jitter`
    /// (fraction), `deadline` (s). Unmentioned keys keep their defaults.
    pub fn parse(s: &str) -> Result<RetryPolicy> {
        let mut p = RetryPolicy::default();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (key, val) = tok
                .split_once('=')
                .ok_or_else(|| anyhow!("retry token `{tok}` is not key=value"))?;
            let num = || -> Result<f64> {
                val.parse()
                    .map_err(|_| anyhow!("retry value `{val}` in `{tok}` is not a number"))
            };
            match key {
                "max" => {
                    p.max_retries = val
                        .parse()
                        .map_err(|_| anyhow!("retry max `{val}` is not an integer"))?;
                }
                "base" => {
                    p.backoff_base_s = num()?;
                    ensure!(p.backoff_base_s >= 0.0, "backoff base must be >= 0");
                }
                "factor" => {
                    p.backoff_factor = num()?;
                    ensure!(p.backoff_factor >= 1.0, "backoff factor must be >= 1");
                }
                "cap" => {
                    p.backoff_max_s = num()?;
                    ensure!(p.backoff_max_s >= 0.0, "backoff cap must be >= 0");
                }
                "jitter" => {
                    p.jitter = num()?;
                    ensure!((0.0..=1.0).contains(&p.jitter), "jitter must be in [0,1]");
                }
                "deadline" => {
                    let d = num()?;
                    ensure!(d > 0.0, "deadline must be positive seconds");
                    p.probe_deadline_s = Some(d);
                }
                other => {
                    return Err(anyhow!(
                        "unknown retry key `{other}` (known: max, base, factor, cap, \
                         jitter, deadline)"
                    ))
                }
            }
        }
        Ok(p)
    }

    /// Delay before requeueing after the `failures`-th failure (1-based).
    fn backoff_delay_s(&self, failures: usize, rng: &mut Rng) -> f64 {
        if self.backoff_base_s <= 0.0 {
            return 0.0;
        }
        let exp = (failures.saturating_sub(1)).min(30) as i32;
        let base =
            (self.backoff_base_s * self.backoff_factor.powi(exp)).min(self.backoff_max_s);
        let jitter = 1.0 + self.jitter * (2.0 * rng.f64() - 1.0);
        (base * jitter).max(0.0)
    }
}

/// Default seed for the retry rng (jitter draws) when the caller does not
/// route one through [`LiveEval::with_retry`].
const RETRY_RNG_SEED: u64 = 0xBAC0_0FF5;

/// Per-slot outcome of one drained deployment batch: the job's result when
/// an attempt eventually completed (`None` = abandoned), plus the fault
/// accounting accumulated across its failed attempts.
struct SlotOutcome {
    result: Option<JobResult>,
    /// partial cost charged by interrupted attempts (preemption, deadline)
    fault_cost: f64,
    fault_time: f64,
    /// total launch attempts made for the slot
    attempts: usize,
}

/// Retry book of one in-flight asynchronous probe: what to resubmit on
/// failure and the fault accounting accumulated so far. The async
/// counterpart of one `run_jobs` slot.
struct TicketState {
    config: Config,
    s_idx: usize,
    failures: usize,
    fault_cost: f64,
    fault_time: f64,
}

/// Handle to an asynchronously submitted probe ([`EvalBackend::submit_probe`]).
/// Replay resolves at submission (the lookup is instant and deterministic);
/// a live deployment hands back the primary job id as its logical-clock
/// ticket, redeemed later — in submission order — by
/// [`EvalBackend::await_probe`].
#[derive(Debug)]
pub enum ProbeTicket {
    /// Resolved at submission (replay backend).
    Ready(ProbeResult),
    /// Primary job id of an in-flight live deployment.
    Pending(u64),
}

/// Live evaluation state: the worker pool, job-id bookkeeping, the retry
/// policy, fault counters, and the observability log.
pub struct LiveEval<'a> {
    pool: WorkerPool,
    /// worker-thread count of the pool — the occupancy target the async
    /// engine saturates when no explicit `--max-inflight` pins it
    workers: usize,
    next_job: u64,
    pub log: EventLog,
    retry: RetryPolicy,
    retry_rng: Rng,
    faults: FaultStats,
    /// in-flight asynchronous tickets (primary job id → retry book)
    pending_tickets: BTreeMap<u64, TicketState>,
    /// completed-but-unredeemed asynchronous tickets: the reorder buffer
    /// that turns completion order back into submission (logical) order
    ready_tickets: BTreeMap<u64, (usize, SlotOutcome)>,
    /// Optional ground-truth oracle for *evaluation-only* record fields
    /// (`inc_acc`, `accuracy_c`, `optimum_acc`). A real deployment has
    /// none; without it those fields are NaN and the optimizer still runs.
    eval: Option<&'a Dataset>,
}

impl<'a> LiveEval<'a> {
    pub fn new(launcher: Box<dyn JobLauncher>, workers: usize) -> LiveEval<'a> {
        LiveEval {
            pool: WorkerPool::new(launcher, workers),
            workers,
            next_job: 0,
            log: EventLog::new(),
            retry: RetryPolicy::default(),
            retry_rng: Rng::new(RETRY_RNG_SEED),
            faults: FaultStats::default(),
            pending_tickets: BTreeMap::new(),
            ready_tickets: BTreeMap::new(),
            eval: None,
        }
    }

    /// Install a [`RetryPolicy`]; `seed` feeds the backoff-jitter rng (the
    /// sanctioned entropy route — nothing else in the retry path draws).
    pub fn with_retry(mut self, policy: RetryPolicy, seed: u64) -> LiveEval<'a> {
        self.retry = policy;
        self.retry_rng = Rng::new(seed ^ RETRY_RNG_SEED);
        self
    }

    /// Attach an offline ground-truth oracle so records carry the same
    /// evaluation metrics a replay run would (for experiments/parity only —
    /// nothing on the optimization path reads it).
    pub fn with_eval(mut self, dataset: &'a Dataset) -> LiveEval<'a> {
        self.eval = Some(dataset);
        self
    }

    fn submit(&mut self, config: Config, s_levels: Vec<usize>) -> Result<u64> {
        let id = self.next_job;
        self.next_job += 1;
        self.submit_with_id(id, config, s_levels)?;
        Ok(id)
    }

    fn submit_with_id(
        &mut self,
        id: u64,
        config: Config,
        s_levels: Vec<usize>,
    ) -> Result<()> {
        self.log.record(EventKind::JobSubmitted { job: id });
        self.pool.submit(Job { id, config, s_levels })
    }

    /// Drive a batch of deployments to completion and return per-slot
    /// outcomes in *submission order* (not completion order), so
    /// multi-worker runs stay deterministic. Failed launches are requeued
    /// per the [`RetryPolicy`] with deterministic retry ids
    /// ([`job_ids::retry`] — a pure function of (primary id, attempt), so
    /// which of two concurrently-failed jobs reports first cannot swap ids
    /// or the launcher's per-id draws); a slot whose budget runs out is
    /// *abandoned* (`result: None`, `ProbeAbandoned` logged) instead of
    /// aborting the batch, with the partial cost of its interrupted
    /// attempts ([`Interrupted`]) retained for charging.
    fn run_jobs(
        &mut self,
        specs: &[(Config, Vec<usize>)],
    ) -> Result<Vec<SlotOutcome>> {
        let mut slot_of: BTreeMap<u64, usize> = BTreeMap::new();
        let mut failures = vec![0usize; specs.len()];
        let mut primary = vec![0u64; specs.len()];
        let mut outcomes: Vec<SlotOutcome> = specs
            .iter()
            .map(|_| SlotOutcome {
                result: None,
                fault_cost: 0.0,
                fault_time: 0.0,
                attempts: 1,
            })
            .collect();
        for (slot, (config, levels)) in specs.iter().enumerate() {
            let id = self.submit(*config, levels.clone())?;
            primary[slot] = id;
            slot_of.insert(id, slot);
        }
        let mut pending = specs.len();
        while pending > 0 {
            // Completion order is nondeterministic under N workers; every
            // update below is keyed by slot (and each slot's attempts are
            // strictly sequential), so nothing drain-order-dependent can
            // reach the returned outcomes.
            let failed_slot: usize = match self.pool.recv() {
                Ok(r) => {
                    let slot = *slot_of.get(&r.job_id).ok_or_else(|| {
                        anyhow!("pool returned unknown job id {}", r.job_id)
                    })?;
                    let deadline = self.retry.probe_deadline_s;
                    match deadline {
                        Some(d) if r.duration_s > d => {
                            // over the per-probe deadline: the run is
                            // killed at `d` and the truncated fraction of
                            // its cost is still charged — deterministic,
                            // because the launcher's duration is.
                            slot_of.remove(&r.job_id);
                            let frac = d / r.duration_s;
                            outcomes[slot].fault_cost += r.charged_cost * frac;
                            outcomes[slot].fault_time += d;
                            self.log.record(EventKind::JobFailed {
                                job: r.job_id,
                                reason: format!(
                                    "probe deadline {d}s exceeded ({:.1}s)",
                                    r.duration_s
                                ),
                            });
                            slot
                        }
                        _ => {
                            slot_of.remove(&r.job_id);
                            self.log.record(EventKind::JobCompleted {
                                job: r.job_id,
                                cost: r.charged_cost,
                            });
                            outcomes[slot].attempts = failures[slot] + 1;
                            outcomes[slot].result = Some(r);
                            pending -= 1;
                            continue;
                        }
                    }
                }
                Err(e) => {
                    // job-id attribution lets us requeue the exact probe
                    let slot = slot_of.remove(&e.job_id).ok_or_else(|| {
                        anyhow!("unattributable launcher failure: {e}")
                    })?;
                    self.log.record(EventKind::JobFailed {
                        job: e.job_id,
                        reason: e.error.to_string(),
                    });
                    // an interrupted deployment (preemption, timeout)
                    // consumed real resources before dying — keep the
                    // partial charge (paper §III: the snapshot run was
                    // paid for even though no snapshot came back)
                    if let Some(i) = e.error.downcast_ref::<Interrupted>() {
                        outcomes[slot].fault_cost += i.partial_cost;
                        outcomes[slot].fault_time += i.partial_duration_s;
                    }
                    slot
                }
            };
            failures[failed_slot] += 1;
            if failures[failed_slot] > self.retry.max_retries {
                // retry budget exhausted: abandon the probe, keep the
                // campaign alive — the caller re-plans around the hole
                outcomes[failed_slot].attempts = failures[failed_slot];
                self.log.record(EventKind::ProbeAbandoned {
                    job: primary[failed_slot],
                    attempts: failures[failed_slot],
                    wasted_cost: outcomes[failed_slot].fault_cost,
                });
                pending -= 1;
                continue;
            }
            let delay =
                self.retry.backoff_delay_s(failures[failed_slot], &mut self.retry_rng);
            if delay > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(delay));
            }
            let (config, levels) = &specs[failed_slot];
            let id = job_ids::retry(primary[failed_slot], failures[failed_slot]);
            self.submit_with_id(id, *config, levels.clone())?;
            slot_of.insert(id, failed_slot);
        }
        // Fault counters are summed in slot order here, not in drain order
        // above, so the floating-point waste totals cannot depend on
        // completion order across worker counts.
        for (slot, o) in outcomes.iter().enumerate() {
            self.faults.n_failures += failures[slot];
            self.faults.wasted_cost += o.fault_cost;
            self.faults.wasted_time += o.fault_time;
            if o.result.is_none() {
                self.faults.n_abandoned += 1;
            }
        }
        Ok(outcomes)
    }

    /// Submit one probe asynchronously; returns the primary job id as the
    /// ticket. The caller redeems tickets in submission order through
    /// [`LiveEval::await_ticket`] — the logical clock that keeps async
    /// trajectories independent of physical completion order.
    ///
    /// Invariant: async tickets and the barriered [`LiveEval::run_jobs`]
    /// drain never overlap — the engine runs one mode per campaign phase,
    /// so neither path can steal the other's completions off the shared
    /// result channel.
    fn submit_ticket(&mut self, p: Point) -> Result<u64> {
        let id = self.submit(p.config, vec![p.s_idx])?;
        self.pending_tickets.insert(
            id,
            TicketState {
                config: p.config,
                s_idx: p.s_idx,
                failures: 0,
                fault_cost: 0.0,
                fault_time: 0.0,
            },
        );
        Ok(id)
    }

    /// Block until `ticket` resolves and return its probe result, buffering
    /// any other tickets' completions in the reorder buffer on the way.
    /// Fault counters fold in redemption (= submission) order here, never
    /// in completion order, so the floating-point waste totals cannot
    /// depend on worker count.
    fn await_ticket(&mut self, ticket: u64) -> Result<ProbeResult> {
        // Opportunistic non-blocking drain first: completions that landed
        // while the engine was selecting move to the reorder buffer
        // without ever blocking on the pool.
        while let Some(msg) = self.pool.try_recv() {
            self.settle_async(msg)?;
        }
        while !self.ready_tickets.contains_key(&ticket) {
            ensure!(
                self.pending_tickets.contains_key(&ticket),
                "await on unknown async ticket {ticket}"
            );
            let msg = self.pool.recv();
            self.settle_async(msg)?;
        }
        let (s_idx, slot) =
            self.ready_tickets.remove(&ticket).expect("resolved above");
        let failures = if slot.result.is_some() {
            slot.attempts - 1
        } else {
            slot.attempts
        };
        self.faults.n_failures += failures;
        self.faults.wasted_cost += slot.fault_cost;
        self.faults.wasted_time += slot.fault_time;
        match slot.result {
            Some(r) => {
                let o = r
                    .outcomes
                    .iter()
                    .find(|(lvl, _)| *lvl == s_idx)
                    .map(|(_, o)| *o)
                    .ok_or_else(|| {
                        anyhow!("launcher returned no snapshot at level {s_idx}")
                    })?;
                Ok(ProbeResult::Observed(Probe {
                    outcome: o,
                    charged_cost: r.charged_cost + slot.fault_cost,
                    duration_s: r.duration_s + slot.fault_time,
                }))
            }
            None => {
                self.faults.n_abandoned += 1;
                Ok(ProbeResult::Abandoned {
                    charged_cost: slot.fault_cost,
                    duration_s: slot.fault_time,
                    attempts: slot.attempts,
                })
            }
        }
    }

    /// Apply one pool completion/failure to the async ticket books:
    /// success (or deadline breach) resolves the ticket into the reorder
    /// buffer; a failure within budget resubmits with the deterministic
    /// retry id; an exhausted budget abandons. Mirrors `run_jobs`'s
    /// per-slot state machine exactly, so barriered and async runs see
    /// identical retry/abandonment semantics.
    fn settle_async(
        &mut self,
        msg: std::result::Result<JobResult, crate::coordinator::JobError>,
    ) -> Result<()> {
        let failed_primary: u64 = match msg {
            Ok(r) => {
                let primary = job_ids::original(r.job_id);
                ensure!(
                    self.pending_tickets.contains_key(&primary),
                    "pool returned unknown job id {}",
                    r.job_id
                );
                match self.retry.probe_deadline_s {
                    Some(d) if r.duration_s > d => {
                        let state = self
                            .pending_tickets
                            .get_mut(&primary)
                            .expect("checked above");
                        let frac = d / r.duration_s;
                        state.fault_cost += r.charged_cost * frac;
                        state.fault_time += d;
                        self.log.record(EventKind::JobFailed {
                            job: r.job_id,
                            reason: format!(
                                "probe deadline {d}s exceeded ({:.1}s)",
                                r.duration_s
                            ),
                        });
                        primary
                    }
                    _ => {
                        self.log.record(EventKind::JobCompleted {
                            job: r.job_id,
                            cost: r.charged_cost,
                        });
                        let state = self
                            .pending_tickets
                            .remove(&primary)
                            .expect("checked above");
                        self.ready_tickets.insert(
                            primary,
                            (
                                state.s_idx,
                                SlotOutcome {
                                    result: Some(r),
                                    fault_cost: state.fault_cost,
                                    fault_time: state.fault_time,
                                    attempts: state.failures + 1,
                                },
                            ),
                        );
                        return Ok(());
                    }
                }
            }
            Err(e) => {
                ensure!(
                    e.job_id != crate::coordinator::JobError::NO_JOB,
                    "worker pool failure: {e}"
                );
                let primary = job_ids::original(e.job_id);
                let state =
                    self.pending_tickets.get_mut(&primary).ok_or_else(|| {
                        anyhow!("unattributable launcher failure: {e}")
                    })?;
                self.log.record(EventKind::JobFailed {
                    job: e.job_id,
                    reason: e.error.to_string(),
                });
                if let Some(i) = e.error.downcast_ref::<Interrupted>() {
                    state.fault_cost += i.partial_cost;
                    state.fault_time += i.partial_duration_s;
                }
                primary
            }
        };
        let state = self
            .pending_tickets
            .get_mut(&failed_primary)
            .expect("present on every failure path");
        state.failures += 1;
        let failures = state.failures;
        if failures > self.retry.max_retries {
            let state = self
                .pending_tickets
                .remove(&failed_primary)
                .expect("present above");
            self.log.record(EventKind::ProbeAbandoned {
                job: failed_primary,
                attempts: state.failures,
                wasted_cost: state.fault_cost,
            });
            self.ready_tickets.insert(
                failed_primary,
                (
                    state.s_idx,
                    SlotOutcome {
                        result: None,
                        fault_cost: state.fault_cost,
                        fault_time: state.fault_time,
                        attempts: state.failures,
                    },
                ),
            );
            return Ok(());
        }
        let delay = self.retry.backoff_delay_s(failures, &mut self.retry_rng);
        if delay > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(delay));
        }
        let (config, s_idx) = {
            let state = self
                .pending_tickets
                .get(&failed_primary)
                .expect("present above");
            (state.config, state.s_idx)
        };
        let id = job_ids::retry(failed_primary, failures);
        self.submit_with_id(id, config, vec![s_idx])
    }
}

/// Replay-side snapshot accounting, shared by [`EvalBackend::snapshot`]
/// and the grouped slates of [`EvalBackend::probe_slate`]: look up each
/// level's measured outcome and charge the one training run that would
/// have produced every snapshot — the largest (last, levels ascending)
/// level's cost and time. This is the single place the replay charging
/// rule lives; the live side's equivalent is the launcher's own
/// accounting ([`crate::coordinator::SimLauncher`]).
fn replay_snapshot(
    d: &Dataset,
    config: Config,
    levels: &[usize],
) -> (Vec<(usize, Outcome)>, f64, f64) {
    let outcomes: Vec<(usize, Outcome)> = levels
        .iter()
        .map(|&s| (s, d.outcome(&Point { config, s_idx: s })))
        .collect();
    let (_, largest) = *outcomes.last().expect("nonempty levels");
    (outcomes, largest.cost_usd, largest.time_s)
}

/// The engine's evaluation substrate: trace replay or live deployments.
pub enum EvalBackend<'a> {
    /// The paper's methodology: every probe is a lookup in a
    /// pre-materialized measurement campaign.
    Replay(&'a Dataset),
    /// Every probe is a (simulated-latency, noisy, or real) deployment
    /// through the worker pool.
    Live(LiveEval<'a>),
}

impl<'a> EvalBackend<'a> {
    /// Evaluate one (config, s) probe.
    pub fn probe(&mut self, p: Point) -> Result<Probe> {
        let mut probes = self.probe_batch(&[p])?;
        Ok(probes.pop().expect("one probe per point"))
    }

    /// Evaluate a batch of independent probes (parallel across the worker
    /// pool under `Live`); results are in input order. This is the *strict*
    /// path: a probe abandoned after exhausting its retry budget is an
    /// error here — only [`EvalBackend::probe_slate`] tolerates holes.
    pub fn probe_batch(&mut self, points: &[Point]) -> Result<Vec<Probe>> {
        self.probe_results(points)?
            .into_iter()
            .map(|r| match r {
                ProbeResult::Observed(p) => Ok(p),
                ProbeResult::Abandoned { attempts, .. } => Err(anyhow!(
                    "probe abandoned after {attempts} failed launches (strict \
                     probe path — only slate rounds tolerate abandonment)"
                )),
            })
            .collect()
    }

    /// Fault-tolerant per-point evaluation: like [`EvalBackend::probe_batch`]
    /// but abandoned probes come back as [`ProbeResult::Abandoned`] holes
    /// carrying their partial charge. Replay never abandons.
    fn probe_results(&mut self, points: &[Point]) -> Result<Vec<ProbeResult>> {
        match self {
            EvalBackend::Replay(d) => Ok(points
                .iter()
                .map(|p| {
                    let o = d.outcome(p);
                    ProbeResult::Observed(Probe {
                        outcome: o,
                        charged_cost: o.cost_usd,
                        duration_s: o.time_s,
                    })
                })
                .collect()),
            EvalBackend::Live(live) => {
                let specs: Vec<(Config, Vec<usize>)> = points
                    .iter()
                    .map(|p| (p.config, vec![p.s_idx]))
                    .collect();
                let slots = live.run_jobs(&specs)?;
                points
                    .iter()
                    .zip(slots)
                    .map(|(p, s)| match s.result {
                        Some(r) => {
                            let o = r
                                .outcomes
                                .iter()
                                .find(|(lvl, _)| *lvl == p.s_idx)
                                .map(|(_, o)| *o)
                                .ok_or_else(|| {
                                    anyhow!(
                                        "launcher returned no snapshot at level {}",
                                        p.s_idx
                                    )
                                })?;
                            // faulted-but-recovered attempts still cost
                            // money: fold their partial charge into the
                            // probe (exactly +0.0 on the clean path)
                            Ok(ProbeResult::Observed(Probe {
                                outcome: o,
                                charged_cost: r.charged_cost + s.fault_cost,
                                duration_s: r.duration_s + s.fault_time,
                            }))
                        }
                        None => Ok(ProbeResult::Abandoned {
                            charged_cost: s.fault_cost,
                            duration_s: s.fault_time,
                            attempts: s.attempts,
                        }),
                    })
                    .collect()
            }
        }
    }

    /// Evaluate one acquisition slate (a round's probes). Points sharing a
    /// configuration ride a single snapshot deployment (ascending levels,
    /// charged once at the largest — paper §III snapshot semantics), while
    /// distinct configurations launch as independent jobs, concurrent
    /// across the worker pool under `Live`. Results come back in slate
    /// order regardless of completion order. Within a config group the
    /// group's charge and duration are attributed to its largest-level
    /// point and the remaining points cost 0, mirroring the init batch's
    /// accounting. A slate of one point is exactly [`EvalBackend::probe`].
    ///
    /// This is the *fault-tolerant* path: a probe whose deployment was
    /// abandoned after the retry budget comes back as
    /// [`ProbeResult::Abandoned`] (for a shared deployment, every rider of
    /// the group) so the round can re-plan around the hole; the partial
    /// cost of its interrupted attempts rides on the group's payer point.
    pub fn probe_slate(&mut self, points: &[Point]) -> Result<Vec<ProbeResult>> {
        ensure!(!points.is_empty(), "empty probe slate");
        // group slate indices by config, preserving first-appearance order
        let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut groups: Vec<(Config, Vec<usize>)> = Vec::new();
        for (i, p) in points.iter().enumerate() {
            let g = *group_of.entry(p.config.id()).or_insert_with(|| {
                groups.push((p.config, Vec::new()));
                groups.len() - 1
            });
            groups[g].1.push(i);
        }
        if groups.len() == points.len() {
            // every config distinct: plain independent probes
            return self.probe_results(points);
        }
        let specs: Vec<(Config, Vec<usize>)> = groups
            .iter()
            .map(|(config, idxs)| {
                let mut levels: Vec<usize> =
                    idxs.iter().map(|&i| points[i].s_idx).collect();
                levels.sort_unstable();
                levels.dedup();
                (*config, levels)
            })
            .collect();
        // per-group slot outcomes — replay emulates the launcher's
        // snapshot accounting on the lookup table and never faults
        let slots: Vec<SlotOutcome> = match self {
            EvalBackend::Replay(d) => specs
                .iter()
                .map(|(config, levels)| {
                    let (outcomes, charged_cost, duration_s) =
                        replay_snapshot(d, *config, levels);
                    SlotOutcome {
                        result: Some(JobResult {
                            job_id: 0,
                            outcomes,
                            charged_cost,
                            duration_s,
                        }),
                        fault_cost: 0.0,
                        fault_time: 0.0,
                        attempts: 1,
                    }
                })
                .collect(),
            EvalBackend::Live(live) => live.run_jobs(&specs)?,
        };
        // redistribute to slate order with snapshot accounting per group
        let mut probes: Vec<Option<ProbeResult>> = vec![None; points.len()];
        for ((_, idxs), slot) in groups.iter().zip(&slots) {
            // the group's largest-level point carries the whole charge
            let payer = *idxs
                .iter()
                .max_by_key(|&&i| points[i].s_idx)
                .expect("nonempty group");
            match &slot.result {
                Some(r) => {
                    for &i in idxs {
                        let s = points[i].s_idx;
                        let o = r
                            .outcomes
                            .iter()
                            .find(|(lvl, _)| *lvl == s)
                            .map(|(_, o)| *o)
                            .ok_or_else(|| {
                                anyhow!("launcher returned no snapshot at level {s}")
                            })?;
                        let pays = i == payer;
                        probes[i] = Some(ProbeResult::Observed(Probe {
                            outcome: o,
                            charged_cost: if pays {
                                r.charged_cost + slot.fault_cost
                            } else {
                                0.0
                            },
                            duration_s: if pays {
                                r.duration_s + slot.fault_time
                            } else {
                                0.0
                            },
                        }));
                    }
                }
                None => {
                    // the shared deployment died for good: every rider of
                    // the group is a hole, the payer carries the waste
                    for &i in idxs {
                        let pays = i == payer;
                        probes[i] = Some(ProbeResult::Abandoned {
                            charged_cost: if pays { slot.fault_cost } else { 0.0 },
                            duration_s: if pays { slot.fault_time } else { 0.0 },
                            attempts: slot.attempts,
                        });
                    }
                }
            }
        }
        Ok(probes
            .into_iter()
            .map(|p| p.expect("all slate slots filled"))
            .collect())
    }

    /// Submit one probe without waiting for it — the asynchronous engine's
    /// submission half. Replay resolves instantly (the ticket comes back
    /// [`ProbeTicket::Ready`]); a live deployment enters the pool and the
    /// ticket is its primary job id. Tickets must be redeemed via
    /// [`EvalBackend::await_probe`] in submission order — the logical
    /// clock that makes async trajectories bitwise independent of physical
    /// completion order (see `docs/ARCHITECTURE.md`, "Asynchronous
    /// selection").
    pub fn submit_probe(&mut self, p: Point) -> Result<ProbeTicket> {
        match self {
            EvalBackend::Replay(d) => {
                let o = d.outcome(&p);
                Ok(ProbeTicket::Ready(ProbeResult::Observed(Probe {
                    outcome: o,
                    charged_cost: o.cost_usd,
                    duration_s: o.time_s,
                })))
            }
            EvalBackend::Live(live) => {
                Ok(ProbeTicket::Pending(live.submit_ticket(p)?))
            }
        }
    }

    /// Redeem an asynchronous ticket, blocking until it resolves; other
    /// tickets completing in the meantime buffer (reorder buffer) without
    /// being lost. Like [`EvalBackend::probe_slate`], this is a
    /// fault-tolerant path: an exhausted retry budget comes back as
    /// [`ProbeResult::Abandoned`] with its partial charge.
    pub fn await_probe(&mut self, ticket: ProbeTicket) -> Result<ProbeResult> {
        match ticket {
            ProbeTicket::Ready(r) => Ok(r),
            ProbeTicket::Pending(id) => match self {
                EvalBackend::Live(live) => live.await_ticket(id),
                EvalBackend::Replay(_) => Err(anyhow!(
                    "live ticket {id} redeemed against a replay backend"
                )),
            },
        }
    }

    /// Worker-thread count of the live pool — the occupancy target the
    /// asynchronous engine saturates. Replay "completes" every submission
    /// instantly, so its effective width is 1.
    pub fn pool_width(&self) -> usize {
        match self {
            EvalBackend::Replay(_) => 1,
            EvalBackend::Live(live) => live.workers,
        }
    }

    /// Snapshot deployment of one config at several *ascending*
    /// sub-sampling levels, charged once at the largest level (paper §III).
    /// Replay emulates the same accounting on the lookup table: the charge
    /// is the last (largest) level's measured cost — the one training run
    /// that would have produced every snapshot.
    pub fn snapshot(
        &mut self,
        config: Config,
        s_levels: &[usize],
    ) -> Result<Snapshot> {
        anyhow::ensure!(!s_levels.is_empty(), "snapshot without levels");
        anyhow::ensure!(
            s_levels.windows(2).all(|w| w[0] < w[1]),
            "snapshot levels must be strictly ascending: {s_levels:?}"
        );
        match self {
            EvalBackend::Replay(d) => {
                let (outcomes, charged_cost, duration_s) =
                    replay_snapshot(d, config, s_levels);
                Ok(Snapshot { outcomes, charged_cost, duration_s })
            }
            EvalBackend::Live(live) => {
                let slots = live.run_jobs(&[(config, s_levels.to_vec())])?;
                let slot = slots.into_iter().next().expect("one job");
                match slot.result {
                    Some(r) => Ok(Snapshot {
                        outcomes: r.outcomes,
                        charged_cost: r.charged_cost + slot.fault_cost,
                        duration_s: r.duration_s + slot.fault_time,
                    }),
                    // strict path: callers that need the snapshot (e.g.
                    // tests) get a hard error; the engine's init re-plans
                    // via probe_slate instead
                    None => Err(anyhow!(
                        "snapshot of {} abandoned after {} failed launches; \
                         raise the retry budget (--retry max=N) or lower the \
                         fault rate",
                        config.describe(),
                        slot.attempts
                    )),
                }
            }
        }
    }

    /// Fault counters accumulated so far (all zero under replay).
    pub fn fault_stats(&self) -> FaultStats {
        match self {
            EvalBackend::Replay(_) => FaultStats::default(),
            EvalBackend::Live(live) => live.faults,
        }
    }

    /// Ground truth for evaluation-only metrics, when available (always in
    /// replay; in live runs only if an oracle was attached).
    pub fn eval_dataset(&self) -> Option<&Dataset> {
        match self {
            EvalBackend::Replay(d) => Some(*d),
            EvalBackend::Live(live) => live.eval,
        }
    }

    /// The live event log (`None` under replay).
    pub fn event_log(&self) -> Option<&EventLog> {
        match self {
            EvalBackend::Replay(_) => None,
            EvalBackend::Live(live) => Some(&live.log),
        }
    }

    /// Tear down the live worker pool (no-op for replay). Dropping the
    /// backend does the same — the pool's `Drop` joins its workers.
    pub fn shutdown(self) {
        if let EvalBackend::Live(live) = self {
            live.pool.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SimLauncher;
    use crate::sim::NetKind;
    use crate::space::{S_INIT, S_VALUES};

    fn backend_pair(net: NetKind) -> (Dataset, LiveEval<'static>) {
        let truth = Dataset::ground_truth(net);
        let live =
            LiveEval::new(Box::new(SimLauncher::noiseless(net)), 2);
        (truth, live)
    }

    #[test]
    fn replay_and_noiseless_live_probes_agree_exactly() {
        let (truth, live) = backend_pair(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        for id in [3usize, 600, 1204] {
            let p = Point::from_id(id);
            let a = replay.probe(p).unwrap();
            let b = live.probe(p).unwrap();
            assert_eq!(a.outcome, b.outcome);
            assert_eq!(a.charged_cost, b.charged_cost);
            assert_eq!(a.duration_s, b.duration_s);
        }
    }

    #[test]
    fn snapshot_accounting_matches_across_backends() {
        let (truth, live) = backend_pair(NetKind::Mlp);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        let config = Config::from_id(42);
        let a = replay.snapshot(config, &S_INIT).unwrap();
        let b = live.snapshot(config, &S_INIT).unwrap();
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for ((sa, oa), (sb, ob)) in a.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(sa, sb);
            assert_eq!(oa, ob);
        }
        assert_eq!(a.charged_cost, b.charged_cost);
        // charged at the largest level, not the sum
        let largest = truth
            .outcome(&Point { config, s_idx: S_INIT[S_INIT.len() - 1] })
            .cost_usd;
        assert_eq!(a.charged_cost, largest);
        let sum: f64 = a.outcomes.iter().map(|(_, o)| o.cost_usd).sum();
        assert!(a.charged_cost < sum);
    }

    #[test]
    fn live_batch_results_come_back_in_submission_order() {
        let (_, live) = backend_pair(NetKind::Rnn);
        let mut live = EvalBackend::Live(live);
        let points: Vec<Point> = (0..12)
            .map(|i| Point { config: Config::from_id(i * 20), s_idx: 4 })
            .collect();
        let probes = live.probe_batch(&points).unwrap();
        let truth = Dataset::ground_truth(NetKind::Rnn);
        for (p, pr) in points.iter().zip(&probes) {
            assert_eq!(pr.outcome, truth.outcome(p));
        }
        // and the log saw every submission + completion
        let log = live.event_log().unwrap();
        let submitted = log
            .count(|k| matches!(k, EventKind::JobSubmitted { .. }));
        let completed = log
            .count(|k| matches!(k, EventKind::JobCompleted { .. }));
        assert_eq!((submitted, completed), (12, 12));
    }

    /// Launcher that fails the first `fail_first` launches (by a global
    /// counter), then succeeds — exercises the requeue path end to end.
    struct FlakyLauncher {
        inner: SimLauncher,
        remaining_failures: std::sync::atomic::AtomicUsize,
    }

    impl JobLauncher for FlakyLauncher {
        fn launch(&self, job: &Job) -> Result<JobResult> {
            use std::sync::atomic::Ordering;
            let prev = self
                .remaining_failures
                .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                    v.checked_sub(1)
                })
                .unwrap_or(0);
            if prev > 0 {
                anyhow::bail!("transient launch failure");
            }
            self.inner.launch(job)
        }
    }

    #[test]
    fn failed_launches_are_requeued_and_the_run_completes() {
        let launcher = FlakyLauncher {
            inner: SimLauncher::noiseless(NetKind::Rnn),
            remaining_failures: std::sync::atomic::AtomicUsize::new(2),
        };
        let mut live =
            EvalBackend::Live(LiveEval::new(Box::new(launcher), 2));
        let points: Vec<Point> = (0..6)
            .map(|i| Point { config: Config::from_id(i * 40), s_idx: 4 })
            .collect();
        let probes = live.probe_batch(&points).unwrap();
        assert_eq!(probes.len(), 6);
        let truth = Dataset::ground_truth(NetKind::Rnn);
        for (p, pr) in points.iter().zip(&probes) {
            assert_eq!(pr.outcome, truth.outcome(p));
        }
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::JobFailed { .. })),
            2
        );
    }

    #[test]
    fn probe_slate_groups_shared_configs_into_one_snapshot() {
        let (truth, live) = backend_pair(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        let mut live = EvalBackend::Live(live);
        // two picks share config 7 (levels 1 and 3, deliberately not in
        // slate order), one pick is a distinct config
        let shared = Config::from_id(7);
        let slate = [
            Point { config: shared, s_idx: 3 },
            Point { config: Config::from_id(100), s_idx: 4 },
            Point { config: shared, s_idx: 1 },
        ];
        let a: Vec<Probe> = replay
            .probe_slate(&slate)
            .unwrap()
            .iter()
            .map(|r| *r.observed().expect("replay never abandons"))
            .collect();
        let b: Vec<Probe> = live
            .probe_slate(&slate)
            .unwrap()
            .iter()
            .map(|r| *r.observed().expect("clean live run never abandons"))
            .collect();
        assert_eq!(a.len(), 3);
        for ((p, ra), rb) in slate.iter().zip(&a).zip(&b) {
            assert_eq!(ra.outcome, truth.outcome(p));
            assert_eq!(ra.outcome, rb.outcome);
            assert_eq!(ra.charged_cost, rb.charged_cost);
            assert_eq!(ra.duration_s, rb.duration_s);
        }
        // snapshot accounting: the s=3 pick (largest level of its group)
        // pays the one training run, the s=1 rider is free
        assert_eq!(
            a[0].charged_cost,
            truth.outcome(&Point { config: shared, s_idx: 3 }).cost_usd
        );
        assert_eq!(a[2].charged_cost, 0.0);
        assert_eq!(a[2].duration_s, 0.0);
        assert_eq!(
            a[1].charged_cost,
            truth.outcome(&slate[1]).cost_usd,
            "independent config pays its own probe"
        );
        // only two jobs were deployed for the three observations
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::JobSubmitted { .. })),
            2
        );
    }

    #[test]
    fn probe_slate_of_one_matches_probe_exactly() {
        let truth = Dataset::ground_truth(NetKind::Mlp);
        let mut replay = EvalBackend::Replay(&truth);
        let p = Point::from_id(777);
        let a = replay.probe(p).unwrap();
        let slate = replay.probe_slate(&[p]).unwrap();
        let b = slate[0].observed().expect("replay never abandons");
        assert_eq!(a.outcome, b.outcome);
        assert_eq!(a.charged_cost, b.charged_cost);
        assert_eq!(a.duration_s, b.duration_s);
    }

    /// Asynchronous tickets redeemed in submission order agree exactly
    /// with replay, regardless of physical completion order across the
    /// pool's workers — the logical-clock contract the async engine
    /// stands on.
    #[test]
    fn async_tickets_redeem_in_submission_order_and_match_replay() {
        let (truth, live) = backend_pair(NetKind::Rnn);
        let mut live = EvalBackend::Live(live);
        let points: Vec<Point> = (0..8)
            .map(|i| Point { config: Config::from_id(i * 150), s_idx: 4 })
            .collect();
        let tickets: Vec<ProbeTicket> = points
            .iter()
            .map(|&p| live.submit_probe(p).unwrap())
            .collect();
        for (p, t) in points.iter().zip(tickets) {
            let r = live.await_probe(t).unwrap();
            let pr = r.observed().expect("noiseless run never abandons");
            assert_eq!(pr.outcome, truth.outcome(p));
            assert_eq!(pr.charged_cost, truth.outcome(p).cost_usd);
        }
        let log = live.event_log().unwrap();
        let submitted =
            log.count(|k| matches!(k, EventKind::JobSubmitted { .. }));
        let completed =
            log.count(|k| matches!(k, EventKind::JobCompleted { .. }));
        assert_eq!((submitted, completed), (8, 8));
    }

    /// A replay backend resolves every async ticket at submission, with
    /// the same observation and accounting the blocking probe returns.
    #[test]
    fn replay_async_tickets_resolve_instantly() {
        let truth = Dataset::ground_truth(NetKind::Mlp);
        let mut replay = EvalBackend::Replay(&truth);
        let p = Point::from_id(512);
        let blocking = replay.probe(p).unwrap();
        let t = replay.submit_probe(p).unwrap();
        assert!(matches!(t, ProbeTicket::Ready(_)));
        let r = replay.await_probe(t).unwrap();
        let pr = r.observed().expect("replay never abandons");
        assert_eq!(pr.outcome, blocking.outcome);
        assert_eq!(pr.charged_cost, blocking.charged_cost);
        assert_eq!(replay.pool_width(), 1);
    }

    /// Launcher that kills every attempt (primary and retries) of the
    /// probes whose *primary* job id is listed, with an [`Interrupted`]
    /// payload charging half the real cost — a deterministic preemption
    /// that always exhausts the retry budget.
    struct KillListLauncher {
        inner: SimLauncher,
        kill_primary: Vec<u64>,
    }

    impl JobLauncher for KillListLauncher {
        fn launch(&self, job: &Job) -> Result<JobResult> {
            let r = self.inner.launch(job)?;
            if self.kill_primary.contains(&job_ids::original(job.id)) {
                return Err(anyhow::Error::new(Interrupted {
                    partial_cost: r.charged_cost * 0.5,
                    partial_duration_s: r.duration_s * 0.5,
                }));
            }
            Ok(r)
        }
    }

    #[test]
    fn exhausted_retries_abandon_the_probe_with_partial_charge() {
        let launcher = KillListLauncher {
            inner: SimLauncher::noiseless(NetKind::Rnn),
            // 1 = slot 1 of the slate below; 4 = the first id of the
            // follow-up strict probe_batch call
            kill_primary: vec![1, 4],
        };
        let mut live = EvalBackend::Live(
            LiveEval::new(Box::new(launcher), 2)
                .with_retry(RetryPolicy { max_retries: 2, ..RetryPolicy::default() }, 7),
        );
        let points: Vec<Point> = (0..4)
            .map(|i| Point { config: Config::from_id(i * 40), s_idx: 4 })
            .collect();
        let results = live.probe_slate(&points).unwrap();
        let truth = Dataset::ground_truth(NetKind::Rnn);
        for (i, (p, r)) in points.iter().zip(&results).enumerate() {
            match r {
                ProbeResult::Observed(pr) => {
                    assert_ne!(i, 1, "killed slot must be abandoned");
                    assert_eq!(pr.outcome, truth.outcome(p));
                }
                ProbeResult::Abandoned { charged_cost, attempts, .. } => {
                    assert_eq!(i, 1);
                    assert_eq!(*attempts, 3, "1 primary + 2 retries");
                    // every interrupted attempt charged half a run
                    let full = truth.outcome(p).cost_usd;
                    assert!((charged_cost - 1.5 * full).abs() < 1e-9);
                }
            }
        }
        let stats = live.fault_stats();
        assert_eq!((stats.n_failures, stats.n_abandoned), (3, 1));
        assert!(stats.wasted_cost > 0.0 && stats.wasted_time > 0.0);
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::ProbeAbandoned { .. })),
            1
        );
        // the strict path refuses the same situation
        assert!(live.probe_batch(&points[..2]).is_err());
    }

    /// Exhausted retries on the async ticket path abandon with the same
    /// partial-charge accounting as the barriered slate path, and fault
    /// counters fold at redemption.
    #[test]
    fn async_ticket_abandonment_matches_barriered_accounting() {
        let launcher = KillListLauncher {
            inner: SimLauncher::noiseless(NetKind::Rnn),
            kill_primary: vec![1],
        };
        let mut live = EvalBackend::Live(
            LiveEval::new(Box::new(launcher), 2).with_retry(
                RetryPolicy { max_retries: 2, ..RetryPolicy::default() },
                7,
            ),
        );
        let truth = Dataset::ground_truth(NetKind::Rnn);
        let points: Vec<Point> = (0..3)
            .map(|i| Point { config: Config::from_id(i * 40), s_idx: 4 })
            .collect();
        let tickets: Vec<ProbeTicket> = points
            .iter()
            .map(|&p| live.submit_probe(p).unwrap())
            .collect();
        for (i, (p, t)) in points.iter().zip(tickets).enumerate() {
            match live.await_probe(t).unwrap() {
                ProbeResult::Observed(pr) => {
                    assert_ne!(i, 1, "killed ticket must be abandoned");
                    assert_eq!(pr.outcome, truth.outcome(p));
                }
                ProbeResult::Abandoned { charged_cost, attempts, .. } => {
                    assert_eq!(i, 1);
                    assert_eq!(attempts, 3, "1 primary + 2 retries");
                    let full = truth.outcome(p).cost_usd;
                    assert!((charged_cost - 1.5 * full).abs() < 1e-9);
                }
            }
        }
        let stats = live.fault_stats();
        assert_eq!((stats.n_failures, stats.n_abandoned), (3, 1));
        let log = live.event_log().unwrap();
        assert_eq!(
            log.count(|k| matches!(k, EventKind::ProbeAbandoned { .. })),
            1
        );
    }

    #[test]
    fn deadline_treats_stragglers_as_failures_with_prorata_charge() {
        let truth = Dataset::ground_truth(NetKind::Rnn);
        let p = Point::from_id(900);
        let real = truth.outcome(&p);
        let policy = RetryPolicy {
            max_retries: 0,
            probe_deadline_s: Some(real.time_s * 0.5),
            ..RetryPolicy::default()
        };
        let mut live = EvalBackend::Live(
            LiveEval::new(Box::new(SimLauncher::noiseless(NetKind::Rnn)), 1)
                .with_retry(policy, 7),
        );
        let results = live.probe_slate(&[p]).unwrap();
        match &results[0] {
            ProbeResult::Abandoned { charged_cost, duration_s, attempts } => {
                assert_eq!(*attempts, 1);
                assert!((charged_cost - real.cost_usd * 0.5).abs() < 1e-9);
                assert!((duration_s - real.time_s * 0.5).abs() < 1e-9);
            }
            ProbeResult::Observed(_) => panic!("deadline at half runtime must kill"),
        }
    }

    #[test]
    fn retry_policy_parses_and_rejects_garbage() {
        let p = RetryPolicy::parse("max=2,base=0.5,factor=3,cap=10,jitter=0.2,deadline=600")
            .unwrap();
        assert_eq!(p.max_retries, 2);
        assert_eq!(p.backoff_base_s, 0.5);
        assert_eq!(p.backoff_factor, 3.0);
        assert_eq!(p.backoff_max_s, 10.0);
        assert_eq!(p.jitter, 0.2);
        assert_eq!(p.probe_deadline_s, Some(600.0));
        assert_eq!(RetryPolicy::parse("").unwrap(), RetryPolicy::default());
        assert!(RetryPolicy::parse("max").is_err());
        assert!(RetryPolicy::parse("bogus=1").is_err());
        assert!(RetryPolicy::parse("factor=0.5").is_err());
        assert!(RetryPolicy::parse("deadline=-1").is_err());
    }

    #[test]
    fn backoff_schedule_is_exponential_capped_and_jittered_deterministically() {
        let p = RetryPolicy {
            backoff_base_s: 1.0,
            backoff_factor: 2.0,
            backoff_max_s: 8.0,
            jitter: 0.0,
            ..RetryPolicy::default()
        };
        let mut rng = Rng::new(1);
        assert_eq!(p.backoff_delay_s(1, &mut rng), 1.0);
        assert_eq!(p.backoff_delay_s(2, &mut rng), 2.0);
        assert_eq!(p.backoff_delay_s(3, &mut rng), 4.0);
        assert_eq!(p.backoff_delay_s(5, &mut rng), 8.0, "capped");
        let jittered = RetryPolicy { jitter: 0.5, ..p.clone() };
        let d1 = jittered.backoff_delay_s(2, &mut Rng::new(9));
        let d2 = jittered.backoff_delay_s(2, &mut Rng::new(9));
        assert_eq!(d1, d2, "jitter is seeded, not ambient");
        assert!((1.0..=3.0).contains(&d1));
        let none = RetryPolicy::default();
        assert_eq!(none.backoff_delay_s(3, &mut Rng::new(0)), 0.0, "base 0 = no sleep");
    }

    #[test]
    fn snapshot_rejects_empty_levels_everywhere() {
        let truth = Dataset::ground_truth(NetKind::Rnn);
        let mut replay = EvalBackend::Replay(&truth);
        assert!(replay.snapshot(Config::from_id(0), &[]).is_err());
        assert_eq!(S_VALUES.len(), 5); // levels referenced above stay valid
    }
}
