//! Quickstart: tune a cloud ML training job with TrimTuner in ~20 lines.
//!
//! Run with: `cargo run --release --offline --example quickstart`

use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn main() -> anyhow::Result<()> {
    // 1. The workload: the simulated measurement campaign for the MLP
    //    network (or load a CSV you measured yourself via Dataset::load_csv).
    let dataset = Dataset::generate(NetKind::Mlp, 42);

    // 2. The QoS constraint: training must cost at most $0.06 per run.
    let constraints = vec![Constraint::cost_max(0.06)];

    // 3. TrimTuner with decision-tree surrogates, paper defaults
    //    (CEA filter at beta = 10%, 4 snapshot init samples, 44 iterations).
    let cfg = EngineConfig::paper_default(
        OptimizerKind::TrimTuner(ModelKind::Trees),
        /* seed = */ 7,
    );

    // 4. Optimize.
    let run = engine::run(&dataset, &constraints, &cfg);

    // 5. Inspect the recommendation.
    let last = run.records.last().expect("no iterations recorded");
    println!("recommended configuration: {}", last.incumbent.config.describe());
    println!(
        "its measured accuracy: {:.4} (true optimum: {:.4})",
        last.inc_acc, run.optimum_acc
    );
    println!(
        "constrained accuracy (Eq. 7): {:.4}  feasible: {}",
        last.accuracy_c, last.inc_feasible
    );
    println!(
        "total exploration spend: ${:.4} over {} tests",
        run.total_cost(),
        run.records.len()
    );
    assert!(last.accuracy_c > 0.8 * run.optimum_acc, "tuning went wrong");
    Ok(())
}
