// A2 allow: the per-candidate loop routed through the scratch twin with a
// hoisted output buffer, plus one pragma'd wrapper call on a cold path.

pub struct Factor {
    l: Vec<f64>,
    n: usize,
}

impl Factor {
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_lower_in_place(&mut x);
        x
    }

    pub fn solve_lower_into(&self, b: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(b);
        self.solve_lower_in_place(out);
    }

    fn solve_lower_in_place(&self, x: &mut [f64]) {
        for i in 0..self.n {
            for j in 0..i {
                x[i] -= self.l[i * self.n + j] * x[j];
            }
            x[i] /= self.l[i * self.n + i];
        }
    }
}

pub fn score_slate(factor: &Factor, slate: &[Vec<f64>]) -> f64 {
    let mut acc = 0.0;
    let mut v = Vec::new();
    for rhs in slate {
        factor.solve_lower_into(rhs, &mut v);
        acc += v.iter().sum::<f64>();
    }
    acc
}

pub fn spot_check(factor: &Factor, rhs: &[f64]) -> f64 {
    // detlint: allow(A2, reason="one-shot diagnostic, not on the slate sweep")
    let v = factor.solve_lower(rhs);
    v.iter().sum::<f64>()
}
