//! The surrogate bundle every optimizer maintains (paper Alg. 1 line 10):
//! accuracy model A(x,s), cost model C(x,s) and one model per QoS metric —
//! here cost and time, which cover all constraints the paper evaluates.

use crate::models::{
    Basis, ExtraTrees, Feat, FitOptions, Gp, ModelKind, Surrogate,
    TreesOptions,
};
use crate::sim::Outcome;
use crate::space::{encode, Constraint, Metric, Point};
use crate::util::stats::{cmp_nan_low, normal_cdf};

/// Accuracy + log-cost + log-time surrogates over (config, s) features.
pub struct Models {
    pub acc: Box<dyn Surrogate>,
    /// models ln(cost_usd)
    pub cost: Box<dyn Surrogate>,
    /// models ln(time_s)
    pub time: Box<dyn Surrogate>,
    pub kind: ModelKind,
    /// bumped on every [`Models::fit`] — lets per-iteration acquisition
    /// context (CEA ordering, entropy estimator, fantasy surfaces) be
    /// cached and reused as long as the fitted models are unchanged
    generation: u64,
}

impl Models {
    pub fn new(kind: ModelKind, seed: u64) -> Models {
        Models::with_gp_hyper_samples(kind, seed, 1)
    }

    /// `gp_k > 1` enables FABOLAS-style hyper-parameter marginalization for
    /// GP surrogates (K MCMC samples; K x prediction cost).
    pub fn with_gp_hyper_samples(
        kind: ModelKind,
        seed: u64,
        gp_k: usize,
    ) -> Models {
        match kind {
            ModelKind::Gp => Models {
                acc: Box::new(Gp::with_hyper_samples(Basis::Acc, seed, gp_k)),
                cost: Box::new(Gp::with_hyper_samples(
                    Basis::Cost,
                    seed ^ 1,
                    gp_k,
                )),
                time: Box::new(Gp::with_hyper_samples(
                    Basis::Cost,
                    seed ^ 2,
                    gp_k,
                )),
                kind,
                generation: 0,
            },
            ModelKind::Trees => Models {
                acc: Box::new(ExtraTrees::with_seed(
                    TreesOptions::default(),
                    seed,
                )),
                cost: Box::new(ExtraTrees::with_seed(
                    TreesOptions::default(),
                    seed ^ 1,
                )),
                time: Box::new(ExtraTrees::with_seed(
                    TreesOptions::default(),
                    seed ^ 2,
                )),
                kind,
                generation: 0,
            },
        }
    }

    /// Fit generation: distinct values mean the surrogates were refitted
    /// in between (conditioned clones inherit the parent's generation).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Fit all three surrogates from the observation log.
    pub fn fit(
        &mut self,
        points: &[Point],
        outcomes: &[Outcome],
        opts: FitOptions,
    ) {
        let xs: Vec<Feat> = points.iter().map(encode).collect();
        let acc: Vec<f64> = outcomes.iter().map(|o| o.acc).collect();
        let lc: Vec<f64> =
            outcomes.iter().map(|o| o.cost_usd.max(1e-9).ln()).collect();
        let lt: Vec<f64> =
            outcomes.iter().map(|o| o.time_s.max(1e-9).ln()).collect();
        self.acc.fit(&xs, &acc, opts);
        self.cost.fit(&xs, &lc, opts);
        self.time.fit(&xs, &lt, opts);
        self.generation += 1;
    }

    /// Fold a round's fresh observations into all three surrogates
    /// incrementally ([`Surrogate::absorb`]: GP hyper-parameters and tree
    /// structure frozen) — the amortized-O(n²) alternative to
    /// [`Models::fit`] on rounds where the engine's refit policy skips the
    /// full refit. Target transforms match `fit` exactly. One generation
    /// bump per absorbed batch, like one `fit`.
    pub fn absorb(&mut self, points: &[Point], outcomes: &[Outcome]) {
        for (p, o) in points.iter().zip(outcomes) {
            let x = encode(p);
            self.acc.absorb(&x, o.acc);
            self.cost.absorb(&x, o.cost_usd.max(1e-9).ln());
            self.time.absorb(&x, o.time_s.max(1e-9).ln());
        }
        self.generation += 1;
    }

    /// The from-scratch twin of [`Models::absorb`] (`TRIMTUNER_REFIT=full`
    /// parity hatch): recompute every surrogate's absorbed state from its
    /// stored history ([`Surrogate::refit_frozen`]) — identical state,
    /// none of the incremental arithmetic.
    pub fn refit_frozen(&mut self) {
        self.acc.refit_frozen();
        self.cost.refit_frozen();
        self.time.refit_frozen();
        self.generation += 1;
    }

    /// The surrogate that models a constraint's metric.
    pub fn metric_model(&self, metric: Metric) -> &dyn Surrogate {
        match metric {
            Metric::Cost => self.cost.as_ref(),
            Metric::Time => self.time.as_ref(),
        }
    }

    /// Predicted cost (USD) of testing a point — the α denominator.
    pub fn predicted_cost(&self, x: &Feat) -> f64 {
        let (mu, _) = self.cost.predict(x);
        mu.exp().max(1e-9)
    }

    /// Batched [`Models::predicted_cost`] over a slate of points.
    pub fn predicted_cost_many(&self, xs: &[Feat]) -> Vec<f64> {
        self.cost
            .predict_many(xs)
            .into_iter()
            .map(|(mu, _)| mu.exp().max(1e-9))
            .collect()
    }

    /// Does [`Models::condition`] leave the constraint (cost/time) models
    /// untouched? True for tree ensembles — see the perf note on
    /// `condition`. Callers may then precompute constraint feasibility
    /// once per iteration and reuse it across conditioned clones; keep
    /// this predicate in sync with `condition` below.
    pub fn constraints_fixed_under_condition(&self) -> bool {
        self.kind == ModelKind::Trees
    }

    /// Clone of the bundle with one simulated observation added to every
    /// surrogate (hyper-parameters frozen) — TrimTuner's 1-root
    /// Gauss–Hermite "simulate the refit" step (§III, simulation approach).
    /// Perf (EXPERIMENTS.md §Perf): for tree ensembles, conditioning the
    /// *constraint* models on their own predictive mean is statistically a
    /// no-op (bagged trees refit with one self-predicted point barely move)
    /// but costs a full 30-tree rebuild each — so the DT variant shares the
    /// unconditioned cost/time models. The accuracy model, which drives the
    /// information gain, is always conditioned. GPs condition everything
    /// (rank-1 Cholesky extension is O(n²)).
    pub fn condition(&self, x: &Feat) -> Models {
        let (a_hat, _) = self.acc.predict(x);
        self.condition_with_acc(x, a_hat)
    }

    /// [`Models::condition`] with the simulated *accuracy* outcome supplied
    /// by the caller instead of the predictive mean — the constant-liar
    /// batch-selection strategy conditions every pending slate pick on a
    /// fixed lie (e.g. the best observed accuracy) so that the next pick is
    /// repelled from the pending ones. Cost/time surrogates are conditioned
    /// exactly as in `condition` (they have no sensible lie: the deployment
    /// bill does not depend on how optimistic the batch strategy is).
    pub fn condition_with_acc(&self, x: &Feat, acc_value: f64) -> Models {
        let (cost, time) = match self.kind {
            ModelKind::Gp => {
                let (c_hat, _) = self.cost.predict(x);
                let (t_hat, _) = self.time.predict(x);
                (
                    self.cost.condition(x, c_hat),
                    self.time.condition(x, t_hat),
                )
            }
            ModelKind::Trees => {
                (self.cost.clone_box(), self.time.clone_box())
            }
        };
        Models {
            acc: self.acc.condition(x, acc_value),
            cost,
            time,
            kind: self.kind,
            generation: self.generation,
        }
    }
}

/// P(q >= 0) = P(metric <= max) under the log-metric surrogate at `x`.
pub fn feasibility_prob(models: &Models, c: &Constraint, x: &Feat) -> f64 {
    let (mu, std) = models.metric_model(c.metric).predict(x);
    let z = (c.max.max(1e-12).ln() - mu) / std.max(1e-9);
    normal_cdf(z)
}

/// Batched [`feasibility_prob`] over a slate of points (one constraint).
pub fn feasibility_probs(
    models: &Models,
    c: &Constraint,
    xs: &[Feat],
) -> Vec<f64> {
    let lim = c.max.max(1e-12).ln();
    models
        .metric_model(c.metric)
        .predict_many(xs)
        .into_iter()
        .map(|(mu, std)| normal_cdf((lim - mu) / std.max(1e-9)))
        .collect()
}

/// Joint feasibility (constraints independent, paper Eq. 5 product).
pub fn joint_feasibility(
    models: &Models,
    constraints: &[Constraint],
    x: &Feat,
) -> f64 {
    constraints
        .iter()
        .map(|c| feasibility_prob(models, c, x))
        .product()
}

/// Batched [`joint_feasibility`] over a slate of points: one
/// [`Surrogate::predict_many`] call per constraint instead of a scalar
/// prediction per (constraint, point) pair.
pub fn joint_feasibility_many(
    models: &Models,
    constraints: &[Constraint],
    xs: &[Feat],
) -> Vec<f64> {
    let mut out = vec![1.0; xs.len()];
    for c in constraints {
        for (o, p) in out.iter_mut().zip(feasibility_probs(models, c, xs)) {
            *o *= p;
        }
    }
    out
}

/// Recommended incumbent (paper footnote 2: feasible with probability
/// >= 0.9, maximum predicted accuracy, always at s = 1).
#[derive(Debug, Clone, Copy)]
pub struct Incumbent {
    /// dense config id (0..288)
    pub config_id: usize,
    pub pred_acc: f64,
    pub feas_prob: f64,
}

pub const FEAS_THRESHOLD: f64 = 0.9;
/// Laxer bar for *retaining* an already-recommended incumbent (hysteresis
/// band prevents flapping right at the 0.9 boundary).
pub const FEAS_THRESHOLD_HYST: f64 = 0.8;

/// Scan all full-data-set configs; pick the most accurate among those that
/// are feasible with >= 90% probability. Falls back to the configuration
/// with the highest feasibility probability when none clears the bar
/// (early iterations).
///
/// `full_feats[i]` must be `encode(config_i at s=1)` — precomputed once by
/// the engine since it never changes.
pub fn select_incumbent(
    models: &Models,
    constraints: &[Constraint],
    full_feats: &[Feat],
) -> Incumbent {
    let all: Vec<usize> = (0..full_feats.len()).collect();
    select_incumbent_from(models, constraints, full_feats, &all)
}

/// Incumbent selection restricted to a subset of config ids — the
/// acquisition hot path uses a CEA-ranked shortlist so the per-candidate
/// simulated-refit scan costs O(|shortlist|) instead of O(288) predictions
/// per surrogate (EXPERIMENTS.md §Perf).
pub fn select_incumbent_from(
    models: &Models,
    constraints: &[Constraint],
    full_feats: &[Feat],
    subset: &[usize],
) -> Incumbent {
    let feats: Vec<Feat> = subset.iter().map(|&id| full_feats[id]).collect();
    select_incumbent_over(models, constraints, subset, &feats)
}

/// Incumbent scan over pre-gathered subset features (`feats[k]` is the
/// feature vector of config `subset[k]`) — the α_T hot path gathers the
/// shortlist features once per iteration instead of once per candidate.
pub fn select_incumbent_over(
    models: &Models,
    constraints: &[Constraint],
    subset: &[usize],
    feats: &[Feat],
) -> Incumbent {
    let feas = joint_feasibility_many(models, constraints, feats);
    let accs = models.acc.predict_many(feats);
    incumbent_scan(subset, &feas, &accs)
}

/// [`select_incumbent_over`] with the joint feasibility also supplied by
/// the caller. Valid when conditioning leaves the constraint models
/// untouched ([`Models::constraints_fixed_under_condition`]): the
/// shortlist feasibility is then iteration-constant and the engine
/// precomputes it once instead of re-deriving it inside every α_T call.
pub fn select_incumbent_over_with_feas(
    models: &Models,
    subset: &[usize],
    feats: &[Feat],
    feas: &[f64],
) -> Incumbent {
    assert_eq!(subset.len(), feas.len());
    let accs = models.acc.predict_many(feats);
    incumbent_scan(subset, feas, &accs)
}

/// Core incumbent argmax over pre-gathered (feasibility, prediction) rows —
/// shared by the scan entry points above and the fantasy α_T evaluator,
/// which supplies conditioned predictions without a conditioned surrogate.
pub(crate) fn incumbent_scan(
    subset: &[usize],
    feas: &[f64],
    accs: &[(f64, f64)],
) -> Incumbent {
    let mut best: Option<Incumbent> = None;
    let mut fallback: Option<Incumbent> = None;
    for ((&id, &p), &(acc, _)) in subset.iter().zip(feas).zip(accs) {
        let cand = Incumbent { config_id: id, pred_acc: acc, feas_prob: p };
        // NaN-safe comparisons: a NaN prediction loses to any real value
        // instead of freezing an early entry in place
        if p >= FEAS_THRESHOLD
            && best
                .as_ref()
                .map_or(true, |b| cmp_nan_low(acc, b.pred_acc).is_gt())
        {
            best = Some(cand);
        }
        if fallback.as_ref().map_or(true, |f| {
            cmp_nan_low(p, f.feas_prob)
                .then_with(|| cmp_nan_low(acc, f.pred_acc))
                .is_gt()
        }) {
            fallback = Some(cand);
        }
    }
    best.or(fallback).expect("non-empty subset")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{Config, S_VALUES};
    use crate::util::Rng;

    pub(crate) fn fitted_models(kind: ModelKind, n: usize) -> (Models, Vec<Point>, Vec<Outcome>) {
        let sim = CloudSim::new(NetKind::Mlp);
        let mut rng = Rng::new(7);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..n {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(S_VALUES.len()),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut m = Models::new(kind, 3);
        m.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
        (m, pts, outs)
    }

    #[test]
    fn feasibility_prob_monotone_in_cap() {
        for kind in [ModelKind::Gp, ModelKind::Trees] {
            let (m, pts, _) = fitted_models(kind, 20);
            let x = encode(&pts[0]);
            let p_tight = feasibility_prob(&m, &Constraint::cost_max(1e-6), &x);
            let p_loose = feasibility_prob(&m, &Constraint::cost_max(100.0), &x);
            assert!(p_tight < 0.05, "{kind:?} tight {p_tight}");
            assert!(p_loose > 0.95, "{kind:?} loose {p_loose}");
        }
    }

    #[test]
    fn predicted_cost_positive_and_sane() {
        let (m, pts, outs) = fitted_models(ModelKind::Gp, 24);
        for (p, o) in pts.iter().zip(&outs) {
            let c = m.predicted_cost(&encode(p));
            assert!(c > 0.0);
            // within an order of magnitude of the observation at obs points
            assert!(
                c / o.cost_usd < 10.0 && o.cost_usd / c < 10.0,
                "pred {c} vs obs {}",
                o.cost_usd
            );
        }
    }

    #[test]
    fn incumbent_prefers_feasible_high_accuracy() {
        let (m, _, _) = fitted_models(ModelKind::Trees, 30);
        let full_feats: Vec<Feat> = (0..288)
            .map(|id| {
                encode(&Point { config: Config::from_id(id), s_idx: 4 })
            })
            .collect();
        let caps = [Constraint::cost_max(0.06)];
        let inc = select_incumbent(&m, &caps, &full_feats);
        assert!(inc.config_id < 288);
        assert!(inc.pred_acc > 0.0 && inc.pred_acc <= 1.2);
        // with a loose cap, the incumbent must clear the 0.9 bar
        let loose = [Constraint::cost_max(1e9)];
        let inc2 = select_incumbent(&m, &loose, &full_feats);
        assert!(inc2.feas_prob >= 0.89, "{inc2:?}");
    }

    #[test]
    fn condition_with_acc_honors_the_lie() {
        let (m, pts, _) = fitted_models(ModelKind::Gp, 16);
        let x = encode(&pts[1]);
        let (mu, s1) = m.acc.predict(&x);
        // an optimistic lie must pull the local mean up, and still shrink
        // the local uncertainty like any conditioning does
        let lied = m.condition_with_acc(&x, mu + 0.5);
        let (mu2, s2) = lied.acc.predict(&x);
        assert!(mu2 > mu + 1e-6, "lie ignored: {mu} -> {mu2}");
        assert!(s2 <= s1 + 1e-9);
        // the predictive-mean lie is exactly `condition`
        let a = m.condition(&x);
        let b = m.condition_with_acc(&x, mu);
        let q = encode(&pts[2]);
        assert_eq!(a.acc.predict(&q), b.acc.predict(&q));
        assert_eq!(a.cost.predict(&q), b.cost.predict(&q));
    }

    #[test]
    fn condition_shifts_local_prediction() {
        let (m, pts, _) = fitted_models(ModelKind::Gp, 16);
        let x = encode(&pts[0]);
        let m2 = m.condition(&x);
        // conditioning on the model's own prediction must not move the mean
        let (a1, s1) = m.acc.predict(&x);
        let (a2, s2) = m2.acc.predict(&x);
        assert!((a1 - a2).abs() < 0.05, "{a1} vs {a2}");
        // but must reduce uncertainty there
        assert!(s2 <= s1 + 1e-9, "{s2} > {s1}");
        assert_eq!(m2.acc.n_obs(), m.acc.n_obs() + 1);
    }
}
