//! Figures 1–4 of the paper.

use super::aggregate::average_runs;
use super::ExpOptions;
use crate::engine::{self, EngineConfig, OptimizerKind, RunResult};
use crate::heuristics::FilterKind;
use crate::models::ModelKind;
use crate::sim::{Dataset, NetKind};
use crate::space::Constraint;
use crate::util::csv::CsvWriter;
use anyhow::Result;
use std::collections::HashMap;

/// (net name, optimizer name) -> per-seed runs.
pub type RunStore = HashMap<(String, String), Vec<RunResult>>;

pub const FIG1_OPTIMIZERS: [OptimizerKind; 6] = [
    OptimizerKind::TrimTuner(ModelKind::Trees),
    OptimizerKind::TrimTuner(ModelKind::Gp),
    OptimizerKind::Eic,
    OptimizerKind::EicUsd,
    OptimizerKind::Fabolas,
    OptimizerKind::RandomSearch,
];

/// Run `seeds` independent runs of each (net, optimizer) pair.
pub fn run_matrix(
    opts: &ExpOptions,
    nets: &[NetKind],
    optimizers: &[OptimizerKind],
) -> Result<RunStore> {
    let mut store = RunStore::new();
    for &net in nets {
        let dataset = Dataset::generate(net, opts.dataset_seed);
        let caps = [Constraint::cost_max(net.paper_cost_cap())];
        for &optimizer in optimizers {
            let t0 = std::time::Instant::now();
            let mut runs = Vec::with_capacity(opts.seeds);
            for seed in 0..opts.seeds {
                let mut cfg =
                    EngineConfig::paper_default(optimizer, seed as u64);
                cfg.max_iters = opts.max_iters;
                runs.push(engine::run(&dataset, &caps, &cfg));
            }
            eprintln!(
                "  [{}] {} x{} seeds: final Acc_C {:.4} (opt {:.4}), {:.1}s",
                net.name(),
                optimizer.name(),
                opts.seeds,
                crate::util::stats::mean(
                    &runs.iter().map(|r| r.final_accuracy_c()).collect::<Vec<_>>()
                ),
                runs[0].optimum_acc,
                t0.elapsed().as_secs_f64()
            );
            store.insert((net.name().into(), optimizer.name()), runs);
        }
    }
    Ok(store)
}

fn write_curves(
    path: &str,
    _store: &RunStore,
    net: NetKind,
    series: &[(String, &Vec<RunResult>)],
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &[
            "series", "cost_usd", "mean_accuracy_c", "std_accuracy_c",
            "main_phase_frac",
        ],
    )?;
    w.comment(&format!(
        "net={} cost cap=${}",
        net.name(),
        net.paper_cost_cap()
    ))?;
    for (name, runs) in series {
        for pt in average_runs(runs, 60) {
            w.row(&[
                name.clone(),
                format!("{:.6}", pt.cost),
                format!("{:.5}", pt.mean_accuracy_c),
                format!("{:.5}", pt.std_accuracy_c),
                format!("{:.3}", pt.main_phase_frac),
            ])?;
        }
    }
    w.flush()
}

/// Fig. 1: Accuracy_C vs optimization cost, per network × optimizer.
pub fn fig1(opts: &ExpOptions) -> Result<RunStore> {
    println!("== Fig 1: Accuracy_C vs optimization cost ==");
    let store = run_matrix(opts, &NetKind::ALL, &FIG1_OPTIMIZERS)?;
    for net in NetKind::ALL {
        let series: Vec<(String, &Vec<RunResult>)> = FIG1_OPTIMIZERS
            .iter()
            .map(|o| {
                let key = (net.name().to_string(), o.name());
                (o.name(), store.get(&key).unwrap())
            })
            .collect();
        write_curves(
            &format!("{}/fig1_{}.csv", opts.out_dir, net.name()),
            &store,
            net,
            &series,
        )?;
        // printed summary: final Accuracy_C and total cost per optimizer
        println!("  [{}]", net.name());
        for (name, runs) in &series {
            let finals: Vec<f64> =
                runs.iter().map(|r| r.final_accuracy_c()).collect();
            let costs: Vec<f64> =
                runs.iter().map(|r| r.total_cost()).collect();
            println!(
                "    {:<14} final Acc_C {:.4}±{:.4}  explore cost ${:.3}±{:.3}",
                name,
                crate::util::stats::mean(&finals),
                crate::util::stats::std_dev(&finals),
                crate::util::stats::mean(&costs),
                crate::util::stats::std_dev(&costs),
            );
        }
    }
    Ok(store)
}

/// Fig. 2: time (a) and cost (b) savings of TrimTuner (DT) vs EIc and
/// EIc/USD to reach >= 90% of the optimal feasible accuracy.
pub fn fig2(opts: &ExpOptions) -> Result<()> {
    let needed = [
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
    ];
    let store = run_matrix(opts, &NetKind::ALL, &needed)?;
    fig2_from(opts, &store)
}

pub fn fig2_from(opts: &ExpOptions, store: &RunStore) -> Result<()> {
    println!("== Fig 2: time & cost savings of TrimTuner(DT) at 90% of optimum ==");
    let mut w = CsvWriter::create(
        format!("{}/fig2.csv", opts.out_dir),
        &[
            "net", "baseline", "time_saving_x", "cost_saving_x",
            "tt_cost_usd", "baseline_cost_usd", "tt_time_s", "baseline_time_s",
        ],
    )?;
    for net in NetKind::ALL {
        let tt = reach_stats(store, net, "trimtuner-dt");
        for baseline in ["eic", "eic-usd"] {
            let bl = reach_stats(store, net, baseline);
            let (Some((tc, tt_s)), Some((bc, bt_s))) = (tt, bl) else {
                println!("  [{}] {baseline}: 90% never reached", net.name());
                continue;
            };
            let cost_x = bc / tc;
            let time_x = bt_s / tt_s;
            println!(
                "  [{}] vs {:<8} time saving {:>6.1}x  cost saving {:>6.1}x",
                net.name(),
                baseline,
                time_x,
                cost_x
            );
            w.row(&[
                net.name().to_string(),
                baseline.to_string(),
                format!("{time_x:.2}"),
                format!("{cost_x:.2}"),
                format!("{tc:.5}"),
                format!("{bc:.5}"),
                format!("{tt_s:.1}"),
                format!("{bt_s:.1}"),
            ])?;
        }
    }
    w.flush()
}

/// (cost, time) at which the *averaged* Accuracy_C curve stably reaches
/// 90% of the optimum — the quantity read off the paper's Fig. 1 plots.
fn reach_stats(
    store: &RunStore,
    net: NetKind,
    optimizer: &str,
) -> Option<(f64, f64)> {
    use super::aggregate::{budget_to_target, BudgetAxis};
    let runs = store.get(&(net.name().to_string(), optimizer.to_string()))?;
    let target = 0.90 * runs[0].optimum_acc;
    let cost = budget_to_target(runs, BudgetAxis::Cost, target)?;
    let time = budget_to_target(runs, BudgetAxis::Time, target)?;
    Some((cost, time))
}

/// Fig. 3: filtering-heuristic comparison (RNN, TrimTuner-GP, β = 10%).
pub fn fig3(opts: &ExpOptions) -> Result<()> {
    println!("== Fig 3: heuristics on RNN (TrimTuner-GP, beta=10%) ==");
    let dataset = Dataset::generate(NetKind::Rnn, opts.dataset_seed);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
    let filters = [
        FilterKind::Cea,
        FilterKind::Direct,
        FilterKind::Cmaes,
        FilterKind::RandomFilter,
    ];
    let mut store = RunStore::new();
    for filter in filters {
        let t0 = std::time::Instant::now();
        let mut runs = Vec::new();
        for seed in 0..opts.seeds {
            let mut cfg = EngineConfig::paper_default(
                OptimizerKind::TrimTuner(ModelKind::Gp),
                seed as u64,
            );
            cfg.filter = filter;
            cfg.max_iters = opts.max_iters;
            runs.push(engine::run(&dataset, &caps, &cfg));
        }
        let finals: Vec<f64> =
            runs.iter().map(|r| r.final_accuracy_c()).collect();
        let reach: Vec<Option<(f64, f64)>> =
            runs.iter().map(|r| crate::engine::cost_to_quality(r, 0.90)).collect();
        let reach_cost = if reach.iter().all(|r| r.is_some()) {
            format!(
                "{:.4}",
                crate::util::stats::mean(
                    &reach.iter().map(|r| r.unwrap().0).collect::<Vec<_>>()
                )
            )
        } else {
            "n/a".to_string()
        };
        println!(
            "  {:<8} final Acc_C {:.4}  cost to 90% ${}  ({:.1}s)",
            filter.name(),
            crate::util::stats::mean(&finals),
            reach_cost,
            t0.elapsed().as_secs_f64()
        );
        store.insert(("rnn".into(), filter.name().into()), runs);
    }
    let series: Vec<(String, &Vec<RunResult>)> = filters
        .iter()
        .map(|f| {
            (
                f.name().to_string(),
                store.get(&("rnn".to_string(), f.name().to_string())).unwrap(),
            )
        })
        .collect();
    write_curves(
        &format!("{}/fig3.csv", opts.out_dir),
        &store,
        NetKind::Rnn,
        &series,
    )
}

/// Fig. 4: sensitivity to the CEA filtering level β (RNN, TrimTuner-DT).
pub fn fig4(opts: &ExpOptions) -> Result<()> {
    println!("== Fig 4: beta sensitivity (RNN, TrimTuner-DT, CEA) ==");
    let dataset = Dataset::generate(NetKind::Rnn, opts.dataset_seed);
    let caps = [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
    let betas: [(f64, &str); 4] =
        [(0.01, "1%"), (0.10, "10%"), (0.20, "20%"), (1.0, "nofilter")];
    let mut store = RunStore::new();
    for (beta, label) in betas {
        let mut runs = Vec::new();
        for seed in 0..opts.seeds {
            let mut cfg = EngineConfig::paper_default(
                OptimizerKind::TrimTuner(ModelKind::Trees),
                seed as u64,
            );
            cfg.beta = beta;
            cfg.filter = if beta >= 1.0 {
                FilterKind::NoFilter
            } else {
                FilterKind::Cea
            };
            cfg.max_iters = opts.max_iters;
            runs.push(engine::run(&dataset, &caps, &cfg));
        }
        let finals: Vec<f64> =
            runs.iter().map(|r| r.final_accuracy_c()).collect();
        println!(
            "  beta {:<9} final Acc_C {:.4}±{:.4}",
            label,
            crate::util::stats::mean(&finals),
            crate::util::stats::std_dev(&finals)
        );
        store.insert(("rnn".into(), label.into()), runs);
    }
    let series: Vec<(String, &Vec<RunResult>)> = betas
        .iter()
        .map(|(_, label)| {
            (
                label.to_string(),
                store
                    .get(&("rnn".to_string(), label.to_string()))
                    .unwrap(),
            )
        })
        .collect();
    write_curves(
        &format!("{}/fig4.csv", opts.out_dir),
        &store,
        NetKind::Rnn,
        &series,
    )
}
