//! The Bayesian-optimization engine: paper Algorithm 1 plus all baseline
//! optimizers, replaying a measured [`Dataset`] exactly like the paper's
//! trace-driven evaluation.

mod loop_;
mod metrics;
mod pareto;
mod stop;

pub use loop_::{run, EngineConfig, OptimizerKind};
pub use metrics::{accuracy_c, cost_to_quality, IterRecord, RunResult};
pub use pareto::{pareto_front, recommend_pareto, ParetoPoint};
pub use stop::StopCondition;
