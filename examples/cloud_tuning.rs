//! Multi-constraint cloud tuning through the threaded coordinator.
//!
//! This example exercises the systems layer the way the paper's intro
//! motivates: a user wants the most accurate model trainable under BOTH a
//! cost cap and a wall-clock deadline, and job deployments go through the
//! coordinator's worker pool (with snapshot semantics for sub-sampled
//! probes) rather than a pre-materialized lookup table.
//!
//! Run with: `cargo run --release --offline --example cloud_tuning`

use trimtuner::coordinator::{Job, JobLauncher, SimLauncher, WorkerPool};
use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::{Config, Constraint, S_INIT};
use trimtuner::util::Rng;

fn main() -> anyhow::Result<()> {
    let net = NetKind::Cnn;

    // ---- phase 1: parallel pre-exploration through the coordinator ------
    // Deploy a small batch of random snapshot jobs on 4 workers to warm the
    // models before the sequential BO loop (a natural TrimTuner extension).
    let launcher = SimLauncher::new(net, 11);
    let pool = WorkerPool::new(Box::new(launcher), 4);
    let mut rng = Rng::new(11);
    let n_jobs = 6;
    for i in 0..n_jobs {
        pool.submit(Job {
            id: i,
            config: Config::from_id(rng.below(288)),
            s_levels: S_INIT.to_vec(),
        })?;
    }
    let mut warm_cost = 0.0;
    let mut snapshots = 0;
    for _ in 0..n_jobs {
        let r = pool.recv()?;
        warm_cost += r.charged_cost;
        snapshots += r.outcomes.len();
    }
    pool.shutdown();
    println!(
        "warm-up: {n_jobs} snapshot jobs ({snapshots} observations) for ${warm_cost:.4}"
    );

    // ---- phase 2: constrained optimization ------------------------------
    // Two QoS constraints: cost <= $0.10 AND training time <= 12 minutes.
    let constraints = vec![
        Constraint::cost_max(0.10),
        Constraint::time_max(12.0 * 60.0),
    ];
    let dataset = Dataset::generate(net, 42);
    let mut cfg = EngineConfig::paper_default(
        OptimizerKind::TrimTuner(ModelKind::Trees),
        3,
    );
    cfg.max_iters = 30;
    let run = engine::run(&dataset, &constraints, &cfg);

    let last = run.records.last().unwrap();
    let out = dataset.outcome(&last.incumbent);
    println!("constraints: {}", constraints[0].describe());
    println!("             {}", constraints[1].describe());
    println!("recommended: {}", last.incumbent.config.describe());
    println!(
        "   accuracy {:.4} | cost ${:.4} | time {:.0}s | feasible: {}",
        out.acc, out.cost_usd, out.time_s, last.inc_feasible
    );
    println!(
        "   Accuracy_C {:.4} vs optimum {:.4} | exploration spend ${:.4}",
        last.accuracy_c,
        run.optimum_acc,
        run.total_cost()
    );

    // sanity for CI-style usage
    assert!(run.optimum_acc.is_finite());
    assert!(last.accuracy_c > 0.7 * run.optimum_acc);

    // also report what the unconstrained-accuracy pick would have violated
    let launcher = SimLauncher::new(net, 99);
    let naive = Job { id: 999, config: Config::from_id(0), s_levels: vec![4] };
    let r = launcher.launch(&naive)?;
    println!(
        "naive full-test of config 0 would have cost ${:.4} ({} snapshot[s])",
        r.charged_cost,
        r.outcomes.len()
    );
    Ok(())
}
