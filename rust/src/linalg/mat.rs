//! Row-major dense matrix of `f64`.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from an existing row-major buffer (must be rows × cols long).
    pub fn from_flat(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "flat buffer size mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// Matrix product (naive; matrices here are <= a few hundred square).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Append a row (grows the matrix; used by incremental GP refits).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(
                f,
                "  {:?}",
                &self.row(i)[..self.cols.min(8)]
            )?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[2.0, 1.0]);
        assert_eq!(c.row(1), &[4.0, 3.0]);
    }

    #[test]
    fn transpose_and_push_row() {
        let mut a = Mat::from_rows(&[vec![1.0, 2.0, 3.0]]);
        a.push_row(&[4.0, 5.0, 6.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
    }
}
