//! Loom interleaving models of the `WorkerPool` shutdown protocol
//! (`rust/src/coordinator/pool.rs::close`) over the shared bounded channel
//! in `rust/src/coordinator/sync.rs` — included below by `#[path]`, so the
//! model can never drift from the production shim's source.
//!
//! What loom buys over the timing-based regression tests in `pool.rs`:
//! those tests catch the deadlock only when the scheduler happens to park
//! a worker in `send` at the wrong moment; loom *enumerates* the
//! interleavings, so both directions are checked exhaustively —
//! the fixed ordering (receiver released before join) terminates on every
//! schedule, and the pre-fix ordering (join with the receiver live) is
//! positively shown to deadlock rather than merely suspected to.
//!
//! Run with: `RUSTFLAGS="--cfg loom" cargo test --release` from this
//! crate's directory (the scheduled CI job does exactly that; detlint's
//! R5 is the static half of the same contract).
#![allow(unknown_lints)]
#![allow(unexpected_cfgs)]
#![cfg(loom)]

#[path = "../../../rust/src/coordinator/sync.rs"]
mod csync;

use csync::queue::bounded;
use loom::thread;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// The fixed `close()` ordering: drop the result receiver *before*
/// joining. The worker may be parked in `send` on the full (capacity-1)
/// result channel at that moment; every interleaving must terminate.
#[test]
fn shutdown_drops_receiver_before_join() {
    loom::model(|| {
        let (tx, rx) = bounded::<u32>(1);
        let h = thread::spawn(move || {
            // worker: push results until shutdown disconnects the channel
            let mut sent = 0u32;
            while tx.send(sent).is_ok() {
                sent += 1;
                if sent > 2 {
                    break; // bound the model's state space
                }
            }
        });
        drop(rx); // release the receiver first ...
        h.join().unwrap(); // ... then join: terminates on every schedule
    });
}

/// The pre-fix ordering join-deadlocks: with the receiver still live and
/// the capacity-1 result channel full, the worker is parked in `send`
/// waiting for a `recv` that never comes while `join` waits for the
/// worker. Loom detects the cycle and panics; the catch_unwind asserts
/// that at least one interleaving really does deadlock — this is the
/// dynamic proof behind detlint rule R5 and PR 2's fix.
#[test]
fn join_with_live_receiver_deadlocks() {
    let result = catch_unwind(AssertUnwindSafe(|| {
        loom::model(|| {
            let (tx, rx) = bounded::<u32>(1);
            let h = thread::spawn(move || {
                let _ = tx.send(1);
                let _ = tx.send(2); // blocks: capacity 1, nobody receiving
            });
            h.join().unwrap(); // joins while `rx` is still alive
            drop(rx);
        });
    }));
    assert!(
        result.is_err(),
        "expected loom to detect the join/send deadlock in the pre-fix \
         ordering, but every interleaving terminated"
    );
}

/// The full pool shape at model scale: a submit channel feeding a worker
/// loop that forwards into a capacity-1 result channel, shut down exactly
/// like `WorkerPool::close` (submit sender taken, receiver released, then
/// join) with jobs still in flight.
#[test]
fn pool_loop_shutdown_with_full_result_channel() {
    loom::model(|| {
        let (submit_tx, submit_rx) = bounded::<u32>(2);
        let (result_tx, result_rx) = bounded::<u32>(1);
        let h = thread::spawn(move || {
            // the worker loop from pool.rs: recv until the submit queue
            // closes, forward until the result receiver disappears
            while let Ok(job) = submit_rx.recv() {
                if result_tx.send(job).is_err() {
                    break;
                }
            }
        });
        submit_tx.send(7).unwrap();
        submit_tx.send(8).unwrap();
        // close() ordering: submit queue first, then the result receiver,
        // then the join — with both jobs potentially still in flight
        drop(submit_tx);
        drop(result_rx);
        h.join().unwrap();
    });
}

/// Receiver-side semantics the engine's submission-order draining relies
/// on: after the sender is gone, buffered values still drain in FIFO
/// order before the disconnect error surfaces.
#[test]
fn receiver_drains_fifo_then_disconnects() {
    loom::model(|| {
        let (tx, rx) = bounded::<u32>(2);
        let h = thread::spawn(move || {
            tx.send(1).unwrap();
            tx.send(2).unwrap();
        });
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        h.join().unwrap();
        assert!(rx.recv().is_err());
    });
}

/// The non-blocking drain behind the async engine's reorder buffer
/// (`WorkerPool::try_recv` → `queue::Receiver::try_recv`): on every
/// interleaving, `try_recv` never blocks, `Empty` only means "nothing
/// buffered while a sender is alive", a successful pop frees a sender
/// parked on the full capacity-1 channel, and after the sender is gone
/// the buffered tail still drains before `Disconnected` surfaces.
#[test]
fn try_recv_never_blocks_and_drains_before_disconnect() {
    use csync::queue::TryRecvError;
    loom::model(|| {
        let (tx, rx) = bounded::<u32>(1);
        let h = thread::spawn(move || {
            tx.send(1).unwrap();
            tx.send(2).unwrap(); // may park until the first pop frees a slot
        });
        // Non-blocking probe: on every schedule this returns immediately
        // with Ok(1) or Empty — a live sender must never surface as
        // Disconnected. (Both branches are reached across interleavings.)
        let first = match rx.try_recv() {
            Ok(v) => v,
            Err(TryRecvError::Empty) => rx.recv().unwrap(),
            Err(TryRecvError::Disconnected) => {
                panic!("live sender reported as disconnected")
            }
        };
        assert_eq!(first, 1);
        // Popping 1 freed the capacity-1 slot (try_recv notifies the
        // cond), so the parked second send lands and the sender exits —
        // the join terminates on every schedule.
        h.join().unwrap();
        // Sender gone with a value still buffered: the tail drains first,
        // Disconnected surfaces only once the buffer is empty.
        assert_eq!(rx.try_recv().unwrap(), 2);
        assert!(matches!(
            rx.try_recv(),
            Err(TryRecvError::Disconnected)
        ));
    });
}
