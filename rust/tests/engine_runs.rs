//! Integration tests: full Algorithm-1 runs over the measurement campaigns.

use trimtuner::engine::{self, EngineConfig, OptimizerKind};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

#[test]
fn trimtuner_dt_reaches_90pct_on_every_network() {
    for net in NetKind::ALL {
        let dataset = Dataset::generate(net, 42);
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            1,
        );
        cfg.max_iters = 30;
        let run = engine::run(&dataset, &caps(net), &cfg);
        let best = run
            .records
            .iter()
            .map(|r| r.accuracy_c)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(
            best >= 0.90 * run.optimum_acc,
            "{net:?}: best Accuracy_C {best:.4} < 90% of {:.4}",
            run.optimum_acc
        );
    }
}

#[test]
fn runs_are_deterministic_per_seed() {
    let dataset = Dataset::generate(NetKind::Rnn, 42);
    let mk = |seed| {
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            seed,
        );
        cfg.max_iters = 6;
        engine::run(&dataset, &caps(NetKind::Rnn), &cfg)
    };
    let (a, b, c) = (mk(5), mk(5), mk(6));
    assert_eq!(a.records.len(), b.records.len());
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id());
        assert_eq!(ra.accuracy_c, rb.accuracy_c);
    }
    // a different seed must explore differently
    let same = a
        .records
        .iter()
        .zip(&c.records)
        .all(|(x, y)| x.tested.id() == y.tested.id());
    assert!(!same, "seeds 5 and 6 produced identical runs");
}

#[test]
fn baselines_test_only_full_configs_and_trimtuner_subsamples() {
    let dataset = Dataset::generate(NetKind::Mlp, 42);
    let mut cfg = EngineConfig::paper_default(OptimizerKind::Eic, 2);
    cfg.max_iters = 8;
    let run = engine::run(&dataset, &caps(NetKind::Mlp), &cfg);
    assert!(run.records.iter().all(|r| r.tested.is_full()));

    let mut cfg = EngineConfig::paper_default(
        OptimizerKind::TrimTuner(ModelKind::Trees),
        2,
    );
    cfg.max_iters = 12;
    let run = engine::run(&dataset, &caps(NetKind::Mlp), &cfg);
    let sub = run.records.iter().filter(|r| !r.tested.is_full()).count();
    assert!(
        sub * 2 > run.records.len(),
        "TrimTuner barely sub-sampled: {sub}/{}",
        run.records.len()
    );
}

#[test]
fn engine_accounting_invariants() {
    let dataset = Dataset::generate(NetKind::Rnn, 42);
    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Eic,
        OptimizerKind::EicUsd,
        OptimizerKind::Fabolas,
        OptimizerKind::RandomSearch,
    ] {
        let mut cfg = EngineConfig::paper_default(optimizer, 3);
        cfg.max_iters = 6;
        let run = engine::run(&dataset, &caps(NetKind::Rnn), &cfg);
        let mut last_cost = 0.0;
        let mut seen = std::collections::HashSet::new();
        for r in &run.records {
            assert!(r.cum_cost >= last_cost - 1e-12, "{optimizer:?}: cost regressed");
            last_cost = r.cum_cost;
            assert!(r.explore_cost >= 0.0);
            assert!(r.incumbent.is_full(), "{optimizer:?}: incumbent not full");
            assert!((0.0..=1.0).contains(&r.accuracy_c));
            assert!(seen.insert(r.tested.id()), "{optimizer:?}: retested a point");
        }
        assert_eq!(run.records.len(), 4 + 6, "{optimizer:?}: record count");
    }
}

#[test]
fn trimtuner_cheaper_than_eic_at_same_iteration_count() {
    // The paper's core claim in miniature: same number of probes, far less
    // exploration spend thanks to sub-sampling.
    let dataset = Dataset::generate(NetKind::Cnn, 42);
    let caps = caps(NetKind::Cnn);
    let mut tt_cost = 0.0;
    let mut eic_cost = 0.0;
    for seed in 0..3 {
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            seed,
        );
        cfg.max_iters = 15;
        tt_cost += engine::run(&dataset, &caps, &cfg).total_cost();
        let mut cfg = EngineConfig::paper_default(OptimizerKind::Eic, seed);
        cfg.max_iters = 15;
        eic_cost += engine::run(&dataset, &caps, &cfg).total_cost();
    }
    assert!(
        tt_cost * 2.0 < eic_cost,
        "sub-sampling saved too little: TrimTuner ${tt_cost:.3} vs EIc ${eic_cost:.3}"
    );
}

#[test]
fn random_search_is_dominated_on_average() {
    // best-ever Accuracy_C over the run, averaged across seeds: random can
    // get lucky on single seeds, so allow a small tolerance.
    let dataset = Dataset::generate(NetKind::Cnn, 42);
    let caps = caps(NetKind::Cnn);
    let best_of = |run: &trimtuner::engine::RunResult| {
        run.records
            .iter()
            .map(|r| r.accuracy_c)
            .fold(f64::NEG_INFINITY, f64::max)
    };
    let mut tt = 0.0;
    let mut rnd = 0.0;
    for seed in 0..4 {
        let mut cfg = EngineConfig::paper_default(
            OptimizerKind::TrimTuner(ModelKind::Trees),
            seed,
        );
        cfg.max_iters = 30;
        tt += best_of(&engine::run(&dataset, &caps, &cfg));
        let mut cfg =
            EngineConfig::paper_default(OptimizerKind::RandomSearch, seed);
        cfg.max_iters = 30;
        rnd += best_of(&engine::run(&dataset, &caps, &cfg));
    }
    assert!(
        tt >= rnd - 0.1,
        "TrimTuner {tt:.3} clearly worse than random {rnd:.3}"
    );
}
