//! TrimTuner's acquisition function α_T (paper Eq. 5): FABOLAS's
//! information-gain-per-dollar, additionally weighted by the probability
//! that the incumbent recommended *after* the simulated observation
//! satisfies every QoS constraint.

use super::entropy::{EntropyEstimator, EntropyScratch};
use super::models::{
    incumbent_scan, joint_feasibility_many, select_incumbent_over,
    select_incumbent_over_with_feas, Models,
};
use crate::models::{
    FantasyScratch, FantasySurface, FantasyView, Feat, PrimedSlate,
};
use crate::space::{encode, Constraint, Point};
use crate::util::stats::normal_cdf;

/// Precomputed per-iteration context for evaluating α_T on many candidates.
pub struct TrimTunerAcq<'a> {
    pub models: &'a Models,
    pub est: &'a EntropyEstimator,
    pub constraints: &'a [Constraint],
    /// CEA-ranked shortlist of config ids scanned for the simulated
    /// incumbent (perf: O(shortlist) instead of O(288 configs) per
    /// candidate)
    pub inc_shortlist: &'a [usize],
    /// `encode(config at s=1)` for each shortlist id, gathered once per
    /// iteration so the per-candidate incumbent scan allocates nothing
    pub inc_shortlist_feats: &'a [Feat],
    /// Joint feasibility of each shortlist entry under the *current*
    /// models, precomputed once per iteration by the engine. Only valid
    /// when conditioning leaves the constraint models untouched
    /// ([`Models::constraints_fixed_under_condition`] — tree surrogates);
    /// `None` recomputes per candidate (GPs, whose conditioning shifts the
    /// cost/time posteriors).
    pub inc_feas: Option<&'a [f64]>,
    /// KL(p_opt ‖ u) of the current accuracy model
    pub baseline: f64,
}

/// α_T(x, s) following the paper's simulation recipe (§III, steps 1–4):
///
/// 1. extend every surrogate with the predicted outcome at (x, s)
///    (single-root Gauss–Hermite collapse of the outer expectation);
/// 2. re-select the incumbent x* under the updated models;
/// 3. weight by Π_i P(q_i(x*, s=1) ≥ 0 | updated models);
/// 4. multiply by the information gain on p_opt and divide by the
///    predicted cost C(x, s) of the probe.
pub fn trimtuner_alpha(ctx: &TrimTunerAcq<'_>, x: &Feat) -> f64 {
    // 1. simulate testing (x, s)
    let updated = ctx.models.condition(x);
    // 2. incumbent under updated models (shortlist scan; the precomputed
    //    per-iteration feasibility is used when conditioning cannot move it)
    let inc = match ctx.inc_feas {
        Some(feas) => select_incumbent_over_with_feas(
            &updated,
            ctx.inc_shortlist,
            ctx.inc_shortlist_feats,
            feas,
        ),
        None => select_incumbent_over(
            &updated,
            ctx.constraints,
            ctx.inc_shortlist,
            ctx.inc_shortlist_feats,
        ),
    };
    // 3. probability the new incumbent is actually feasible — already
    //    computed by the shortlist scan for exactly this config
    let p_feas = inc.feas_prob;
    // 4. information gain per dollar
    let gain = ctx.est.info_gain(updated.acc.as_ref(), ctx.baseline);
    p_feas * gain / ctx.models.predicted_cost(x)
}

/// Which α_T evaluation strategy the slate evaluator uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlphaMode {
    /// Shared per-iteration fantasy posteriors + rank-one conditioning per
    /// candidate (the default).
    Fantasy,
    /// Per-candidate `Models::condition` clone-and-extend — the reference
    /// path [`trimtuner_alpha`] implements.
    Clone,
}

impl AlphaMode {
    /// `TRIMTUNER_ALPHA=clone` is the escape hatch back to per-candidate
    /// clone-conditioning; anything else (or unset) is the fantasy path.
    pub fn from_env() -> AlphaMode {
        match std::env::var("TRIMTUNER_ALPHA") {
            Ok(v) if v.eq_ignore_ascii_case("clone") => AlphaMode::Clone,
            _ => AlphaMode::Fantasy,
        }
    }
}

/// Per-iteration slate evaluator for α_T.
///
/// Construction performs all work that is shared across the whole
/// candidate slate once: the fused query grid Q = representer set ∪
/// incumbent shortlist, one [`FantasySurface`] per conditioned surrogate
/// (joint posterior + cross-solve matrices), and — when conditioning
/// cannot move the constraint models — the shortlist feasibility. Each
/// candidate then costs one O(n·|Q| + m²) rank-one view instead of a
/// surrogate clone, a shortlist re-prediction and an O(m³) representer
/// covariance refactorization. Evaluation shards candidates across
/// `std::thread::scope` workers with order-independent, bit-stable
/// results (the CRN z-matrix is fixed per iteration).
///
/// Parity with mapping [`trimtuner_alpha`]: bit-exact for tree
/// surrogates, within 1e-9 relative for GPs (hyper-sample mixtures
/// included) — see `tests/alpha_parity.rs`.
pub struct AlphaSlate<'a> {
    ctx: &'a TrimTunerAcq<'a>,
    mode: AlphaMode,
    threads: usize,
    /// conditioned-accuracy surface over reps ++ shortlist (fantasy mode)
    acc: Option<Box<dyn FantasySurface>>,
    /// conditioned constraint-metric surfaces over the shortlist, one per
    /// constraint — built only when conditioning moves the constraint
    /// models (GPs)
    metrics: Vec<Box<dyn FantasySurface>>,
    /// owned shortlist feasibility when conditioning cannot move it and
    /// the engine did not precompute `ctx.inc_feas`
    fixed_feas: Option<Vec<f64>>,
}

impl<'a> AlphaSlate<'a> {
    /// Build the per-iteration evaluator, honoring `TRIMTUNER_ALPHA`.
    pub fn new(ctx: &'a TrimTunerAcq<'a>) -> AlphaSlate<'a> {
        AlphaSlate::with_mode(ctx, AlphaMode::from_env())
    }

    pub fn with_mode(
        ctx: &'a TrimTunerAcq<'a>,
        mode: AlphaMode,
    ) -> AlphaSlate<'a> {
        let mut slate = AlphaSlate {
            ctx,
            mode,
            threads: crate::util::slate_threads(),
            acc: None,
            metrics: Vec::new(),
            fixed_feas: None,
        };
        if mode == AlphaMode::Clone {
            return slate;
        }
        // fused query grid: representer set first (the joint prefix p_opt
        // samples over), then the incumbent shortlist
        let m = ctx.est.rep_feats.len();
        let mut grid: Vec<Feat> =
            Vec::with_capacity(m + ctx.inc_shortlist_feats.len());
        grid.extend_from_slice(&ctx.est.rep_feats);
        grid.extend_from_slice(ctx.inc_shortlist_feats);
        slate.acc = Some(ctx.models.acc.fantasy_surface(&grid, m));
        if ctx.models.constraints_fixed_under_condition() {
            if ctx.inc_feas.is_none() {
                slate.fixed_feas = Some(joint_feasibility_many(
                    ctx.models,
                    ctx.constraints,
                    ctx.inc_shortlist_feats,
                ));
            }
        } else {
            slate.metrics = ctx
                .constraints
                .iter()
                .map(|c| {
                    ctx.models
                        .metric_model(c.metric)
                        .fantasy_surface(ctx.inc_shortlist_feats, 0)
                })
                .collect();
        }
        slate
    }

    /// Override the worker count (1 forces sequential evaluation).
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// α_T for every candidate of the slate, in slate order. Bit-stable
    /// for any worker count.
    ///
    /// The fantasy path is slate-batched top to bottom: the candidates'
    /// probe costs come from one batched cost prediction, and every
    /// fantasy surface is primed for the whole slate up front
    /// ([`FantasySurface::prime`] — for GPs one multi-RHS `w = L⁻¹k(X, x)`
    /// solve per hyper-sample replaces a triangular solve per candidate).
    /// Per-worker scratch buffers are reused across candidates, so the
    /// per-candidate sweep allocates only its output.
    pub fn eval_feats(&self, xs: &[Feat]) -> Vec<f64> {
        if xs.is_empty() {
            return Vec::new();
        }
        match self.mode {
            AlphaMode::Clone => crate::util::shard_map(xs, self.threads, |x| {
                trimtuner_alpha(self.ctx, x)
            }),
            AlphaMode::Fantasy => {
                let acc = self.acc.as_ref().expect("fantasy surfaces built");
                let acc_primed = acc.prime(xs);
                let metric_primed: Vec<Box<dyn PrimedSlate + '_>> =
                    self.metrics.iter().map(|m| m.prime(xs)).collect();
                let costs = self.ctx.models.predicted_cost_many(xs);
                let idx: Vec<usize> = (0..xs.len()).collect();
                crate::util::shard_map_with(
                    &idx,
                    self.threads,
                    SweepScratch::default,
                    |scratch, &i| {
                        self.eval_primed(
                            i,
                            &*acc_primed,
                            &metric_primed,
                            costs[i],
                            scratch,
                        )
                    },
                )
            }
        }
    }

    /// [`AlphaSlate::eval_feats`] over grid points.
    pub fn eval_points(&self, pts: &[Point]) -> Vec<f64> {
        let xs: Vec<Feat> = pts.iter().map(encode).collect();
        self.eval_feats(&xs)
    }

    /// α_T of one candidate under the configured mode (a one-candidate
    /// slate: single-column priming is bit-identical to the scalar path).
    pub fn eval_one(&self, x: &Feat) -> f64 {
        match self.mode {
            AlphaMode::Clone => trimtuner_alpha(self.ctx, x),
            AlphaMode::Fantasy => self.eval_feats(std::slice::from_ref(x))[0],
        }
    }

    // detlint: hot
    fn eval_primed(
        &self,
        i: usize,
        acc_primed: &dyn PrimedSlate,
        metric_primed: &[Box<dyn PrimedSlate + '_>],
        cost: f64,
        scratch: &mut SweepScratch,
    ) -> f64 {
        let ctx = self.ctx;
        let m = ctx.est.rep_feats.len();
        // two persistent view buffers: the accuracy view outlives the
        // per-constraint metric views it is compared against
        let SweepScratch { fantasy, entropy, feas, acc_view, metric_view } =
            scratch;
        acc_primed.view_into(i, fantasy, acc_view);
        // steps 2-3: incumbent under the conditioned models, and its
        // feasibility — conditioned accuracy comes from the shortlist
        // suffix of the fused grid
        let accs = &acc_view.grid[m..];
        let inc = match ctx.inc_feas.or(self.fixed_feas.as_deref()) {
            Some(feas) => incumbent_scan(ctx.inc_shortlist, feas, accs),
            None => {
                feas.clear();
                feas.resize(ctx.inc_shortlist.len(), 1.0);
                for (c, surf) in ctx.constraints.iter().zip(metric_primed) {
                    surf.view_into(i, fantasy, metric_view);
                    let lim = c.max.max(1e-12).ln();
                    for (f, &(mu, std)) in
                        feas.iter_mut().zip(&metric_view.grid)
                    {
                        *f *= normal_cdf((lim - mu) / std.max(1e-9));
                    }
                }
                incumbent_scan(ctx.inc_shortlist, feas, accs)
            }
        };
        // step 4: information gain per dollar, from the conditioned joint
        // posterior over the representer prefix
        let joint = acc_view.joint.as_ref().expect("joint prefix present");
        let gain = ctx.est.info_gain_from_with(joint, ctx.baseline, entropy);
        inc.feas_prob * gain / cost
    }
}

/// Per-worker scratch for one slate sweep: fantasy-view buffers, p_opt
/// Monte-Carlo buffers, the conditioned shortlist feasibility, and two
/// reusable fantasy-view output slots (accuracy + per-constraint metric).
#[derive(Default)]
struct SweepScratch {
    fantasy: FantasyScratch,
    entropy: EntropyScratch,
    feas: Vec<f64>,
    acc_view: FantasyView,
    metric_view: FantasyView,
}

/// Batched α_T over a candidate slate: one shared per-iteration
/// precomputation, then a rank-one fantasy view per candidate (honors the
/// `TRIMTUNER_ALPHA=clone` escape hatch). Equal to mapping
/// [`trimtuner_alpha`] over the slate — bit-exact for tree surrogates,
/// within 1e-9 relative for GPs.
pub fn alpha_slate(ctx: &TrimTunerAcq<'_>, slate: &[Point]) -> Vec<f64> {
    AlphaSlate::new(ctx).eval_points(slate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{encode, Config, Point};
    use crate::util::Rng;

    struct Fixture {
        models: Models,
        est: EntropyEstimator,
        shortlist: Vec<usize>,
        shortlist_feats: Vec<Feat>,
        constraints: Vec<Constraint>,
        baseline: f64,
    }

    fn setup(kind: ModelKind, cap: f64) -> Fixture {
        let sim = CloudSim::new(NetKind::Rnn);
        let mut rng = Rng::new(21);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..20 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut models = Models::new(kind, 9);
        models.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
        let full_feats: Vec<Feat> = (0..288)
            .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
            .collect();
        let rep: Vec<Feat> =
            (0..20).map(|i| full_feats[i * 14]).collect();
        let est = EntropyEstimator::new(rep, 150, &mut rng);
        let baseline =
            EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));
        let constraints = vec![Constraint::cost_max(cap)];
        let shortlist: Vec<usize> = (0..288).step_by(4).collect();
        let shortlist_feats: Vec<Feat> =
            shortlist.iter().map(|&id| full_feats[id]).collect();
        Fixture {
            models,
            est,
            shortlist,
            shortlist_feats,
            constraints,
            baseline,
        }
    }

    fn ctx(f: &Fixture) -> TrimTunerAcq<'_> {
        TrimTunerAcq {
            models: &f.models,
            est: &f.est,
            constraints: &f.constraints,
            inc_shortlist: &f.shortlist,
            inc_shortlist_feats: &f.shortlist_feats,
            inc_feas: None,
            baseline: f.baseline,
        }
    }

    #[test]
    fn alpha_nonnegative_finite_both_model_kinds() {
        for kind in [ModelKind::Gp, ModelKind::Trees] {
            let f = setup(kind, 0.02);
            let c = ctx(&f);
            let mut rng = Rng::new(31);
            for _ in 0..8 {
                let p = Point {
                    config: Config::from_id(rng.below(288)),
                    s_idx: rng.below(5),
                };
                let a = trimtuner_alpha(&c, &encode(&p));
                assert!(a.is_finite() && a >= 0.0, "{kind:?}: {a}");
            }
        }
    }

    #[test]
    fn impossible_constraints_crush_alpha() {
        // With an impossible cap the feasibility factor should push α_T
        // towards zero relative to a loose cap, point-by-point.
        let f_loose = setup(ModelKind::Gp, 1e9);
        let f_tight = Fixture {
            constraints: vec![Constraint::cost_max(1e-9)],
            ..setup(ModelKind::Gp, 1e9)
        };
        let (cl, ct) = (ctx(&f_loose), ctx(&f_tight));
        let mut rng = Rng::new(41);
        let mut sum_loose = 0.0;
        let mut sum_tight = 0.0;
        for _ in 0..10 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            let x = encode(&p);
            sum_loose += trimtuner_alpha(&cl, &x);
            sum_tight += trimtuner_alpha(&ct, &x);
        }
        assert!(
            sum_tight < 0.05 * sum_loose + 1e-12,
            "tight {sum_tight} vs loose {sum_loose}"
        );
    }

    #[test]
    fn alpha_is_deterministic() {
        let f = setup(ModelKind::Gp, 0.02);
        let c = ctx(&f);
        let x = encode(&Point { config: Config::from_id(33), s_idx: 1 });
        assert_eq!(trimtuner_alpha(&c, &x), trimtuner_alpha(&c, &x));
    }

    fn mixed_slate(seed: u64, n: usize) -> Vec<Point> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            })
            .collect()
    }

    #[test]
    fn alpha_slate_bit_identical_to_per_candidate_for_trees() {
        let f = setup(ModelKind::Trees, 0.02);
        // both engine configurations: precomputed shortlist feasibility
        // (the engine's trees path) and the recompute-inside variant
        let feas = crate::acq::joint_feasibility_many(
            &f.models,
            &f.constraints,
            &f.shortlist_feats,
        );
        for with_feas in [false, true] {
            let c = TrimTunerAcq {
                inc_feas: with_feas.then_some(feas.as_slice()),
                ..ctx(&f)
            };
            let slate = mixed_slate(61, 12);
            // pin the fantasy path: an ambient TRIMTUNER_ALPHA=clone must
            // not turn this into a clone-vs-clone no-op
            let batch = AlphaSlate::with_mode(&c, AlphaMode::Fantasy)
                .eval_points(&slate);
            for (p, b) in slate.iter().zip(&batch) {
                let a = trimtuner_alpha(&c, &encode(p));
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "with_feas={with_feas}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn alpha_slate_matches_per_candidate_for_gp_within_1e9() {
        let f = setup(ModelKind::Gp, 0.02);
        let c = ctx(&f);
        let slate = mixed_slate(71, 10);
        let batch = AlphaSlate::with_mode(&c, AlphaMode::Fantasy)
            .eval_points(&slate);
        for (p, b) in slate.iter().zip(&batch) {
            let a = trimtuner_alpha(&c, &encode(p));
            assert!(
                (a - b).abs() <= 1e-9 * a.abs().max(1e-12),
                "fantasy {b} vs clone {a}"
            );
        }
    }

    #[test]
    fn clone_mode_escape_hatch_is_bitwise_reference() {
        for kind in [ModelKind::Gp, ModelKind::Trees] {
            let f = setup(kind, 0.02);
            let c = ctx(&f);
            let slate = mixed_slate(81, 8);
            let evals = AlphaSlate::with_mode(&c, AlphaMode::Clone)
                .eval_points(&slate);
            for (p, b) in slate.iter().zip(&evals) {
                let a = trimtuner_alpha(&c, &encode(p));
                assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}");
            }
        }
    }

    #[test]
    fn alpha_slate_sharded_matches_sequential_bitwise() {
        let f = setup(ModelKind::Trees, 0.02);
        let c = ctx(&f);
        let slate = mixed_slate(91, 16);
        let seq = AlphaSlate::with_mode(&c, AlphaMode::Fantasy)
            .with_threads(1)
            .eval_points(&slate);
        let par = AlphaSlate::with_mode(&c, AlphaMode::Fantasy)
            .with_threads(5)
            .eval_points(&slate);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn precomputed_shortlist_feasibility_is_bit_identical_for_trees() {
        // For tree surrogates, conditioning shares the constraint models,
        // so the engine's precomputed shortlist feasibility must reproduce
        // the recompute-inside-α_T path exactly.
        let f = setup(ModelKind::Trees, 0.02);
        let feas = crate::acq::joint_feasibility_many(
            &f.models,
            &f.constraints,
            &f.shortlist_feats,
        );
        let slow = ctx(&f);
        let fast = TrimTunerAcq { inc_feas: Some(feas.as_slice()), ..ctx(&f) };
        let mut rng = Rng::new(51);
        for _ in 0..6 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            let x = encode(&p);
            let a = trimtuner_alpha(&slow, &x);
            let b = trimtuner_alpha(&fast, &x);
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }
}
