//! # TrimTuner — constrained Bayesian optimization of ML jobs in the cloud via sub-sampling
//!
//! Reproduction of *TrimTuner: Efficient Optimization of Machine Learning Jobs
//! in the Cloud via Sub-Sampling* (Mendes, Casimiro, Romano, Garlan — 2020).
//!
//! TrimTuner jointly optimizes the cloud configuration (VM type, #VMs) and the
//! training hyper-parameters (learning rate, batch size, sync/async) of an ML
//! training job so as to maximize final model accuracy subject to user QoS
//! constraints (e.g. max training cost), while probing candidate
//! configurations on *sub-sampled* data-sets to keep each probe cheap.
//!
//! ## Layering
//!
//! - Layer 3 (this crate): the optimizer — surrogate models, acquisition
//!   functions, the CEA filtering heuristic, the Algorithm-1 engine, a
//!   threaded job coordinator, the cloud simulator used as evaluation
//!   substrate, and the experiment harness reproducing every table/figure of
//!   the paper's evaluation.
//! - Layer 2 (build-time JAX, `python/compile/model.py`): GP posterior and
//!   MLP train/eval graphs, AOT-lowered to HLO text artifacts.
//! - Layer 1 (build-time Pallas, `python/compile/kernels/`): the fused
//!   Matérn-5/2 × sub-sampling covariance-matrix kernel.
//!
//! The `runtime` module loads the AOT artifacts through PJRT (`xla` crate)
//! so that Python is never on the optimization path. It is gated behind the
//! off-by-default `xla` cargo feature: the default build is fully offline
//! and self-contained, while `--features xla` (with the `xla` crate
//! vendored) re-enables the accelerated backend.
//!
//! ## Subsystem map
//!
//! Four subsystems carry the optimizer (see `docs/ARCHITECTURE.md` in the
//! repository for the full data-flow walkthrough):
//!
//! - [`models`] + [`acq`] — surrogates (GP / extra-trees) with batched
//!   prediction, joint posteriors and rank-one *fantasy surfaces*; the
//!   acquisition functions up to TrimTuner's α_T and its slate evaluator
//!   [`acq::AlphaSlate`].
//! - [`heuristics`] — acquisition filtering (CEA, random, DIRECT,
//!   CMA-ES) over a memoizing [`heuristics::AlphaCache`], ending in the
//!   α-argmax or a ranked top-q slate ([`heuristics::select_slate`]).
//! - [`engine`] — Algorithm 1 organized in selection rounds over an
//!   [`engine::EvalBackend`]: trace replay or live deployments, with
//!   batched probe slates (`EngineConfig::batch_size`), per-round
//!   refits, metrics and adaptive stop conditions.
//! - [`coordinator`] — the threaded execution spine: worker pool,
//!   launcher abstraction, job-id-attributed failures, event log.
//!
//! ## Runtime escape hatches
//!
//! Four environment variables tune the hot path without recompiling:
//! `TRIMTUNER_ALPHA=clone` (reference per-candidate clone-conditioning
//! for α_T), `TRIMTUNER_TREES=rebuild` (per-candidate seeded tree
//! rebuilds instead of the incremental leaf-statistics conditioning, see
//! [`models::TreesMode`]), `TRIMTUNER_BATCH=fantasy|liar|topq`
//! (batched-slate diversification strategy, see [`engine::BatchMode`]),
//! and `TRIMTUNER_SLATE_THREADS=n` (α-sweep sharding width; results are
//! bit-stable in this knob by construction).

pub mod cli;
pub mod util;
pub mod linalg;
pub mod opt;
pub mod space;
pub mod sim;
pub mod models;
pub mod acq;
pub mod heuristics;
pub mod engine;
pub mod coordinator;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod experiments;
