//! Per-thread allocation counting for the dynamic side of the allocation
//! contracts (detlint's A rules are the static side; see
//! `docs/ARCHITECTURE.md` § Allocation contracts).
//!
//! [`CountingAlloc`] wraps the system allocator and bumps a thread-local
//! counter on every `alloc`/`alloc_zeroed`/`realloc`. It is **not**
//! registered here: production binaries keep the plain system allocator.
//! Only the `alloc_contracts` integration test opts in, via
//!
//! ```ignore
//! #[global_allocator]
//! static A: trimtuner::util::alloc_count::CountingAlloc = CountingAlloc;
//! ```
//!
//! The counter is per-thread so parallel test threads (and the worker pool
//! inside a measured region) cannot corrupt each other's deltas; a test
//! that wants a zero-allocation guarantee measures on its own thread and
//! runs the measured closure inline.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Allocations observed on the current thread since it started (wrapping).
/// Diff two readings around a region to count its allocations.
pub fn thread_allocations() -> u64 {
    TL_ALLOCS.with(|c| c.get())
}

#[inline]
fn bump() {
    // try_with: the allocator may be called during TLS teardown, when the
    // counter's slot is already destroyed — counting must never panic.
    let _ = TL_ALLOCS.try_with(|c| c.set(c.get().wrapping_add(1)));
}

/// Counting `#[global_allocator]` over [`System`]. Zero overhead beyond a
/// thread-local increment per allocation; deallocation is not counted (the
/// contracts bound allocations, frees follow from them).
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}
