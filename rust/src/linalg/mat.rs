//! Row-major dense matrix of `f64`.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    /// Build from an existing row-major buffer (must be rows × cols long).
    pub fn from_flat(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "flat buffer size mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Overwrite `self` with `other`, reusing the existing allocation when
    /// it is large enough (the scratch-buffer entry points of the hot
    /// slate sweep rely on this to avoid per-call heap traffic).
    pub fn copy_from(&mut self, other: &Mat) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// Inner-dimension tile for the blocked [`Mat::matmul`]: a tile of
    /// `other`'s rows (`MM_BLOCK × cols` f64s) stays resident in cache
    /// across every row of `self` instead of being re-streamed per row.
    const MM_BLOCK: usize = 32;

    /// Matrix product, cache-blocked over the inner dimension. For every
    /// output element the inner-index accumulation order is ascending —
    /// exactly the naive triple loop's order — so results are bitwise
    /// identical to the unblocked product.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// [`Mat::matmul`] into a caller-provided output (resized as needed;
    /// reuses its allocation). `out` must not alias either operand.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, other.rows);
        out.rows = self.rows;
        out.cols = other.cols;
        out.data.clear();
        out.data.resize(self.rows * other.cols, 0.0);
        let mut k0 = 0;
        while k0 < self.cols {
            let k1 = (k0 + Self::MM_BLOCK).min(self.cols);
            for i in 0..self.rows {
                let arow = &self.row(i)[k0..k1];
                let out_row = out.row_mut(i);
                for (k, &a) in (k0..k1).zip(arow) {
                    if a == 0.0 {
                        continue;
                    }
                    let orow = other.row(k);
                    for (o, &b) in out_row.iter_mut().zip(orow) {
                        *o += a * b;
                    }
                }
            }
            k0 = k1;
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Append a row (grows the matrix; used by incremental GP refits).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Reshape to `rows × cols`, zero-filled, reusing the allocation when
    /// it is large enough (contents are NOT preserved) — the matrix-shaped
    /// analogue of `Vec::clear` + `resize` that the `_into` scratch entry
    /// points rely on to stay allocation-free when warm.
    pub fn reshape_zeroed(&mut self, rows: usize, cols: usize) {
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
        self.rows = rows;
        self.cols = cols;
    }

    /// Grow a square n×n matrix to (n+1)×(n+1) in place, preserving the
    /// existing entries and zeroing the new border. The backing buffer is
    /// re-strided back to front — row i's new slot only overlaps rows ≥ i,
    /// which have already been relocated — so this is a single `resize`
    /// plus O(n²) moves. `Vec`'s amortized-doubling growth makes a warm
    /// grow loop allocation-free between capacity doublings, which is what
    /// lets [`crate::linalg::Cholesky::extend_in_place`] absorb
    /// observations at zero allocations per call.
    pub fn grow_square(&mut self) {
        assert_eq!(self.rows, self.cols, "grow_square needs a square matrix");
        let n = self.rows;
        self.data.resize((n + 1) * (n + 1), 0.0);
        for i in (1..n).rev() {
            self.data.copy_within(i * n..(i + 1) * n, i * (n + 1));
        }
        for i in 0..n {
            self.data[i * (n + 1) + n] = 0.0;
        }
        self.rows = n + 1;
        self.cols = n + 1;
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(
                f,
                "  {:?}",
                &self.row(i)[..self.cols.min(8)]
            )?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.row(0), &[2.0, 1.0]);
        assert_eq!(c.row(1), &[4.0, 3.0]);
    }

    #[test]
    fn transpose_and_push_row() {
        let mut a = Mat::from_rows(&[vec![1.0, 2.0, 3.0]]);
        a.push_row(&[4.0, 5.0, 6.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn eye_is_identity_under_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(a.matmul(&i), a);
    }

    /// Reference naive product with ascending-k accumulation — the op
    /// order the blocked matmul promises to preserve bit for bit.
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.rows);
        Mat::from_fn(a.rows, b.cols, |i, j| {
            let mut acc = 0.0;
            for k in 0..a.cols {
                let v = a[(i, k)];
                if v == 0.0 {
                    continue;
                }
                acc += v * b[(k, j)];
            }
            acc
        })
    }

    #[test]
    fn blocked_matmul_bitwise_matches_naive_across_block_boundaries() {
        use crate::util::proptest::check;
        use crate::util::Rng;
        check("blocked matmul == naive", 16, |rng| {
            // shapes straddle the 32-wide inner block (1 … ~3 blocks)
            let m = 1 + rng.below(70);
            let k = 1 + rng.below(70);
            let n = 1 + rng.below(40);
            let a = Mat::from_fn(m, k, |_, _| rng.normal());
            let b = Mat::from_fn(k, n, |_, _| rng.normal());
            let got = a.matmul(&b);
            let want = matmul_naive(&a, &b);
            for i in 0..m {
                for j in 0..n {
                    if got[(i, j)].to_bits() != want[(i, j)].to_bits() {
                        return Err(format!(
                            "({i},{j}): {} != {}",
                            got[(i, j)],
                            want[(i, j)]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grow_square_preserves_entries_and_zeros_the_border() {
        use crate::util::Rng;
        let mut rng = Rng::new(41);
        for n in [0usize, 1, 2, 5, 33] {
            let a = Mat::from_fn(n, n, |_, _| rng.normal());
            let mut g = a.clone();
            g.grow_square();
            assert_eq!((g.rows, g.cols), (n + 1, n + 1));
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(g[(i, j)].to_bits(), a[(i, j)].to_bits());
                }
                assert_eq!(g[(i, n)], 0.0);
                assert_eq!(g[(n, i)], 0.0);
            }
            assert_eq!(g[(n, n)], 0.0);
        }
    }

    #[test]
    fn reshape_zeroed_reuses_allocation() {
        let mut m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        m.reshape_zeroed(3, 1);
        assert_eq!((m.rows, m.cols), (3, 1));
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn matmul_into_reuses_allocation_and_copy_from_resizes() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let mut out = Mat::zeros(5, 7); // wrong shape on purpose
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
        let mut c = Mat::zeros(1, 1);
        c.copy_from(&a);
        assert_eq!(c, a);
    }
}
