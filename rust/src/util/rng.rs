//! Deterministic xoshiro256** RNG seeded via splitmix64.
//!
//! All stochastic components of the optimizer (bootstrap resampling, p_opt
//! Monte-Carlo, simulator noise, CMA-ES, …) draw from this generator so that
//! every experiment is reproducible from a single `u64` seed.

/// xoshiro256** 1.0 (Blackman & Vigna) — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second output of Box–Muller
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (used to give each component / worker
    /// its own generator without correlation).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Unbiased via rejection: `zone` is
    /// 2^64 mod n (computed as `n.wrapping_neg() % n`, the standard
    /// formulation), and rejecting the `zone` lowest draws leaves exactly
    /// 2^64 − (2^64 mod n) values — a multiple of n — mapping uniformly
    /// onto [0, n) under `% n`. This is the minimal rejection zone: the
    /// previous `u64::MAX - (u64::MAX % n)` cutoff was also unbiased but
    /// rejected n values (instead of 0) whenever n divides 2^64.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = n.wrapping_neg() % n;
        loop {
            let v = self.next_u64();
            if v >= zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller (with cached spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_rejection_zone_is_exact() {
        // The accepted range [zone, 2^64) must have a length divisible by n
        // for every n, which is what makes the draw unbiased.
        for n in [1u64, 2, 3, 7, 10, 288, 1440, (1 << 33) + 5, u64::MAX / 3] {
            let zone = n.wrapping_neg() % n;
            // length of [zone, 2^64) = 2^64 - zone ≡ 0 (mod n)
            assert_eq!(zone.wrapping_neg() % n, 0, "n = {n}");
        }
    }

    #[test]
    fn below_deterministic_per_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for n in [1usize, 2, 3, 7, 288, 1440] {
            for _ in 0..200 {
                let (x, y) = (a.below(n), b.below(n));
                assert_eq!(x, y);
                assert!(x < n);
            }
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
