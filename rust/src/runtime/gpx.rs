//! Batched GP posterior through the AOT artifacts (the XLA backend).
//!
//! Fixed AOT shapes: N_TRAIN training rows, N_QUERY query rows. Smaller
//! training sets are padded with the "padding-as-noise" trick (y = 0,
//! noise = 1e6 — exactly removes the padded rows' influence, see
//! python/compile/model.py); query batches are padded to a whole tile and
//! truncated on the way out.

use super::artifacts::{literal_f32, Runtime};
use crate::models::{Basis, Feat, KernelParams};
use anyhow::{bail, Result};

pub const PAD_NOISE: f32 = 1e6;

/// Batched predictive posterior via the `gp_predict_{acc,cost}` artifacts.
pub struct XlaGp<'rt> {
    rt: &'rt Runtime,
    pub basis: Basis,
    x_tr: Vec<f32>,
    y: Vec<f32>,
    noise: Vec<f32>,
    hyp: Vec<f32>,
    n_real: usize,
}

impl<'rt> XlaGp<'rt> {
    /// Build from a training set (<= manifest.n_train rows after padding).
    pub fn new(
        rt: &'rt Runtime,
        basis: Basis,
        params: &KernelParams,
        xs: &[Feat],
        ys: &[f64],
    ) -> Result<XlaGp<'rt>> {
        let n = rt.manifest.n_train;
        let d = rt.manifest.d_in;
        if xs.len() > n {
            bail!("training set {} exceeds artifact capacity {n}", xs.len());
        }
        if xs.len() != ys.len() {
            bail!("xs/ys length mismatch");
        }
        let mut x_tr = vec![0.0f32; n * d];
        let mut y = vec![0.0f32; n];
        let mut noise = vec![PAD_NOISE; n];
        for (i, (x, &yv)) in xs.iter().zip(ys).enumerate() {
            for (j, &v) in x.iter().enumerate() {
                x_tr[i * d + j] = v as f32;
            }
            y[i] = yv as f32;
            noise[i] = params.noise as f32;
        }
        let hyp = params.to_f32_vec();
        if hyp.len() != rt.manifest.n_hyp {
            bail!("hyp len {} != manifest {}", hyp.len(), rt.manifest.n_hyp);
        }
        Ok(XlaGp { rt, basis, x_tr, y, noise, hyp, n_real: xs.len() })
    }

    pub fn n_obs(&self) -> usize {
        self.n_real
    }

    fn artifact(&self) -> &'static str {
        match self.basis {
            Basis::Acc => "gp_predict_acc",
            Basis::Cost => "gp_predict_cost",
        }
    }

    /// Predictive (mean, variance) at arbitrary query points, tiled through
    /// the fixed-shape artifact.
    pub fn predict_batch(
        &self,
        queries: &[Feat],
    ) -> Result<(Vec<f64>, Vec<f64>)> {
        let q = self.rt.manifest.n_query;
        let d = self.rt.manifest.d_in;
        let n = self.rt.manifest.n_train;
        let mut mu = Vec::with_capacity(queries.len());
        let mut var = Vec::with_capacity(queries.len());

        let x_tr = literal_f32(&self.x_tr, &[n as i64, d as i64])?;
        let y = literal_f32(&self.y, &[n as i64])?;
        let noise = literal_f32(&self.noise, &[n as i64])?;
        let hyp = literal_f32(&self.hyp, &[self.hyp.len() as i64])?;

        for chunk in queries.chunks(q) {
            let mut xq = vec![0.0f32; q * d];
            for (i, x) in chunk.iter().enumerate() {
                for (j, &v) in x.iter().enumerate() {
                    xq[i * d + j] = v as f32;
                }
            }
            let xq = literal_f32(&xq, &[q as i64, d as i64])?;
            let out = self.rt.run(
                self.artifact(),
                &[x_tr.clone(), y.clone(), noise.clone(), xq, hyp.clone()],
            )?;
            let mu_t: Vec<f32> = out[0].to_vec()?;
            let var_t: Vec<f32> = out[1].to_vec()?;
            mu.extend(mu_t[..chunk.len()].iter().map(|&v| v as f64));
            var.extend(var_t[..chunk.len()].iter().map(|&v| v as f64));
        }
        Ok((mu, var))
    }

    /// Log marginal likelihood via the `gp_mll_*` artifact.
    pub fn mll(&self) -> Result<f64> {
        let n = self.rt.manifest.n_train;
        let d = self.rt.manifest.d_in;
        let name = match self.basis {
            Basis::Acc => "gp_mll_acc",
            Basis::Cost => "gp_mll_cost",
        };
        let out = self.rt.run(
            name,
            &[
                literal_f32(&self.x_tr, &[n as i64, d as i64])?,
                literal_f32(&self.y, &[n as i64])?,
                literal_f32(&self.noise, &[n as i64])?,
                literal_f32(&self.hyp, &[self.hyp.len() as i64])?,
            ],
        )?;
        Ok(out[0].to_vec::<f32>()?[0] as f64)
    }
}

/// Parity check: `cov_acc` artifact (Pallas kernel lowering) vs the native
/// f64 kernel. Returns (max abs error, number of entries compared).
pub fn cov_parity_check(rt: &Runtime) -> Result<(f64, usize)> {
    let n = rt.manifest.n_train;
    let q = rt.manifest.n_query;
    let d = rt.manifest.d_in;
    let mut rng = crate::util::Rng::new(0xC0F);
    let params = KernelParams {
        ls: [0.4, 0.6, 0.8, 0.5, 0.7, 0.9],
        sigma2: 1.3,
        l00: 0.9,
        l10: 0.35,
        l11: 0.45,
        noise: 0.0,
    };
    let xs1: Vec<Feat> = (0..n).map(|_| rand_feat(&mut rng)).collect();
    let xs2: Vec<Feat> = (0..q).map(|_| rand_feat(&mut rng)).collect();

    let flat = |xs: &[Feat]| -> Vec<f32> {
        xs.iter().flat_map(|x| x.iter().map(|&v| v as f32)).collect()
    };
    let out = rt.run(
        "cov_acc",
        &[
            literal_f32(&flat(&xs1), &[n as i64, d as i64])?,
            literal_f32(&flat(&xs2), &[q as i64, d as i64])?,
            literal_f32(&params.to_f32_vec(), &[rt.manifest.n_hyp as i64])?,
        ],
    )?;
    let k_xla: Vec<f32> = out[0].to_vec()?;
    let mut max_err = 0.0f64;
    for i in 0..n {
        for j in 0..q {
            let native = params.k(Basis::Acc, &xs1[i], &xs2[j]);
            let err = (k_xla[i * q + j] as f64 - native).abs();
            max_err = max_err.max(err);
        }
    }
    Ok((max_err, n * q))
}

/// Parity check: artifact GP posterior vs the native Rust GP with identical
/// hyper-parameters. Returns (max |mu| error, max |var| error).
pub fn gp_parity_check(rt: &Runtime) -> Result<(f64, f64)> {
    let mut rng = crate::util::Rng::new(0x6B);
    let n_obs = 24;
    let xs: Vec<Feat> = (0..n_obs).map(|_| rand_feat(&mut rng)).collect();
    let ys: Vec<f64> = xs
        .iter()
        .map(|x| (2.5 * x[0]).sin() * 0.4 + 0.3 * x[6])
        .collect();

    let params = KernelParams {
        ls: [0.5; 6],
        sigma2: 1.0,
        l00: 0.8,
        l10: 0.3,
        l11: 0.4,
        noise: 1e-3,
    };
    // XlaGp models raw targets (no y-standardization), so the reference is
    // a from-scratch posterior via the native kernel + Cholesky.
    let k = params.cov_matrix(Basis::Acc, &xs);
    let chol = crate::linalg::Cholesky::factor(&k)?;
    let alpha = chol.solve(&ys);

    let queries: Vec<Feat> = (0..50).map(|_| rand_feat(&mut rng)).collect();
    let xgp = XlaGp::new(rt, Basis::Acc, &params, &xs, &ys)?;
    let (mu_x, var_x) = xgp.predict_batch(&queries)?;

    let mut mu_err = 0.0f64;
    let mut var_err = 0.0f64;
    for (qi, xq) in queries.iter().enumerate() {
        let ks = params.cov_vec(Basis::Acc, &xs, xq);
        let mu: f64 = ks.iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let v = chol.solve_lower(&ks);
        let var = params.k_diag(Basis::Acc, xq)
            - v.iter().map(|z| z * z).sum::<f64>();
        mu_err = mu_err.max((mu - mu_x[qi]).abs());
        var_err = var_err.max((var.max(1e-12) - var_x[qi]).abs());
    }
    Ok((mu_err, var_err))
}

fn rand_feat(rng: &mut crate::util::Rng) -> Feat {
    let mut f = [0.0; crate::space::D_IN];
    for v in f.iter_mut() {
        *v = rng.f64();
    }
    f
}
