//! Job abstraction: one cloud deployment of the training job, observed at a
//! set of sub-sampling snapshots (paper §III: "we can test all the
//! configurations ⟨x, s_i⟩ via a single training instance by taking a
//! snapshot ... whenever the sub-sampling rate s_i is achieved").

use crate::sim::{CloudSim, NetKind, Outcome};
use crate::space::{Config, Point};
use crate::util::Rng;
use anyhow::Result;

/// Deterministic job-id scheme shared by the engine's retry path and the
/// fault decorators. A retry of job `original` gets an id that is a pure
/// function of (original id, attempt number) — never of completion order —
/// so requeued work stays deterministic at any worker count. A high marker
/// bit keeps retry ids disjoint from the engine's sequential primary ids
/// and lets launch-side policies recognize a retry (e.g. the spot
/// launcher's on-demand fallback in [`super::faults`]).
pub mod job_ids {
    /// Marker bit distinguishing retry ids from primary ids.
    pub const RETRY_BIT: u64 = 1 << 63;
    /// Low bits carrying the original (primary) job id.
    pub const ORIGINAL_MASK: u64 = 0xFFFF_FFFF_FFFF;

    /// Id of the `attempt`-th retry (attempt ≥ 1) of job `original`.
    pub fn retry(original: u64, attempt: usize) -> u64 {
        RETRY_BIT | ((attempt as u64) << 48) | (original & ORIGINAL_MASK)
    }

    /// Whether `id` names a retry attempt rather than a first launch.
    pub fn is_retry(id: u64) -> bool {
        id & RETRY_BIT != 0
    }

    /// The primary job id behind `id` (identity for primary ids).
    pub fn original(id: u64) -> u64 {
        if is_retry(id) { id & ORIGINAL_MASK } else { id }
    }
}

/// A deployment request: train `config` once, snapshotting at each of
/// `s_levels` (indices into S_VALUES, ascending).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub config: Config,
    pub s_levels: Vec<usize>,
}

/// Outcomes per snapshot + the cost actually charged (one training run at
/// the largest snapshot level, not the sum).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job_id: u64,
    pub outcomes: Vec<(usize, Outcome)>,
    pub charged_cost: f64,
    /// wall-clock duration of the (simulated or real) training run
    pub duration_s: f64,
}

/// Anything that can execute a training deployment. Implementations:
/// [`SimLauncher`] (parametric cloud simulator) and the PJRT-backed MLP
/// trainer in `examples/end_to_end.rs`.
pub trait JobLauncher: Send + Sync {
    fn launch(&self, job: &Job) -> Result<JobResult>;
}

/// Simulated cloud: noisy observations from [`CloudSim`], deterministic per
/// (seed, job id). Observation noise can be scaled (0 = exact ground truth,
/// the reference point for live-vs-replay parity tests), and an optional
/// wall-clock latency proportional to the simulated training duration makes
/// multi-worker throughput measurable for the coordinator benches.
pub struct SimLauncher {
    sim: CloudSim,
    seed: u64,
    /// seconds of real `thread::sleep` per simulated training second
    latency_per_sim_s: f64,
}

impl SimLauncher {
    pub fn new(net: NetKind, seed: u64) -> SimLauncher {
        SimLauncher::with_options(net, seed, 1.0, 0.0)
    }

    /// Zero-noise launcher: every observation equals the oracle's ground
    /// truth, so a live run is exactly reproducible against
    /// `Dataset::ground_truth`.
    pub fn noiseless(net: NetKind) -> SimLauncher {
        SimLauncher::with_options(net, 0, 0.0, 0.0)
    }

    /// Full-control constructor: `noise_scale` multiplies the oracle's
    /// observation-noise parameters (1 = calibrated noise, 0 = noiseless);
    /// `latency_per_sim_s` makes each launch sleep that many wall-clock
    /// seconds per simulated training second (0 = return immediately).
    pub fn with_options(
        net: NetKind,
        seed: u64,
        noise_scale: f64,
        latency_per_sim_s: f64,
    ) -> SimLauncher {
        assert!(noise_scale >= 0.0 && latency_per_sim_s >= 0.0);
        let mut sim = CloudSim::new(net);
        sim.params.noise_acc *= noise_scale;
        sim.params.noise_time *= noise_scale;
        SimLauncher { sim, seed, latency_per_sim_s }
    }

    pub fn net(&self) -> NetKind {
        self.sim.kind
    }
}

impl JobLauncher for SimLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        anyhow::ensure!(!job.s_levels.is_empty(), "job without snapshots");
        let mut rng = Rng::new(self.seed ^ job.id.wrapping_mul(0x9E3779B9));
        let mut outcomes = Vec::with_capacity(job.s_levels.len());
        let mut charged = 0.0f64;
        let mut duration = 0.0f64;
        for &s_idx in &job.s_levels {
            let p = Point { config: job.config, s_idx };
            let o = self.sim.observe(&p, &mut rng);
            // Snapshot semantics: one run that keeps training past each
            // snapshot — the cost/time of the run is the *largest* level's.
            charged = charged.max(o.cost_usd);
            duration = duration.max(o.time_s);
            outcomes.push((s_idx, o));
        }
        if self.latency_per_sim_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                duration * self.latency_per_sim_s,
            ));
        }
        Ok(JobResult {
            job_id: job.id,
            outcomes,
            charged_cost: charged,
            duration_s: duration,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::S_INIT;

    #[test]
    fn snapshot_cost_is_max_not_sum() {
        let l = SimLauncher::new(NetKind::Cnn, 1);
        let job = Job {
            id: 1,
            config: Config::from_id(40),
            s_levels: S_INIT.to_vec(),
        };
        let r = l.launch(&job).unwrap();
        let sum: f64 = r.outcomes.iter().map(|(_, o)| o.cost_usd).sum();
        let max = r
            .outcomes
            .iter()
            .map(|(_, o)| o.cost_usd)
            .fold(0.0, f64::max);
        assert!(r.charged_cost < sum);
        assert!((r.charged_cost - max).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_list_rejected() {
        let l = SimLauncher::new(NetKind::Cnn, 1);
        let job = Job { id: 1, config: Config::from_id(0), s_levels: vec![] };
        assert!(l.launch(&job).is_err());
    }

    #[test]
    fn noiseless_launcher_reproduces_ground_truth_exactly() {
        let l = SimLauncher::noiseless(NetKind::Mlp);
        let sim = CloudSim::new(NetKind::Mlp);
        let config = Config::from_id(123);
        let job = Job { id: 9, config, s_levels: vec![0, 2, 4] };
        let r = l.launch(&job).unwrap();
        for (s_idx, o) in &r.outcomes {
            let gt = sim.ground_truth(&Point { config, s_idx: *s_idx });
            assert_eq!(o.acc, gt.acc);
            assert_eq!(o.time_s, gt.time_s);
            assert_eq!(o.cost_usd, gt.cost_usd);
        }
    }
}
