// R4 allow: poisoning tolerated where continuing is sound (a Vec append
// cannot be torn by a panicking appender), pragma'd where crashing is the
// deliberate response.
use std::sync::{Mutex, PoisonError};

fn record(events: &Mutex<Vec<u64>>, e: u64) {
    events.lock().unwrap_or_else(PoisonError::into_inner).push(e);
}

fn must_len(events: &Mutex<Vec<u64>>) -> usize {
    // detlint: allow(R4, reason="a poisoned log already lost events; crash loudly")
    events.lock().expect("event log poisoned").len()
}
