//! Shared fixtures for the custom bench harness (no criterion offline).
#![allow(dead_code)]

use trimtuner::acq::Models;
use trimtuner::models::{FitOptions, ModelKind};
use trimtuner::sim::{CloudSim, NetKind, Outcome};
use trimtuner::space::{Config, Constraint, Point};
use trimtuner::util::timer::BenchStats;
use trimtuner::util::Rng;

pub fn observations(n: usize, seed: u64) -> (Vec<Point>, Vec<Outcome>) {
    let sim = CloudSim::new(NetKind::Rnn);
    let mut rng = Rng::new(seed);
    let mut pts = Vec::with_capacity(n);
    let mut outs = Vec::with_capacity(n);
    for _ in 0..n {
        let p = Point {
            config: Config::from_id(rng.below(288)),
            s_idx: rng.below(5),
        };
        pts.push(p);
        outs.push(sim.observe(&p, &mut rng));
    }
    (pts, outs)
}

pub fn fitted(kind: ModelKind, n: usize, gp_k: usize) -> Models {
    let (pts, outs) = observations(n, 42);
    let mut m = Models::with_gp_hyper_samples(kind, 1, gp_k);
    m.fit(&pts, &outs, FitOptions { hyperopt: true, restarts: 1 });
    m
}

pub fn caps() -> Vec<Constraint> {
    vec![Constraint::cost_max(0.02)]
}

pub fn print_header(name: &str) {
    println!("\n### bench: {name} ###");
}

/// Serialize bench results as JSON so CI can archive the perf trajectory
/// (no serde in the offline registry — names are plain ASCII labels, so a
/// minimal escape of `"` and `\` suffices).
pub fn write_bench_json(bench: &str, path: &str, all: &[BenchStats]) {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"bench\": \"{bench}\",\n  \"results\": [\n"));
    for (i, s) in all.iter().enumerate() {
        let name = s.name.replace('\\', "\\\\").replace('"', "\\\"");
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {:e}, \
             \"p50_s\": {:e}, \"p99_s\": {:e}, \"min_s\": {:e}, \
             \"max_s\": {:e}}}{}\n",
            name,
            s.iters,
            s.mean_s,
            s.p50_s,
            s.p99_s,
            s.min_s,
            s.max_s,
            if i + 1 == all.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(path, out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
