//! Filtering heuristics (paper §III-B, Fig. 3, Table IV): given the set of
//! untested (config, s) points and an acquisition-evaluation budget
//! k = β·|T|, pick the next point to test while evaluating the (expensive)
//! acquisition function at most k times.
//!
//! - **CEA** — the paper's contribution: rank all untested points by the
//!   cheap Constrained-Expected-Accuracy score, evaluate α only on the
//!   top-k.
//! - **Random** — evaluate α on k uniformly-sampled untested points.
//! - **NoFilter** — evaluate α everywhere (Table IV "No filter" row).
//! - **DIRECT** / **CMA-ES** — generic black-box optimizers (as used by
//!   FABOLAS) maximizing α over the continuous relaxation of the feature
//!   space, snapping iterates to the nearest untested grid point, capped at
//!   k unique α evaluations.

mod cea;
mod cmaes;
mod direct;

pub use cea::cea_scores;
pub use cmaes::CmaesSearch;
pub use direct::DirectSearch;

use crate::acq::Models;
use crate::space::{encode, Constraint, Point};
use crate::util::stats::argmax;
use crate::util::Rng;
use std::collections::HashMap;

/// Which heuristic an optimizer uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FilterKind {
    Cea,
    RandomFilter,
    NoFilter,
    Direct,
    Cmaes,
}

impl FilterKind {
    pub fn name(&self) -> &'static str {
        match self {
            FilterKind::Cea => "cea",
            FilterKind::RandomFilter => "random",
            FilterKind::NoFilter => "nofilter",
            FilterKind::Direct => "direct",
            FilterKind::Cmaes => "cmaes",
        }
    }

    pub fn from_name(s: &str) -> Option<FilterKind> {
        match s.to_ascii_lowercase().as_str() {
            "cea" => Some(FilterKind::Cea),
            "random" => Some(FilterKind::RandomFilter),
            "nofilter" | "none" => Some(FilterKind::NoFilter),
            "direct" => Some(FilterKind::Direct),
            "cmaes" | "cma-es" => Some(FilterKind::Cmaes),
            _ => None,
        }
    }
}

/// Memoizing α evaluator: unique grid evaluations count against the budget.
pub struct AlphaCache<'a> {
    f: Box<dyn FnMut(&Point) -> f64 + 'a>,
    cache: HashMap<usize, f64>,
}

impl<'a> AlphaCache<'a> {
    pub fn new(f: impl FnMut(&Point) -> f64 + 'a) -> Self {
        AlphaCache { f: Box::new(f), cache: HashMap::new() }
    }

    pub fn eval(&mut self, p: &Point) -> f64 {
        let id = p.id();
        if let Some(&v) = self.cache.get(&id) {
            return v;
        }
        let v = (self.f)(p);
        self.cache.insert(id, v);
        v
    }

    pub fn unique_evals(&self) -> usize {
        self.cache.len()
    }

    pub fn best(&self) -> Option<(Point, f64)> {
        // deterministic argmax: ties break towards the lowest point id
        // (HashMap iteration order is seeded per instance — without an
        // explicit tie-break, equal-α candidates would make runs
        // non-reproducible)
        self.cache
            .iter()
            .max_by(|a, b| {
                a.1.partial_cmp(b.1)
                    .unwrap()
                    .then_with(|| b.0.cmp(a.0))
            })
            .map(|(&id, &v)| (Point::from_id(id), v))
    }
}

/// Run one candidate-selection round: pick the untested point maximizing α,
/// spending at most `budget` unique α evaluations (plus the heuristic's own
/// cheap work). Returns the chosen point and the number of α evaluations.
pub fn select_next(
    kind: FilterKind,
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
    budget: usize,
    alpha: &mut AlphaCache<'_>,
    rng: &mut Rng,
) -> (Point, usize) {
    assert!(!untested.is_empty(), "nothing left to test");
    let budget = budget.clamp(1, untested.len());
    match kind {
        FilterKind::NoFilter => {
            for p in untested {
                alpha.eval(p);
            }
        }
        FilterKind::Cea => {
            let scores = cea_scores(models, constraints, untested);
            let mut order: Vec<usize> = (0..untested.len()).collect();
            order.sort_by(|&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap()
            });
            for &i in order.iter().take(budget) {
                alpha.eval(&untested[i]);
            }
        }
        FilterKind::RandomFilter => {
            let idx = rng.sample_indices(untested.len(), budget);
            for i in idx {
                alpha.eval(&untested[i]);
            }
        }
        FilterKind::Direct => {
            DirectSearch::new().run(untested, budget, alpha);
        }
        FilterKind::Cmaes => {
            CmaesSearch::new(rng.fork(0xC3A)).run(untested, budget, alpha);
        }
    }
    let (p, _) = alpha.best().expect("at least one alpha evaluation");
    (p, alpha.unique_evals())
}

/// Snap a continuous feature vector to the nearest *untested* grid point.
pub(crate) fn nearest_untested(feat: &[f64], untested: &[Point]) -> Point {
    let mut best = untested[0];
    let mut best_d = f64::INFINITY;
    for p in untested {
        let e = encode(p);
        let mut d = 0.0;
        for (a, b) in e.iter().zip(feat) {
            d += (a - b) * (a - b);
        }
        if d < best_d {
            best_d = d;
            best = *p;
        }
    }
    best
}

pub(crate) use crate::space::D_IN;

/// Helper for tests: index of max CEA score.
pub fn argmax_cea(
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
) -> Option<usize> {
    argmax(&cea_scores(models, constraints, untested))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{FitOptions, ModelKind};
    use crate::sim::{CloudSim, NetKind};
    use crate::space::{all_points, Config};

    pub(crate) fn fixture() -> (Models, Vec<Constraint>, Vec<Point>) {
        let sim = CloudSim::new(NetKind::Mlp);
        let mut rng = Rng::new(17);
        let mut pts = Vec::new();
        let mut outs = Vec::new();
        for _ in 0..24 {
            let p = Point {
                config: Config::from_id(rng.below(288)),
                s_idx: rng.below(5),
            };
            pts.push(p);
            outs.push(sim.observe(&p, &mut rng));
        }
        let mut m = Models::new(ModelKind::Trees, 3);
        m.fit(&pts, &outs, FitOptions::default());
        let tested: std::collections::HashSet<usize> =
            pts.iter().map(|p| p.id()).collect();
        let untested: Vec<Point> =
            all_points().filter(|p| !tested.contains(&p.id())).collect();
        (m, vec![Constraint::cost_max(0.06)], untested)
    }

    #[test]
    fn all_filters_respect_budget_and_return_untested() {
        let (m, cs, untested) = fixture();
        for kind in [
            FilterKind::Cea,
            FilterKind::RandomFilter,
            FilterKind::Direct,
            FilterKind::Cmaes,
        ] {
            let mut rng = Rng::new(5);
            // cheap stand-in acquisition: predicted accuracy
            let mut alpha =
                AlphaCache::new(|p: &Point| m.acc.predict(&encode(p)).0);
            let budget = 40;
            let (chosen, evals) =
                select_next(kind, &m, &cs, &untested, budget, &mut alpha, &mut rng);
            assert!(evals <= budget, "{kind:?} used {evals} > {budget}");
            assert!(
                untested.iter().any(|p| p.id() == chosen.id()),
                "{kind:?} returned tested point"
            );
        }
    }

    #[test]
    fn no_filter_evaluates_everything() {
        let (m, cs, untested) = fixture();
        let small: Vec<Point> = untested.into_iter().take(50).collect();
        let mut rng = Rng::new(6);
        let mut alpha = AlphaCache::new(|p: &Point| encode(p)[0]);
        let (_, evals) = select_next(
            FilterKind::NoFilter,
            &m,
            &cs,
            &small,
            usize::MAX.min(small.len()),
            &mut alpha,
            &mut rng,
        );
        assert_eq!(evals, 50);
    }

    #[test]
    fn alpha_cache_deduplicates() {
        let mut calls = 0usize;
        let mut cache = AlphaCache::new(|_: &Point| {
            calls += 1;
            1.0
        });
        let p = Point::from_id(3);
        cache.eval(&p);
        cache.eval(&p);
        assert_eq!(cache.unique_evals(), 1);
        drop(cache);
        assert_eq!(calls, 1);
    }

    #[test]
    fn nearest_untested_prefers_exact_match() {
        let untested: Vec<Point> = (0..100).map(Point::from_id).collect();
        let target = Point::from_id(42);
        let snapped = nearest_untested(&encode(&target), &untested);
        assert_eq!(snapped.id(), 42);
    }
}
