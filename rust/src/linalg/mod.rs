//! Dense linear algebra substrate: column-ordered matrices, Cholesky
//! factorization with incremental extension, and triangular solves.
//!
//! This is all the linear algebra the GP surrogate needs. The hot path of
//! TrimTuner's acquisition function simulates *adding one observation and
//! refitting* for every filtered candidate; [`Cholesky::extend`] makes that
//! an O(n²) update instead of an O(n³) refactorization (see DESIGN.md §8).

mod chol;
mod mat;

pub use chol::Cholesky;
pub use mat::Mat;
