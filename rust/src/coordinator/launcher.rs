//! Job abstraction: one cloud deployment of the training job, observed at a
//! set of sub-sampling snapshots (paper §III: "we can test all the
//! configurations ⟨x, s_i⟩ via a single training instance by taking a
//! snapshot ... whenever the sub-sampling rate s_i is achieved").

use crate::sim::{CloudSim, NetKind, Outcome};
use crate::space::{Config, Point};
use crate::util::Rng;
use anyhow::Result;

/// A deployment request: train `config` once, snapshotting at each of
/// `s_levels` (indices into S_VALUES, ascending).
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u64,
    pub config: Config,
    pub s_levels: Vec<usize>,
}

/// Outcomes per snapshot + the cost actually charged (one training run at
/// the largest snapshot level, not the sum).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job_id: u64,
    pub outcomes: Vec<(usize, Outcome)>,
    pub charged_cost: f64,
    /// wall-clock duration of the (simulated or real) training run
    pub duration_s: f64,
}

/// Anything that can execute a training deployment. Implementations:
/// [`SimLauncher`] (parametric cloud simulator) and the PJRT-backed MLP
/// trainer in `examples/end_to_end.rs`.
pub trait JobLauncher: Send + Sync {
    fn launch(&self, job: &Job) -> Result<JobResult>;
}

/// Simulated cloud: noisy observations from [`CloudSim`], deterministic per
/// (seed, job id).
pub struct SimLauncher {
    sim: CloudSim,
    seed: u64,
}

impl SimLauncher {
    pub fn new(net: NetKind, seed: u64) -> SimLauncher {
        SimLauncher { sim: CloudSim::new(net), seed }
    }

    pub fn net(&self) -> NetKind {
        self.sim.kind
    }
}

impl JobLauncher for SimLauncher {
    fn launch(&self, job: &Job) -> Result<JobResult> {
        anyhow::ensure!(!job.s_levels.is_empty(), "job without snapshots");
        let mut rng = Rng::new(self.seed ^ job.id.wrapping_mul(0x9E3779B9));
        let mut outcomes = Vec::with_capacity(job.s_levels.len());
        let mut charged = 0.0f64;
        let mut duration = 0.0f64;
        for &s_idx in &job.s_levels {
            let p = Point { config: job.config, s_idx };
            let o = self.sim.observe(&p, &mut rng);
            // Snapshot semantics: one run that keeps training past each
            // snapshot — the cost/time of the run is the *largest* level's.
            charged = charged.max(o.cost_usd);
            duration = duration.max(o.time_s);
            outcomes.push((s_idx, o));
        }
        Ok(JobResult { job_id: job.id, outcomes, charged_cost: charged, duration_s: duration })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::S_INIT;

    #[test]
    fn snapshot_cost_is_max_not_sum() {
        let l = SimLauncher::new(NetKind::Cnn, 1);
        let job =
            Job { id: 1, config: Config::from_id(40), s_levels: S_INIT.to_vec() };
        let r = l.launch(&job).unwrap();
        let sum: f64 = r.outcomes.iter().map(|(_, o)| o.cost_usd).sum();
        let max = r
            .outcomes
            .iter()
            .map(|(_, o)| o.cost_usd)
            .fold(0.0, f64::max);
        assert!(r.charged_cost < sum);
        assert!((r.charged_cost - max).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_list_rejected() {
        let l = SimLauncher::new(NetKind::Cnn, 1);
        let job = Job { id: 1, config: Config::from_id(0), s_levels: vec![] };
        assert!(l.launch(&job).is_err());
    }
}
