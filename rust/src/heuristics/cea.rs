//! Constrained Expected Accuracy (paper Eq. 6):
//! CEA(x, s) = A(x, s) · Π_i P(q_i(x, s) ≥ 0 | S).
//!
//! A cheap stand-in for α_T used to rank untested points: unlike α_T it
//! scores the *point itself* (no model refits, no p_opt), so it can be
//! evaluated on the entire untested set every iteration.

use crate::acq::{joint_feasibility_many, Models};
use crate::models::Feat;
use crate::space::{encode, Constraint, Point};

/// CEA score for every point in `untested` (same order).
pub fn cea_scores(
    models: &Models,
    constraints: &[Constraint],
    untested: &[Point],
) -> Vec<f64> {
    let xs: Vec<Feat> = untested.iter().map(encode).collect();
    cea_scores_feats(models, constraints, &xs)
}

/// CEA over pre-encoded features: one batched accuracy prediction plus one
/// batched feasibility pass per constraint, instead of per-point scalar
/// predictions across three surrogates.
pub fn cea_scores_feats(
    models: &Models,
    constraints: &[Constraint],
    xs: &[Feat],
) -> Vec<f64> {
    let feas = joint_feasibility_many(models, constraints, xs);
    cea_scores_feats_with_feas(models, xs, &feas)
}

/// [`cea_scores_feats`] with the joint feasibility supplied by the caller.
/// Valid whenever the caller's cached feasibility was computed under
/// constraint models identical to `models`' — in particular, pending-
/// conditioned re-selection in batched rounds: tree-surrogate conditioning
/// shares the constraint models
/// ([`Models::constraints_fixed_under_condition`]), so the engine computes
/// the full-grid feasibility once per refit and every conditioned CEA
/// re-ranking reuses it instead of re-predicting two surrogates over the
/// whole config grid per pick.
pub fn cea_scores_feats_with_feas(
    models: &Models,
    xs: &[Feat],
    feas: &[f64],
) -> Vec<f64> {
    assert_eq!(xs.len(), feas.len());
    let accs = models.acc.predict_many(xs);
    accs.into_iter()
        .zip(feas)
        .map(|((acc, _), &pfeas)| acc.max(0.0) * pfeas)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::heuristics::tests::fixture;

    #[test]
    fn scores_in_unit_range_and_ordered_by_feasibility() {
        let (m, cs, untested) = fixture();
        let scores = cea_scores(&m, &cs, &untested);
        assert_eq!(scores.len(), untested.len());
        for &s in &scores {
            assert!((0.0..=1.2).contains(&s), "score {s}");
        }
        // tightening the constraint can only lower each score
        let tight = vec![Constraint::cost_max(cs[0].max / 100.0)];
        let tight_scores = cea_scores(&m, &tight, &untested);
        for (a, b) in scores.iter().zip(&tight_scores) {
            assert!(b <= a, "tightening raised CEA: {a} -> {b}");
        }
    }

    #[test]
    fn cached_feasibility_path_is_bitwise_identical() {
        // the engine's full-grid feasibility cache must reproduce the
        // recompute-inside path exactly, including under a conditioned
        // accuracy model (trees share constraint models when conditioned)
        let (m, cs, untested) = fixture();
        let xs: Vec<Feat> = untested.iter().take(60).map(encode).collect();
        let feas = joint_feasibility_many(&m, &cs, &xs);
        let cond = m.condition(&xs[0]);
        for models in [&m, &cond] {
            let want = cea_scores_feats(models, &cs, &xs);
            let got = cea_scores_feats_with_feas(models, &xs, &feas);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn infeasible_points_scored_near_zero() {
        let (m, _, untested) = fixture();
        let impossible = vec![Constraint::cost_max(1e-12)];
        let scores = cea_scores(&m, &impossible, &untested);
        assert!(scores.iter().all(|&s| s < 1e-3));
    }
}
