//! Full paper reproduction driver: regenerates every table and figure of
//! the evaluation (§IV) into `results/` and prints the same rows the paper
//! reports. Equivalent to `trimtuner repro all`, packaged as an example so
//! `cargo run --example repro_paper` works out of the box.
//!
//! Flags (forwarded to the harness): `--seeds N`, `--iters N`, `--full`,
//! `--out DIR`.

use trimtuner::cli::Args;
use trimtuner::experiments;

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = vec!["repro".into(), "all".into()];
    argv.extend(std::env::args().skip(1));
    let args = Args::parse(&argv);
    experiments::cmd_repro(&args)
}
