//! Artifact registry: manifest parsing + lazy PJRT compilation.

use super::json::JsonValue;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json` (shapes fixed at AOT time).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub n_train: usize,
    pub n_query: usize,
    pub d_in: usize,
    pub n_hyp: usize,
    pub mlp_batch: usize,
    pub mlp_eval: usize,
    pub mlp_in: usize,
    pub mlp_hidden: usize,
    pub mlp_out: usize,
    pub artifacts: Vec<String>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let v = JsonValue::parse(src)?;
        let u = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest missing {key}"))
        };
        let mlp = v.get("mlp").ok_or_else(|| anyhow!("missing mlp"))?;
        let m = |key: &str| -> Result<usize> {
            mlp.get(key)
                .and_then(|x| x.as_usize())
                .ok_or_else(|| anyhow!("manifest missing mlp.{key}"))
        };
        let artifacts = v
            .get("artifacts")
            .map(|a| a.keys().into_iter().cloned().collect())
            .unwrap_or_default();
        Ok(Manifest {
            n_train: u("n_train")?,
            n_query: u("n_query")?,
            d_in: u("d_in")?,
            n_hyp: u("n_hyp")?,
            mlp_batch: m("batch")?,
            mlp_eval: m("eval")?,
            mlp_in: m("in")?,
            mlp_hidden: m("hidden")?,
            mlp_out: m("out")?,
            artifacts,
        })
    }
}

/// PJRT client + compiled executables, keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    compiled: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Load the manifest and create the CPU client. Executables are
    /// compiled lazily on first use and cached.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_src =
            std::fs::read_to_string(dir.join("manifest.json")).with_context(
                || format!("read {:?} — run `make artifacts` first", dir),
            )?;
        let manifest = Manifest::parse(&manifest_src)?;
        // sanity: shapes must match the Rust-side constants
        if manifest.d_in != crate::space::D_IN {
            bail!(
                "artifact D_IN {} != rust D_IN {} — re-run make artifacts",
                manifest.d_in,
                crate::space::D_IN
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            compiled: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<String> {
        self.manifest.artifacts.clone()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parse {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.compiled.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 literals; returns the decomposed output
    /// tuple (aot.py lowers everything with return_tuple=True).
    pub fn run(
        &self,
        name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = shape.iter().product();
    if expect != data.len() as i64 {
        bail!("literal shape {:?} != data len {}", shape, data.len());
    }
    if shape.len() <= 1 {
        return Ok(xla::Literal::vec1(data));
    }
    Ok(xla::Literal::vec1(data).reshape(shape)?)
}

pub fn literal_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let src = r#"{
          "n_train": 64, "n_query": 288, "d_in": 7, "n_hyp": 10,
          "mlp": {"batch": 128, "eval": 512, "in": 784, "hidden": 256, "out": 10},
          "artifacts": {"gp_predict_acc": {"inputs": [], "bytes": 1}}
        }"#;
        let m = Manifest::parse(src).unwrap();
        assert_eq!(m.n_train, 64);
        assert_eq!(m.n_query, 288);
        assert_eq!(m.mlp_hidden, 256);
        assert_eq!(m.artifacts, vec!["gp_predict_acc".to_string()]);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"n_train": 64}"#).is_err());
    }
}
