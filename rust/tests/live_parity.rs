//! Live/replay parity: the coordinator-driven `Live` backend must be an
//! exact substitute for trace replay when observation noise is zero, and a
//! deterministic one regardless of worker count.

use trimtuner::coordinator::SimLauncher;
use trimtuner::engine::{
    self, EngineConfig, EvalBackend, LiveEval, OptimizerKind, RunResult,
    StopCondition,
};
use trimtuner::models::ModelKind;
use trimtuner::sim::{Dataset, NetKind};
use trimtuner::space::Constraint;

fn caps(net: NetKind) -> Vec<Constraint> {
    vec![Constraint::cost_max(net.paper_cost_cap())]
}

/// Paper defaults shrunk like `parallel_slate`'s smoke test so the GP
/// variants stay fast.
fn small_cfg(optimizer: OptimizerKind, seed: u64, iters: usize) -> EngineConfig {
    let mut cfg = EngineConfig::paper_default(optimizer, seed);
    cfg.max_iters = iters;
    cfg.n_rep = 10;
    cfg.n_popt_samples = 40;
    cfg.gp_hyper_samples = cfg.gp_hyper_samples.min(2);
    cfg
}

fn live_run(
    launcher: SimLauncher,
    workers: usize,
    eval: &Dataset,
    constraints: &[Constraint],
    cfg: &EngineConfig,
) -> RunResult {
    let mut backend = EvalBackend::Live(
        LiveEval::new(Box::new(launcher), workers).with_eval(eval),
    );
    let run = engine::run_backend(&mut backend, constraints, cfg)
        .expect("live run failed");
    backend.shutdown();
    run
}

fn assert_same_trajectory(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.tested.id(), rb.tested.id(), "{label}: tested point");
        assert_eq!(
            ra.outcome.acc.to_bits(),
            rb.outcome.acc.to_bits(),
            "{label}: observed accuracy"
        );
        assert_eq!(
            ra.explore_cost.to_bits(),
            rb.explore_cost.to_bits(),
            "{label}: charged cost"
        );
        assert_eq!(
            ra.cum_cost.to_bits(),
            rb.cum_cost.to_bits(),
            "{label}: cumulative cost"
        );
        assert_eq!(
            ra.duration_s.to_bits(),
            rb.duration_s.to_bits(),
            "{label}: measured duration"
        );
        assert_eq!(
            ra.incumbent.id(),
            rb.incumbent.id(),
            "{label}: incumbent"
        );
    }
}

/// ISSUE acceptance: with a zero-noise launcher, a `Live` run produces the
/// same tested-point trajectory and charged costs as `Replay` on the
/// matching ground-truth dataset — for both TrimTuner model kinds and a
/// full-config baseline (which also exercises the parallel LHS init batch).
#[test]
fn zero_noise_live_matches_replay_exactly() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for (optimizer, iters) in [
        (OptimizerKind::TrimTuner(ModelKind::Gp), 3),
        (OptimizerKind::TrimTuner(ModelKind::Trees), 6),
        (OptimizerKind::Eic, 4),
    ] {
        let cfg = small_cfg(optimizer, 5, iters);
        let replay = engine::run(&truth, &constraints, &cfg);
        let live = live_run(
            SimLauncher::noiseless(net),
            2,
            &truth,
            &constraints,
            &cfg,
        );
        assert_same_trajectory(&replay, &live, &optimizer.name());
        // with the same eval oracle the evaluation metrics agree too
        for (ra, rb) in replay.records.iter().zip(&live.records) {
            assert_eq!(
                ra.accuracy_c.to_bits(),
                rb.accuracy_c.to_bits(),
                "{}: accuracy_c",
                optimizer.name()
            );
        }
    }
}

/// A *noisy* live run must be deterministic in the worker count: the
/// launcher draws noise per job id, ids are assigned in submission order,
/// and results are consumed in submission order — so 1 worker and 4
/// workers must produce identical trajectories.
#[test]
fn noisy_live_runs_identical_across_worker_counts() {
    let net = NetKind::Mlp;
    let truth = Dataset::ground_truth(net);
    let constraints = caps(net);
    for optimizer in [
        OptimizerKind::TrimTuner(ModelKind::Trees),
        OptimizerKind::Eic,
    ] {
        let cfg = small_cfg(optimizer, 9, 5);
        let mk = |workers| {
            live_run(
                SimLauncher::new(net, 33),
                workers,
                &truth,
                &constraints,
                &cfg,
            )
        };
        let one = mk(1);
        let four = mk(4);
        assert_same_trajectory(&one, &four, &optimizer.name());
    }
}

/// Without an eval oracle the live run still works end to end; the
/// evaluation-only fields are NaN while the decision-side fields (model
/// predictions, charged costs) stay real — and the `NoImprovement` stop
/// condition keeps functioning, since it reads only predictions.
#[test]
fn live_without_oracle_runs_and_quarantines_ground_truth() {
    let net = NetKind::Multilayer;
    let mut cfg =
        small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 2, 12);
    cfg.stop = StopCondition::NoImprovement { window: 3, min_delta: 1e-4 };
    let mut backend = EvalBackend::Live(LiveEval::new(
        Box::new(SimLauncher::new(net, 4)),
        3,
    ));
    let run = engine::run_backend(&mut backend, &caps(net), &cfg)
        .expect("live run failed");
    assert!(run.optimum_acc.is_nan(), "no oracle, no ground-truth optimum");
    assert!(run.optimum.is_none());
    assert!(!run.records.is_empty());
    for r in &run.records {
        assert!(r.inc_acc.is_nan(), "ground truth leaked into live record");
        assert!(r.accuracy_c.is_nan());
        assert!(r.outcome.acc.is_finite(), "observations must be real");
        assert!(r.cum_cost.is_finite() && r.cum_cost >= 0.0);
    }
    // the last main-loop record's prediction is finite: the stop decision
    // was computable without ground truth
    let last = run.records.last().unwrap();
    assert!(last.inc_pred_acc.is_finite());
}

/// The init snapshot charge must match between backends even when noisy:
/// one training run at the largest level, not four separate probes.
#[test]
fn live_init_charges_snapshot_cost_once() {
    let net = NetKind::Rnn;
    let truth = Dataset::ground_truth(net);
    let cfg = small_cfg(OptimizerKind::TrimTuner(ModelKind::Trees), 7, 1);
    let run = live_run(
        SimLauncher::noiseless(net),
        2,
        &truth,
        &caps(net),
        &cfg,
    );
    let init: Vec<_> = run.records.iter().filter(|r| r.is_init).collect();
    assert_eq!(init.len(), 4);
    // only the last (largest-level) init record carries a charge
    for r in &init[..3] {
        assert_eq!(r.explore_cost, 0.0);
        assert_eq!(r.duration_s, 0.0);
    }
    let last = init[3];
    assert!(last.explore_cost > 0.0);
    // and that charge is exactly the largest tested level's ground truth
    assert_eq!(last.explore_cost, truth.outcome(&last.tested).cost_usd);
}
