//! Channel shim for the coordinator: `std::sync::mpsc` in production
//! builds, a hand-rolled loom-modelable bounded channel under
//! `--cfg loom`.
//!
//! `tools/loom-models` compiles this exact file (by `#[path]` include)
//! with `--cfg loom` and model-checks the worker pool's shutdown protocol
//! over it: the bounded [`queue`] below has the same blocking/disconnect
//! semantics as `std::sync::mpsc::sync_channel` — `send` blocks while
//! full and unblocks with an error when the receiver drops, `recv` drains
//! buffered values then errors once every sender is gone — which is
//! precisely the surface the PR 2 `WorkerPool` join deadlock lived on.
//! Production code keeps the battle-tested std channel; the queue is
//! still compiled and unit-tested under `cfg(test)` so the loom model
//! can never drift from a stale copy of the semantics.
#![allow(unknown_lints)]
// `--cfg loom` is set only by the tools/loom-models build
#![allow(unexpected_cfgs)]

#[cfg(loom)]
pub(crate) use queue::{bounded, Receiver, Sender, TryRecvError};
#[cfg(not(loom))]
pub(crate) use std_mpsc::{bounded, Receiver, Sender, TryRecvError};

/// Thin aliases over `std::sync::mpsc` — the production channel.
#[cfg(not(loom))]
mod std_mpsc {
    pub use std::sync::mpsc::{Receiver, SyncSender as Sender, TryRecvError};

    /// Bounded MPSC channel (`std::sync::mpsc::sync_channel`).
    pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
        std::sync::mpsc::sync_channel(cap)
    }
}

/// Hand-rolled bounded MPSC channel over `Mutex` + `Condvar`, with
/// `sync_channel` semantics. Exists because loom models its own `Mutex`/
/// `Condvar`/`Arc` but has no bounded mpsc; building the channel from
/// primitives loom *does* model lets the interleaving checker drive every
/// blocking edge the pool's shutdown protocol depends on.
#[cfg(any(loom, test))]
pub(crate) mod queue {
    #[cfg(loom)]
    use loom::sync::{Arc, Condvar, Mutex};
    #[cfg(not(loom))]
    use std::sync::{Arc, Condvar, Mutex};

    use std::collections::VecDeque;
    use std::sync::PoisonError;

    /// The receiver disconnected; the unsent value comes back.
    #[derive(Debug)]
    pub struct SendError<T>(pub T);

    impl<T> std::fmt::Display for SendError<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "sending on a closed channel")
        }
    }

    /// Every sender disconnected and the buffer is drained.
    #[derive(Debug, PartialEq, Eq)]
    pub struct RecvError;

    /// Non-blocking recv outcome; mirrors `std::sync::mpsc::TryRecvError`
    /// variant-for-variant so callers match the same names against either
    /// channel implementation.
    #[derive(Debug, PartialEq, Eq)]
    pub enum TryRecvError {
        /// Nothing buffered right now, but senders remain — a value may
        /// still arrive.
        Empty,
        /// Every sender disconnected and the buffer is drained; no value
        /// will ever arrive.
        Disconnected,
    }

    impl std::fmt::Display for RecvError {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "receiving on a closed channel")
        }
    }

    struct State<T> {
        buf: VecDeque<T>,
        cap: usize,
        senders: usize,
        rx_alive: bool,
    }

    struct Shared<T> {
        state: Mutex<State<T>>,
        cond: Condvar,
    }

    pub struct Sender<T> {
        shared: Arc<Shared<T>>,
    }

    pub struct Receiver<T> {
        shared: Arc<Shared<T>>,
    }

    /// Bounded MPSC channel with `sync_channel` semantics for `cap >= 1`.
    ///
    /// **Divergence from std:** `sync_channel(0)` is a rendezvous channel
    /// (every send blocks for a matching recv); this queue instead
    /// *rejects* `cap == 0` with a panic. The coordinator never uses
    /// rendezvous hand-off — its channels carry buffered work/results —
    /// and a rendezvous mode would add blocking edges the loom model
    /// would have to check without any production code exercising them.
    /// The rejection is asserted in the unit tests below so the contract
    /// can't silently drift.
    pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
        assert!(cap > 0, "bounded channel needs capacity");
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                buf: VecDeque::new(),
                cap,
                senders: 1,
                rx_alive: true,
            }),
            cond: Condvar::new(),
        });
        (Sender { shared: shared.clone() }, Receiver { shared })
    }

    impl<T> Sender<T> {
        /// Blocks while the buffer is full; errors (returning the value)
        /// once the receiver is gone — which is exactly how a worker
        /// blocked mid-`send` observes pool shutdown.
        pub fn send(&self, value: T) -> Result<(), SendError<T>> {
            let mut value = Some(value);
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            loop {
                if !st.rx_alive {
                    return Err(SendError(value.take().expect("unsent")));
                }
                if st.buf.len() < st.cap {
                    st.buf.push_back(value.take().expect("unsent"));
                    self.shared.cond.notify_all();
                    return Ok(());
                }
                st = self
                    .shared
                    .cond
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Sender<T> {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            st.senders += 1;
            drop(st);
            Sender { shared: self.shared.clone() }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            st.senders -= 1;
            let last = st.senders == 0;
            drop(st);
            if last {
                // wake a receiver blocked in recv so it can disconnect
                self.shared.cond.notify_all();
            }
        }
    }

    impl<T> Receiver<T> {
        /// Blocks while the buffer is empty and senders remain; drains
        /// buffered values even after every sender dropped.
        pub fn recv(&self) -> Result<T, RecvError> {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            loop {
                if let Some(v) = st.buf.pop_front() {
                    // a slot freed: wake senders blocked on the bound
                    self.shared.cond.notify_all();
                    return Ok(v);
                }
                if st.senders == 0 {
                    return Err(RecvError);
                }
                st = self
                    .shared
                    .cond
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        }

        /// Non-blocking [`Receiver::recv`]: pops a buffered value if one is
        /// ready, otherwise reports [`TryRecvError::Empty`] while senders
        /// remain and [`TryRecvError::Disconnected`] once every sender is
        /// gone and the buffer is drained — the same tri-state contract as
        /// `std::sync::mpsc::Receiver::try_recv`, which the engine's
        /// asynchronous result-drain path polls between selections.
        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            if let Some(v) = st.buf.pop_front() {
                // a slot freed: wake senders blocked on the bound
                self.shared.cond.notify_all();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(TryRecvError::Disconnected);
            }
            Err(TryRecvError::Empty)
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            let mut st = self
                .shared
                .state
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            st.rx_alive = false;
            drop(st);
            // unblock every sender waiting on a full buffer — the
            // deadlock-critical property the pool's shutdown order
            // depends on (see WorkerPool::close and detlint rule R5)
            self.shared.cond.notify_all();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::queue::{bounded, RecvError, TryRecvError};
    use std::time::Duration;

    #[test]
    fn fifo_order_and_drain_after_sender_drop() {
        let (tx, rx) = bounded::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_blocks_at_capacity_until_recv() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the 1 is received
        });
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        h.join().unwrap();
    }

    /// The property the pool's shutdown protocol rests on: a sender
    /// blocked on a full buffer unblocks with an error when the receiver
    /// drops, instead of deadlocking.
    #[test]
    fn receiver_drop_unblocks_a_full_send() {
        let (tx, rx) = bounded::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || tx.send(2));
        std::thread::sleep(Duration::from_millis(50));
        drop(rx);
        match h.join().unwrap() {
            Err(e) => assert_eq!(e.0, 2, "the unsent value comes back"),
            Ok(()) => panic!("send must fail once the receiver is gone"),
        }
    }

    #[test]
    fn cloned_senders_keep_the_channel_open() {
        let (tx, rx) = bounded::<u32>(4);
        let tx2 = tx.clone();
        drop(tx);
        tx2.send(9).unwrap();
        drop(tx2);
        assert_eq!(rx.recv(), Ok(9));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    /// The documented divergence from `std::sync::mpsc::sync_channel`:
    /// capacity 0 (rendezvous) is rejected, not supported (see
    /// [`super::queue::bounded`]'s docs for why).
    #[test]
    fn zero_capacity_is_rejected() {
        let r = std::panic::catch_unwind(|| bounded::<u32>(0));
        assert!(r.is_err(), "cap 0 must panic, not build a rendezvous");
    }

    /// The production (`std::sync::mpsc`) path drains buffered values in
    /// FIFO order after every sender dropped, then reports disconnect —
    /// the same contract `fifo_order_and_drain_after_sender_drop` pins on
    /// the loom-modelable queue.
    #[test]
    fn std_path_drains_fifo_after_sender_drop() {
        let (tx, rx) = super::bounded::<u32>(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert!(rx.recv().is_err(), "disconnect after drain");
    }

    /// The tri-state `try_recv` contract the async engine's result drain
    /// polls: `Empty` while senders remain and nothing is buffered, a
    /// value when one is ready (and a blocked sender wakes — the bound
    /// frees), `Disconnected` only after every sender dropped *and* the
    /// buffer drained.
    #[test]
    fn try_recv_tri_state_on_queue_path() {
        let (tx, rx) = bounded::<u32>(1);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        tx.send(1).unwrap();
        // a second send blocks on the full bound; try_recv must free it
        let h = std::thread::spawn(move || {
            tx.send(2).unwrap();
        });
        assert_eq!(rx.try_recv(), Ok(1));
        h.join().unwrap();
        assert_eq!(rx.try_recv(), Ok(2)); // drains even after sender drop
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    /// Same tri-state over the production `std::sync::mpsc` path, so the
    /// two channel implementations cannot drift apart on the non-blocking
    /// surface the way they are pinned together on the blocking one.
    #[test]
    fn try_recv_tri_state_on_std_path() {
        let (tx, rx) = super::bounded::<u32>(4);
        assert!(matches!(rx.try_recv(), Err(super::TryRecvError::Empty)));
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.try_recv().unwrap(), 1);
        drop(tx);
        assert_eq!(rx.try_recv().unwrap(), 2);
        assert!(matches!(
            rx.try_recv(),
            Err(super::TryRecvError::Disconnected)
        ));
    }

    /// Send-after-receiver-drop parity: both implementations fail the
    /// send and hand the unsent value back through field `.0` of the
    /// error, so the worker pool's shutdown handling is source-compatible
    /// with either channel.
    #[test]
    fn send_after_receiver_drop_error_parity() {
        // std::sync::mpsc path (production under cfg(not(loom)))
        let (tx, rx) = super::bounded::<u32>(1);
        drop(rx);
        let std_err = tx.send(7).expect_err("receiver gone");
        assert_eq!(std_err.0, 7, "std path returns the unsent value");

        // hand-rolled queue (the loom model's channel)
        let (tx, rx) = bounded::<u32>(1);
        drop(rx);
        let q_err = tx.send(7).expect_err("receiver gone");
        assert_eq!(q_err.0, 7, "queue path returns the unsent value");
    }
}
