"""Layer-2 JAX compute graphs, lowered AOT by aot.py and executed from Rust.

Two families of graphs:

1. GP posterior / marginal likelihood over the TrimTuner feature space.
   These call the Layer-1 Pallas covariance kernel (kernels.matern_fabolas)
   so the hot covariance computation lowers into the same HLO module. Shapes
   are fixed at lowering time (PJRT AOT requires static shapes): the Rust
   side pads the training set to ``N_TRAIN`` rows using the
   "padding-as-noise" trick — padded rows carry y=0 and observation noise
   1e6, which removes their influence from the posterior *exactly* (a GP
   observation with infinite noise contributes nothing).

2. A small MLP (784 -> 256 -> 10) train/eval step used by the end-to-end
   example: the Rust coordinator *actually trains* models at different
   sub-sampling rates through these artifacts, proving all three layers
   compose on a real workload.
"""

import jax
import jax.numpy as jnp

from .kernels import matern_fabolas as mk
from .kernels.matern_fabolas import D_IN, N_HYP

# Fixed AOT shapes — keep in sync with rust/src/runtime/shapes.rs.
N_TRAIN = 64  # padded training-set size for GP artifacts
N_QUERY = 288  # query tile (one full cloud x hyper-param grid slice)
JITTER = 1e-6

MLP_IN = 784
MLP_HIDDEN = 256
MLP_OUT = 10
MLP_BATCH = 128
MLP_EVAL = 512


# --------------------------------------------------------------------------
# Pure-jnp linear algebra
#
# jax's lax.linalg.{cholesky, triangular_solve} lower to LAPACK custom-calls
# with API_VERSION_TYPED_FFI, which xla_extension 0.5.1 (the runtime the
# `xla` 0.1.6 crate links) rejects at compile time. These loop-based
# versions lower to plain HLO (fori_loop + dynamic slicing) and run anywhere.
# N_TRAIN is 64, so the O(n) sequential loop is cheap.
# --------------------------------------------------------------------------

def cholesky_jnp(a):
    """Right-looking (outer-product) Cholesky; returns lower-triangular L."""
    a = jnp.asarray(a)
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(k, state):
        a_k, l = state
        pivot = jnp.sqrt(jnp.maximum(a_k[k, k], 1e-30))
        col = jnp.where(rows >= k, a_k[:, k] / pivot, 0.0)
        l = l.at[:, k].set(col)
        a_k = a_k - jnp.outer(col, col)
        return (a_k, l)

    _, l = jax.lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def solve_lower_jnp(l, b):
    """Forward substitution: solve L Y = B for lower-triangular L.

    b may be (n,) or (n, m).
    """
    l, b = jnp.asarray(l), jnp.asarray(b)
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    n = l.shape[0]

    def body(i, y):
        yi = (b[i, :] - l[i, :] @ y) / l[i, i]
        return y.at[i, :].set(yi)

    y = jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))
    return y[:, 0] if squeeze else y


def solve_lower_t_jnp(l, b):
    """Back substitution: solve Lᵀ X = B."""
    l, b = jnp.asarray(l), jnp.asarray(b)
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    n = l.shape[0]

    def body(j, x):
        i = n - 1 - j
        xi = (b[i, :] - l[:, i] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))
    return x[:, 0] if squeeze else x


# --------------------------------------------------------------------------
# GP graphs
# --------------------------------------------------------------------------

def gp_posterior(x_tr, y, noise, x_q, hyp, *, basis: str):
    """Predictive mean and variance at x_q.

    x_tr: (N_TRAIN, D_IN), y: (N_TRAIN,), noise: (N_TRAIN,) per-point
    observation noise (big value == padding), x_q: (N_QUERY, D_IN),
    hyp: (N_HYP,). Returns (mu, var), each (N_QUERY,).
    """
    n = x_tr.shape[0]
    k = mk.cov(x_tr, x_tr, hyp, basis=basis)
    k = k + jnp.diag(noise) + JITTER * jnp.eye(n, dtype=jnp.float32)
    l = cholesky_jnp(k)
    alpha = solve_lower_t_jnp(l, solve_lower_jnp(l, y))
    ks = mk.cov(x_tr, x_q, hyp, basis=basis)  # (N, Q)
    mu = ks.T @ alpha
    v = solve_lower_jnp(l, ks)
    var = mk.cov_diag(x_q, hyp, basis=basis) - jnp.sum(v * v, axis=0)
    return mu, jnp.maximum(var, 1e-12)


def gp_mll(x_tr, y, noise, hyp, *, basis: str):
    """Log marginal likelihood of the (padded) training set.

    With padding-as-noise the padded rows contribute a constant (independent
    of hyp up to the tiny k/1e6 term), so argmax over hyp is preserved.
    """
    n = x_tr.shape[0]
    k = mk.cov(x_tr, x_tr, hyp, basis=basis)
    k = k + jnp.diag(noise) + JITTER * jnp.eye(n, dtype=jnp.float32)
    l = cholesky_jnp(k)
    alpha = solve_lower_jnp(l, y)
    quad = jnp.sum(alpha * alpha)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    return -0.5 * quad - 0.5 * logdet - 0.5 * n * jnp.log(2.0 * jnp.pi)


def make_gp_posterior(basis: str):
    def fn(x_tr, y, noise, x_q, hyp):
        mu, var = gp_posterior(x_tr, y, noise, x_q, hyp, basis=basis)
        return (mu, var)

    return fn


def make_gp_mll(basis: str):
    def fn(x_tr, y, noise, hyp):
        return (gp_mll(x_tr, y, noise, hyp, basis=basis),)

    return fn


def make_cov(basis: str):
    def fn(x1, x2, hyp):
        return (mk.cov(x1, x2, hyp, basis=basis),)

    return fn


# --------------------------------------------------------------------------
# MLP graphs (end-to-end real workload)
# --------------------------------------------------------------------------

def _mlp_logits(w1, b1, w2, b2, x):
    h = jnp.tanh(x @ w1 + b1)
    return h @ w2 + b2


def mlp_train_step(w1, b1, w2, b2, xb, yb, lr):
    """One SGD step on softmax cross-entropy. yb is one-hot (B, 10).

    Returns (w1', b1', w2', b2', loss).
    """

    def loss_fn(params):
        w1, b1, w2, b2 = params
        logits = _mlp_logits(w1, b1, w2, b2, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.sum(yb * logp, axis=1))

    loss, grads = jax.value_and_grad(loss_fn)((w1, b1, w2, b2))
    g1, gb1, g2, gb2 = grads
    return (
        w1 - lr * g1,
        b1 - lr * gb1,
        w2 - lr * g2,
        b2 - lr * gb2,
        loss,
    )


def mlp_eval(w1, b1, w2, b2, x, y):
    """Classification accuracy and mean CE loss on an eval batch."""
    logits = _mlp_logits(w1, b1, w2, b2, x)
    acc = jnp.mean(
        (jnp.argmax(logits, axis=1) == jnp.argmax(y, axis=1)).astype(
            jnp.float32
        )
    )
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.sum(y * logp, axis=1))
    return (acc, loss)
