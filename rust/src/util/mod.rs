//! Substrate utilities: RNG, statistics, CSV I/O, timing, property testing.
//!
//! The offline crate registry for this build has no `rand`, `serde`,
//! `criterion` or `proptest`, so these are small, self-contained
//! implementations with unit tests of their own (see DESIGN.md §2,
//! "Environment deviations").

pub mod alloc_count;
pub mod csv;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;

/// Worker count for slate-parallel acquisition evaluation:
/// `TRIMTUNER_SLATE_THREADS` if set, otherwise the machine's available
/// parallelism. Shared by `AlphaCache::eval_slate` and `acq::AlphaSlate`.
pub fn slate_threads() -> usize {
    if let Ok(v) = std::env::var("TRIMTUNER_SLATE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `xs`, sharded across up to `threads` scoped workers.
/// The chunk layout and per-item call order are independent of the worker
/// count and every result is written into its own slot, so the output is
/// bit-identical to the sequential map for any `threads`. The single
/// sharding implementation behind `AlphaCache::eval_slate` and
/// `acq::AlphaSlate::eval_feats` — their cross-path bit-stability
/// contracts depend on these two never diverging.
pub fn shard_map<T, F>(xs: &[T], threads: usize, f: F) -> Vec<f64>
where
    T: Sync,
    F: Fn(&T) -> f64 + Sync,
{
    shard_map_with(xs, threads, || (), |_, x| f(x))
}

/// [`shard_map`] with per-worker mutable state: `init` builds one fresh
/// state per worker (one total on the sequential path) and `f` receives it
/// mutably alongside each item. This is how the slate sweep reuses scratch
/// buffers across candidates without any cross-worker sharing; results
/// must not depend on the state's history (every scratch consumer resets
/// its buffers on use), which keeps the output bit-identical for any
/// worker count.
pub fn shard_map_with<T, S, I, F>(
    xs: &[T],
    threads: usize,
    init: I,
    f: F,
) -> Vec<f64>
where
    T: Sync,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &T) -> f64 + Sync,
{
    let workers = threads.min(xs.len());
    if workers <= 1 {
        let mut state = init();
        return xs.iter().map(|x| f(&mut state, x)).collect();
    }
    let mut out = vec![0.0f64; xs.len()];
    let chunk = (xs.len() + workers - 1) / workers;
    let (fr, ir) = (&f, &init);
    std::thread::scope(|s| {
        for (cx, co) in xs.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || {
                let mut state = ir();
                for (slot, x) in co.iter_mut().zip(cx) {
                    *slot = fr(&mut state, x);
                }
            });
        }
    });
    out
}
