//! Simulator benchmarks: oracle evaluation and full measurement-campaign
//! generation (the substrate behind every experiment).
mod common;

use trimtuner::sim::{CloudSim, Dataset, NetKind};
use trimtuner::space::{all_points, Point};
use trimtuner::util::timer::bench;
use trimtuner::util::Rng;

fn main() {
    common::print_header("simulator");
    let sim = CloudSim::new(NetKind::Cnn);
    let pts: Vec<Point> = all_points().collect();

    let stats = bench("ground_truth x1440", 3, 50, || {
        pts.iter().map(|p| sim.ground_truth(p).acc).sum::<f64>()
    });
    println!("{}", stats.report());

    let stats = bench("observe (noisy) x1440", 3, 50, || {
        let mut rng = Rng::new(1);
        pts.iter().map(|p| sim.observe(p, &mut rng).acc).sum::<f64>()
    });
    println!("{}", stats.report());

    let stats = bench("Dataset::generate (3 reps x 1440)", 1, 10, || {
        Dataset::generate(NetKind::Cnn, 42).len()
    });
    println!("{}", stats.report());
}
