//! Minimal JSON parser (offline registry has no `serde`), sufficient for
//! the artifact manifest: objects, arrays, strings, numbers, bools, null.

use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(HashMap<String, JsonValue>),
}

impl JsonValue {
    pub fn parse(src: &str) -> Result<JsonValue> {
        let mut p = Parser { s: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&String> {
        match self {
            JsonValue::Obj(m) => m.keys().collect(),
            _ => Vec::new(),
        }
    }
}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.s.len()
            && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(m));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(a));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().unwrap_or(b'"');
                    self.i += 1;
                    match e {
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            // \uXXXX (BMP only — enough for a manifest)
                            let hex = std::str::from_utf8(
                                &self.s[self.i..self.i + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{FFFD}'),
                            );
                        }
                        other => out.push(other as char),
                    }
                }
                other => out.push(other as char),
            }
        }
        bail!("unterminated string")
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.i])?;
        Ok(JsonValue::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
            "n_train": 64, "d_in": 7,
            "artifacts": {"gp_predict_acc": {"inputs": [[64,7],[64]], "bytes": 123}},
            "ok": true, "none": null, "pi": -3.5e0
        }"#;
        let v = JsonValue::parse(src).unwrap();
        assert_eq!(v.get("n_train").unwrap().as_usize(), Some(64));
        let inputs = v
            .get("artifacts")
            .and_then(|a| a.get("gp_predict_acc"))
            .and_then(|a| a.get("inputs"))
            .and_then(|a| a.as_arr())
            .unwrap();
        assert_eq!(inputs[0].as_arr().unwrap()[1].as_usize(), Some(7));
        assert_eq!(v.get("ok"), Some(&JsonValue::Bool(true)));
        assert_eq!(v.get("pi").unwrap().as_f64(), Some(-3.5));
    }

    #[test]
    fn rejects_garbage() {
        assert!(JsonValue::parse("{,}").is_err());
        assert!(JsonValue::parse("[1, 2").is_err());
        assert!(JsonValue::parse("12 34").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = JsonValue::parse(r#""a\nbA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nbA"));
    }
}
