//! Hand-rolled CLI argument parsing (offline registry has no `clap`).
//!
//! The parser is generic (`--key value` / bare `--flag` switches); the
//! flags each subcommand actually reads live next to their `cmd_*`
//! handlers. For reference, the `optimize` subcommand — the one users hit
//! first — understands (see `main.rs` and `docs/ARCHITECTURE.md`, which
//! must stay in agreement with this table):
//!
//! | flag | default | meaning |
//! |------|---------|---------|
//! | `--net rnn\|mlp\|cnn\|multilayer` | `rnn` | workload / dataset |
//! | `--optimizer <name>` | `trimtuner-dt` | `trimtuner-dt`, `trimtuner-gp`, `eic`, `eic-usd`, `fabolas`, `random` |
//! | `--filter cea\|random\|nofilter\|direct\|cmaes` | per-optimizer | acquisition filtering heuristic |
//! | `--beta 0.1` | 0.1 | filtering level β (fraction of untested points scored) |
//! | `--iters 44` | 44 | total probe budget (submitted probes; equals observations unless probes are abandoned under faults) |
//! | `--seed 0` | 0 | RNG seed (runs are deterministic per seed) |
//! | `--cost-cap <usd>` | per-net | QoS constraint: max training cost |
//! | `--pareto` | off | also report the predicted (cost, accuracy) frontier |
//! | `--live` | off | deploy probes through the worker-pool coordinator instead of trace replay |
//! | `--workers 4` | 4 | worker threads of the live coordinator pool |
//! | `--batch-size 1` | 1 | probes launched concurrently per selection round (q); 1 = the paper's sequential loop |
//! | `--async` | off | non-barrier scheduler: re-select the moment a pool slot frees, conditioning on all in-flight probes; absorbs completions in logical order so traces are bit-identical at any worker count |
//! | `--max-inflight N` | pool width | pin the async in-flight target (decouples the logical trajectory from the physical worker count) |
//! | `--refit <spec>` | `every=1` | full-refit policy: `every=K,evidence-drop=X` — full surrogate refit (hyperopt + tree rebuild) every K rounds, incremental O(n²) absorption in between; X nats of predictive surprise over the baseline force an early full refit |
//! | `--launcher-noise 1.0` | 1.0 | observation-noise scale of the simulated launcher (0 = ground truth) |
//! | `--launcher-seed <seed>` | derived | seed of the launcher's per-job noise stream |
//! | `--faults <spec>` | none | fault injection into the live launcher stack: `spot:RATE,straggle:SEV,flaky:RATE,timeout:SECS,fallback` (requires `--live`) |
//! | `--retry <spec>` | `max=3` | retry/abandonment policy: `max=N,base=S,factor=F,cap=S,jitter=J,deadline=S` |
//! | `--fault-seed <seed>` | derived | seed of the fault decorators' per-job decision streams |
//!
//! `optimize --help` prints the same synopsis at the terminal.

use std::collections::HashMap;

/// Parsed command line: positional arguments + `--key value` flags
/// (`--flag` with no value is stored as "true").
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                let next_is_value =
                    argv.get(i + 1).map_or(false, |n| !n.starts_with("--"));
                if next_is_value {
                    a.flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    a.flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Boolean switch: bare `--flag` (stored as "true") or an explicit
    /// `--flag true|false`.
    pub fn get_bool(&self, key: &str) -> bool {
        match self.get(key) {
            Some(v) => v != "false" && v != "0",
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_positional_and_flags() {
        let a = Args::parse(&argv(
            "repro fig1 --out results --seeds 5 --full",
        ));
        assert_eq!(a.positional, vec!["repro", "fig1"]);
        assert_eq!(a.get("out"), Some("results"));
        assert_eq!(a.get_usize("seeds", 0), 5);
        assert!(a.has("full"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("optimize"));
        assert_eq!(a.get_f64("beta", 0.1), 0.1);
        assert_eq!(a.get_or("net", "mlp"), "mlp");
    }

    #[test]
    fn bool_switches() {
        let a = Args::parse(&argv("optimize --live --workers 4"));
        assert!(a.get_bool("live"));
        assert!(!a.get_bool("replay"));
        let b = Args::parse(&argv("optimize --live false"));
        assert!(!b.get_bool("live"));
    }
}
