//! PJRT runtime benchmarks: artifact execution latency for the covariance
//! kernel (Layer-1), batched GP posterior (Layer-2) and MLP training step.
//! Skips gracefully when `make artifacts` has not been run.
mod common;

use trimtuner::models::{Basis, Feat, KernelParams};
use trimtuner::runtime::{MlpParams, MlpTrainer, Runtime, SyntheticMnist, XlaGp};
use trimtuner::util::timer::bench;
use trimtuner::util::Rng;

fn main() {
    common::print_header("runtime (PJRT artifacts)");
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP: {e:#} (run `make artifacts`)");
            return;
        }
    };
    let mut rng = Rng::new(2);
    let rand_feat = |rng: &mut Rng| {
        let mut f: Feat = [0.0; trimtuner::space::D_IN];
        for v in f.iter_mut() {
            *v = rng.f64();
        }
        f
    };

    let params = KernelParams::default();
    let xs: Vec<Feat> = (0..48).map(|_| rand_feat(&mut rng)).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x[0]).collect();
    let queries: Vec<Feat> = (0..288).map(|_| rand_feat(&mut rng)).collect();
    let gp = XlaGp::new(&rt, Basis::Acc, &params, &xs, &ys).unwrap();

    let stats = bench("xla gp_predict (48 tr, 288 q)", 1, 10, || {
        gp.predict_batch(&queries).unwrap().0[0]
    });
    println!("{}", stats.report());
    let stats = bench("xla gp_mll (64 padded)", 1, 10, || gp.mll().unwrap());
    println!("{}", stats.report());

    let m = &rt.manifest;
    let data = SyntheticMnist::generate(m.mlp_batch * 4, m.mlp_in, m.mlp_out, 3);
    let idx: Vec<usize> = (0..m.mlp_batch).collect();
    let (bx, by) = data.batch(&idx);
    let mut trainer =
        MlpTrainer::new(&rt, MlpParams::init(&rt, &mut rng), 0.3);
    let stats = bench("xla mlp_train_step (B=128)", 1, 10, || {
        trainer.step(&bx, &by).unwrap()
    });
    println!("{}", stats.report());
}
