//! Simplified DIRECT (DIviding RECTangles, Jones et al. 1993) over the
//! [0,1]^7 continuous relaxation of the search space.
//!
//! Each rectangle's center is snapped to the nearest untested grid point for
//! evaluation; potentially-optimal rectangles (Pareto front over size ×
//! value) are trisected along their longest side until the budget of unique
//! acquisition evaluations is exhausted.

use super::{nearest_untested, AlphaCache, D_IN};
use crate::models::Feat;
use crate::space::Point;

#[derive(Debug, Clone)]
struct Rect {
    center: [f64; D_IN],
    /// half-side length per dimension
    half: [f64; D_IN],
    value: f64,
}

impl Rect {
    fn size(&self) -> f64 {
        // l2 norm of the half-sides (standard DIRECT measure)
        self.half.iter().map(|h| h * h).sum::<f64>().sqrt()
    }
    fn longest_dim(&self) -> usize {
        let mut best = 0;
        for d in 1..D_IN {
            if self.half[d] > self.half[best] + 1e-15 {
                best = d;
            }
        }
        best
    }
}

pub struct DirectSearch;

impl DirectSearch {
    #[allow(clippy::new_without_default)]
    pub fn new() -> DirectSearch {
        DirectSearch
    }

    /// `untested_feats[i]` must be `encode(&untested[i])` — encoded once by
    /// the caller, reused across every center snap.
    pub fn run(
        &self,
        untested: &[Point],
        untested_feats: &[Feat],
        budget: usize,
        alpha: &mut AlphaCache<'_>,
    ) {
        let eval = |center: &[f64; D_IN], alpha: &mut AlphaCache<'_>| {
            let p = nearest_untested(center, untested, untested_feats);
            alpha.eval(&p)
        };

        let mut rects = vec![Rect {
            center: [0.5; D_IN],
            half: [0.5; D_IN],
            value: 0.0,
        }];
        rects[0].value = eval(&rects[0].center, alpha);

        // Termination guards beyond the α-eval budget: snapped grid
        // evaluations can hit the cache (no *unique* evals), so bound the
        // outer rounds, the rectangle population (the Pareto scan is
        // quadratic) and consecutive rounds without new unique evals.
        let mut stalls = 0usize;
        let mut rounds = 0usize;
        let max_rects = (8 * budget).clamp(64, 4096);
        while alpha.unique_evals() < budget
            && stalls < 3
            && rounds < 100
            && rects.len() < max_rects
        {
            rounds += 1;
            let evals_before = alpha.unique_evals();
            // potentially-optimal: Pareto-maximal in (size, value)
            let mut chosen: Vec<usize> = Vec::new();
            for i in 0..rects.len() {
                let dominated = rects.iter().enumerate().any(|(j, r)| {
                    j != i
                        && r.size() >= rects[i].size()
                        && r.value >= rects[i].value
                        && (r.size() > rects[i].size()
                            || r.value > rects[i].value)
                });
                if !dominated {
                    chosen.push(i);
                }
            }
            if chosen.is_empty() {
                break;
            }
            let mut progressed = false;
            for &i in &chosen {
                if alpha.unique_evals() >= budget {
                    break;
                }
                let dim = rects[i].longest_dim();
                if rects[i].half[dim] < 1e-4 {
                    continue; // too small to split further
                }
                let step = 2.0 * rects[i].half[dim] / 3.0;
                // trisect: two new rects offset along `dim`
                let mut parent = rects[i].clone();
                parent.half[dim] /= 3.0;
                for side in [-1.0, 1.0] {
                    let mut child = parent.clone();
                    child.center[dim] += side * step;
                    child.value = eval(&child.center, alpha);
                    rects.push(child);
                    if alpha.unique_evals() >= budget {
                        break;
                    }
                }
                rects[i].half[dim] /= 3.0;
                progressed = true;
            }
            if !progressed {
                break; // everything at resolution floor
            }
            if alpha.unique_evals() == evals_before {
                stalls += 1;
            } else {
                stalls = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{all_points, encode};

    #[test]
    fn direct_finds_good_point_on_smooth_surface() {
        let untested: Vec<Point> = all_points().collect();
        let feats: Vec<Feat> = untested.iter().map(encode).collect();
        // objective: negative distance to a known target point
        let target = encode(&Point::from_id(777));
        let mut alpha = AlphaCache::new(|p: &Point| {
            let e = encode(p);
            -e.iter()
                .zip(&target)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
        });
        DirectSearch::new().run(&untested, &feats, 120, &mut alpha);
        let (best, v) = alpha.best().unwrap();
        assert!(alpha.unique_evals() <= 120);
        // must get close to the optimum (value 0 at the target itself)
        assert!(v > -0.4, "best {v} at {best:?}");
    }

    #[test]
    fn direct_respects_tiny_budget() {
        let untested: Vec<Point> = all_points().take(200).collect();
        let feats: Vec<Feat> = untested.iter().map(encode).collect();
        let mut alpha = AlphaCache::new(|p: &Point| encode(p)[5]);
        DirectSearch::new().run(&untested, &feats, 5, &mut alpha);
        assert!(alpha.unique_evals() <= 5);
        assert!(alpha.unique_evals() >= 1);
    }
}
