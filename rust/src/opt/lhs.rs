//! Latin Hypercube Sampling over the unit hypercube.
//!
//! Used to pick initial full-data-set configurations for the EIc / EIc/USD
//! baselines (the paper bootstraps them with 4 LHS samples, §IV) and offered
//! for TrimTuner's multi-config initialization (paper footnote 1).

use crate::util::Rng;

/// `n` points in `[0,1]^d`, one per row, stratified per dimension.
pub fn latin_hypercube(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
    let mut out = vec![vec![0.0; d]; n];
    for dim in 0..d {
        // Stratified samples: one uniform draw inside each of n bins...
        let mut vals: Vec<f64> =
            (0..n).map(|i| (i as f64 + rng.f64()) / n as f64).collect();
        // ...assigned to points in random order.
        rng.shuffle(&mut vals);
        for (row, v) in out.iter_mut().zip(vals) {
            row[dim] = v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn stratification_holds_per_dimension() {
        check("lhs stratification", 16, |rng| {
            let n = 2 + rng.below(20);
            let d = 1 + rng.below(6);
            let pts = latin_hypercube(rng, n, d);
            for dim in 0..d {
                let mut bins = vec![0usize; n];
                for p in &pts {
                    let b = ((p[dim] * n as f64) as usize).min(n - 1);
                    bins[b] += 1;
                }
                if bins.iter().any(|&c| c != 1) {
                    return Err(format!("dim {dim} bins {bins:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn values_in_unit_cube() {
        let mut rng = Rng::new(9);
        for p in latin_hypercube(&mut rng, 16, 4) {
            assert!(p.iter().all(|&v| (0.0..1.0).contains(&v)));
        }
    }
}
