//! End-to-end validation: TrimTuner drives *real* model training through
//! all three layers of the stack.
//!
//! For every configuration the optimizer probes, a real MLP classifier is
//! trained on a sub-sampled synthetic-MNIST dataset via the AOT-compiled
//! `mlp_train_step` / `mlp_eval` artifacts (JAX Layer-2 graphs with the
//! Pallas Layer-1 kernel lowered in, executed by the PJRT CPU client from
//! Rust). Python is never on the path. The cloud dimension (fleet size,
//! pricing) is simulated: cost = measured wall time x price model, scaled
//! by the configured fleet's throughput model.
//!
//! Requires `make artifacts` first.
//! Run with: `cargo run --release --offline --example end_to_end`

use anyhow::Result;
use std::cell::RefCell;
use trimtuner::acq::Models;
use trimtuner::heuristics::cea_scores;
use trimtuner::models::{FitOptions, ModelKind};
use trimtuner::runtime::{MlpParams, MlpTrainer, Runtime, SyntheticMnist};
use trimtuner::sim::Outcome;
use trimtuner::space::{Config, Constraint, Point, S_VALUES};
use trimtuner::util::timer::Timer;
use trimtuner::util::Rng;

/// Epochs of SGD per probe (small: this is a demo workload).
const EPOCHS: usize = 2;
/// Full synthetic-MNIST training set size (sub-sampled by s).
const FULL_N: usize = 8192;
/// Cost cap for the QoS constraint (USD).
const COST_CAP: f64 = 0.004;

struct XlaCloud<'rt> {
    rt: &'rt Runtime,
    train: SyntheticMnist,
    eval: SyntheticMnist,
    rng: RefCell<Rng>,
}

impl<'rt> XlaCloud<'rt> {
    /// Train the MLP at configuration `p` (lr/batch from the config, data
    /// sub-sampled at rate s) and measure accuracy + simulated cloud cost.
    fn run_job(&self, p: &Point) -> Result<Outcome> {
        let m = &self.rt.manifest;
        let mut rng = self.rng.borrow_mut();
        let n = ((p.s() * FULL_N as f64) as usize).max(m.mlp_batch);
        let lr = (p.config.learning_rate() * 2e3) as f32; // rescale to useful range
        let timer = Timer::start();

        let params = MlpParams::init(self.rt, &mut rng);
        let mut trainer = MlpTrainer::new(self.rt, params, lr);
        let steps = (n * EPOCHS / m.mlp_batch).max(1);
        for _ in 0..steps {
            // draw a batch from the first n rows (the sub-sample)
            let idx: Vec<usize> =
                (0..m.mlp_batch).map(|_| rng.below(n)).collect();
            let (bx, by) = self.train.batch(&idx);
            trainer.step(&bx, &by)?;
        }
        let idx: Vec<usize> = (0..m.mlp_eval).collect();
        let (ex, ey) = self.eval.batch(&idx);
        let (acc, _) = trainer.eval(&ex, &ey)?;

        // cloud simulation on top of the *measured* compute time: the fleet
        // parallelizes compute but adds per-step coordination.
        let wall = timer.elapsed_s();
        let w = p.config.nvms() as f64;
        let vcpus = p.config.vm().vcpus as f64;
        let eff = w * vcpus.powf(0.85);
        let coord = steps as f64 * 0.002 * (1.0 + w.log2());
        let sim_time = 3.0 + wall * 8.0 / eff + coord;
        let cost = sim_time / 3600.0 * p.config.fleet_price_hr();
        Ok(Outcome { acc, time_s: sim_time, cost_usd: cost })
    }
}

fn main() -> Result<()> {
    let rt = Runtime::load("artifacts")?;
    println!("runtime: platform={}", rt.platform());
    let m = &rt.manifest;
    let cloud = XlaCloud {
        rt: &rt,
        train: SyntheticMnist::generate(FULL_N, m.mlp_in, m.mlp_out, 1234),
        eval: SyntheticMnist::generate(m.mlp_eval, m.mlp_in, m.mlp_out, 1234),
        rng: RefCell::new(Rng::new(5)),
    };
    let constraints = vec![Constraint::cost_max(COST_CAP)];

    // A reduced search space for the live demo: 24 configs x 3 s-levels.
    let candidates: Vec<Point> = (0..288)
        .step_by(12)
        .flat_map(|id| {
            [0usize, 2, 4].into_iter().map(move |s_idx| Point {
                config: Config::from_id(id),
                s_idx,
            })
        })
        .collect();

    // ---- init: one config at 3 sub-sampling levels (snapshot-style) ----
    let mut tested: Vec<Point> = Vec::new();
    let mut outcomes: Vec<Outcome> = Vec::new();
    let mut cum_cost = 0.0;
    for s_idx in [0usize, 2, 4] {
        let p = Point { config: Config::from_id(144), s_idx };
        let o = cloud.run_job(&p)?;
        println!(
            "init  s={:<5.3} acc {:.3} time {:>6.1}s cost ${:.5}",
            p.s(),
            o.acc,
            o.time_s,
            o.cost_usd
        );
        cum_cost += if s_idx == 4 { o.cost_usd } else { 0.0 };
        tested.push(p);
        outcomes.push(o);
    }

    let mut models = Models::new(ModelKind::Trees, 9);
    models.fit(&tested, &outcomes, FitOptions::default());

    // ---- main loop: CEA-guided probing of the live workload -------------
    let iters = 10;
    for it in 0..iters {
        let untested: Vec<Point> = candidates
            .iter()
            .filter(|p| !tested.iter().any(|t| t == *p))
            .copied()
            .collect();
        if untested.is_empty() {
            break;
        }
        let scores = cea_scores(&models, &constraints, &untested);
        let best = crate_argmax(&scores);
        let p = untested[best];
        let o = cloud.run_job(&p)?;
        cum_cost += o.cost_usd;
        println!(
            "it {it:>2} {} s={:<5.3} -> acc {:.3} cost ${:.5} (cum ${:.5})",
            p.config.describe(),
            p.s(),
            o.acc,
            o.cost_usd,
            cum_cost
        );
        tested.push(p);
        outcomes.push(o);
        models.fit(&tested, &outcomes, FitOptions::default());
    }

    // ---- recommendation --------------------------------------------------
    let full: Vec<Point> = candidates.iter().filter(|p| p.is_full()).copied().collect();
    let feats: Vec<_> = full.iter().map(trimtuner::space::encode).collect();
    let inc = trimtuner::acq::select_incumbent(&models, &constraints, &feats);
    let rec = full[inc.config_id.min(full.len() - 1)];
    let check = cloud.run_job(&rec)?;
    println!("--------------------------------------------------------");
    println!("recommended: {}", rec.config.describe());
    println!(
        "verification run: acc {:.3}, cost ${:.5} (cap ${COST_CAP}), feasible: {}",
        check.acc,
        check.cost_usd,
        check.cost_usd <= COST_CAP
    );
    println!("total exploration spend: ${cum_cost:.5}");
    anyhow::ensure!(check.acc > 0.5, "end-to-end training failed to learn");
    println!("end_to_end OK");
    Ok(())
}

fn crate_argmax(xs: &[f64]) -> usize {
    trimtuner::util::stats::argmax(xs).expect("non-empty")
}
