"""Layer-1 correctness: Pallas covariance kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and hyper-parameters; every case asserts
assert_allclose between the tiled/fused Pallas kernel and ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import matern_fabolas as mk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand_inputs(rng, m, n):
    x1 = rng.uniform(0.0, 1.0, size=(m, mk.D_IN)).astype(np.float32)
    x2 = rng.uniform(0.0, 1.0, size=(n, mk.D_IN)).astype(np.float32)
    hyp = np.concatenate(
        [
            rng.uniform(0.1, 2.0, size=mk.D_FEAT),  # lengthscales
            [rng.uniform(0.1, 3.0)],  # sigma2
            rng.uniform(0.05, 1.5, size=3),  # basis Cholesky l00,l10,l11
        ]
    ).astype(np.float32)
    return x1, x2, hyp


@pytest.mark.parametrize("basis", ["acc", "cost"])
@pytest.mark.parametrize("m,n", [(1, 1), (4, 7), (64, 288), (64, 64), (32, 96)])
def test_cov_matches_ref(basis, m, n):
    rng = np.random.default_rng(m * 1000 + n)
    x1, x2, hyp = rand_inputs(rng, m, n)
    got = np.asarray(mk.cov(x1, x2, hyp, basis=basis))
    want = np.asarray(ref.cov_ref(x1, x2, hyp, basis=basis))
    assert got.shape == (m, n)
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    basis=st.sampled_from(["acc", "cost"]),
    bm=st.sampled_from([8, 16, 32, 64]),
)
def test_cov_matches_ref_hypothesis(m, n, seed, basis, bm):
    rng = np.random.default_rng(seed)
    x1, x2, hyp = rand_inputs(rng, m, n)
    got = np.asarray(mk.cov(x1, x2, hyp, basis=basis, bm=bm, bn=bm))
    want = np.asarray(ref.cov_ref(x1, x2, hyp, basis=basis))
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), basis=st.sampled_from(["acc", "cost"]))
def test_cov_self_is_psd_and_symmetric(seed, basis):
    rng = np.random.default_rng(seed)
    x, _, hyp = rand_inputs(rng, 24, 1)
    k = np.asarray(mk.cov(x, x, hyp, basis=basis), dtype=np.float64)
    assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)
    evals = np.linalg.eigvalsh(k + 1e-5 * np.eye(24))
    assert evals.min() > 0, f"covariance not PSD: min eig {evals.min()}"


@pytest.mark.parametrize("basis", ["acc", "cost"])
def test_cov_diag_matches_full(basis):
    rng = np.random.default_rng(7)
    x, _, hyp = rand_inputs(rng, 32, 1)
    full = np.asarray(mk.cov(x, x, hyp, basis=basis))
    diag = np.asarray(mk.cov_diag(x, hyp, basis=basis))
    assert_allclose(np.diag(full), diag, rtol=1e-5, atol=1e-6)


def test_basis_semantics_acc():
    """For the accuracy basis, s=1 zeroes the data-size term: phi=(1,0)."""
    rng = np.random.default_rng(3)
    x1, _, hyp = rand_inputs(rng, 8, 1)
    x1[:, mk.D_FEAT] = 1.0
    k = np.asarray(mk.cov(x1, x1, hyp, basis="acc"))
    l00, sigma2 = hyp[mk.D_FEAT + 1], hyp[mk.D_FEAT]
    # all pairs share phi=(1,0): basis == Theta[0,0] == l00^2 everywhere
    x_cfg_equal = np.allclose(x1[:1, : mk.D_FEAT], x1[:1, : mk.D_FEAT])
    assert x_cfg_equal
    assert_allclose(k[0, 0], sigma2 * l00 * l00, rtol=1e-5)


def test_cov_blocks_partial_fallback():
    """Non-divisible sizes fall back to divisor tiles and stay correct."""
    rng = np.random.default_rng(11)
    x1, x2, hyp = rand_inputs(rng, 13, 29)
    got = np.asarray(mk.cov(x1, x2, hyp, basis="acc"))
    want = np.asarray(ref.cov_ref(x1, x2, hyp, basis="acc"))
    assert_allclose(got, want, rtol=1e-5, atol=1e-6)
