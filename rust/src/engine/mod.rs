//! The Bayesian-optimization engine: paper Algorithm 1 plus all baseline
//! optimizers, driven through an [`EvalBackend`] — trace replay over a
//! measured [`crate::sim::Dataset`] (the paper's evaluation methodology) or
//! live job deployments through the threaded coordinator.

mod backend;
mod loop_;
mod metrics;
mod pareto;
mod stop;

pub use backend::{EvalBackend, LiveEval, Probe, Snapshot};
pub use loop_::{run, run_backend, EngineConfig, OptimizerKind};
pub use metrics::{accuracy_c, cost_to_quality, IterRecord, RunResult};
pub use pareto::{
    frontier_quality, hypervolume, pareto_front, recommend_pareto,
    true_frontier, ParetoPoint,
};
pub use stop::StopCondition;
