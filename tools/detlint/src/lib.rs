//! detlint — the repo's determinism & concurrency contracts (rules R1–R5)
//! and hot-path allocation contracts (rules A1–A3) as a source-level lint
//! over `rust/src/**`.
//!
//! The engine's value rests on invariants the compiler cannot see:
//! bit-exact parity between sequential and sharded slate sweeps,
//! submission-order determinism across worker counts, seeded RNG
//! streams that make live runs replayable, and an allocation-free
//! per-candidate slate sweep (the paper's 65x recommendation speedup).
//! detlint encodes those as named, individually-suppressible rules;
//! `docs/ARCHITECTURE.md` ("Determinism contracts", "Allocation
//! contracts") maps each invariant to its rule, and this crate's README
//! documents every rule with fire/allow examples.
//!
//! Suppression, most local first:
//! - `// detlint: allow(R1, reason="…")` on the finding's line or the
//!   line above;
//! - `// detlint: allow-file(R3, reason="…")` anywhere in the file;
//! - an entry in `tools/detlint/detlint.allow` (`<rule> <path> <reason>`).
//!
//! Malformed pragmas are themselves findings (`P0`) and cannot be
//! suppressed.

pub mod lexer;
pub mod rules;

use rules::{Finding, RuleSet};
use std::path::{Path, PathBuf};

/// Tree-scan result. Suppressed findings are retained (pragma- and
/// allowlist-suppressed alike) so `--json` can emit them with
/// `"suppressed": true`.
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub suppressed_findings: Vec<Finding>,
    pub files: usize,
}

/// Parse `tools/detlint/hotpaths.toml`: a single `hot = [...]` array of
/// quoted `Type::fn` strings, with `#` comments and blank lines ignored.
/// Hand-rolled on purpose — the lint stays zero-dependency.
pub fn parse_hotpaths(text: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    let mut in_array = false;
    for (idx, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(h) => &raw[..h],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if !in_array {
            if let Some(rest) = line.strip_prefix("hot") {
                let rest = rest.trim_start();
                if let Some(rest) = rest.strip_prefix('=') {
                    let rest = rest.trim_start();
                    if let Some(rest) = rest.strip_prefix('[') {
                        in_array = true;
                        collect_quoted(rest, &mut out);
                        if rest.contains(']') {
                            in_array = false;
                        }
                        continue;
                    }
                }
            }
            return Err(format!(
                "hotpaths.toml:{}: expected `hot = [` or a comment, got \
                 `{line}`",
                idx + 1
            ));
        }
        collect_quoted(line, &mut out);
        if line.contains(']') {
            in_array = false;
        }
    }
    if in_array {
        return Err("hotpaths.toml: unterminated `hot = [` array".to_string());
    }
    Ok(out)
}

fn collect_quoted(line: &str, out: &mut Vec<String>) {
    let mut rest = line;
    while let Some(a) = rest.find('"') {
        let Some(b) = rest[a + 1..].find('"') else {
            return;
        };
        out.push(rest[a + 1..a + 1 + b].to_string());
        rest = &rest[a + 2 + b..];
    }
}

/// One `detlint.allow` entry: suppress `rule` everywhere in `path`.
pub struct AllowEntry {
    pub rule: String,
    pub path: String,
}

/// Parse the allowlist file: `<rule> <path> <reason…>` per line, `#`
/// comments and blank lines ignored. The reason column is mandatory for
/// the same reason pragmas require one.
pub fn parse_allowlist(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut out = Vec::new();
    for (idx, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let rule = parts.next().unwrap_or_default();
        let path = parts.next().unwrap_or_default();
        let reason = parts.next();
        if path.is_empty() || reason.is_none() {
            return Err(format!(
                "detlint.allow:{}: expected `<rule> <path> <reason…>`, got `{line}`",
                idx + 1
            ));
        }
        out.push(AllowEntry { rule: rule.to_string(), path: path.to_string() });
    }
    Ok(out)
}

/// Recursively collect `*.rs` files, sorted for deterministic output.
pub fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<Result<_, _>>()?;
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn normalize(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Lint every `.rs` file under `paths` (files or directories), applying
/// path-scoped rules, the allowlist, and the A1 hot-function registry
/// (`hot`; pass `None` to keep the built-in [`rules::DEFAULT_HOT`]).
pub fn scan_tree(
    paths: &[PathBuf],
    allow: &[AllowEntry],
    hot: Option<&[String]>,
) -> std::io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(p, &mut files)?;
        } else {
            files.push(p.clone());
        }
    }
    files.sort();
    files.dedup();
    let mut findings = Vec::new();
    let mut suppressed_findings = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)?;
        let rel = normalize(f);
        let mut rules_for = RuleSet::for_path(&rel);
        if let Some(hot) = hot {
            rules_for = rules_for.with_hot_fns(hot);
        }
        let mut out = rules::scan_source(&rel, &src, rules_for);
        suppressed_findings.append(&mut out.suppressed_findings);
        out.findings.retain(|fi| {
            let hit = allow.iter().any(|a| {
                a.rule.eq_ignore_ascii_case(fi.rule)
                    && (a.path == fi.file || fi.file.ends_with(&a.path))
            });
            if hit {
                suppressed_findings.push(fi.clone());
            }
            !hit
        });
        findings.append(&mut out.findings);
    }
    Ok(Report {
        findings,
        suppressed: suppressed_findings.len(),
        suppressed_findings,
        files: files.len(),
    })
}

/// Rustc-style rendering: `file:line:col: [rule] message`.
pub fn fmt_finding(f: &Finding) -> String {
    format!("{}:{}:{}: [{}] {}", f.file, f.line, f.col, f.rule, f.msg)
}

/// One finding as a single JSON object line (JSON Lines output mode).
pub fn fmt_finding_json(f: &Finding, suppressed: bool) -> String {
    format!(
        "{{\"file\":\"{}\",\"line\":{},\"col\":{},\"rule\":\"{}\",\
         \"message\":\"{}\",\"suppressed\":{}}}",
        json_escape(&f.file),
        f.line,
        f.col,
        f.rule,
        json_escape(&f.msg),
        suppressed
    )
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Run the fixture self-test: every rule R1–R5 and A1–A3 must fire on its
/// `*_fire.rs` fixture and stay silent on its `*_allow.rs` variant (which
/// contains both a compliant rewrite and a pragma-suppressed violation,
/// proving the suppression machinery too). Returns one human-readable line
/// per check.
pub fn self_test(fixtures: &Path) -> Result<Vec<String>, String> {
    let mut lines = Vec::new();
    for rule in ["R1", "R2", "R3", "R4", "R5", "A1", "A2", "A3"] {
        for (suffix, expect_fire) in [("fire", true), ("allow", false)] {
            let name =
                format!("{}_{suffix}.rs", rule.to_ascii_lowercase());
            let path = fixtures.join(&name);
            let src = std::fs::read_to_string(&path)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let out = rules::scan_source(
                &format!("fixtures/{name}"),
                &src,
                RuleSet::all(),
            );
            if expect_fire {
                let hits =
                    out.findings.iter().filter(|f| f.rule == rule).count();
                if hits == 0 {
                    return Err(format!(
                        "{name}: expected {rule} to fire, got: {:?}",
                        out.findings
                            .iter()
                            .map(fmt_finding)
                            .collect::<Vec<_>>()
                    ));
                }
                lines.push(format!("{rule} fires on {name} ({hits}x)"));
            } else if let Some(f) = out.findings.first() {
                return Err(format!(
                    "{name}: expected a clean pass, got: {}",
                    fmt_finding(f)
                ));
            } else {
                lines.push(format!(
                    "{rule} passes {name} ({} pragma-suppressed)",
                    out.suppressed
                ));
            }
        }
    }
    Ok(lines)
}
