//! detlint CLI. Exit codes: 0 clean, 1 findings, 2 usage/IO error.
//!
//! Argument parsing is hand-rolled like the main crate's `cli.rs` — the
//! offline registry has no clap.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
detlint — determinism, concurrency & allocation contract linter
(rules R1–R5, A1–A3)

USAGE:
    cargo run -p detlint [-- OPTIONS] [PATH...]

    PATH            files or directories to lint (default: <root>/rust/src)

OPTIONS:
    --root DIR      repo root the default scan paths, allowlist and hot
                    registry resolve against (default: .)
    --allow FILE    allowlist file
                    (default: <root>/tools/detlint/detlint.allow)
    --hotpaths FILE A1 hot-function registry
                    (default: <root>/tools/detlint/hotpaths.toml; the
                    built-in registry applies when the file is absent)
    --json          one JSON object per finding (file/line/col/rule/
                    message/suppressed) instead of the human format
    --self-test     verify every rule against its fire/allow fixtures
                    and exit
    --rules         print the rule catalog and exit
    -h, --help      this text";

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut allow_path: Option<PathBuf> = None;
    let mut hot_path: Option<PathBuf> = None;
    let mut selftest = false;
    let mut list_rules = false;
    let mut json = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage_err("--root needs a value"),
            },
            "--allow" => match args.next() {
                Some(v) => allow_path = Some(PathBuf::from(v)),
                None => return usage_err("--allow needs a value"),
            },
            "--hotpaths" => match args.next() {
                Some(v) => hot_path = Some(PathBuf::from(v)),
                None => return usage_err("--hotpaths needs a value"),
            },
            "--self-test" => selftest = true,
            "--rules" => list_rules = true,
            "--json" => json = true,
            "-h" | "--help" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            p if !p.starts_with('-') => paths.push(PathBuf::from(p)),
            other => {
                return usage_err(&format!("unknown flag `{other}`"));
            }
        }
    }

    if list_rules {
        for (id, contract) in detlint::rules::RULES {
            println!("{id}  {contract}");
        }
        return ExitCode::SUCCESS;
    }

    if selftest {
        // fixtures live next to this crate's manifest, wherever the
        // working directory is
        let fixtures =
            PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures"));
        return match detlint::self_test(&fixtures) {
            Ok(lines) => {
                for l in lines {
                    println!("detlint self-test: {l}");
                }
                println!("detlint self-test: all rules verified");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("detlint self-test FAILED: {e}");
                ExitCode::FAILURE
            }
        };
    }

    let root = root.unwrap_or_else(|| PathBuf::from("."));
    let scan: Vec<PathBuf> =
        if paths.is_empty() { vec![root.join("rust/src")] } else { paths };
    let allow_file = allow_path.or_else(|| {
        let p = root.join("tools/detlint/detlint.allow");
        p.exists().then_some(p)
    });
    let allow = match &allow_file {
        Some(p) => {
            let text = match std::fs::read_to_string(p) {
                Ok(t) => t,
                Err(e) => return usage_err(&format!("{}: {e}", p.display())),
            };
            match detlint::parse_allowlist(&text) {
                Ok(a) => a,
                Err(e) => return usage_err(&e),
            }
        }
        None => Vec::new(),
    };
    let hot_file = hot_path.or_else(|| {
        let p = root.join("tools/detlint/hotpaths.toml");
        p.exists().then_some(p)
    });
    let hot = match &hot_file {
        Some(p) => {
            let text = match std::fs::read_to_string(p) {
                Ok(t) => t,
                Err(e) => return usage_err(&format!("{}: {e}", p.display())),
            };
            match detlint::parse_hotpaths(&text) {
                Ok(h) => Some(h),
                Err(e) => return usage_err(&e),
            }
        }
        None => None,
    };

    match detlint::scan_tree(&scan, &allow, hot.as_deref()) {
        Err(e) => {
            eprintln!("detlint: {e}");
            ExitCode::from(2)
        }
        Ok(rep) => {
            if json {
                // machine mode: every finding as one JSON line, suppressed
                // ones last, no summary trailer
                for f in &rep.findings {
                    println!("{}", detlint::fmt_finding_json(f, false));
                }
                for f in &rep.suppressed_findings {
                    println!("{}", detlint::fmt_finding_json(f, true));
                }
            } else {
                for f in &rep.findings {
                    println!("{}", detlint::fmt_finding(f));
                }
                println!(
                    "detlint: {} unsuppressed finding(s), {} suppressed, {} file(s) scanned",
                    rep.findings.len(),
                    rep.suppressed,
                    rep.files
                );
            }
            if rep.findings.is_empty() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
    }
}

fn usage_err(msg: &str) -> ExitCode {
    eprintln!("detlint: {msg}\n\n{USAGE}");
    ExitCode::from(2)
}
