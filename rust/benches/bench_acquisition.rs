//! Acquisition-function micro-benchmarks: the cost of one α_T evaluation
//! (the unit Table IV counts), its EI/EIc baselines, and p_opt estimation.
mod common;

use trimtuner::acq::{
    eic, eic_usd, fabolas_alpha, trimtuner_alpha, EntropyEstimator,
    TrimTunerAcq,
};
use trimtuner::models::{Feat, ModelKind};
use trimtuner::space::{encode, Config, Point};
use trimtuner::util::timer::bench;
use trimtuner::util::Rng;

fn main() {
    common::print_header("acquisition");
    let caps = common::caps();
    let full_feats: Vec<Feat> = (0..288)
        .map(|id| encode(&Point { config: Config::from_id(id), s_idx: 4 }))
        .collect();
    let probe = encode(&Point { config: Config::from_id(33), s_idx: 1 });

    for (label, kind, k) in [
        ("dt", ModelKind::Trees, 1usize),
        ("gp-ml2", ModelKind::Gp, 1),
        ("gp-mcmc8", ModelKind::Gp, 8),
    ] {
        let models = common::fitted(kind, 48, k);
        let mut rng = Rng::new(5);
        let rep: Vec<Feat> = (0..40).map(|i| full_feats[i * 7]).collect();
        let est = EntropyEstimator::new(rep, 160, &mut rng);
        let baseline =
            EntropyEstimator::kl_from_uniform(&est.p_opt(models.acc.as_ref()));

        let stats = bench(&format!("{label} p_opt(40 reps,160 mc)"), 1, 10, || {
            est.p_opt(models.acc.as_ref())
        });
        println!("{}", stats.report());

        let shortlist: Vec<usize> = (0..32).collect();
        let ctx = TrimTunerAcq {
            models: &models,
            est: &est,
            constraints: &caps,
            full_feats: &full_feats,
            inc_shortlist: &shortlist,
            baseline,
        };
        let stats = bench(&format!("{label} alpha_T(1 candidate)"), 1, 10, || {
            trimtuner_alpha(&ctx, &probe)
        });
        println!("{}", stats.report());
        let stats = bench(&format!("{label} fabolas(1 candidate)"), 1, 10, || {
            fabolas_alpha(&models, &est, baseline, &probe)
        });
        println!("{}", stats.report());
        let stats = bench(&format!("{label} eic x288"), 2, 10, || {
            full_feats
                .iter()
                .map(|x| eic(&models, &caps, x, 0.9))
                .sum::<f64>()
        });
        println!("{}", stats.report());
        let stats = bench(&format!("{label} eic_usd x288"), 2, 10, || {
            full_feats
                .iter()
                .map(|x| eic_usd(&models, &caps, x, 0.9))
                .sum::<f64>()
        });
        println!("{}", stats.report());
    }
}
