// R2 fire: NaN-unsafe ranking — one NaN alpha value and this panics
// (or, with a silent fallback, misorders the slate).
fn rank(xs: &mut [(usize, f64)]) {
    xs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
}
