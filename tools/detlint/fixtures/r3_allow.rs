// R3 allow: timing routed through util::timer, randomness through the
// run's seeded RNG, and one pragma'd log-only clock read.
use crate::util::timer::Timer;
use crate::util::Rng;

fn stamp_s() -> f64 {
    let t0 = Timer::start();
    t0.elapsed_s()
}

fn draw(rng: &mut Rng) -> u64 {
    rng.next_u64()
}

fn wall_clock_s() -> u64 {
    // detlint: allow(R3, reason="log-only timestamp, never read by the optimizer")
    match std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
        Ok(d) => d.as_secs(),
        Err(_) => 0,
    }
}
