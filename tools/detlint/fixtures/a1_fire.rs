// A1 fire: allocating calls inside hot functions — a marked per-candidate
// helper and a registry-listed `view_at` both allocate per call, which is
// exactly the regression that erodes the fused slate sweep's speedup.

pub struct View {
    pub grid: Vec<(f64, f64)>,
}

pub struct Slate {
    mus: Vec<f64>,
    vars: Vec<f64>,
}

impl Slate {
    // registry-hot via hotpaths.toml (`PrimedSlate::view_at`): collect()
    // and clone() build fresh buffers for every candidate scored
    fn view_at(&self, i: usize) -> View {
        let grid = self
            .mus
            .iter()
            .zip(&self.vars)
            .map(|(&m, &v)| (m + i as f64, v.sqrt()))
            .collect();
        let _stash = self.mus.clone();
        View { grid }
    }
}

// detlint: hot
fn score_candidate(slate: &Slate, i: usize) -> f64 {
    let mut acc = Vec::new();
    for (m, _) in &slate.view_at(i).grid {
        acc.push(*m);
    }
    let top = vec![acc.iter().cloned().fold(f64::MIN, f64::max)];
    top[0]
}
