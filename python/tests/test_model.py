"""Layer-2 correctness: GP posterior graph vs jnp reference, padding trick,
MLP train/eval behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import matern_fabolas as mk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def toy_gp_problem(rng, n, q):
    x_tr = rng.uniform(0, 1, size=(n, mk.D_IN)).astype(np.float32)
    y = np.sin(3 * x_tr[:, 0]) * 0.5 + 0.1 * rng.normal(size=n)
    y = y.astype(np.float32)
    noise = np.full(n, 1e-3, dtype=np.float32)
    x_q = rng.uniform(0, 1, size=(q, mk.D_IN)).astype(np.float32)
    hyp = np.array(
        [0.5] * mk.D_FEAT + [1.0, 0.8, 0.3, 0.4], dtype=np.float32
    )
    return x_tr, y, noise, x_q, hyp


@pytest.mark.parametrize("basis", ["acc", "cost"])
def test_gp_posterior_matches_ref(basis):
    rng = np.random.default_rng(0)
    x_tr, y, noise, x_q, hyp = toy_gp_problem(rng, 32, 50)
    mu, var = model.gp_posterior(x_tr, y, noise, x_q, hyp, basis=basis)
    mu_r, var_r = ref.gp_posterior_ref(x_tr, y, noise, x_q, hyp, basis=basis)
    assert_allclose(np.asarray(mu), np.asarray(mu_r), rtol=1e-4, atol=1e-5)
    assert_allclose(np.asarray(var), np.asarray(var_r), rtol=1e-3, atol=1e-5)


def test_gp_posterior_interpolates_training_points():
    rng = np.random.default_rng(1)
    x_tr, y, _, _, hyp = toy_gp_problem(rng, 24, 1)
    noise = np.full(24, 1e-6, dtype=np.float32)
    mu, var = model.gp_posterior(x_tr, y, noise, x_tr, hyp, basis="acc")
    assert_allclose(np.asarray(mu), y, atol=5e-3)
    assert float(jnp.max(var)) < 1e-2


def test_padding_as_noise_is_exact():
    """Posterior with N real + P huge-noise points == posterior with N only."""
    rng = np.random.default_rng(2)
    x_tr, y, noise, x_q, hyp = toy_gp_problem(rng, 20, 30)
    mu0, var0 = ref.gp_posterior_ref(x_tr, y, noise, x_q, hyp, basis="acc")

    pad = 12
    x_pad = np.concatenate(
        [x_tr, rng.uniform(0, 1, size=(pad, mk.D_IN)).astype(np.float32)]
    )
    y_pad = np.concatenate([y, np.zeros(pad, dtype=np.float32)])
    noise_pad = np.concatenate(
        [noise, np.full(pad, 1e6, dtype=np.float32)]
    )
    mu1, var1 = model.gp_posterior(
        x_pad, y_pad, noise_pad, x_q, hyp, basis="acc"
    )
    assert_allclose(np.asarray(mu1), np.asarray(mu0), rtol=1e-3, atol=1e-4)
    assert_allclose(np.asarray(var1), np.asarray(var0), rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gp_posterior_variance_nonnegative(seed):
    rng = np.random.default_rng(seed)
    x_tr, y, noise, x_q, hyp = toy_gp_problem(rng, 16, 40)
    _, var = model.gp_posterior(x_tr, y, noise, x_q, hyp, basis="acc")
    assert float(jnp.min(var)) >= 0.0


def test_gp_mll_prefers_true_noise_scale():
    """MLL at sane hyper-params beats MLL at absurd ones (sanity of fit)."""
    rng = np.random.default_rng(3)
    x_tr, y, noise, _, hyp = toy_gp_problem(rng, 32, 1)
    good = float(model.gp_mll(x_tr, y, noise, hyp, basis="acc"))
    bad_hyp = hyp.copy()
    bad_hyp[: mk.D_FEAT] = 1e-3  # absurdly short lengthscales
    bad = float(model.gp_mll(x_tr, y, noise, bad_hyp, basis="acc"))
    assert good > bad


def _mlp_toy(rng, n):
    i, h, o = model.MLP_IN, model.MLP_HIDDEN, model.MLP_OUT
    w1 = (rng.normal(size=(i, h)) * 0.05).astype(np.float32)
    b1 = np.zeros(h, dtype=np.float32)
    w2 = (rng.normal(size=(h, o)) * 0.05).astype(np.float32)
    b2 = np.zeros(o, dtype=np.float32)
    x = rng.normal(size=(n, i)).astype(np.float32)
    labels = rng.integers(0, o, size=n)
    y = np.eye(o, dtype=np.float32)[labels]
    return (w1, b1, w2, b2), x, y


def test_mlp_train_step_reduces_loss():
    rng = np.random.default_rng(4)
    params, x, y = _mlp_toy(rng, model.MLP_BATCH)
    lr = np.float32(0.5)
    w1, b1, w2, b2 = params
    losses = []
    for _ in range(20):
        w1, b1, w2, b2, loss = model.mlp_train_step(w1, b1, w2, b2, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_mlp_eval_bounds_and_consistency():
    rng = np.random.default_rng(5)
    params, x, y = _mlp_toy(rng, model.MLP_EVAL)
    acc, loss = model.mlp_eval(*params, x, y)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0.0


def test_pure_jnp_cholesky_and_solves_match_numpy():
    rng = np.random.default_rng(9)
    n, m = 24, 7
    a = rng.normal(size=(n, n)).astype(np.float32)
    k = a @ a.T + n * np.eye(n, dtype=np.float32)
    l = np.asarray(model.cholesky_jnp(jnp.asarray(k)))
    assert_allclose(l @ l.T, k, rtol=2e-4, atol=2e-3)
    assert_allclose(np.triu(l, 1), 0.0, atol=1e-7)

    b = rng.normal(size=(n, m)).astype(np.float32)
    y = np.asarray(model.solve_lower_jnp(jnp.asarray(l), jnp.asarray(b)))
    assert_allclose(l @ y, b, rtol=2e-4, atol=2e-3)
    x = np.asarray(model.solve_lower_t_jnp(jnp.asarray(l), jnp.asarray(b)))
    assert_allclose(l.T @ x, b, rtol=2e-4, atol=2e-3)

    v = rng.normal(size=n).astype(np.float32)
    yv = np.asarray(model.solve_lower_jnp(jnp.asarray(l), jnp.asarray(v)))
    assert yv.shape == (n,)
    assert_allclose(l @ yv, v, rtol=2e-4, atol=2e-3)
