//! Exact Gaussian-Process regression with the TrimTuner kernel.
//!
//! Targets are standardized internally; hyper-parameters are fitted by
//! maximizing the log marginal likelihood with Nelder–Mead in log space
//! (multi-start). [`Gp::condition`] extends the Cholesky factor in O(n²)
//! for the acquisition function's simulate-one-observation step.

use super::kernel::{Basis, KernelParams};
use super::surrogate::{
    FantasyScratch, FantasySurface, FantasyView, Feat, FitOptions, Posterior,
    PrimedSlate, Surrogate,
};
use crate::linalg::{Cholesky, Mat};
use crate::opt::{nelder_mead, NmOptions};
use crate::util::Rng;

/// Hyper-parameters of a fitted GP (kernel + noise).
pub type GpHyp = KernelParams;

#[derive(Clone)]
pub struct Gp {
    pub basis: Basis,
    pub params: KernelParams,
    xs: Vec<Feat>,
    /// standardized targets
    ys: Vec<f64>,
    /// raw (unstandardized) targets — the absorption path re-standardizes
    /// from these, so the standardization constants track the growing
    /// history exactly as a fresh `fit` would compute them
    ys_raw: Vec<f64>,
    y_mean: f64,
    y_std: f64,
    chol: Option<Cholesky>,
    alpha: Vec<f64>,
    /// reused k(X, x_new) buffer for the zero-allocation absorb path
    scr_k12: Vec<f64>,
    /// reused triangular-solve buffer for the zero-allocation absorb path
    scr_w: Vec<f64>,
    /// deterministic seed for hyper-parameter restarts
    seed: u64,
    /// total number of hyper-parameter posterior samples (>= 1). K > 1
    /// reproduces FABOLAS-style MCMC marginalization: predictions become a
    /// K-component mixture, and every GP operation costs K x more — the
    /// source of the paper's Table-III GP-vs-DT gap.
    pub n_hyper: usize,
    /// extra components beyond the MAP: (params, chol, alpha)
    extra: Vec<(KernelParams, Cholesky, Vec<f64>)>,
}

impl Gp {
    pub fn new(basis: Basis) -> Gp {
        Gp {
            basis,
            params: KernelParams::default(),
            xs: Vec::new(),
            ys: Vec::new(),
            ys_raw: Vec::new(),
            y_mean: 0.0,
            y_std: 1.0,
            chol: None,
            alpha: Vec::new(),
            scr_k12: Vec::new(),
            scr_w: Vec::new(),
            seed: 0x9a_5eed,
            n_hyper: 1,
            extra: Vec::new(),
        }
    }

    pub fn with_seed(basis: Basis, seed: u64) -> Gp {
        Gp { seed, ..Gp::new(basis) }
    }

    /// FABOLAS-style hyper-parameter marginalization with K total samples.
    pub fn with_hyper_samples(basis: Basis, seed: u64, k: usize) -> Gp {
        Gp { seed, n_hyper: k.max(1), ..Gp::new(basis) }
    }

    fn standardize(&mut self, ys: &[f64]) {
        let (m, s) = crate::util::stats::mean_std_pop(ys);
        self.y_mean = m;
        self.y_std = if s > 1e-9 { s } else { 1.0 };
        self.ys = ys.iter().map(|y| (y - m) / self.y_std).collect();
    }

    /// Negative log marginal likelihood for `params` on the stored data.
    fn nll(&self, params: &KernelParams) -> f64 {
        let k = params.cov_matrix(self.basis, &self.xs);
        let chol = match Cholesky::factor(&k) {
            Ok(c) => c,
            Err(_) => return 1e12,
        };
        let alpha = chol.solve(&self.ys);
        let quad: f64 = alpha.iter().zip(&self.ys).map(|(a, y)| a * y).sum();
        0.5 * quad + 0.5 * chol.log_det()
    }

    fn refresh_factor(&mut self) {
        let k = self.params.cov_matrix(self.basis, &self.xs);
        let chol = Cholesky::factor(&k).expect("cov not PD after jitter");
        self.alpha = chol.solve(&self.ys);
        self.chol = Some(chol);
    }

    /// Predictive (mean, std) in *standardized* space.
    fn predict_norm(&self, x: &Feat) -> (f64, f64) {
        let chol = self.chol.as_ref().expect("predict before fit");
        let ks = self.params.cov_vec(self.basis, &self.xs, x);
        let mu: f64 = ks.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
        let mut v = Vec::new();
        chol.solve_lower_into(&ks, &mut v);
        let var = self.params.k_diag(self.basis, x)
            - v.iter().map(|z| z * z).sum::<f64>();
        (mu, var.max(1e-12).sqrt())
    }

    pub fn hyp(&self) -> &KernelParams {
        &self.params
    }

    /// (params, training factor, alpha) per hyper-parameter sample, MAP
    /// first — the component order every mixture path iterates in.
    fn hyper_comps(&self) -> Vec<(&KernelParams, &Cholesky, &[f64])> {
        let chol = self.chol.as_ref().expect("hyper_comps before fit");
        let mut out = vec![(&self.params, chol, self.alpha.as_slice())];
        for (p, c, a) in &self.extra {
            out.push((p, c, a.as_slice()));
        }
        out
    }

    /// Cross-covariance matrix K(X, Xq) (one column per query) and the
    /// standardized predictive means, shared by every batched path. The
    /// mean is accumulated in ascending training-row order — the same op
    /// order as the scalar `predict_norm` dot product, keeping the batched
    /// paths bit-identical to the scalar ones.
    fn cross_cov_mus(
        &self,
        params: &KernelParams,
        alpha: &[f64],
        xs: &[Feat],
    ) -> (Mat, Vec<f64>) {
        let n = self.xs.len();
        let m = xs.len();
        let mut ks = Mat::zeros(n, m);
        for (i, xi) in self.xs.iter().enumerate() {
            let row = ks.row_mut(i);
            for (c, xq) in xs.iter().enumerate() {
                row[c] = params.k(self.basis, xi, xq);
            }
        }
        let mut mus = vec![0.0; m];
        for (i, &a) in alpha.iter().enumerate() {
            for (mu, &k) in mus.iter_mut().zip(ks.row(i)) {
                *mu += k * a;
            }
        }
        (ks, mus)
    }

    /// Batched core shared by `predict_many` and the joint posterior:
    /// standardized predictive means and *unclamped* variances for one
    /// hyper-parameter sample, via one K(X, Xq) build and one multi-RHS
    /// forward solve against the stored Cholesky factor. The per-point
    /// accumulation order mirrors `predict_norm` op for op, so results are
    /// bit-identical to the scalar path.
    fn predict_raw_many(
        &self,
        params: &KernelParams,
        chol: &Cholesky,
        alpha: &[f64],
        xs: &[Feat],
    ) -> (Vec<f64>, Vec<f64>) {
        let (ks, mus) = self.cross_cov_mus(params, alpha, xs);
        let mut v = Mat::zeros(0, 0);
        chol.solve_lower_multi_into(&ks, &mut v);
        let mut ss = vec![0.0; xs.len()];
        for i in 0..self.xs.len() {
            for (s, &z) in ss.iter_mut().zip(v.row(i)) {
                *s += z * z;
            }
        }
        let vars = xs
            .iter()
            .zip(&ss)
            .map(|(x, &s)| params.k_diag(self.basis, x) - s)
            .collect();
        (mus, vars)
    }

    /// Joint posterior (mean, cov factor) over `xs` for one hyper sample.
    #[allow(clippy::type_complexity)]
    fn posterior_component(
        &self,
        params: &KernelParams,
        chol: &Cholesky,
        alpha: &[f64],
        xs: &[Feat],
    ) -> (Vec<f64>, Option<Cholesky>, Option<Vec<f64>>) {
        let m = xs.len();
        let n = self.xs.len();
        // batched cross-covariance + one multi-RHS solve (the p_opt hot
        // path calls this once per α_T evaluation)
        let (ks, mus) = self.cross_cov_mus(params, alpha, xs);
        let mean: Vec<f64> =
            mus.into_iter().map(|mu| mu * self.y_std + self.y_mean).collect();
        let mut vmat = Mat::zeros(0, 0);
        chol.solve_lower_multi_into(&ks, &mut vmat);
        let vcols: Vec<Vec<f64>> = (0..m)
            .map(|c| (0..n).map(|i| vmat[(i, c)]).collect())
            .collect();
        // posterior covariance: K(Xq,Xq) - V^T V, scaled back
        let mut cov = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..=i {
                let kij = params.k(self.basis, &xs[i], &xs[j]);
                let vv: f64 = vcols[i]
                    .iter()
                    .zip(&vcols[j])
                    .map(|(a, b)| a * b)
                    .sum();
                let c = (kij - vv) * self.y_std * self.y_std;
                cov[(i, j)] = c;
                cov[(j, i)] = c;
            }
            cov[(i, i)] += 1e-9;
        }
        match Cholesky::factor(&cov) {
            Ok(l) => (mean, Some(l), None),
            Err(_) => {
                // numerically degenerate: fall back to diagonal
                let std =
                    (0..m).map(|i| cov[(i, i)].max(0.0).sqrt()).collect();
                (mean, None, Some(std))
            }
        }
    }
}

/// Shared per-iteration precomputation for one hyper-parameter sample of a
/// [`GpFantasy`] surface.
struct GpFantasyComp {
    /// query-major cross-solves: row q holds column q of `L⁻¹ K(X, grid)`
    vt_grid: Mat,
    /// standardized predictive means on the grid
    mu_grid: Vec<f64>,
    /// raw (unclamped) standardized predictive variances on the grid
    var_grid: Vec<f64>,
    /// factor of the *scaled* joint-prefix posterior covariance (incl. the
    /// 1e-9 jitter `posterior_component` adds), when it is PD
    joint_l: Option<Cholesky>,
    /// diagonal of that matrix — the diagonal fallback for downdates that
    /// lose positive definiteness (mirrors `posterior_component`'s
    /// degenerate branch)
    joint_diag: Vec<f64>,
}

/// Rank-one fantasy surface for a fitted GP (all hyper-parameter samples).
///
/// Per iteration it precomputes, for every component, the cross-solve
/// matrix `V = L⁻¹ K(X, Q)` over the fused query grid Q plus the current
/// joint posterior (means, variances, and the Cholesky factor of the
/// joint-prefix covariance). Conditioning on a simulated observation
/// `(x, ŷ(x))` then reduces to closed-form rank-one algebra per candidate:
///
/// - posterior cross-covariance `c(q) = k(x, q) − wᵀ V[:, q]` with
///   `w = L⁻¹ k(X, x)` — O(n·|Q|). When the surface is primed for a slate
///   ([`FantasySurface::prime`]), the `w` vectors of *all* candidates are
///   produced by one multi-RHS solve per hyper-sample up front, so each
///   view degrades from a triangular solve to this dot-product sweep;
/// - conditioned mean `μ(q) + c(q)·(ŷ − μ(x))/v` and variance
///   `σ²(q) − c(q)²/v`, with `v = σ²(x) + noise` (exactly the `l22²` pivot
///   the clone path's `Cholesky::extend` produces, guard included);
/// - conditioned joint covariance `Σ − c cᵀ/v`: one O(m²)
///   [`Cholesky::downdate`] of the shared prefix factor.
///
/// No surrogate clone, no per-candidate re-factorization; agreement with
/// the clone-and-extend path is within 1e-9 relative (`tests/alpha_parity`).
/// Caveat: that bound presumes the shared prefix factor succeeds without
/// `Cholesky::factor`'s jitter retries (the explicit +1e-9 diagonal makes
/// this the overwhelmingly common case) — a fit degenerate enough to need
/// retry jitter can put the two paths on different jitter levels, where
/// only the coarser 1e-6 sanity bound is guaranteed.
pub(crate) struct GpFantasy {
    gp: Gp,
    grid: Vec<Feat>,
    m_joint: usize,
    comps: Vec<GpFantasyComp>,
}

impl GpFantasy {
    fn new(gp: &Gp, grid: &[Feat], m_joint: usize) -> GpFantasy {
        let comps = gp
            .hyper_comps()
            .into_iter()
            .map(|(params, chol, alpha)| {
                GpFantasyComp::build(gp, params, chol, alpha, grid, m_joint)
            })
            .collect();
        GpFantasy { gp: gp.clone(), grid: grid.to_vec(), m_joint, comps }
    }
}

impl GpFantasyComp {
    fn build(
        gp: &Gp,
        params: &KernelParams,
        chol: &Cholesky,
        alpha: &[f64],
        grid: &[Feat],
        m_joint: usize,
    ) -> GpFantasyComp {
        let n = gp.xs.len();
        let nq = grid.len();
        let (ks, mu_grid) = gp.cross_cov_mus(params, alpha, grid);
        let mut v = Mat::zeros(0, 0);
        chol.solve_lower_multi_into(&ks, &mut v);
        // raw variances, same accumulation order as predict_raw_many
        let mut ss = vec![0.0; nq];
        for i in 0..n {
            for (s, &z) in ss.iter_mut().zip(v.row(i)) {
                *s += z * z;
            }
        }
        let var_grid: Vec<f64> = grid
            .iter()
            .zip(&ss)
            .map(|(x, &s)| params.k_diag(gp.basis, x) - s)
            .collect();
        // scaled joint-prefix covariance, mirroring posterior_component
        let m = m_joint;
        let vcols: Vec<Vec<f64>> = (0..m)
            .map(|c| (0..n).map(|i| v[(i, c)]).collect())
            .collect();
        let mut cov = Mat::zeros(m, m);
        for i in 0..m {
            for j in 0..=i {
                let kij = params.k(gp.basis, &grid[i], &grid[j]);
                let vv: f64 = vcols[i]
                    .iter()
                    .zip(&vcols[j])
                    .map(|(a, b)| a * b)
                    .sum();
                let c = (kij - vv) * gp.y_std * gp.y_std;
                cov[(i, j)] = c;
                cov[(j, i)] = c;
            }
            cov[(i, i)] += 1e-9;
        }
        let joint_diag: Vec<f64> = (0..m).map(|i| cov[(i, i)]).collect();
        let joint_l =
            if m > 0 { Cholesky::factor(&cov).ok() } else { None };
        // query-major layout: each view's cross-covariance pass walks one
        // contiguous row per grid point
        let mut vt_grid = Mat::zeros(nq, n);
        for q in 0..nq {
            let row = vt_grid.row_mut(q);
            for (i, slot) in row.iter_mut().enumerate() {
                *slot = v[(i, q)];
            }
        }
        GpFantasyComp { vt_grid, mu_grid, var_grid, joint_l, joint_diag }
    }
}

/// One hyper-sample's batched candidate solves for a primed slate: the
/// cross-kernel vectors of *every* slate candidate collected into one
/// matrix and pushed through a single [`Cholesky::solve_lower_multi`] pass,
/// so each `view_at` pays a contiguous dot-product sweep instead of its own
/// O(n²) triangular solve.
struct GpPrimedComp {
    /// kernel hyper-parameters of this component (copied so `view_at`
    /// needs no per-call `hyper_comps` round trip)
    params: KernelParams,
    /// candidate-major cross-solves: row c is `w_c = L⁻¹ k(X, x_c)`
    w: Mat,
    /// standardized predictive mean at every candidate
    mu_x: Vec<f64>,
    /// conditioning pivot per candidate — the clone path's `l22²`, guard
    /// included
    v_eff: Vec<f64>,
}

/// A [`GpFantasy`] surface primed for one candidate slate.
struct GpPrimed<'s> {
    surf: &'s GpFantasy,
    xs: &'s [Feat],
    /// standardized simulated outcomes ỹ(x_c), batched via `predict_many`
    y_tilde: Vec<f64>,
    comps: Vec<GpPrimedComp>,
}

impl PrimedSlate for GpPrimed<'_> {
    // detlint: hot
    fn view_into(
        &self,
        ci: usize,
        scratch: &mut FantasyScratch,
        out: &mut FantasyView,
    ) {
        let surf = self.surf;
        let gp = &surf.gp;
        let x = &self.xs[ci];
        let nq = surf.grid.len();
        let m = surf.m_joint;
        let k_comps = surf.comps.len();
        let y_tilde = self.y_tilde[ci];

        // disjoint borrows of every scratch buffer the sweep threads
        let FantasyScratch { cross, rank1, sweep, mus, vars, .. } = scratch;
        // flattened per-component (mean, var) grids: segment k is
        // component k, exactly the rows comp_mus/comp_vars used to hold
        mus.clear();
        mus.resize(k_comps * nq, 0.0);
        vars.clear();
        vars.resize(k_comps * nq, 0.0);
        if m > 0 {
            let post = out.joint.get_or_insert_with(Posterior::new_empty);
            post.clear_components();
        } else {
            out.joint = None;
        }
        for (k, (fc, pc)) in surf.comps.iter().zip(&self.comps).enumerate() {
            let params = &pc.params;
            let w = pc.w.row(ci);
            let v_eff = pc.v_eff[ci];
            let r = y_tilde - pc.mu_x[ci];
            // posterior cross-covariances candidate → grid, into the
            // per-worker scratch (no per-candidate allocation)
            cross.clear();
            cross.resize(nq, 0.0);
            for (q, cq) in cross.iter_mut().enumerate() {
                let dot: f64 = w
                    .iter()
                    .zip(fc.vt_grid.row(q))
                    .map(|(a, b)| a * b)
                    .sum();
                *cq = params.k(gp.basis, x, &surf.grid[q]) - dot;
            }
            let mseg = &mut mus[k * nq..(k + 1) * nq];
            for (q, mu) in mseg.iter_mut().enumerate() {
                *mu = fc.mu_grid[q] + cross[q] * r / v_eff;
            }
            let vseg = &mut vars[k * nq..(k + 1) * nq];
            for (q, va) in vseg.iter_mut().enumerate() {
                *va = fc.var_grid[q] - cross[q] * cross[q] / v_eff;
            }
            if m > 0 {
                let post = out.joint.as_mut().expect("joint prefix present");
                let comp = post.push_component();
                comp.mean.clear();
                comp.mean
                    .extend(mseg[..m].iter().map(|mu| mu * gp.y_std + gp.y_mean));
                let scale = gp.y_std / v_eff.sqrt();
                rank1.clear();
                rank1.extend(cross[..m].iter().map(|ci| ci * scale));
                // downdate straight into the reused component factor; on
                // failure the component flips to the diagonal fallback,
                // like posterior_component's failed factorization
                let down_ok = fc.joint_l.as_ref().is_some_and(|l| {
                    l.downdate_into(rank1, comp.joint_mut(), sweep).is_ok()
                });
                if !down_ok {
                    let std = comp.diag_mut();
                    std.clear();
                    std.extend((0..m).map(|i| {
                        (fc.joint_diag[i] - rank1[i] * rank1[i])
                            .max(0.0)
                            .sqrt()
                    }));
                }
            }
        }
        if m > 0 {
            out.joint.as_mut().expect("joint prefix present").finish();
        }

        // mixture (mean, std) on the grid, op-for-op like Gp::predict_many
        out.grid.clear();
        if k_comps == 1 {
            for q in 0..nq {
                let std = vars[q].max(1e-12).sqrt();
                out.grid
                    .push((mus[q] * gp.y_std + gp.y_mean, std * gp.y_std));
            }
        } else {
            let kf = k_comps as f64;
            for q in 0..nq {
                let mean: f64 =
                    (0..k_comps).map(|k| mus[k * nq + q]).sum::<f64>() / kf;
                let var: f64 = (0..k_comps)
                    .map(|k| {
                        // the MAP variance round-trips through
                        // predict_norm's sqrt, the samples clamp raw
                        let v = if k == 0 {
                            let std = vars[q].max(1e-12).sqrt();
                            std * std
                        } else {
                            vars[k * nq + q].max(1e-12)
                        };
                        let mu = mus[k * nq + q];
                        v + (mu - mean) * (mu - mean)
                    })
                    .sum::<f64>()
                    / kf;
                out.grid.push((
                    mean * gp.y_std + gp.y_mean,
                    var.max(1e-12).sqrt() * gp.y_std,
                ));
            }
        }
    }
}

impl FantasySurface for GpFantasy {
    fn view_with(&self, x: &Feat, scratch: &mut FantasyScratch) -> FantasyView {
        // one-candidate slate through the batched path: a single-column
        // multi-RHS solve and a one-point `predict_many` are bit-identical
        // to the scalar solves, so this cannot drift from `view_into`
        self.prime(std::slice::from_ref(x)).view_at(0, scratch)
    }

    fn prime<'s>(&'s self, xs: &'s [Feat]) -> Box<dyn PrimedSlate + 's> {
        let gp = &self.gp;
        let n = gp.xs.len();
        let nc = xs.len();
        let comps: Vec<GpPrimedComp> = gp
            .hyper_comps()
            .into_iter()
            .map(|(params, chol, alpha)| {
                // K(X, slate) with one column per candidate (shared with
                // the predictive means below), then ONE multi-RHS forward
                // solve instead of a triangular solve per candidate
                let (ks, mu_x) = gp.cross_cov_mus(params, alpha, xs);
                let mut wcol = Mat::zeros(0, 0);
                chol.solve_lower_multi_into(&ks, &mut wcol);
                // candidate-major layout: each view's dot-product sweep
                // walks one contiguous row per candidate
                let mut w = Mat::zeros(nc, n);
                for c in 0..nc {
                    let row = w.row_mut(c);
                    for (i, slot) in row.iter_mut().enumerate() {
                        *slot = wcol[(i, c)];
                    }
                }
                // mirror Cholesky::extend's pivot guard: v is the clone
                // path's l22² (1e-6² when the remainder degenerates)
                let v_eff: Vec<f64> = xs
                    .iter()
                    .enumerate()
                    .map(|(c, x)| {
                        let k22 = params.k_diag(gp.basis, x) + params.noise;
                        let rem = k22
                            - w.row(c).iter().map(|v| v * v).sum::<f64>();
                        if rem > 1e-12 {
                            rem
                        } else {
                            1e-12
                        }
                    })
                    .collect();
                GpPrimedComp { params: *params, w, mu_x, v_eff }
            })
            .collect();
        // simulated outcomes ŷ(x_c): the mixture predictive mean, reusing
        // the per-component means computed above instead of a second
        // kernel-matrix build + solve inside `predict_many`. The value is
        // destandardized and re-standardized on purpose — that exact
        // round trip is what `Models::condition` feeds the clone path
        // (and what `predict`/`predict_many` emit), bit for bit.
        let kf = comps.len() as f64;
        let y_tilde: Vec<f64> = (0..nc)
            .map(|c| {
                let mean = if comps.len() == 1 {
                    comps[0].mu_x[c]
                } else {
                    comps.iter().map(|pc| pc.mu_x[c]).sum::<f64>() / kf
                };
                let destd = mean * gp.y_std + gp.y_mean;
                (destd - gp.y_mean) / gp.y_std
            })
            .collect();
        Box::new(GpPrimed { surf: self, xs, y_tilde, comps })
    }
}

/// Cold fallback for [`Gp::absorb`]: refactor one hyper component's
/// covariance from scratch (with `factor`'s jitter retries). Kept out of
/// the hot function so absorb's zero-allocation fast path stays clean for
/// detlint's A-rules; false means even the jittered factorization failed.
fn try_refactor_frozen(
    basis: Basis,
    params: &KernelParams,
    xs: &[Feat],
    chol: &mut Cholesky,
) -> bool {
    let k = params.cov_matrix(basis, xs);
    match Cholesky::factor(&k) {
        Ok(c) => {
            *chol = c;
            true
        }
        Err(_) => false,
    }
}

impl Surrogate for Gp {
    fn fit(&mut self, xs: &[Feat], ys: &[f64], opts: FitOptions) {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty(), "cannot fit GP on empty data");
        self.xs = xs.to_vec();
        self.ys_raw.clear();
        self.ys_raw.extend_from_slice(ys);
        self.standardize(ys);

        if opts.hyperopt {
            let nm_opts = NmOptions { max_iters: 120, ..Default::default() };
            let mut best: Option<(Vec<f64>, f64)> = None;
            let mut rng = Rng::new(self.seed ^ (self.xs.len() as u64) << 32);
            // start 0: current params; starts 1..: random log-space draws
            let mut starts = vec![self.params.to_log_vec()];
            for _ in 0..opts.restarts {
                let v: Vec<f64> = (0..starts[0].len())
                    .map(|_| rng.uniform(-2.0, 0.7))
                    .collect();
                starts.push(v);
            }
            for start in starts {
                let (v, f) = nelder_mead(
                    |log_v| self.nll(&KernelParams::from_log_vec(log_v)),
                    &start,
                    &nm_opts,
                );
                if best.as_ref().map_or(true, |(_, bf)| f < *bf) {
                    best = Some((v, f));
                }
            }
            self.params = KernelParams::from_log_vec(&best.unwrap().0);
        }
        self.refresh_factor();

        // hyper-parameter posterior samples via random-walk Metropolis on
        // the MLL, started at the MAP (FABOLAS marginalizes the same way,
        // with emcee); thinned to decorrelate.
        if opts.hyperopt && self.n_hyper > 1 {
            self.extra.clear();
            let mut mc =
                Rng::new(self.seed ^ 0x3C ^ ((self.xs.len() as u64) << 17));
            let mut v = self.params.to_log_vec();
            let mut nll_cur = self.nll(&KernelParams::from_log_vec(&v));
            while self.extra.len() < self.n_hyper - 1 {
                // 3 thinning steps per retained sample
                for _ in 0..3 {
                    let prop: Vec<f64> = v
                        .iter()
                        .map(|x| x + 0.15 * mc.normal())
                        .collect();
                    let nll_prop =
                        self.nll(&KernelParams::from_log_vec(&prop));
                    if nll_prop < nll_cur
                        || mc.f64() < (nll_cur - nll_prop).exp()
                    {
                        v = prop;
                        nll_cur = nll_prop;
                    }
                }
                let params = KernelParams::from_log_vec(&v);
                let k = params.cov_matrix(self.basis, &self.xs);
                if let Ok(chol) = Cholesky::factor(&k) {
                    let alpha = chol.solve(&self.ys);
                    self.extra.push((params, chol, alpha));
                }
            }
        } else if self.n_hyper > 1 && !self.extra.is_empty() {
            // refit without hyperopt keeps the sampled params, refreshing
            // their factors on the new data
            let comps: Vec<KernelParams> =
                self.extra.iter().map(|(p, _, _)| *p).collect();
            self.extra.clear();
            for params in comps {
                let k = params.cov_matrix(self.basis, &self.xs);
                if let Ok(chol) = Cholesky::factor(&k) {
                    let alpha = chol.solve(&self.ys);
                    self.extra.push((params, chol, alpha));
                }
            }
        }
    }

    fn predict(&self, x: &Feat) -> (f64, f64) {
        if self.extra.is_empty() {
            let (mu, std) = self.predict_norm(x);
            return (mu * self.y_std + self.y_mean, std * self.y_std);
        }
        // mixture moments over MAP + sampled hyper-parameters
        let mut mus = Vec::with_capacity(self.extra.len() + 1);
        let mut vars = Vec::with_capacity(self.extra.len() + 1);
        let (m0, s0) = self.predict_norm(x);
        mus.push(m0);
        vars.push(s0 * s0);
        let mut v = Vec::new();
        for (params, chol, alpha) in &self.extra {
            let ks = params.cov_vec(self.basis, &self.xs, x);
            let mu: f64 = ks.iter().zip(alpha).map(|(k, a)| k * a).sum();
            chol.solve_lower_into(&ks, &mut v);
            let var = (params.k_diag(self.basis, x)
                - v.iter().map(|z| z * z).sum::<f64>())
            .max(1e-12);
            mus.push(mu);
            vars.push(var);
        }
        let kf = mus.len() as f64;
        let mean: f64 = mus.iter().sum::<f64>() / kf;
        let var: f64 = mus
            .iter()
            .zip(&vars)
            .map(|(m, v)| v + (m - mean) * (m - mean))
            .sum::<f64>()
            / kf;
        (
            mean * self.y_std + self.y_mean,
            var.max(1e-12).sqrt() * self.y_std,
        )
    }

    /// Native batch prediction: one shared multi-RHS triangular solve for
    /// the whole query slate (per hyper-parameter sample) instead of an
    /// O(n²) solve per point. Bit-identical to mapping [`Gp::predict`].
    fn predict_many(&self, xs: &[Feat]) -> Vec<(f64, f64)> {
        if xs.is_empty() {
            return Vec::new();
        }
        let chol = self.chol.as_ref().expect("predict before fit");
        let (mus, vars) =
            self.predict_raw_many(&self.params, chol, &self.alpha, xs);
        if self.extra.is_empty() {
            return mus
                .into_iter()
                .zip(vars)
                .map(|(mu, var)| {
                    let std = var.max(1e-12).sqrt();
                    (mu * self.y_std + self.y_mean, std * self.y_std)
                })
                .collect();
        }
        // Mixture moments over MAP + sampled hyper-parameters. Component
        // order and clamping mirror the scalar path exactly: the MAP
        // variance round-trips through predict_norm's sqrt (std²), the
        // sampled components clamp the raw variance.
        let map_vars: Vec<f64> = vars
            .iter()
            .map(|&v| {
                let std = v.max(1e-12).sqrt();
                std * std
            })
            .collect();
        let mut comp_mus = vec![mus];
        let mut comp_vars = vec![map_vars];
        for (params, chol_k, alpha_k) in &self.extra {
            let (mk, vk) = self.predict_raw_many(params, chol_k, alpha_k, xs);
            comp_mus.push(mk);
            comp_vars.push(vk.into_iter().map(|v| v.max(1e-12)).collect());
        }
        let kf = comp_mus.len() as f64;
        (0..xs.len())
            .map(|c| {
                let mean: f64 =
                    comp_mus.iter().map(|m| m[c]).sum::<f64>() / kf;
                let var: f64 = comp_mus
                    .iter()
                    .zip(&comp_vars)
                    .map(|(m, v)| v[c] + (m[c] - mean) * (m[c] - mean))
                    .sum::<f64>()
                    / kf;
                (
                    mean * self.y_std + self.y_mean,
                    var.max(1e-12).sqrt() * self.y_std,
                )
            })
            .collect()
    }

    fn posterior(&self, xs: &[Feat]) -> Posterior {
        let chol = self.chol.as_ref().expect("posterior before fit");
        let mut comps =
            vec![self.posterior_component(&self.params, chol, &self.alpha, xs)];
        for (params, chol, alpha) in &self.extra {
            comps.push(self.posterior_component(params, chol, alpha, xs));
        }
        Posterior::mixture(comps)
    }

    fn condition(&self, x: &Feat, y: f64) -> Box<dyn Surrogate> {
        let chol = self.chol.as_ref().expect("condition before fit");
        let k12 = self.params.cov_vec(self.basis, &self.xs, x);
        let k22 = self.params.k_diag(self.basis, x) + self.params.noise;
        // clamped: the fantasy path must never fail, mirroring the v_eff
        // variance clamp (a fantasy y at a near-duplicate x is routine)
        let ext = chol.extend_clamped(&k12, k22);
        let mut g = self.clone();
        g.xs.push(*x);
        g.ys_raw.push(y);
        g.ys.push((y - self.y_mean) / self.y_std);
        g.alpha = ext.solve(&g.ys);
        g.chol = Some(ext);
        // extend every hyper-sample component as well
        g.extra.clear();
        for (params, chol_k, _) in &self.extra {
            let k12 = params.cov_vec(self.basis, &self.xs, x);
            let k22 = params.k_diag(self.basis, x) + params.noise;
            let ext_k = chol_k.extend_clamped(&k12, k22);
            let alpha = ext_k.solve(&g.ys);
            g.extra.push((*params, ext_k, alpha));
        }
        Box::new(g)
    }

    /// Fold one real observation into the fitted state in O(n²) per hyper
    /// component: re-standardize the targets from the raw history (the
    /// covariance is target-independent, so the factors are unaffected),
    /// grow each stored factor by one row in place
    /// ([`Cholesky::extend_in_place`]) and re-solve each alpha against the
    /// grown factor — the same `solve_lower` / `solve_lower_t` composition
    /// `solve` uses, so the result is bitwise what a frozen refactor's
    /// solve would produce on the same factor. A component whose extension
    /// loses positive definiteness falls back to a from-scratch
    /// refactorization (with `factor`'s jitter retries); hyper-parameters
    /// never move here — that is `fit(hyperopt: true)`'s job on the
    /// engine's refit schedule.
    // detlint: hot
    fn absorb(&mut self, x: &Feat, y: f64) {
        assert!(self.chol.is_some(), "absorb before fit");
        self.xs.push(*x);
        self.ys_raw.push(y);
        // re-standardize against the raw history, exactly like `fit`
        let (m, s) = crate::util::stats::mean_std_pop(&self.ys_raw);
        self.y_mean = m;
        self.y_std = if s > 1e-9 { s } else { 1.0 };
        let y_std = self.y_std;
        self.ys.clear();
        for i in 0..self.ys_raw.len() {
            self.ys.push((self.ys_raw[i] - m) / y_std);
        }
        let n_prev = self.xs.len() - 1;
        let Gp {
            basis,
            params,
            xs,
            ys,
            chol,
            alpha,
            scr_k12,
            scr_w,
            extra,
            ..
        } = self;
        let basis = *basis;
        let chol = chol.as_mut().expect("absorb before fit");
        scr_k12.clear();
        for xi in &xs[..n_prev] {
            scr_k12.push(params.k(basis, xi, x));
        }
        let k22 = params.k_diag(basis, x) + params.noise;
        if chol.extend_in_place(scr_k12, k22, scr_w).is_err() {
            assert!(
                try_refactor_frozen(basis, params, xs, chol),
                "cov not PD after jitter"
            );
        }
        chol.solve_lower_into(ys, scr_w);
        chol.solve_lower_t_into(scr_w, alpha);
        extra.retain_mut(|(p, c, a)| {
            scr_k12.clear();
            for xi in &xs[..n_prev] {
                scr_k12.push(p.k(basis, xi, x));
            }
            let k22 = p.k_diag(basis, x) + p.noise;
            if c.extend_in_place(scr_k12, k22, scr_w).is_err()
                && !try_refactor_frozen(basis, p, xs, c)
            {
                // mirror `fit`: a component whose covariance cannot be
                // factored even with jitter is dropped from the mixture
                return false;
            }
            c.solve_lower_into(ys, scr_w);
            c.solve_lower_t_into(scr_w, a);
            true
        });
    }

    /// The from-scratch twin of [`Gp::absorb`] (`TRIMTUNER_REFIT=full`):
    /// recompute the standardization, every stored factor and every alpha
    /// from the raw history with hyper-parameters frozen — exactly the
    /// state the incremental path maintains, derived without any
    /// incremental arithmetic. `tests/refit_parity.rs` pins the two
    /// together at ≤1e-9.
    fn refit_frozen(&mut self) {
        let ys_raw = std::mem::take(&mut self.ys_raw);
        self.standardize(&ys_raw);
        self.ys_raw = ys_raw;
        self.refresh_factor();
        if !self.extra.is_empty() {
            let comps: Vec<KernelParams> =
                self.extra.iter().map(|(p, _, _)| *p).collect();
            self.extra.clear();
            for params in comps {
                let k = params.cov_matrix(self.basis, &self.xs);
                if let Ok(chol) = Cholesky::factor(&k) {
                    let alpha = chol.solve(&self.ys);
                    self.extra.push((params, chol, alpha));
                }
            }
        }
    }

    fn n_obs(&self) -> usize {
        self.xs.len()
    }

    fn clone_box(&self) -> Box<dyn Surrogate> {
        Box::new(self.clone())
    }

    fn fantasy_surface(
        &self,
        grid: &[Feat],
        m_joint: usize,
    ) -> Box<dyn FantasySurface> {
        assert!(m_joint <= grid.len());
        Box::new(GpFantasy::new(self, grid, m_joint))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::D_IN;
    use crate::util::proptest::check;

    fn feat(vals: &[f64]) -> Feat {
        let mut f = [0.0; D_IN];
        f[..vals.len()].copy_from_slice(vals);
        f
    }

    /// y = sin(3 x0) + 0.5 s, observed with tiny noise.
    fn toy(n: usize, rng: &mut Rng) -> (Vec<Feat>, Vec<f64>) {
        let xs: Vec<Feat> = (0..n)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let ys = xs
            .iter()
            .map(|x| (3.0 * x[0]).sin() + 0.5 * x[6] + 0.01 * rng.normal())
            .collect();
        (xs, ys)
    }

    #[test]
    fn interpolates_observations() {
        let mut rng = Rng::new(1);
        let (xs, ys) = toy(24, &mut rng);
        let mut gp = Gp::new(Basis::Acc);
        gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
        for (x, y) in xs.iter().zip(&ys) {
            let (mu, _) = gp.predict(x);
            assert!((mu - y).abs() < 0.15, "pred {mu} vs obs {y}");
        }
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let mut rng = Rng::new(2);
        let (xs, ys) = toy(16, &mut rng);
        let mut gp = Gp::new(Basis::Acc);
        gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
        let (_, std_at_data) = gp.predict(&xs[0]);
        let far = feat(&[5.0, 5.0, 5.0, 5.0, 5.0, 5.0, 0.5]);
        let (_, std_far) = gp.predict(&far);
        assert!(std_far > std_at_data, "{std_far} <= {std_at_data}");
    }

    #[test]
    fn generalizes_on_toy_function() {
        let mut rng = Rng::new(3);
        let (xs, ys) = toy(40, &mut rng);
        let mut gp = Gp::new(Basis::Acc);
        gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 2 });
        let mut err = 0.0;
        for _ in 0..50 {
            let mut f = [0.0; D_IN];
            for v in f.iter_mut() {
                *v = rng.f64();
            }
            let truth = (3.0 * f[0]).sin() + 0.5 * f[6];
            let (mu, _) = gp.predict(&f);
            err += (mu - truth).abs();
        }
        err /= 50.0;
        assert!(err < 0.12, "mean abs error {err}");
    }

    #[test]
    fn condition_matches_full_refit() {
        check("condition == refit (frozen hyp)", 12, |rng| {
            let (xs, ys) = toy(10 + rng.below(10), rng);
            let mut gp = Gp::new(Basis::Acc);
            gp.fit(&xs, &ys, FitOptions { hyperopt: false, restarts: 0 });

            let mut xnew = [0.0; D_IN];
            for v in xnew.iter_mut() {
                *v = rng.f64();
            }
            let ynew = 0.3;
            let cond = gp.condition(&xnew, ynew);

            // full refactorization with identical params AND identical
            // normalization constants -> must agree to numerical precision.
            let mut gp2 = gp.clone();
            gp2.xs.push(xnew);
            gp2.ys.push((ynew - gp.y_mean) / gp.y_std);
            gp2.refresh_factor();

            for _ in 0..5 {
                let mut probe = [0.0; D_IN];
                for v in probe.iter_mut() {
                    *v = rng.f64();
                }
                let (m1, s1) = cond.predict(&probe);
                let (m2, s2) = gp2.predict(&probe);
                if (m1 - m2).abs() > 1e-6 || (s1 - s2).abs() > 1e-6 {
                    return Err(format!(
                        "cond ({m1:.8},{s1:.8}) vs refit ({m2:.8},{s2:.8})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn posterior_diag_matches_predict() {
        let mut rng = Rng::new(5);
        let (xs, ys) = toy(20, &mut rng);
        let mut gp = Gp::new(Basis::Acc);
        gp.fit(&xs, &ys, FitOptions { hyperopt: false, restarts: 0 });
        let probes: Vec<Feat> = (0..6)
            .map(|_| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            })
            .collect();
        let post = gp.posterior(&probes);
        for (i, p) in probes.iter().enumerate() {
            let (mu, _) = gp.predict(p);
            assert!((post.mean[i] - mu).abs() < 1e-8);
        }
    }

    #[test]
    fn predict_many_bitwise_matches_scalar() {
        // ML-II GP and hyper-marginalized mixture GP: the batched path must
        // reproduce the scalar path bit for bit.
        for k in [1usize, 4] {
            let mut rng = Rng::new(11 + k as u64);
            let (xs, ys) = toy(18, &mut rng);
            let mut gp = Gp::with_hyper_samples(Basis::Acc, 7, k);
            gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
            let probes: Vec<Feat> = (0..25)
                .map(|_| {
                    let mut f = [0.0; D_IN];
                    for v in f.iter_mut() {
                        *v = rng.f64();
                    }
                    f
                })
                .collect();
            let batch = gp.predict_many(&probes);
            for (p, (bm, bs)) in probes.iter().zip(&batch) {
                let (m, s) = gp.predict(p);
                assert_eq!(m.to_bits(), bm.to_bits(), "k={k} mean mismatch");
                assert_eq!(s.to_bits(), bs.to_bits(), "k={k} std mismatch");
            }
        }
    }

    #[test]
    fn fantasy_view_matches_clone_and_extend() {
        // Rank-one fantasy conditioning vs the reference clone path, for
        // ML-II and hyper-marginalized mixture GPs: conditioned grid
        // (mean, std) and the conditioned joint posterior (via CRN draws)
        // must agree to numerical precision.
        for k in [1usize, 4] {
            let mut rng = Rng::new(23 + k as u64);
            let (xs, ys) = toy(20, &mut rng);
            let mut gp = Gp::with_hyper_samples(Basis::Acc, 5, k);
            gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
            let grid: Vec<Feat> = (0..14)
                .map(|_| {
                    let mut f = [0.0; D_IN];
                    for v in f.iter_mut() {
                        *v = rng.f64();
                    }
                    f
                })
                .collect();
            let m_joint = 8;
            let surf = gp.fantasy_surface(&grid, m_joint);
            for _ in 0..4 {
                let mut x = [0.0; D_IN];
                for v in x.iter_mut() {
                    *v = rng.f64();
                }
                let view = surf.view(&x);
                // reference: clone, extend, re-predict
                let (y, _) = gp.predict(&x);
                let cond = gp.condition(&x, y);
                let want = cond.predict_many(&grid);
                for (q, ((vm, vs), (wm, ws))) in
                    view.grid.iter().zip(&want).enumerate()
                {
                    assert!(
                        (vm - wm).abs() <= 1e-9 * wm.abs().max(1.0),
                        "k={k} q={q} mean {vm} vs {wm}"
                    );
                    assert!(
                        (vs - ws).abs() <= 1e-9 * ws.abs().max(1.0),
                        "k={k} q={q} std {vs} vs {ws}"
                    );
                }
                // joint posterior: identical CRN draws must agree
                let post_f = view.joint.expect("joint prefix");
                let post_c = cond.posterior(&grid[..m_joint]);
                assert_eq!(post_f.n_components(), post_c.n_components());
                let z: Vec<f64> =
                    (0..m_joint).map(|_| rng.normal()).collect();
                let (mut df, mut dc) = (Vec::new(), Vec::new());
                for comp in 0..post_f.n_components() {
                    post_f.sample_component_with(comp, &z, &mut df);
                    post_c.sample_component_with(comp, &z, &mut dc);
                    for (a, b) in df.iter().zip(&dc) {
                        assert!(
                            (a - b).abs() <= 2e-7 * b.abs().max(1.0),
                            "k={k} comp={comp} draw {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn primed_slate_views_bitwise_match_per_candidate_views() {
        // The batched multi-RHS priming must reproduce the per-candidate
        // path bit for bit (single-column solves are bit-identical, so any
        // divergence is a layout/order bug). ML-II and mixture GPs.
        for k in [1usize, 3] {
            let mut rng = Rng::new(31 + k as u64);
            let (xs, ys) = toy(22, &mut rng);
            let mut gp = Gp::with_hyper_samples(Basis::Acc, 9, k);
            gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
            let rand_feat = |rng: &mut Rng| {
                let mut f = [0.0; D_IN];
                for v in f.iter_mut() {
                    *v = rng.f64();
                }
                f
            };
            let grid: Vec<Feat> =
                (0..10).map(|_| rand_feat(&mut rng)).collect();
            let surf = gp.fantasy_surface(&grid, 6);
            let slate: Vec<Feat> =
                (0..9).map(|_| rand_feat(&mut rng)).collect();
            let primed = surf.prime(&slate);
            let mut scratch = FantasyScratch::new();
            for (i, x) in slate.iter().enumerate() {
                let a = surf.view(x);
                let b = primed.view_at(i, &mut scratch);
                for ((am, astd), (bm, bstd)) in a.grid.iter().zip(&b.grid) {
                    assert_eq!(am.to_bits(), bm.to_bits(), "k={k} i={i}");
                    assert_eq!(astd.to_bits(), bstd.to_bits(), "k={k} i={i}");
                }
                let (pa, pb) = (a.joint.unwrap(), b.joint.unwrap());
                assert_eq!(pa.n_components(), pb.n_components());
                let z: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
                let (mut da, mut db) = (Vec::new(), Vec::new());
                for comp in 0..pa.n_components() {
                    pa.sample_component_with(comp, &z, &mut da);
                    pb.sample_component_with(comp, &z, &mut db);
                    for (va, vb) in da.iter().zip(&db) {
                        assert_eq!(va.to_bits(), vb.to_bits(), "k={k} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn constant_targets_do_not_crash() {
        let mut rng = Rng::new(6);
        let (xs, _) = toy(8, &mut rng);
        let ys = vec![0.7; 8];
        let mut gp = Gp::new(Basis::Cost);
        gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
        let (mu, std) = gp.predict(&xs[3]);
        assert!((mu - 0.7).abs() < 0.05);
        assert!(std.is_finite());
    }
}
