//! The end-to-end real workload: an MLP classifier trained entirely through
//! the AOT `mlp_train_step` / `mlp_eval` artifacts, driven from Rust.
//!
//! The synthetic-MNIST generator produces a 10-class problem of 784-dim
//! inputs (class-dependent Gaussian blobs over random prototype images), so
//! the full stack — data loading, sub-sampling, SGD steps, evaluation — runs
//! with Python nowhere on the path.

use super::artifacts::{literal_f32, literal_scalar_f32, Runtime};
use crate::util::Rng;
use anyhow::Result;

/// Host-side copy of the MLP parameters.
#[derive(Clone)]
pub struct MlpParams {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl MlpParams {
    pub fn init(rt: &Runtime, rng: &mut Rng) -> MlpParams {
        let m = &rt.manifest;
        let scale1 = (2.0 / m.mlp_in as f64).sqrt();
        let scale2 = (2.0 / m.mlp_hidden as f64).sqrt();
        MlpParams {
            w1: (0..m.mlp_in * m.mlp_hidden)
                .map(|_| (rng.normal() * scale1) as f32)
                .collect(),
            b1: vec![0.0; m.mlp_hidden],
            w2: (0..m.mlp_hidden * m.mlp_out)
                .map(|_| (rng.normal() * scale2) as f32)
                .collect(),
            b2: vec![0.0; m.mlp_out],
        }
    }
}

/// Synthetic-MNIST dataset: `n` samples of 784 features, 10 classes.
pub struct SyntheticMnist {
    pub x: Vec<f32>,
    /// one-hot labels
    pub y: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub d: usize,
    pub classes: usize,
}

impl SyntheticMnist {
    pub fn generate(n: usize, d: usize, classes: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // class prototypes: sparse random "stroke" patterns
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        if rng.f64() < 0.15 {
                            rng.uniform(0.5, 1.0) as f32
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        let mut x = Vec::with_capacity(n * d);
        let mut y = vec![0.0f32; n * classes];
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.below(classes);
            labels.push(c);
            y[i * classes + c] = 1.0;
            for j in 0..d {
                let noise = (rng.normal() * 0.25) as f32;
                x.push((protos[c][j] + noise).clamp(-1.0, 1.5));
            }
        }
        SyntheticMnist { x, y, labels, n, d, classes }
    }

    /// Rows `[lo, hi)` as flat slices.
    pub fn batch(&self, idx: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let mut bx = Vec::with_capacity(idx.len() * self.d);
        let mut by = Vec::with_capacity(idx.len() * self.classes);
        for &i in idx {
            bx.extend_from_slice(&self.x[i * self.d..(i + 1) * self.d]);
            by.extend_from_slice(
                &self.y[i * self.classes..(i + 1) * self.classes],
            );
        }
        (bx, by)
    }
}

/// Trainer: repeatedly executes the `mlp_train_step` artifact.
pub struct MlpTrainer<'rt> {
    rt: &'rt Runtime,
    pub params: MlpParams,
    pub lr: f32,
}

impl<'rt> MlpTrainer<'rt> {
    pub fn new(rt: &'rt Runtime, params: MlpParams, lr: f32) -> Self {
        MlpTrainer { rt, params, lr }
    }

    /// One SGD step on a (batch, one-hot) pair; returns the loss.
    pub fn step(&mut self, xb: &[f32], yb: &[f32]) -> Result<f64> {
        let m = &self.rt.manifest;
        let out = self.rt.run(
            "mlp_train_step",
            &[
                literal_f32(&self.params.w1, &[m.mlp_in as i64, m.mlp_hidden as i64])?,
                literal_f32(&self.params.b1, &[m.mlp_hidden as i64])?,
                literal_f32(&self.params.w2, &[m.mlp_hidden as i64, m.mlp_out as i64])?,
                literal_f32(&self.params.b2, &[m.mlp_out as i64])?,
                literal_f32(xb, &[m.mlp_batch as i64, m.mlp_in as i64])?,
                literal_f32(yb, &[m.mlp_batch as i64, m.mlp_out as i64])?,
                literal_scalar_f32(self.lr),
            ],
        )?;
        self.params.w1 = out[0].to_vec()?;
        self.params.b1 = out[1].to_vec()?;
        self.params.w2 = out[2].to_vec()?;
        self.params.b2 = out[3].to_vec()?;
        Ok(out[4].to_vec::<f32>()?[0] as f64)
    }

    /// Accuracy + loss on an eval batch (padded/truncated to MLP_EVAL rows).
    pub fn eval(&self, xe: &[f32], ye: &[f32]) -> Result<(f64, f64)> {
        let m = &self.rt.manifest;
        let out = self.rt.run(
            "mlp_eval",
            &[
                literal_f32(&self.params.w1, &[m.mlp_in as i64, m.mlp_hidden as i64])?,
                literal_f32(&self.params.b1, &[m.mlp_hidden as i64])?,
                literal_f32(&self.params.w2, &[m.mlp_hidden as i64, m.mlp_out as i64])?,
                literal_f32(&self.params.b2, &[m.mlp_out as i64])?,
                literal_f32(xe, &[m.mlp_eval as i64, m.mlp_in as i64])?,
                literal_f32(ye, &[m.mlp_eval as i64, m.mlp_out as i64])?,
            ],
        )?;
        Ok((
            out[0].to_vec::<f32>()?[0] as f64,
            out[1].to_vec::<f32>()?[0] as f64,
        ))
    }
}

/// Smoke training used by `runtime-check`: returns (first loss, last loss,
/// final eval accuracy).
pub fn train_smoke(rt: &Runtime, steps: usize) -> Result<(f64, f64, f64)> {
    let m = &rt.manifest;
    let mut rng = Rng::new(0x11);
    let data = SyntheticMnist::generate(
        m.mlp_batch * 8,
        m.mlp_in,
        m.mlp_out,
        7,
    );
    let eval = SyntheticMnist::generate(m.mlp_eval, m.mlp_in, m.mlp_out, 7);
    let params = MlpParams::init(rt, &mut rng);
    let mut trainer = MlpTrainer::new(rt, params, 0.5);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 0..steps {
        let idx = rng.sample_indices(data.n, m.mlp_batch);
        let (bx, by) = data.batch(&idx);
        let loss = trainer.step(&bx, &by)?;
        if step == 0 {
            first = loss;
        }
        last = loss;
    }
    let idx: Vec<usize> = (0..m.mlp_eval).collect();
    let (ex, ey) = eval.batch(&idx);
    let (acc, _) = trainer.eval(&ex, &ey)?;
    Ok((first, last, acc))
}
