//! Surrogate-model micro-benchmarks: GP (ML-II and marginalized) vs
//! Extra-Trees fit / predict / condition — the primitives whose cost ratio
//! drives paper Table III.
mod common;

use trimtuner::models::{
    Basis, ExtraTrees, FitOptions, Gp, Surrogate, TreesOptions,
};
use trimtuner::space::encode;
use trimtuner::util::timer::bench;

fn main() {
    common::print_header("models");
    let (pts, outs) = common::observations(48, 7);
    let xs: Vec<_> = pts.iter().map(encode).collect();
    let ys: Vec<f64> = outs.iter().map(|o| o.acc).collect();
    let probe = encode(&pts[0]);

    for (label, k) in [("gp-ml2", 1usize), ("gp-mcmc8", 8)] {
        let mut gp = Gp::with_hyper_samples(Basis::Acc, 3, k);
        let stats = bench(&format!("{label} fit(48) w/ hyperopt"), 1, 5, || {
            gp.fit(&xs, &ys, FitOptions { hyperopt: true, restarts: 1 });
        });
        println!("{}", stats.report());
        let stats = bench(&format!("{label} predict x288"), 2, 20, || {
            (0..288)
                .map(|i| gp.predict(&xs[i % xs.len()]).0)
                .sum::<f64>()
        });
        println!("{}", stats.report());
        let stats = bench(&format!("{label} condition+predict"), 2, 20, || {
            let g = gp.condition(&probe, 0.9);
            g.predict(&probe).0
        });
        println!("{}", stats.report());
    }

    let mut et = ExtraTrees::new(TreesOptions::default());
    let stats = bench("extra-trees fit(48, 30 trees)", 1, 20, || {
        et.fit(&xs, &ys, FitOptions::default());
    });
    println!("{}", stats.report());
    let stats = bench("extra-trees predict x288", 2, 50, || {
        (0..288).map(|i| et.predict(&xs[i % xs.len()]).0).sum::<f64>()
    });
    println!("{}", stats.report());
    let stats = bench("extra-trees condition+predict", 2, 20, || {
        let t = et.condition(&probe, 0.9);
        t.predict(&probe).0
    });
    println!("{}", stats.report());
}
