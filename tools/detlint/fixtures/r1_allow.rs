// R1 allow: an ordered container for the drain, keyed lookups on the
// hash map, and one justified pragma for an order-insensitive fold.
use std::collections::{BTreeMap, HashMap};

fn sum_costs(ordered: &BTreeMap<usize, f64>) -> f64 {
    ordered.values().sum()
}

fn lookup(by_id: &HashMap<usize, f64>, id: usize) -> f64 {
    by_id.get(&id).copied().unwrap_or(0.0)
}

fn count_entries(tally: &HashMap<usize, f64>) -> usize {
    // detlint: allow(R1, reason="count is independent of iteration order")
    tally.keys().count()
}
