//! The surrogate-model abstraction shared by GP and decision-tree variants.

use crate::linalg::{Cholesky, Mat};
use crate::space::D_IN;
use crate::util::Rng;

/// A feature vector (6 normalized config features + sub-sampling rate).
pub type Feat = [f64; D_IN];

/// Which surrogate family an optimizer uses (paper: "TrimTuner (GPs)" vs
/// "TrimTuner (DTs)").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Gp,
    Trees,
}

impl ModelKind {
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Gp => "gp",
            ModelKind::Trees => "dt",
        }
    }
}

/// Options controlling a (re)fit.
#[derive(Debug, Clone, Copy)]
pub struct FitOptions {
    /// re-optimize hyper-parameters (GP: MLL Nelder–Mead; trees: n/a)
    pub hyperopt: bool,
    /// random restarts for the hyper-parameter search
    pub restarts: usize,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions { hyperopt: true, restarts: 1 }
    }
}

/// Which covariance representation a [`PostComp`] currently carries. Both
/// buffers are retained when a reused component flips form (the slate
/// sweep's downdate-or-diagonal fallback), so nothing is dropped or
/// reallocated per candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum CompForm {
    Joint,
    Diag,
}

/// One mixture component of a joint posterior.
pub struct PostComp {
    pub mean: Vec<f64>,
    cov_l: Option<Cholesky>,
    diag_std: Option<Vec<f64>>,
    form: CompForm,
}

impl PostComp {
    fn empty() -> PostComp {
        PostComp {
            mean: Vec::new(),
            cov_l: None,
            diag_std: None,
            form: CompForm::Diag,
        }
    }

    /// Switch this component to joint form and hand out its covariance
    /// factor for overwriting (allocated on first use, reused after).
    pub fn joint_mut(&mut self) -> &mut Cholesky {
        self.form = CompForm::Joint;
        self.cov_l.get_or_insert_with(Cholesky::scratch)
    }

    /// Switch this component to diagonal form and hand out its std buffer
    /// for overwriting (allocated on first use, reused after).
    pub fn diag_mut(&mut self) -> &mut Vec<f64> {
        self.form = CompForm::Diag;
        self.diag_std.get_or_insert_with(Vec::new)
    }
}

/// Joint posterior over a set of points, used for Entropy-Search p_opt
/// Monte-Carlo. GPs carry the full covariance Cholesky factor; tree
/// ensembles an independent per-point std (their ensemble spread carries no
/// cross-covariance information). Hyper-parameter-marginalized GPs
/// (FABOLAS-style) carry one component per hyper-parameter sample;
/// successive draws rotate across components (a draw from the mixture).
pub struct Posterior {
    comps: Vec<PostComp>,
    /// components in use: `comps[..live]` (slots past `live` are retained
    /// for buffer reuse when the posterior is rebuilt in place)
    live: usize,
    /// round-robin component cursor for mixture sampling
    cursor: std::cell::Cell<usize>,
    /// mixture mean (averaged across components)
    pub mean: Vec<f64>,
}

impl Posterior {
    fn from_comps(comps: Vec<PostComp>) -> Posterior {
        assert!(!comps.is_empty());
        let mut p = Posterior {
            live: comps.len(),
            comps,
            cursor: std::cell::Cell::new(0),
            mean: Vec::new(),
        };
        p.finish();
        p
    }

    /// An empty posterior to be filled in place via
    /// [`Posterior::clear_components`] / [`Posterior::push_component`] /
    /// [`Posterior::finish`] — the zero-allocation rebuild path the primed
    /// slate sweep uses once per candidate.
    pub fn new_empty() -> Posterior {
        Posterior {
            comps: Vec::new(),
            live: 0,
            cursor: std::cell::Cell::new(0),
            mean: Vec::new(),
        }
    }

    /// Start an in-place rebuild: marks every component slot dead (their
    /// buffers are retained for reuse) and resets the mixture cursor.
    pub fn clear_components(&mut self) {
        self.live = 0;
        self.cursor.set(0);
    }

    /// Append one component slot and hand it out for overwriting; reuses a
    /// dead slot's buffers when one is available. Call
    /// [`Posterior::finish`] once all components are written.
    pub fn push_component(&mut self) -> &mut PostComp {
        if self.live == self.comps.len() {
            self.comps.push(PostComp::empty());
        }
        self.live += 1;
        &mut self.comps[self.live - 1]
    }

    /// Recompute the mixture mean from the live components (same
    /// accumulation order as a fresh construction, so in-place rebuilds
    /// are bit-identical to allocating ones).
    pub fn finish(&mut self) {
        assert!(self.live > 0, "posterior with no live components");
        let n = self.comps[0].mean.len();
        self.mean.clear();
        self.mean.resize(n, 0.0);
        for c in &self.comps[..self.live] {
            for (m, v) in self.mean.iter_mut().zip(&c.mean) {
                *m += v / self.live as f64;
            }
        }
    }

    pub fn joint(mean: Vec<f64>, cov_l: Cholesky) -> Posterior {
        Posterior::from_comps(vec![PostComp {
            mean,
            cov_l: Some(cov_l),
            diag_std: None,
            form: CompForm::Joint,
        }])
    }

    pub fn diagonal(mean: Vec<f64>, std: Vec<f64>) -> Posterior {
        Posterior::from_comps(vec![PostComp {
            mean,
            cov_l: None,
            diag_std: Some(std),
            form: CompForm::Diag,
        }])
    }

    pub fn mixture(comps: Vec<(Vec<f64>, Option<Cholesky>, Option<Vec<f64>>)>) -> Posterior {
        Posterior::from_comps(
            comps
                .into_iter()
                .map(|(mean, cov_l, diag_std)| PostComp {
                    form: if cov_l.is_some() {
                        CompForm::Joint
                    } else {
                        CompForm::Diag
                    },
                    mean,
                    cov_l,
                    diag_std,
                })
                .collect(),
        )
    }

    pub fn n_components(&self) -> usize {
        self.live
    }

    pub fn len(&self) -> usize {
        self.mean.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Draw one sample of the joint function values given pre-drawn
    /// standard normals `z` (common random numbers let the acquisition
    /// function compare candidates without MC jitter; DESIGN.md §6).
    /// Successive calls rotate round-robin over mixture components.
    pub fn sample_with(&self, z: &[f64], out: &mut Vec<f64>) {
        let k = self.cursor.get();
        self.cursor.set((k + 1) % self.live);
        self.sample_component_with(k, z, out);
    }

    /// Sample a specific mixture component.
    pub fn sample_component_with(&self, k: usize, z: &[f64], out: &mut Vec<f64>) {
        let comp = &self.comps[k % self.live];
        let n = comp.mean.len();
        assert_eq!(z.len(), n);
        out.clear();
        if comp.form == CompForm::Joint {
            // f = mean + L z
            let l = comp.cov_l.as_ref().expect("joint component without factor");
            let lm: &Mat = l.l();
            for i in 0..n {
                let row = lm.row(i);
                let mut acc = comp.mean[i];
                for j in 0..=i {
                    acc += row[j] * z[j];
                }
                out.push(acc);
            }
        } else {
            let std = comp.diag_std.as_ref().expect("posterior without cov");
            for i in 0..n {
                out.push(comp.mean[i] + std[i] * z[i]);
            }
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> Vec<f64> {
        let z: Vec<f64> = (0..self.len()).map(|_| rng.normal()).collect();
        let mut out = Vec::with_capacity(self.len());
        self.sample_with(&z, &mut out);
        out
    }
}

/// One candidate's conditioned view of a fantasy query grid: the posterior
/// the surrogate *would* have after observing `(x, ŷ(x))`, evaluated on the
/// fixed grid its [`FantasySurface`] was built over.
pub struct FantasyView {
    /// Conditioned mixture (mean, std) on every grid point — matches
    /// `condition(x, ŷ).predict_many(grid)`.
    pub grid: Vec<(f64, f64)>,
    /// Conditioned joint posterior over the grid's joint prefix — matches
    /// `condition(x, ŷ).posterior(&grid[..m_joint])`. `None` when the
    /// surface was built with `m_joint == 0`.
    pub joint: Option<Posterior>,
}

impl FantasyView {
    /// An empty view for [`PrimedSlate::view_into`] to overwrite; keep one
    /// per worker and every buffer inside (grid, posterior components,
    /// covariance factors) is reused across candidates.
    pub fn new() -> FantasyView {
        FantasyView { grid: Vec::new(), joint: None }
    }
}

impl Default for FantasyView {
    fn default() -> Self {
        FantasyView::new()
    }
}

/// Reusable per-worker scratch for the slate sweep's conditioned views —
/// the hot per-candidate loops borrow these buffers instead of allocating
/// fresh vectors per view (each buffer is cleared/overwritten on use, so a
/// dirty scratch can never leak state between candidates).
#[derive(Default)]
pub struct FantasyScratch {
    /// posterior cross-covariance buffer (candidate → grid)
    pub cross: Vec<f64>,
    /// rank-one direction buffer for the joint-factor downdate
    pub rank1: Vec<f64>,
    /// hyperbolic-rotation working vector for `Cholesky::downdate_into`
    pub sweep: Vec<f64>,
    /// per-tree slate accumulators (trees incremental conditioning)
    pub acc: Vec<f64>,
    pub acc2: Vec<f64>,
    /// flattened per-component conditioned means/variances over the grid
    /// (`k * n_grid` entries), for the hyper-marginalized GP combine
    pub mus: Vec<f64>,
    pub vars: Vec<f64>,
}

impl FantasyScratch {
    pub fn new() -> FantasyScratch {
        FantasyScratch::default()
    }
}

/// A fantasy surface primed for one specific candidate slate: every
/// per-candidate quantity that can be batched across the slate (GP: the
/// cross-kernel solves `w = L⁻¹k(X, x_c)` collected into one multi-RHS
/// triangular solve per hyper-sample, plus the simulated outcomes ŷ(x_c);
/// trees: one tree-major ŷ sweep) is computed once at
/// [`FantasySurface::prime`] time, so `view_at(c)` pays only the
/// dot-product sweep of candidate `c`.
pub trait PrimedSlate: Send + Sync {
    /// The conditioned view of slate candidate `i`, written into `out` —
    /// identical (bit for bit) to `view(&slate[i])` on the surface that
    /// primed this slate. Reusing `out` and `scratch` across candidates
    /// makes the sweep allocation-free in steady state (enforced
    /// statically by detlint rule A1 and dynamically by
    /// `tests/alloc_contracts.rs`).
    fn view_into(
        &self,
        i: usize,
        scratch: &mut FantasyScratch,
        out: &mut FantasyView,
    );

    /// Allocating convenience over [`PrimedSlate::view_into`].
    fn view_at(&self, i: usize, scratch: &mut FantasyScratch) -> FantasyView {
        let mut out = FantasyView::new();
        self.view_into(i, scratch, &mut out);
        out
    }
}

/// Fallback primer for surfaces without a batched implementation: defers
/// every candidate to [`FantasySurface::view_with`].
struct MapPrimed<'s, S: ?Sized> {
    surf: &'s S,
    xs: &'s [Feat],
}

impl<S: FantasySurface + ?Sized> PrimedSlate for MapPrimed<'_, S> {
    fn view_into(
        &self,
        i: usize,
        scratch: &mut FantasyScratch,
        out: &mut FantasyView,
    ) {
        *out = self.surf.view_with(&self.xs[i], scratch);
    }
}

/// Per-iteration fantasy-conditioning surface over a fixed query grid.
///
/// Built once per acquisition round via [`Surrogate::fantasy_surface`];
/// every [`FantasySurface::view`] call then yields the grid under the
/// surrogate conditioned on one simulated observation `(x, ŷ(x))` — for
/// GPs via closed-form rank-one posterior algebra (no surrogate clone, no
/// Cholesky re-factorization), for tree ensembles via the incremental
/// leaf-statistics path over one cached conditioned structure.
///
/// `Send + Sync` so the slate evaluator can shard candidate views across
/// `std::thread::scope` workers.
pub trait FantasySurface: Send + Sync {
    /// The conditioned view for one candidate, borrowing the caller's
    /// scratch buffers. The simulated outcome is the surrogate's own
    /// predictive mean at `x` — the single-root Gauss–Hermite collapse
    /// `Models::condition` uses.
    fn view_with(&self, x: &Feat, scratch: &mut FantasyScratch)
        -> FantasyView;

    /// [`FantasySurface::view_with`] with a one-shot local scratch — the
    /// allocating convenience for cold callers and tests.
    fn view(&self, x: &Feat) -> FantasyView {
        let mut scratch = FantasyScratch::new();
        self.view_with(x, &mut scratch)
    }

    /// Prime the surface for a whole candidate slate (see [`PrimedSlate`]).
    /// The default defers to per-candidate [`FantasySurface::view_with`]
    /// calls; the native models override it with genuinely batched
    /// precomputation that stays bit-identical to the per-candidate path.
    fn prime<'s>(&'s self, xs: &'s [Feat]) -> Box<dyn PrimedSlate + 's> {
        Box::new(MapPrimed { surf: self, xs })
    }
}

/// Reference fantasy surface for surrogates without a specialized
/// implementation: clone-and-condition per candidate — exactly the
/// baseline the rank-one paths are verified against.
struct CloneFantasy {
    base: Box<dyn Surrogate>,
    grid: Vec<Feat>,
    m_joint: usize,
}

impl FantasySurface for CloneFantasy {
    fn view_with(
        &self,
        x: &Feat,
        _scratch: &mut FantasyScratch,
    ) -> FantasyView {
        let (y, _) = self.base.predict(x);
        let cond = self.base.condition(x, y);
        let grid = cond.predict_many(&self.grid);
        let joint = (self.m_joint > 0)
            .then(|| cond.posterior(&self.grid[..self.m_joint]));
        FantasyView { grid, joint }
    }
}

/// A Bayesian surrogate over the (config, s) feature space.
///
/// The acquisition hot path relies on [`Surrogate::condition`]: a cheap
/// clone extended with one hypothetical observation while hyper-parameters
/// stay frozen (GP: O(n²) Cholesky extension; trees: a fresh seeded
/// bootstrap whose structure is built from the existing observations, with
/// the new observation folded into the leaf statistics it lands in).
///
/// `Send + Sync` because the slate evaluator shares fitted surrogates
/// (read-only) across `std::thread::scope` workers.
pub trait Surrogate: Send + Sync {
    /// Fit from scratch on (xs, ys).
    fn fit(&mut self, xs: &[Feat], ys: &[f64], opts: FitOptions);

    /// Predictive mean and standard deviation at one point.
    fn predict(&self, x: &Feat) -> (f64, f64);

    /// Batch prediction over a whole candidate slate. The default maps
    /// [`Surrogate::predict`]; both native models override it with a
    /// genuinely batched pass (GP: one multi-RHS triangular solve; trees:
    /// one cache-friendly tree-major traversal) that is bit-identical to
    /// the scalar path.
    fn predict_many(&self, xs: &[Feat]) -> Vec<(f64, f64)> {
        xs.iter().map(|x| self.predict(x)).collect()
    }

    /// Joint posterior over `xs` (for p_opt sampling).
    fn posterior(&self, xs: &[Feat]) -> Posterior;

    /// Clone extended with one observation, hyper-parameters frozen.
    fn condition(&self, x: &Feat, y: f64) -> Box<dyn Surrogate>;

    /// Build a fantasy surface over a fixed query grid: shared
    /// per-iteration precomputation, then one cheap conditioned view per
    /// candidate. Views carry a joint conditioned posterior over the first
    /// `m_joint` grid points (for p_opt sampling) and conditioned
    /// (mean, std) everywhere. The default clones + conditions per view;
    /// the native models override it (GP: rank-one posterior algebra over
    /// precomputed cross-solves; trees: incremental leaf-statistics
    /// conditioning over one cached fused-grid structure).
    fn fantasy_surface(
        &self,
        grid: &[Feat],
        m_joint: usize,
    ) -> Box<dyn FantasySurface> {
        assert!(m_joint <= grid.len());
        Box::new(CloneFantasy {
            base: self.clone_box(),
            grid: grid.to_vec(),
            m_joint,
        })
    }

    /// Fold one *real* observation into the fitted state incrementally,
    /// hyper-parameters (GP) / ensemble structure (trees) frozen: the
    /// amortized-O(n²) absorption path the engine's refit policy uses on
    /// rounds that skip the full refit. Unlike [`Surrogate::condition`]
    /// this mutates the surrogate itself and the observation is permanent.
    /// Parity with [`Surrogate::refit_frozen`] is pinned by
    /// `tests/refit_parity.rs`.
    fn absorb(&mut self, _x: &Feat, _y: f64) {
        unimplemented!("this surrogate does not support incremental absorb")
    }

    /// Recompute, from scratch, exactly the state [`Surrogate::absorb`]
    /// maintains (GP: re-standardize the raw targets and refactor every
    /// hyper component with frozen parameters; trees: rebuild the
    /// structure anchored at the last structural fit and replay the
    /// absorbed tail) — the `TRIMTUNER_REFIT=full` reference twin.
    fn refit_frozen(&mut self) {
        unimplemented!("this surrogate does not support refit_frozen")
    }

    /// Number of observations currently fitted.
    fn n_obs(&self) -> usize;

    fn clone_box(&self) -> Box<dyn Surrogate>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn diagonal_posterior_sampling_moments() {
        let p = Posterior::diagonal(vec![1.0, -2.0], vec![0.5, 2.0]);
        let mut rng = Rng::new(3);
        let n = 20_000;
        let (mut m0, mut m1, mut v0, mut v1) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let s = p.sample(&mut rng);
            m0 += s[0];
            m1 += s[1];
            v0 += (s[0] - 1.0) * (s[0] - 1.0);
            v1 += (s[1] + 2.0) * (s[1] + 2.0);
        }
        let n = n as f64;
        assert!((m0 / n - 1.0).abs() < 0.02);
        assert!((m1 / n + 2.0).abs() < 0.05);
        assert!((v0 / n - 0.25).abs() < 0.02);
        assert!((v1 / n - 4.0).abs() < 0.15);
    }

    #[test]
    fn in_place_posterior_rebuild_matches_fresh_construction() {
        let k = Mat::from_rows(&[vec![1.0, 0.3], vec![0.3, 1.0]]);
        let l = crate::linalg::Cholesky::factor(&k).unwrap();
        let fresh = Posterior::mixture(vec![
            (vec![1.0, 2.0], Some(l.clone()), None),
            (vec![3.0, -1.0], None, Some(vec![0.5, 0.25])),
        ]);
        let mut built = Posterior::new_empty();
        // several rounds so slot reuse (retained buffers, form flips) is
        // exercised, not just the first fill
        for _ in 0..3 {
            built.clear_components();
            let c = built.push_component();
            c.mean.clear();
            c.mean.extend_from_slice(&[1.0, 2.0]);
            *c.joint_mut() = l.clone();
            let c = built.push_component();
            c.mean.clear();
            c.mean.extend_from_slice(&[3.0, -1.0]);
            let d = c.diag_mut();
            d.clear();
            d.extend_from_slice(&[0.5, 0.25]);
            built.finish();
        }
        assert_eq!(built.n_components(), fresh.n_components());
        assert_eq!(built.mean, fresh.mean);
        let z = [0.7, -1.3];
        let (mut a, mut b) = (Vec::new(), Vec::new());
        for comp in 0..2 {
            fresh.sample_component_with(comp, &z, &mut a);
            built.sample_component_with(comp, &z, &mut b);
            assert_eq!(a, b, "component {comp} diverged");
        }
        // a rebuild with fewer components hides the dead slot
        built.clear_components();
        let c = built.push_component();
        c.mean.clear();
        c.mean.extend_from_slice(&[5.0, 5.0]);
        let d = c.diag_mut();
        d.clear();
        d.extend_from_slice(&[1.0, 1.0]);
        built.finish();
        assert_eq!(built.n_components(), 1);
        assert_eq!(built.mean, vec![5.0, 5.0]);
    }

    #[test]
    fn joint_posterior_respects_covariance() {
        // cov = [[1, 0.9], [0.9, 1]] -> samples strongly correlated
        let k = Mat::from_rows(&[vec![1.0, 0.9], vec![0.9, 1.0]]);
        let l = crate::linalg::Cholesky::factor(&k).unwrap();
        let p = Posterior::joint(vec![0.0, 0.0], l);
        let mut rng = Rng::new(4);
        let mut corr = 0.0;
        let n = 20_000;
        for _ in 0..n {
            let s = p.sample(&mut rng);
            corr += s[0] * s[1];
        }
        assert!((corr / n as f64 - 0.9).abs() < 0.05);
    }
}
