//! Placeholder library target; the loom models live in
//! `tests/pool_model.rs` and only compile with `RUSTFLAGS="--cfg loom"`.
//! See Cargo.toml for why this crate sits outside the workspace.
