//! Generic numeric optimizers used as substrates: Nelder–Mead (GP
//! hyper-parameter fitting) and Latin Hypercube Sampling (initial designs).

mod lhs;
mod neldermead;

pub use lhs::latin_hypercube;
pub use neldermead::{nelder_mead, NmOptions};
