//! Expected Improvement and its constrained variants (paper §II Eq. 1 and
//! the EIc / EIc/USD baselines of §IV used by CherryPick and Lynceus).

use super::models::{joint_feasibility, Models};
use crate::models::Feat;
use crate::space::Constraint;
use crate::util::stats::{normal_cdf, normal_pdf};

/// Analytic EI of maximizing over incumbent `eta`:
/// EI = sigma * (gamma Phi(gamma) + phi(gamma)), gamma = (mu - eta)/sigma.
pub fn ei(mu: f64, sigma: f64, eta: f64) -> f64 {
    if sigma < 1e-12 {
        return (mu - eta).max(0.0);
    }
    let gamma = (mu - eta) / sigma;
    (sigma * (gamma * normal_cdf(gamma) + normal_pdf(gamma))).max(0.0)
}

/// Constrained EI (CherryPick): EI on accuracy × joint feasibility
/// probability at the same point.
pub fn eic(
    models: &Models,
    constraints: &[Constraint],
    x: &Feat,
    eta: f64,
) -> f64 {
    let (mu, sigma) = models.acc.predict(x);
    ei(mu, sigma, eta) * joint_feasibility(models, constraints, x)
}

/// EIc per dollar (Lynceus): EIc divided by the predicted cost of running
/// the exploration itself.
pub fn eic_usd(
    models: &Models,
    constraints: &[Constraint],
    x: &Feat,
    eta: f64,
) -> f64 {
    eic(models, constraints, x, eta) / models.predicted_cost(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn ei_zero_when_far_below_incumbent() {
        assert!(ei(0.0, 0.01, 10.0) < 1e-12);
    }

    #[test]
    fn ei_equals_gap_when_certain() {
        assert!((ei(2.0, 0.0, 1.5) - 0.5).abs() < 1e-12);
        assert_eq!(ei(1.0, 0.0, 1.5), 0.0);
    }

    #[test]
    fn ei_increases_with_mean_and_sigma() {
        check("EI monotonicity", 64, |rng| {
            let eta = rng.uniform(-1.0, 1.0);
            let mu = rng.uniform(-2.0, 2.0);
            let s = rng.uniform(0.01, 2.0);
            let e = ei(mu, s, eta);
            if e < 0.0 {
                return Err(format!("negative EI {e}"));
            }
            if ei(mu + 0.1, s, eta) < e - 1e-12 {
                return Err("EI decreased with mean".into());
            }
            if ei(mu, s + 0.1, eta) < e - 1e-12 {
                return Err("EI decreased with sigma".into());
            }
            Ok(())
        });
    }

    #[test]
    fn ei_matches_numerical_integral() {
        check("EI vs quadrature", 16, |rng| {
            let (mu, s, eta) =
                (rng.uniform(-1.0, 1.0), rng.uniform(0.2, 1.5), 0.3);
            let analytic = ei(mu, s, eta);
            // trapezoid over mu ± 8s
            let mut num = 0.0;
            let steps = 4000;
            for i in 0..steps {
                let z = -8.0 + 16.0 * (i as f64 + 0.5) / steps as f64;
                let y = mu + s * z;
                num += (y - eta).max(0.0) * normal_pdf(z) * (16.0 / steps as f64);
            }
            if (analytic - num).abs() < 2e-3 {
                Ok(())
            } else {
                Err(format!("analytic {analytic} vs num {num}"))
            }
        });
    }
}
