// A2 fire: a per-candidate loop calling the allocating wrapper where the
// scratch twin exists — every `.solve_lower(…)` call clones the RHS into
// a fresh buffer the caller immediately throws away.

pub struct Factor {
    l: Vec<f64>,
    n: usize,
}

impl Factor {
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_lower_in_place(&mut x);
        x
    }

    pub fn solve_lower_into(&self, b: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(b);
        self.solve_lower_in_place(out);
    }

    fn solve_lower_in_place(&self, x: &mut [f64]) {
        for i in 0..self.n {
            for j in 0..i {
                x[i] -= self.l[i * self.n + j] * x[j];
            }
            x[i] /= self.l[i * self.n + i];
        }
    }
}

pub fn score_slate(factor: &Factor, slate: &[Vec<f64>]) -> f64 {
    let mut acc = 0.0;
    for rhs in slate {
        let v = factor.solve_lower(rhs);
        acc += v.iter().sum::<f64>();
    }
    acc
}
