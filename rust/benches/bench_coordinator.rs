//! Coordinator throughput: worker-count sweep over snapshot jobs with
//! simulated launch latency, plus a short live engine run — the live-tuning
//! counterpart of `bench_end_to_end`.
//!
//! Results are also written to `BENCH_coordinator.json` (override the path
//! with the `BENCH_JSON` env var) so CI can track the perf trajectory. The
//! headline is the `serve` line sweep: wall time for the same job batch
//! must drop as workers are added (the launcher sleeps proportionally to
//! the simulated training duration, so parallelism is actually observable).
mod common;

use trimtuner::coordinator::{FaultSpec, Job, SimLauncher, WorkerPool};
use trimtuner::engine::{
    self, BatchMode, EngineConfig, EvalBackend, LiveEval, OptimizerKind,
    RetryPolicy,
};
use trimtuner::models::ModelKind;
use trimtuner::sim::NetKind;
use trimtuner::space::{Config, Constraint, N_CONFIGS, S_INIT};
use trimtuner::util::timer::bench;

/// Wall seconds slept per simulated training second: MLP runs simulate
/// O(100 s) trainings, so jobs cost a few ms each — enough to measure
/// scaling, small enough for CI.
const LATENCY: f64 = 3e-5;
const N_JOBS: usize = 24;

fn main() {
    common::print_header("coordinator (worker sweep + live engine)");
    let mut all = Vec::new();

    for workers in [1usize, 2, 4, 8] {
        let stats = bench(
            &format!("serve {N_JOBS} snapshot jobs workers={workers}"),
            1,
            3,
            || {
                let launcher =
                    SimLauncher::with_options(NetKind::Mlp, 7, 1.0, LATENCY);
                let pool = WorkerPool::new(Box::new(launcher), workers);
                for i in 0..N_JOBS {
                    pool.submit(Job {
                        id: i as u64,
                        config: Config::from_id((i * 37) % N_CONFIGS),
                        s_levels: S_INIT.to_vec(),
                    })
                    .unwrap();
                }
                let mut cost = 0.0;
                for _ in 0..N_JOBS {
                    cost += pool.recv().unwrap().charged_cost;
                }
                pool.shutdown();
                cost
            },
        );
        println!("{}", stats.report());
        all.push(stats);
    }

    // Live Algorithm-1 runs through the pool (with the default q = 1 the
    // engine's probe path is sequential, so the workers=1 vs 4 pair
    // measures per-iteration coordinator overhead, not scaling).
    for workers in [1usize, 4] {
        let stats = bench(
            &format!("live trimtuner-dt 6-iter run workers={workers}"),
            0,
            3,
            || {
                let mut cfg = EngineConfig::paper_default(
                    OptimizerKind::TrimTuner(ModelKind::Trees),
                    5,
                );
                cfg.max_iters = 6;
                let launcher =
                    SimLauncher::with_options(NetKind::Rnn, 5, 1.0, LATENCY);
                let mut backend = EvalBackend::Live(LiveEval::new(
                    Box::new(launcher),
                    workers,
                ));
                let caps =
                    [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
                let run = engine::run_backend(&mut backend, &caps, &cfg)
                    .expect("live run failed");
                run.records.len()
            },
        );
        println!("{}", stats.report());
        all.push(stats);
    }

    // Batched-slate sweep (q × workers): the same 8-observation budget
    // spent in rounds of q concurrent deployments. With latency-
    // proportional launches, wall time per observation must drop at q > 1
    // when workers >= q — both from overlapping deployments and from
    // paying the selection + refit cost once per round instead of once per
    // observation. This is the regret-vs-wall-clock trade-off axis the
    // ISSUE's batched-probe work targets; `cum$`/regret stays comparable
    // because the probe budget (max_iters) is fixed across cells.
    const BATCH_ITERS: usize = 8;
    let mut barrier_q4_w4_best = f64::NAN;
    for q in [1usize, 2, 4] {
        for workers in [1usize, 4] {
            let stats = bench(
                &format!(
                    "live trimtuner-dt {BATCH_ITERS}-obs batch q={q} \
                     workers={workers}"
                ),
                0,
                3,
                || {
                    let mut cfg = EngineConfig::paper_default(
                        OptimizerKind::TrimTuner(ModelKind::Trees),
                        5,
                    );
                    cfg.max_iters = BATCH_ITERS;
                    cfg.batch_size = q;
                    // pin the slate strategy: an ambient TRIMTUNER_BATCH
                    // must not silently change what the JSON rows measure
                    cfg.batch_mode = BatchMode::Fantasy;
                    let launcher = SimLauncher::with_options(
                        NetKind::Rnn,
                        5,
                        1.0,
                        LATENCY,
                    );
                    let mut backend = EvalBackend::Live(LiveEval::new(
                        Box::new(launcher),
                        workers,
                    ));
                    let caps = [Constraint::cost_max(
                        NetKind::Rnn.paper_cost_cap(),
                    )];
                    let run = engine::run_backend(&mut backend, &caps, &cfg)
                        .expect("live run failed");
                    // (observations, rounds, cumulative cost): black-boxed
                    // so the whole engine round — selection, deployment,
                    // accounting — stays live under optimization
                    (run.records.len(), run.n_rounds(), run.total_cost())
                },
            );
            println!("{}", stats.report());
            if q == 4 && workers == 4 {
                barrier_q4_w4_best = stats.min_s;
            }
            all.push(stats);
        }
    }

    // Asynchronous (non-barrier) sweep: the same 8-observation budget with
    // continuous re-selection — the engine refills the pool the moment a
    // slot frees instead of waiting out the whole q-slate, so one straggler
    // no longer idles the other workers at a round boundary. workers=1 is
    // the sequential-parity cell (bit-identical trajectory to q=1); the
    // async-vs-barrier headline is workers=4 against the barriered q=4
    // workers=4 cell above, gated under BENCH_COORDINATOR_SMOKE=1.
    let mut async_w4_best = f64::NAN;
    for workers in [1usize, 4, 8] {
        let stats = bench(
            &format!(
                "live trimtuner-dt {BATCH_ITERS}-obs async workers={workers}"
            ),
            0,
            3,
            || {
                let mut cfg = EngineConfig::paper_default(
                    OptimizerKind::TrimTuner(ModelKind::Trees),
                    5,
                );
                cfg.max_iters = BATCH_ITERS;
                cfg.async_mode = true;
                cfg.batch_mode = BatchMode::Fantasy;
                let launcher =
                    SimLauncher::with_options(NetKind::Rnn, 5, 1.0, LATENCY);
                let mut backend = EvalBackend::Live(LiveEval::new(
                    Box::new(launcher),
                    workers,
                ));
                let caps =
                    [Constraint::cost_max(NetKind::Rnn.paper_cost_cap())];
                let run = engine::run_backend(&mut backend, &caps, &cfg)
                    .expect("async live run failed");
                (run.records.len(), run.n_rounds(), run.total_cost())
            },
        );
        println!("{}", stats.report());
        if workers == 4 {
            async_w4_best = stats.min_s;
        }
        all.push(stats);
    }

    // Synthetic ratio row (bench_models idiom): barriered-q4 / async wall
    // at 4 workers, best-of-run in min_s so shared-runner jitter cannot
    // flip a correct build. > 1 means the non-barrier scheduler wins.
    let speedup = barrier_q4_w4_best / async_w4_best;
    let ratio_row = trimtuner::util::timer::BenchStats {
        name: format!(
            "async-vs-barrier q=4 workers=4 speedup ({BATCH_ITERS} obs)"
        ),
        iters: 3,
        mean_s: speedup,
        p50_s: speedup,
        p99_s: speedup,
        min_s: speedup,
        max_s: speedup,
    };
    println!("{}", ratio_row.report());
    all.push(ratio_row);

    // Faulty cells: the same batched run under a spot + straggler + flaky
    // cocktail with a 2-retry budget. Measures the coordinator's retry /
    // abandonment overhead (resubmissions, partial-cost accounting) on top
    // of the clean q=4 cells above — fault decisions are seeded, so every
    // repetition replays the identical fault trace.
    for workers in [1usize, 4] {
        let stats = bench(
            &format!(
                "live trimtuner-dt {BATCH_ITERS}-obs batch q=4 \
                 workers={workers} faults=spot:0.3,straggle:2.0,flaky:0.2"
            ),
            0,
            3,
            || {
                let mut cfg = EngineConfig::paper_default(
                    OptimizerKind::TrimTuner(ModelKind::Trees),
                    5,
                );
                cfg.max_iters = BATCH_ITERS;
                cfg.batch_size = 4;
                cfg.batch_mode = BatchMode::Fantasy;
                let base = Box::new(SimLauncher::with_options(
                    NetKind::Rnn,
                    5,
                    1.0,
                    LATENCY,
                ));
                let spec =
                    FaultSpec::parse("spot:0.3,straggle:2.0,flaky:0.2")
                        .expect("static fault spec");
                let retry = RetryPolicy {
                    max_retries: 2,
                    ..RetryPolicy::default()
                };
                let mut backend = EvalBackend::Live(
                    LiveEval::new(spec.wrap(base, 0xFA17), workers)
                        .with_retry(retry, 5),
                );
                let caps = [Constraint::cost_max(
                    NetKind::Rnn.paper_cost_cap(),
                )];
                let run = engine::run_backend(&mut backend, &caps, &cfg)
                    .expect("faulty live run failed");
                (
                    run.records.len(),
                    run.faults.n_failures,
                    run.faults.n_abandoned,
                    run.total_cost(),
                )
            },
        );
        println!("{}", stats.report());
        all.push(stats);
    }

    let path = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_coordinator.json".to_string());
    common::write_bench_json("coordinator", &path, &all);

    // CI smoke gate: the async scheduler must beat the barriered q=4 run
    // on wall-clock at the same worker count — removing the round barrier
    // is the whole point, so parity or worse is a regression.
    if std::env::var("BENCH_COORDINATOR_SMOKE").is_ok() && !(speedup > 1.0) {
        eprintln!(
            "COORDINATOR PERF GATE FAILED: async workers=4 ({async_w4_best:.4}s) \
             not faster than barriered q=4 workers=4 ({barrier_q4_w4_best:.4}s), \
             speedup {speedup:.3}x"
        );
        std::process::exit(1);
    }
}
