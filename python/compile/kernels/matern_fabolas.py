"""Layer-1 Pallas kernel: fused Matérn-5/2 × FABOLAS sub-sampling covariance.

Computes the GP covariance matrix used by TrimTuner's surrogate models:

    K[i, j] = sigma2 * Matern52(r_ij) * (phi(s_i)^T Theta phi(s_j))

where ``r_ij`` is the lengthscale-scaled Euclidean distance between the
*config* features of rows i and j (columns ``0..D_FEAT``), ``s`` is the
sub-sampling rate stored in column ``D_FEAT``, and the basis vector is

    phi(s) = (1, 1-s)   for the accuracy model  (basis="acc")
    phi(s) = (1, s)     for the cost model      (basis="cost")

``Theta = L L^T`` is a 2x2 PSD matrix parameterized by its Cholesky factor
``L = [[l00, 0], [l10, l11]]`` so the basis kernel is PSD by construction
(this mirrors FABOLAS's "accuracy/cost grow predictably with data-set size"
kernels, Klein et al., AISTATS'17).

Hardware adaptation (see DESIGN.md §2): the M×N covariance matrix is tiled
into VMEM-sized blocks via BlockSpec; the pairwise squared distance is
computed as ``|a|^2 + |b|^2 - 2 a b^T`` so the inner contraction is an
MXU-shaped matmul over the feature dimension, and the Matérn + basis factors
are applied element-wise in the VPU. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Feature layout shared with the Rust side (rust/src/space/encode.rs):
# columns 0..D_FEAT are normalized config features, column D_FEAT is s.
D_FEAT = 6
D_IN = D_FEAT + 1
# Hyper-parameter vector layout (rust/src/models/kernel.rs must match):
# [ls_0 .. ls_5, sigma2, l00, l10, l11]
N_HYP = D_FEAT + 4

_SQRT5 = np.sqrt(5.0).astype(np.float32)


def _cov_kernel(x1_ref, x2_ref, hyp_ref, out_ref, *, basis: str):
    """One (bm, bn) tile of the covariance matrix."""
    x1 = x1_ref[...]  # (bm, D_IN) in VMEM
    x2 = x2_ref[...]  # (bn, D_IN)
    hyp = hyp_ref[...]  # (N_HYP,)
    inv_ls = 1.0 / hyp[:D_FEAT]
    sigma2 = hyp[D_FEAT]
    l00, l10, l11 = hyp[D_FEAT + 1], hyp[D_FEAT + 2], hyp[D_FEAT + 3]

    a = x1[:, :D_FEAT] * inv_ls[None, :]
    b = x2[:, :D_FEAT] * inv_ls[None, :]
    # Pairwise squared distances via an MXU-shaped contraction over D_FEAT.
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    r2 = (
        jnp.sum(a * a, axis=1)[:, None]
        + jnp.sum(b * b, axis=1)[None, :]
        - 2.0 * ab
    )
    r2 = jnp.maximum(r2, 0.0)
    r = jnp.sqrt(r2)
    matern = (1.0 + _SQRT5 * r + (5.0 / 3.0) * r2) * jnp.exp(-_SQRT5 * r)

    s1 = x1[:, D_FEAT]
    s2 = x2[:, D_FEAT]
    if basis == "acc":
        g1, g2 = 1.0 - s1, 1.0 - s2
    elif basis == "cost":
        g1, g2 = s1, s2
    else:
        raise ValueError(f"unknown basis {basis!r}")
    # phi(s) = (1, g);  phi1^T Theta phi2 expanded with Theta = L L^T:
    t00 = l00 * l00
    t01 = l00 * l10
    t11 = l10 * l10 + l11 * l11
    bas = (
        t00
        + t01 * (g1[:, None] + g2[None, :])
        + t11 * (g1[:, None] * g2[None, :])
    )
    out_ref[...] = sigma2 * matern * bas


def _block(dim: int, want: int) -> int:
    """Largest tile <= want that divides dim (falls back to the full dim)."""
    for cand in range(min(want, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("basis", "bm", "bn"))
def cov(x1, x2, hyp, *, basis: str = "acc", bm: int = 64, bn: int = 64):
    """Covariance matrix K(x1, x2) of shape (M, N).

    x1: (M, D_IN) float32 — config features + s in the last column.
    x2: (N, D_IN) float32.
    hyp: (N_HYP,) float32 — see N_HYP layout above.
    """
    m, n = x1.shape[0], x2.shape[0]
    assert x1.shape[1] == D_IN and x2.shape[1] == D_IN, (x1.shape, x2.shape)
    assert hyp.shape == (N_HYP,), hyp.shape
    bm = _block(m, bm)
    bn = _block(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_cov_kernel, basis=basis),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, D_IN), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, D_IN), lambda i, j: (j, 0)),
            pl.BlockSpec((N_HYP,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x1, x2, hyp)


def cov_diag(x, hyp, *, basis: str = "acc"):
    """Diagonal of K(x, x) — Matern52(0) == 1, so only sigma2 * basis(s, s)."""
    sigma2 = hyp[D_FEAT]
    l00, l10, l11 = hyp[D_FEAT + 1], hyp[D_FEAT + 2], hyp[D_FEAT + 3]
    s = x[:, D_FEAT]
    g = (1.0 - s) if basis == "acc" else s
    t00 = l00 * l00
    t01 = l00 * l10
    t11 = l10 * l10 + l11 * l11
    return sigma2 * (t00 + 2.0 * t01 * g + t11 * g * g)
