//! Parametric performance oracle for distributed MNIST training on t2.* VMs.

use crate::space::{Config, Point, FULL_DATASET};
use crate::util::Rng;

/// The three neural networks of the paper's evaluation, plus `Multilayer`,
/// a deeper-MLP extension workload for the live coordinator path (not part
/// of the paper's campaigns, hence excluded from [`NetKind::ALL`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NetKind {
    Cnn,
    Mlp,
    Rnn,
    Multilayer,
}

impl NetKind {
    /// The paper's three evaluation networks (Table II order by feasibility).
    pub const ALL: [NetKind; 3] = [NetKind::Rnn, NetKind::Mlp, NetKind::Cnn];

    pub fn name(&self) -> &'static str {
        match self {
            NetKind::Cnn => "cnn",
            NetKind::Mlp => "mlp",
            NetKind::Rnn => "rnn",
            NetKind::Multilayer => "multilayer",
        }
    }

    pub fn from_name(s: &str) -> Option<NetKind> {
        match s.to_ascii_lowercase().as_str() {
            "cnn" => Some(NetKind::Cnn),
            "mlp" => Some(NetKind::Mlp),
            "rnn" => Some(NetKind::Rnn),
            "multilayer" => Some(NetKind::Multilayer),
            _ => None,
        }
    }

    /// Cost cap used in the paper's evaluation (§IV, Table II); the
    /// `Multilayer` extension net gets a cap scaled like its compute
    /// (1.5× the MLP's, matching its 1.5× per-sample cost).
    pub fn paper_cost_cap(&self) -> f64 {
        match self {
            NetKind::Rnn => 0.02,
            NetKind::Mlp => 0.06,
            NetKind::Cnn => 0.10,
            NetKind::Multilayer => 0.09,
        }
    }
}

/// Noiseless / noisy outcome of training in a configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outcome {
    /// final test accuracy in [0, 1]
    pub acc: f64,
    /// wall-clock training time, seconds
    pub time_s: f64,
    /// cloud cost, USD
    pub cost_usd: f64,
}

/// Generative parameters of one network's measurement campaign.
#[derive(Debug, Clone)]
pub struct SimParams {
    /// asymptotic accuracy with ideal hyper-parameters
    pub a_base: f64,
    /// learning-curve amplitude: acc = a_inf - lc_b * n^(-lc_gamma)
    pub lc_b: f64,
    pub lc_gamma: f64,
    /// optimal log10 learning rate
    pub lr_opt_log10: f64,
    /// accuracy penalty per decade of lr *below* the optimum (undertraining
    /// cliff: with a handful of epochs, lr=1e-5 barely moves the weights)
    pub lr_under_pen: f64,
    /// accuracy penalty per decade of lr *above* the optimum (instability)
    pub lr_over_pen: f64,
    /// accuracy penalty for the large batch (256)
    pub batch_penalty: f64,
    /// async staleness penalty coefficient (× ln(workers) × lr factor)
    pub async_kappa: f64,
    /// effective-batch generalization penalty coefficient
    pub eff_batch_kappa: f64,
    /// seconds of compute per training sample per epoch on one reference vCPU
    pub c_sample: f64,
    /// epochs of training
    pub epochs: f64,
    /// per-step barrier cost, seconds (sync mode)
    pub tau_sync: f64,
    /// per-step coordination cost, seconds (async mode)
    pub tau_async: f64,
    /// fixed startup/teardown overhead, seconds
    pub startup_s: f64,
    /// per-VM additional startup, seconds
    pub startup_per_vm: f64,
    /// observation noise: std of additive accuracy noise
    pub noise_acc: f64,
    /// observation noise: relative std of time noise
    pub noise_time: f64,
    /// per-config ruggedness: amplitude of the deterministic, unmodeled
    /// accuracy interaction term (real measured surfaces are not smooth
    /// parametric functions -- systems effects like NUMA placement,
    /// stragglers and TCP incast produce config-specific offsets that a
    /// surrogate can only learn by sampling)
    pub rugged_acc: f64,
    /// per-config ruggedness of time (log-normal scale)
    pub rugged_time: f64,
}

impl SimParams {
    /// Calibrated parameter sets (see sim::dataset tests: the resulting
    /// Table II feasibility bands match the paper's).
    pub fn for_net(kind: NetKind) -> SimParams {
        match kind {
            // CNN: expensive compute, high asymptotic accuracy, prefers
            // lr=1e-3; constraint $0.10 is tight -> fewest feasible configs.
            NetKind::Cnn => SimParams {
                a_base: 0.993,
                lc_b: 2.9,
                lc_gamma: 0.42,
                lr_opt_log10: -3.0,
                lr_under_pen: 0.20,
                lr_over_pen: 0.07,
                batch_penalty: 0.014,
                async_kappa: 0.007,
                eff_batch_kappa: 0.009,
                c_sample: 2.5e-2,
                epochs: 4.0,
                tau_sync: 0.13,
                tau_async: 0.055,
                startup_s: 4.0,
                startup_per_vm: 0.2,
                noise_acc: 0.004,
                noise_time: 0.05,
                rugged_acc: 0.12,
                rugged_time: 0.30,
            },
            // MLP: cheap compute, prefers lr=1e-4, moderate constraint.
            NetKind::Mlp => SimParams {
                a_base: 0.982,
                lc_b: 1.6,
                lc_gamma: 0.38,
                lr_opt_log10: -4.0,
                lr_under_pen: 0.26,
                lr_over_pen: 0.07,
                batch_penalty: 0.015,
                async_kappa: 0.006,
                eff_batch_kappa: 0.008,
                c_sample: 6.0e-3,
                epochs: 6.0,
                tau_sync: 0.055,
                tau_async: 0.02,
                startup_s: 5.0,
                startup_per_vm: 0.25,
                noise_acc: 0.003,
                noise_time: 0.05,
                rugged_acc: 0.11,
                rugged_time: 0.30,
            },
            // RNN: sequential compute (poor parallel speedup), prefers
            // lr=1e-4, tightest constraint ($0.02) but cheap fleet usage.
            NetKind::Rnn => SimParams {
                a_base: 0.972,
                lc_b: 2.1,
                lc_gamma: 0.36,
                lr_opt_log10: -4.0,
                lr_under_pen: 0.28,
                lr_over_pen: 0.08,
                batch_penalty: 0.012,
                async_kappa: 0.007,
                eff_batch_kappa: 0.011,
                c_sample: 1.5e-3,
                epochs: 3.0,
                tau_sync: 0.045,
                tau_async: 0.016,
                startup_s: 2.0,
                startup_per_vm: 0.1,
                noise_acc: 0.005,
                noise_time: 0.05,
                rugged_acc: 0.12,
                rugged_time: 0.30,
            },
            // Multilayer: a deeper MLP (live-tuning extension scenario, not
            // from the paper): 1.5× the MLP's per-sample compute, slightly
            // higher asymptote, same lr sweet spot; the cost cap scales
            // with the compute so the feasibility structure stays MLP-like.
            NetKind::Multilayer => SimParams {
                a_base: 0.987,
                lc_b: 1.9,
                lc_gamma: 0.37,
                lr_opt_log10: -4.0,
                lr_under_pen: 0.24,
                lr_over_pen: 0.08,
                batch_penalty: 0.016,
                async_kappa: 0.007,
                eff_batch_kappa: 0.009,
                c_sample: 9.0e-3,
                epochs: 6.0,
                tau_sync: 0.06,
                tau_async: 0.022,
                startup_s: 5.0,
                startup_per_vm: 0.25,
                noise_acc: 0.004,
                noise_time: 0.05,
                rugged_acc: 0.11,
                rugged_time: 0.30,
            },
        }
    }
}

/// The simulator: a deterministic ground-truth surface + observation noise.
#[derive(Debug, Clone)]
pub struct CloudSim {
    pub kind: NetKind,
    pub params: SimParams,
}

impl CloudSim {
    pub fn new(kind: NetKind) -> CloudSim {
        CloudSim { kind, params: SimParams::for_net(kind) }
    }

    /// Deterministic per-config pseudo-random value in [-1, 1] (splitmix64
    /// hash of the config id) -- the "unmodeled interaction" source.
    fn rugged(&self, c: &Config, stream: u64) -> f64 {
        let mut z = (c.id() as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
            ^ stream.wrapping_mul(0xD1B54A32D192ED03)
            ^ (self.kind as u64 + 1).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
    }

    /// Asymptotic (infinite-data) accuracy for a config: base minus
    /// hyper-parameter penalties.
    fn a_inf(&self, c: &Config) -> f64 {
        let p = &self.params;
        let w = c.nvms() as f64;
        let lr_log = c.learning_rate().log10();
        let mut a = p.a_base;
        // learning-rate effect, asymmetric in decades from the optimum:
        // too small -> undertrained cliff; too large -> instability.
        let dlr = lr_log - p.lr_opt_log10;
        if dlr < 0.0 {
            a -= p.lr_under_pen * (-dlr);
        } else {
            a -= p.lr_over_pen * dlr;
        }
        // large mini-batch penalty
        if c.batch_size() > 64 {
            a -= p.batch_penalty;
        }
        if c.sync {
            // synchronous data-parallelism: effective batch B*w hurts
            // generalization past 2^10.
            let eff_batch = (c.batch_size() as f64 * w).log2();
            a -= p.eff_batch_kappa * (eff_batch - 10.0).max(0.0);
        } else {
            // asynchrony: gradient staleness grows with workers and with
            // the learning rate.
            let lr_factor = 10f64.powf((lr_log - p.lr_opt_log10) * 0.5);
            a -= p.async_kappa * w.ln() * lr_factor;
        }
        // unmodeled config-specific interactions (one-sided: systems
        // effects rarely make training *better* than the clean model)
        a - p.rugged_acc * (0.5 + 0.5 * self.rugged(c, 1))
    }

    /// Noiseless outcome (the "true" surface the optimizers try to learn).
    pub fn ground_truth(&self, pt: &Point) -> Outcome {
        let p = &self.params;
        let c = &pt.config;
        let n = pt.s() * FULL_DATASET as f64;
        let w = c.nvms() as f64;
        let vcpus = c.vm().vcpus as f64;

        // ---- accuracy: learning curve towards a_inf(c) ------------------
        let mut acc = self.a_inf(c) - p.lc_b * n.powf(-p.lc_gamma);
        // data starvation: fewer than ~50 samples per worker per epoch
        // wastes the fleet.
        let per_worker = n / w;
        if per_worker < 50.0 {
            acc -= 0.05 * (50.0 - per_worker) / 50.0;
        }
        acc = acc.clamp(0.05, 0.999);

        // ---- time -------------------------------------------------------
        // compute: t2.* burstable instances scale sub-linearly in vCPUs;
        // large batches vectorize slightly better.
        let batch_eff = (c.batch_size() as f64 / 256.0).powf(0.12);
        let compute =
            n * p.epochs * p.c_sample / (w * vcpus.powf(0.85) * batch_eff);
        // communication: one barrier per optimization step.
        let steps = (n * p.epochs / (c.batch_size() as f64 * w)).max(1.0);
        let per_step = if c.sync {
            p.tau_sync * (1.0 + w.log2())
        } else {
            p.tau_async * w.log2().max(0.5)
        };
        let comm = steps * per_step;
        let mut time = p.startup_s + p.startup_per_vm * w + compute + comm;
        // config-specific systems effects on throughput (stragglers, NUMA,
        // incast): log-normal deterministic per config
        time *= (p.rugged_time * self.rugged(c, 2)).exp();

        // ---- cost -------------------------------------------------------
        let cost = time / 3600.0 * c.fleet_price_hr();
        Outcome { acc, time_s: time, cost_usd: cost }
    }

    /// One noisy measurement (a single training run).
    pub fn observe(&self, pt: &Point, rng: &mut Rng) -> Outcome {
        let p = &self.params;
        let gt = self.ground_truth(pt);
        let acc = (gt.acc + rng.normal_with(0.0, p.noise_acc)).clamp(0.0, 1.0);
        let time = gt.time_s * (1.0 + rng.normal_with(0.0, p.noise_time)).max(0.2);
        let cost = time / 3600.0 * pt.config.fleet_price_hr();
        Outcome { acc, time_s: time, cost_usd: cost }
    }

    /// Average of `reps` noisy measurements (the paper averages 3 runs).
    pub fn observe_avg(&self, pt: &Point, rng: &mut Rng, reps: usize) -> Outcome {
        let mut acc = 0.0;
        let mut time = 0.0;
        let mut cost = 0.0;
        for _ in 0..reps {
            let o = self.observe(pt, rng);
            acc += o.acc;
            time += o.time_s;
            cost += o.cost_usd;
        }
        let r = reps as f64;
        Outcome { acc: acc / r, time_s: time / r, cost_usd: cost / r }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{all_configs, Point, S_VALUES};
    use crate::util::proptest::check;

    fn pt(cfg_id: usize, s_idx: usize) -> Point {
        Point { config: crate::space::Config::from_id(cfg_id), s_idx }
    }

    #[test]
    fn accuracy_monotone_in_s() {
        for kind in NetKind::ALL {
            let sim = CloudSim::new(kind);
            for c in all_configs() {
                let mut last = 0.0;
                for s_idx in 0..S_VALUES.len() {
                    let o = sim.ground_truth(&Point { config: c, s_idx });
                    assert!(
                        o.acc >= last - 1e-12,
                        "{kind:?} {c:?} s{s_idx}: {} < {last}",
                        o.acc
                    );
                    last = o.acc;
                }
            }
        }
    }

    #[test]
    fn outcomes_physical() {
        check("outcome ranges", 64, |rng| {
            let kind = *rng.choose(&NetKind::ALL);
            let sim = CloudSim::new(kind);
            let p = pt(rng.below(288), rng.below(5));
            let o = sim.ground_truth(&p);
            if !(0.0..=1.0).contains(&o.acc) {
                return Err(format!("acc {o:?}"));
            }
            if o.time_s <= 0.0 || o.cost_usd <= 0.0 {
                return Err(format!("nonpositive {o:?}"));
            }
            // cost must equal time * fleet price
            let expect = o.time_s / 3600.0 * p.config.fleet_price_hr();
            if (o.cost_usd - expect).abs() > 1e-9 {
                return Err(format!("cost inconsistent {o:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sub_sampling_is_cheaper() {
        for kind in NetKind::ALL {
            let sim = CloudSim::new(kind);
            for c in all_configs() {
                let small = sim.ground_truth(&Point { config: c, s_idx: 0 });
                let full = sim.ground_truth(&Point { config: c, s_idx: 4 });
                assert!(
                    small.cost_usd < full.cost_usd,
                    "{kind:?} {}",
                    c.describe()
                );
            }
        }
    }

    #[test]
    fn noise_is_centered_on_ground_truth() {
        let sim = CloudSim::new(NetKind::Mlp);
        let p = pt(100, 3);
        let gt = sim.ground_truth(&p);
        let mut rng = crate::util::Rng::new(11);
        let o = sim.observe_avg(&p, &mut rng, 500);
        assert!((o.acc - gt.acc).abs() < 0.002, "{} vs {}", o.acc, gt.acc);
        assert!((o.time_s / gt.time_s - 1.0).abs() < 0.02);
    }

    #[test]
    fn async_penalty_grows_with_workers() {
        let sim = CloudSim::new(NetKind::Cnn);
        // same cfg but nvm_idx 0 vs 5, async
        let base = crate::space::Config {
            lr_idx: 0,
            batch_idx: 0,
            sync: false,
            vm_idx: 1,
            nvm_idx: 0,
        };
        let big = crate::space::Config { nvm_idx: 5, ..base };
        let a_small = sim.ground_truth(&Point { config: base, s_idx: 4 }).acc;
        let a_big = sim.ground_truth(&Point { config: big, s_idx: 4 }).acc;
        assert!(a_big < a_small);
    }

    #[test]
    fn more_workers_faster_but_costlier_per_sample() {
        let sim = CloudSim::new(NetKind::Cnn);
        let small = crate::space::Config {
            lr_idx: 0,
            batch_idx: 1,
            sync: false,
            vm_idx: 2,
            nvm_idx: 0,
        };
        let big = crate::space::Config { nvm_idx: 4, ..small };
        let t_small = sim.ground_truth(&Point { config: small, s_idx: 4 });
        let t_big = sim.ground_truth(&Point { config: big, s_idx: 4 });
        assert!(t_big.time_s < t_small.time_s, "{t_big:?} {t_small:?}");
    }
}
