//! Surrogate models (paper §III-A): Gaussian Processes with the
//! Matérn-5/2 × FABOLAS sub-sampling kernel, and ensembles of extremely
//! randomized decision trees as the lightweight alternative.

mod gp;
mod kernel;
mod surrogate;
mod trees;

pub use gp::{Gp, GpHyp};
pub use kernel::{Basis, KernelParams};
pub use surrogate::{
    FantasyScratch, FantasySurface, FantasyView, Feat, FitOptions, ModelKind,
    Posterior, PrimedSlate, Surrogate,
};
pub use trees::{ExtraTrees, TreesMode, TreesOptions};
