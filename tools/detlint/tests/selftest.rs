//! The fixture self-test as a tier-1 test (`cargo test -p detlint`) —
//! the same checks `cargo run -p detlint -- --self-test` performs in the
//! CI lint job, plus targeted assertions on the suppression machinery,
//! rule scoping, and the R5 pre-fix pattern.

use detlint::rules::{scan_source, RuleSet};
use std::path::PathBuf;

fn fixtures() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/fixtures"))
}

fn fixture_src(name: &str) -> String {
    std::fs::read_to_string(fixtures().join(name)).expect(name)
}

#[test]
fn every_rule_fires_and_every_allow_variant_passes() {
    let lines = detlint::self_test(&fixtures()).expect("self-test");
    // eight rules (R1–R5, A1–A3) x (fire + allow)
    assert_eq!(lines.len(), 16, "{lines:?}");
}

/// The tentpole regression tie-in: R5 must fire on PR 2's pre-fix
/// `WorkerPool::close` shape (join while the bounded result receiver is
/// still live), under the real module scoping for `coordinator/pool.rs`.
#[test]
fn r5_fires_on_the_pre_fix_worker_pool_shutdown_shape() {
    let rel = "rust/src/coordinator/pool.rs";
    let out = scan_source(rel, &fixture_src("r5_fire.rs"), RuleSet::for_path(rel));
    let r5: Vec<_> = out.findings.iter().filter(|f| f.rule == "R5").collect();
    assert_eq!(r5.len(), 1, "{:?}", out.findings);
    assert!(r5[0].msg.contains("result_rx"), "{}", r5[0].msg);
}

#[test]
fn the_fixed_pool_shutdown_passes_r5() {
    let rel = "rust/src/coordinator/pool.rs";
    let out = scan_source(rel, &fixture_src("r5_allow.rs"), RuleSet::for_path(rel));
    assert!(
        out.findings.is_empty(),
        "{:?}",
        out.findings.iter().map(detlint::fmt_finding).collect::<Vec<_>>()
    );
}

#[test]
fn rule_scoping_follows_module_paths() {
    // HashMap iteration: flagged in a deterministic module ...
    let src = "fn f(m: &std::collections::HashMap<u64, u64>) -> u64 {\n\
               let mut s = 0;\n\
               for (_, v) in m {\n    s += v;\n}\ns\n}\n";
    let det = scan_source("rust/src/engine/x.rs", src, RuleSet::for_path("rust/src/engine/x.rs"));
    assert_eq!(det.findings.len(), 1, "{:?}", det.findings);
    assert_eq!(det.findings[0].rule, "R1");
    // ... but not in, say, the experiments harness (R1 out of scope there)
    let exp = scan_source(
        "rust/src/experiments/x.rs",
        src,
        RuleSet::for_path("rust/src/experiments/x.rs"),
    );
    assert!(exp.findings.is_empty(), "{:?}", exp.findings);
    // R2 is tree-wide
    let r2 = scan_source(
        "rust/src/experiments/x.rs",
        "fn g(a: f64, b: f64) { a.partial_cmp(&b); }",
        RuleSet::for_path("rust/src/experiments/x.rs"),
    );
    assert_eq!(r2.findings.len(), 1);
    assert_eq!(r2.findings[0].rule, "R2");
}

#[test]
fn cfg_test_regions_are_exempt() {
    let src = "\
        pub fn lib_code() {}\n\
        #[cfg(test)]\n\
        mod tests {\n\
            fn stamp() -> std::time::Instant {\n\
                std::time::Instant::now()\n\
            }\n\
        }\n";
    let out = scan_source("rust/src/engine/x.rs", src, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    // combinations like cfg(all(test, not(loom))) count as test regions too
    let src2 = "\
        #[cfg(all(test, not(loom)))]\n\
        mod tests {\n\
            fn t() { let m: std::collections::HashMap<u8, u8> = Default::default(); for _ in m.keys() {} }\n\
        }\n";
    let out2 = scan_source("rust/src/engine/x.rs", src2, RuleSet::all());
    assert!(out2.findings.is_empty(), "{:?}", out2.findings);
}

#[test]
fn pragmas_suppress_only_named_rules_on_adjacent_lines() {
    // same-line suppression
    let same = "fn f(a: f64, b: f64) { a.partial_cmp(&b); } // detlint: allow(R2, reason=\"test\")";
    let out = scan_source("rust/src/engine/x.rs", same, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    assert_eq!(out.suppressed, 1);
    // a pragma for a different rule does not suppress
    let wrong = "fn f(a: f64, b: f64) { a.partial_cmp(&b); } // detlint: allow(R1, reason=\"test\")";
    let out = scan_source("rust/src/engine/x.rs", wrong, RuleSet::all());
    assert_eq!(out.findings.len(), 1);
    // and a pragma two lines above is out of range
    let far = "// detlint: allow(R2, reason=\"test\")\n\n\
               fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
    let out = scan_source("rust/src/engine/x.rs", far, RuleSet::all());
    assert_eq!(out.findings.len(), 1);
    // allow-file reaches everywhere
    let file = "// detlint: allow-file(R2, reason=\"test\")\n\n\
                fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
    let out = scan_source("rust/src/engine/x.rs", file, RuleSet::all());
    assert!(out.findings.is_empty());
    assert_eq!(out.suppressed, 1);
}

#[test]
fn malformed_pragmas_are_unsuppressible_findings() {
    let src = "// detlint: allow(R2)\n\
               fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
    let out = scan_source("rust/src/engine/x.rs", src, RuleSet::all());
    let rules: Vec<&str> = out.findings.iter().map(|f| f.rule).collect();
    // the reason-less pragma is a P0 *and* fails to suppress the R2
    assert!(rules.contains(&"P0"), "{rules:?}");
    assert!(rules.contains(&"R2"), "{rules:?}");
}

#[test]
fn allowlist_parses_and_rejects_reasonless_lines() {
    let ok = "# comment\nR3 rust/src/engine/x.rs diagnostics only\n";
    let entries = detlint::parse_allowlist(ok).expect("parses");
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].rule, "R3");
    assert!(detlint::parse_allowlist("R3 rust/src/engine/x.rs\n").is_err());
}

#[test]
fn keyed_hash_access_is_not_flagged() {
    let src = "\
        use std::collections::HashMap;\n\
        fn f(m: &mut HashMap<u64, u64>) -> Option<u64> {\n\
            m.insert(1, 2);\n\
            m.remove(&3);\n\
            m.get(&1).copied()\n\
        }\n";
    let out = scan_source("rust/src/engine/x.rs", src, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);
}

// ---- A-rule machinery -------------------------------------------------------

#[test]
fn a1_fires_only_in_marked_or_registered_functions() {
    // the same allocating body: cold fn passes, hot-marked fn fires
    let cold = "fn build(n: usize) -> Vec<f64> { let v = Vec::new(); v }";
    let out = scan_source("rust/src/models/x.rs", cold, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);

    let marked = "// detlint: hot\n\
                  fn build(n: usize) -> Vec<f64> { let v = Vec::new(); v }";
    let out = scan_source("rust/src/models/x.rs", marked, RuleSet::all());
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert_eq!(out.findings[0].rule, "A1");

    // the marker tolerates one attribute line between itself and the fn
    let attr = "// detlint: hot\n\
                #[inline]\n\
                fn build(n: usize) -> Vec<f64> { let v = Vec::new(); v }";
    let out = scan_source("rust/src/models/x.rs", attr, RuleSet::all());
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);

    // registry names match on the final `::` segment
    let reg = "fn view_at(&self, i: usize) -> Vec<f64> { self.xs.to_vec() }";
    let out = scan_source(
        "rust/src/models/x.rs",
        reg,
        RuleSet::all()
            .with_hot_fns(&["PrimedSlate::view_at".to_string()]),
    );
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert_eq!(out.findings[0].rule, "A1");
    assert!(out.findings[0].msg.contains("view_at"), "{}", out.findings[0].msg);
}

#[test]
fn a1_matches_collect_through_a_turbofish() {
    let src = "// detlint: hot\n\
               fn grid(&self) -> Vec<f64> {\n\
                   self.xs.iter().map(|x| x + 1.0).collect::<Vec<f64>>()\n\
               }";
    let out = scan_source("rust/src/models/x.rs", src, RuleSet::all());
    let rules: Vec<&str> = out.findings.iter().map(|f| f.rule).collect();
    assert!(rules.contains(&"A1"), "{rules:?}");
}

#[test]
fn a2_requires_the_exact_wrapper_ident() {
    // the scratch twin itself must not be flagged
    let ok = "fn f(c: &Cholesky, b: &[f64], v: &mut Vec<f64>) { c.solve_lower_into(b, v); }";
    let out = scan_source("rust/src/linalg/x.rs", ok, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    // the allocating wrapper is
    let bad = "fn f(c: &Cholesky, b: &[f64]) -> Vec<f64> { c.solve_lower(b) }";
    let out = scan_source("rust/src/linalg/x.rs", bad, RuleSet::all());
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
    assert_eq!(out.findings[0].rule, "A2");
    assert!(
        out.findings[0].msg.contains("solve_lower_into"),
        "{}",
        out.findings[0].msg
    );
}

#[test]
fn a2_is_scoped_to_allocation_contract_modules() {
    let src = "fn f(c: &Cholesky, b: &[f64]) -> Vec<f64> { c.solve_lower(b) }";
    let rel = "rust/src/experiments/x.rs";
    let out = scan_source(rel, src, RuleSet::for_path(rel));
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    let rel = "rust/src/acq/x.rs";
    let out = scan_source(rel, src, RuleSet::for_path(rel));
    assert_eq!(out.findings.len(), 1, "{:?}", out.findings);
}

#[test]
fn a3_flags_only_empty_constructor_temporaries() {
    // seeded/parameterized constructors in argument position are fine
    let ok = "fn f(s: &mut State) { step(s, &mut Rng::new(42), &mut self.work); }";
    let out = scan_source("rust/src/models/x.rs", ok, RuleSet::all());
    assert!(out.findings.is_empty(), "{:?}", out.findings);
    // empty ctor calls are throwaway scratch
    for bad in [
        "fn f(c: &Cholesky, u: &[f64]) { c.update_into(u, &mut Cholesky::scratch()); }",
        "fn f(c: &Cholesky, u: &[f64]) { c.update_into(u, &mut Vec::new()); }",
        "fn f(c: &Cholesky, u: &[f64]) { c.update_into(u, &mut FantasyScratch::default()); }",
        "fn f(c: &Cholesky, u: &[f64]) { c.update_into(u, &mut vec![]); }",
    ] {
        let out = scan_source("rust/src/models/x.rs", bad, RuleSet::all());
        assert_eq!(out.findings.len(), 1, "{bad}: {:?}", out.findings);
        assert_eq!(out.findings[0].rule, "A3");
    }
}

#[test]
fn hotpaths_registry_parses_with_comments_and_trailing_commas() {
    let text = "# registry\nhot = [\n  \"PrimedSlate::view_at\", # sweep\n  \"Mat::matmul_into\",\n]\n";
    let hot = detlint::parse_hotpaths(text).expect("parses");
    assert_eq!(hot, vec!["PrimedSlate::view_at", "Mat::matmul_into"]);
    // the committed registry file itself must stay parseable
    let committed = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/hotpaths.toml"
    );
    let text = std::fs::read_to_string(committed).expect("hotpaths.toml");
    let hot = detlint::parse_hotpaths(&text).expect("committed registry");
    assert!(
        hot.iter().any(|h| h == "PrimedSlate::view_into"),
        "{hot:?}"
    );
    // and stray non-array lines are rejected loudly
    assert!(detlint::parse_hotpaths("hot = foo\n").is_err());
    assert!(detlint::parse_hotpaths("hot = [\n\"x\"\n").is_err());
}

#[test]
fn json_output_escapes_and_flags_suppression() {
    let f = detlint::rules::Finding {
        file: "rust/src/models/x.rs".to_string(),
        line: 3,
        col: 7,
        rule: "A1",
        msg: "`vec![…]` allocates \"here\"".to_string(),
    };
    let line = detlint::fmt_finding_json(&f, true);
    assert_eq!(
        line,
        "{\"file\":\"rust/src/models/x.rs\",\"line\":3,\"col\":7,\
         \"rule\":\"A1\",\"message\":\"`vec![…]` allocates \\\"here\\\"\",\
         \"suppressed\":true}"
    );
}
