//! Nelder–Mead downhill simplex minimizer.
//!
//! Used to maximize the GP log marginal likelihood (we minimize its
//! negation) in log-hyper-parameter space. Derivative-free, robust to the
//! noisy/cliffy MLL surface, and tiny — exactly what the paper's George-based
//! reference implementation uses under the hood.

#[derive(Debug, Clone)]
pub struct NmOptions {
    pub max_iters: usize,
    pub x_tol: f64,
    pub f_tol: f64,
    /// initial simplex edge length per dimension
    pub step: f64,
}

impl Default for NmOptions {
    fn default() -> Self {
        NmOptions { max_iters: 200, x_tol: 1e-6, f_tol: 1e-9, step: 0.5 }
    }
}

/// Minimize `f` starting at `x0`; returns (argmin, min).
pub fn nelder_mead(
    mut f: impl FnMut(&[f64]) -> f64,
    x0: &[f64],
    opts: &NmOptions,
) -> (Vec<f64>, f64) {
    let n = x0.len();
    let (alpha, gamma, rho, sigma) = (1.0, 2.0, 0.5, 0.5);

    // Initial simplex: x0 plus a step along each axis.
    let mut simplex: Vec<(Vec<f64>, f64)> = Vec::with_capacity(n + 1);
    simplex.push((x0.to_vec(), f(x0)));
    for i in 0..n {
        let mut v = x0.to_vec();
        v[i] += opts.step;
        let fv = f(&v);
        simplex.push((v, fv));
    }

    for _ in 0..opts.max_iters {
        simplex.sort_by(|a, b| crate::util::stats::cmp_nan_high(a.1, b.1));
        let best = simplex[0].1;
        let worst = simplex[n].1;
        // Convergence: simplex collapsed in x and f.
        let spread = simplex[1..]
            .iter()
            .flat_map(|(v, _)| v.iter().zip(&simplex[0].0).map(|(a, b)| (a - b).abs()))
            .fold(0.0, f64::max);
        if (worst - best).abs() < opts.f_tol && spread < opts.x_tol {
            break;
        }

        // Centroid of all but worst.
        let mut centroid = vec![0.0; n];
        for (v, _) in &simplex[..n] {
            for (c, x) in centroid.iter_mut().zip(v) {
                *c += x / n as f64;
            }
        }

        let at = |t: f64, towards: &[f64]| -> Vec<f64> {
            centroid
                .iter()
                .zip(towards)
                .map(|(c, w)| c + t * (c - w))
                .collect()
        };

        // Reflection.
        let xr = at(alpha, &simplex[n].0);
        let fr = f(&xr);
        if fr < simplex[0].1 {
            // Expansion.
            let xe = at(gamma, &simplex[n].0);
            let fe = f(&xe);
            simplex[n] = if fe < fr { (xe, fe) } else { (xr, fr) };
            continue;
        }
        if fr < simplex[n - 1].1 {
            simplex[n] = (xr, fr);
            continue;
        }
        // Contraction.
        let xc = at(-rho, &simplex[n].0);
        let fc = f(&xc);
        if fc < simplex[n].1 {
            simplex[n] = (xc, fc);
            continue;
        }
        // Shrink towards best.
        let best_x = simplex[0].0.clone();
        for item in simplex.iter_mut().skip(1) {
            let v: Vec<f64> = item
                .0
                .iter()
                .zip(&best_x)
                .map(|(x, b)| b + sigma * (x - b))
                .collect();
            let fv = f(&v);
            *item = (v, fv);
        }
    }

    simplex.sort_by(|a, b| crate::util::stats::cmp_nan_high(a.1, b.1));
    simplex.swap_remove(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        let (x, fx) = nelder_mead(
            |v| (v[0] - 3.0).powi(2) + (v[1] + 1.0).powi(2) + 0.5,
            &[0.0, 0.0],
            &NmOptions { max_iters: 500, ..Default::default() },
        );
        assert!((x[0] - 3.0).abs() < 1e-3, "{x:?}");
        assert!((x[1] + 1.0).abs() < 1e-3, "{x:?}");
        assert!((fx - 0.5).abs() < 1e-5);
    }

    #[test]
    fn minimizes_rosenbrock_2d() {
        let rosen = |v: &[f64]| {
            (1.0 - v[0]).powi(2) + 100.0 * (v[1] - v[0] * v[0]).powi(2)
        };
        let (x, _) = nelder_mead(
            rosen,
            &[-1.2, 1.0],
            &NmOptions { max_iters: 5000, x_tol: 1e-10, f_tol: 1e-14, step: 0.5 },
        );
        assert!((x[0] - 1.0).abs() < 1e-2, "{x:?}");
        assert!((x[1] - 1.0).abs() < 1e-2, "{x:?}");
    }

    #[test]
    fn respects_max_iters() {
        let mut calls = 0usize;
        let _ = nelder_mead(
            |v| {
                calls += 1;
                v[0] * v[0]
            },
            &[10.0],
            &NmOptions { max_iters: 5, ..Default::default() },
        );
        assert!(calls < 40, "calls {calls}");
    }
}
