//! Append-only event log + counters for the coordinator (observability).
//!
//! Locking tolerates poisoning (`unwrap_or_else(PoisonError::into_inner)`,
//! detlint rule R4): every critical section here is a single atomic Vec
//! operation — append, len, clone, filter-count — so a recorder that
//! panicked mid-call cannot have left the log in a torn state, and
//! observability must keep working while the run unwinds. Timestamps come
//! from [`crate::util::timer::Timer`], the sanctioned clock route (R3):
//! they are log-relative offsets that nothing on the optimization path
//! reads.

use crate::util::timer::Timer;
use std::sync::{Mutex, PoisonError};

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    JobSubmitted { job: u64 },
    JobCompleted { job: u64, cost: f64 },
    JobFailed { job: u64, reason: String },
    /// a probe gave up after exhausting its retry budget; the campaign
    /// continues around the hole (docs/ARCHITECTURE.md, "Failure
    /// semantics"). `job` is the primary (first-attempt) job id;
    /// `wasted_cost` is the partial cost its interrupted attempts charged.
    ProbeAbandoned { job: u64, attempts: usize, wasted_cost: f64 },
    IncumbentUpdated { config_id: usize, pred_acc: f64 },
    IterationDone { iter: usize, cum_cost: f64 },
}

#[derive(Debug, Clone)]
pub struct Event {
    /// seconds since the log was created
    pub t: f64,
    pub kind: EventKind,
}

/// Thread-safe append-only event log.
pub struct EventLog {
    start: Timer,
    events: Mutex<Vec<Event>>,
}

impl EventLog {
    #[allow(clippy::new_without_default)]
    pub fn new() -> EventLog {
        EventLog { start: Timer::start(), events: Mutex::new(Vec::new()) }
    }

    pub fn record(&self, kind: EventKind) {
        let t = self.start.elapsed_s();
        self.events
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .push(Event { t, kind });
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Vec<Event> {
        self.events.lock().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Count events matching a predicate.
    pub fn count(&self, pred: impl Fn(&EventKind) -> bool) -> usize {
        self.events
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .filter(|e| pred(&e.kind))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_in_order_with_monotone_timestamps() {
        let log = EventLog::new();
        log.record(EventKind::JobSubmitted { job: 1 });
        log.record(EventKind::JobCompleted { job: 1, cost: 0.5 });
        let evs = log.snapshot();
        assert_eq!(evs.len(), 2);
        assert!(evs[0].t <= evs[1].t);
        assert_eq!(
            log.count(|k| matches!(k, EventKind::JobCompleted { .. })),
            1
        );
    }

    #[test]
    fn concurrent_recording_is_safe() {
        let log = std::sync::Arc::new(EventLog::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    log.record(EventKind::JobSubmitted { job: t * 100 + i });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.len(), 200);
    }
}
