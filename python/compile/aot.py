"""AOT-lower the Layer-2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly. Lowering goes through stablehlo ->
XlaComputation with return_tuple=True; the Rust side unwraps the tuple.
See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.matern_fabolas import D_IN, N_HYP


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """name -> (fn, example_args). Shapes must match rust/src/runtime."""
    n, q = model.N_TRAIN, model.N_QUERY
    specs = {}
    for basis in ("acc", "cost"):
        specs[f"gp_predict_{basis}"] = (
            model.make_gp_posterior(basis),
            (f32(n, D_IN), f32(n), f32(n), f32(q, D_IN), f32(N_HYP)),
        )
        specs[f"gp_mll_{basis}"] = (
            model.make_gp_mll(basis),
            (f32(n, D_IN), f32(n), f32(n), f32(N_HYP)),
        )
        specs[f"cov_{basis}"] = (
            model.make_cov(basis),
            (f32(n, D_IN), f32(q, D_IN), f32(N_HYP)),
        )
    b, e = model.MLP_BATCH, model.MLP_EVAL
    i, h, o = model.MLP_IN, model.MLP_HIDDEN, model.MLP_OUT
    specs["mlp_train_step"] = (
        model.mlp_train_step,
        (f32(i, h), f32(h), f32(h, o), f32(o), f32(b, i), f32(b, o), f32()),
    )
    specs["mlp_eval"] = (
        model.mlp_eval,
        (f32(i, h), f32(h), f32(h, o), f32(o), f32(e, i), f32(e, o)),
    )
    return specs


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated artifact names"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {}
    for name, (fn, example_args) in artifact_specs().items():
        if only is not None and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "inputs": [list(a.shape) for a in example_args],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(
            {
                "n_train": model.N_TRAIN,
                "n_query": model.N_QUERY,
                "d_in": D_IN,
                "n_hyp": N_HYP,
                "mlp": {
                    "batch": model.MLP_BATCH,
                    "eval": model.MLP_EVAL,
                    "in": model.MLP_IN,
                    "hidden": model.MLP_HIDDEN,
                    "out": model.MLP_OUT,
                },
                "artifacts": manifest,
            },
            f,
            indent=2,
        )
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()
