//! Acquisition functions (paper §II–III): EI, constrained EI (CherryPick),
//! EIc/USD (Lynceus), Entropy-Search machinery (p_opt / information gain),
//! FABOLAS, and TrimTuner's constrained sub-sampling-aware α_T.

mod ei;
mod entropy;
mod fabolas;
mod models;
mod trimtuner;

pub use ei::{ei, eic, eic_usd};
pub use entropy::EntropyEstimator;
pub use fabolas::fabolas_alpha;
pub use models::{
    feasibility_prob, feasibility_probs, joint_feasibility,
    joint_feasibility_many, select_incumbent, select_incumbent_from,
    select_incumbent_over, select_incumbent_over_with_feas, Incumbent,
    Models, FEAS_THRESHOLD, FEAS_THRESHOLD_HYST,
};
pub use trimtuner::{
    alpha_slate, trimtuner_alpha, AlphaMode, AlphaSlate, TrimTunerAcq,
};
